"""L1 Pallas kernel: tiled gated MLP (the dominant S-Part matmuls).

S-Part is compute-bound (Fig 3): three [h, f]-scale matmuls per block.
On a real TPU this kernel tiles the batch and ffn axes so each grid step
runs an MXU-shaped (block_b × h)·(h × block_f) matmul with fp32
accumulation, streaming weight tiles HBM→VMEM. The gate and up
projections share the staged `x` tile; the down-projection is folded into
the same grid via a VMEM output accumulator over the f axis (minor-most
grid dim), so the [B, f] intermediate never hits HBM.

VMEM per step (fp16 weights): block_b*h*2 (x) + 2*h*block_f*2 (Wg, Wu)
+ block_f*h*2 (Wd tile) + block_b*h*4 (acc). h=4096, block_b=64,
block_f=512: ≈ 13 MiB — one buffer set per core, MXU utilization bounded
by the (64×4096)·(4096×512) shapes ≈ full tiles.

interpret=True (see decode_attention.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlp_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref):
    """Grid (num_b_blocks, num_f_blocks); f minor-most, acc over f tiles."""
    f_idx = pl.program_id(1)

    @pl.when(f_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)                      # [bb, h]
    g = x @ wg_ref[...].astype(jnp.float32)                 # [bb, bf]
    u = x @ wu_ref[...].astype(jnp.float32)
    act = (g * (1.0 / (1.0 + jnp.exp(-g)))) * u             # silu(g) * u
    acc_ref[...] += act @ wd_ref[...].astype(jnp.float32)   # [bb, h]

    @pl.when(f_idx == pl.num_programs(1) - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_f"))
def mlp(x, w_gate, w_up, w_down, *, block_b: int = 8, block_f: int = 64):
    """Tiled gated MLP; same contract as ref.mlp_ref.

    x: [B, h]; w_gate/w_up: [h, f]; w_down: [f, h]. Returns [B, h] in
    x's dtype. B and f are padded up to the block sizes internally.
    """
    B, h = x.shape
    f = w_gate.shape[1]
    assert w_gate.shape == (h, f) and w_up.shape == (h, f)
    assert w_down.shape == (f, h)

    block_b = min(block_b, B)
    block_f = min(block_f, f)
    pad_b = (-B) % block_b
    pad_f = (-f) % block_f
    if pad_b:
        x = jnp.pad(x, ((0, pad_b), (0, 0)))
    if pad_f:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, pad_f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, pad_f)))
        w_down = jnp.pad(w_down, ((0, pad_f), (0, 0)))
    Bp, fp = B + pad_b, f + pad_f

    grid = (Bp // block_b, fp // block_f)
    out = pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, h), lambda b, fi: (b, 0)),     # x
            pl.BlockSpec((h, block_f), lambda b, fi: (0, fi)),    # w_gate
            pl.BlockSpec((h, block_f), lambda b, fi: (0, fi)),    # w_up
            pl.BlockSpec((block_f, h), lambda b, fi: (fi, 0)),    # w_down
        ],
        out_specs=pl.BlockSpec((block_b, h), lambda b, fi: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, h), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, h), jnp.float32)],
        interpret=True,
    )(x, w_gate, w_up, w_down)
    return out[:B]
