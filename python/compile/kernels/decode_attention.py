"""L1 Pallas kernel: flash-decoding attention over a ragged KV-cache.

This is the paper's compute hot-spot (*R-Part*, eqs. 2-3): for every
sequence in the batch, the newest token's query attends over that
sequence's own KV-cache. It is memory-bound — each K/V element is read
once per generated token — which is exactly why FastDecode moves it off
the GPU and next to the cache.

Hardware adaptation (GPU paper → TPU kernel, DESIGN.md §Hardware-
Adaptation): the CUDA version assigns one threadblock per (sequence,
head) and streams KV from HBM through shared memory. Here the same
schedule is expressed with a Pallas grid ``(B, H, S/block_s)`` and
``BlockSpec``s that stage one ``(block_s, D)`` K tile and V tile into
VMEM per grid step. A running (online) softmax accumulator lives in VMEM
scratch across the sequence-axis grid dimension, so the ``[B, S]``
attention matrix is never materialized — the flash-attention trick, sized
for a decode workload where Q is a single row.

VMEM budget per grid step (fp16 KV, fp32 scratch):
    2 * block_s * D * 2B  (K,V tiles)  +  D * 4B (acc) + 8B (m, l)
With block_s=512, D=128: 256 KiB — far below the ~16 MiB/core budget, so
on a real TPU several (b, h) programs can be double-buffered; the MXU
sees a (1×D)·(D×block_s) matmul per tile.

Ragged batches: `lengths` masks per-tile via iota comparison, so one
compiled kernel serves any mix of sequence lengths (the paper's batched-
GeMV over ragged KV).

interpret=True always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is established here and perf is estimated
analytically (DESIGN.md §5).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # finite stand-in for -inf: keeps fp16-safe exp() semantics


def _decode_attn_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, block_s: int):
    """One grid step: fold one (block_s, D) KV tile into the online softmax.

    Grid: (B, H, num_s_blocks); the s axis is minor-most, so scratch
    persists across the KV tiles of one (b, h) program.
    """
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, :].astype(jnp.float32)                 # [D]
    k = k_ref[0, 0, :, :].astype(jnp.float32)              # [block_s, D]
    v = v_ref[0, 0, :, :].astype(jnp.float32)              # [block_s, D]

    d = q.shape[0]
    scale = 1.0 / (d ** 0.5)
    scores = (k @ q) * scale                               # [block_s]

    # Mask out positions beyond this sequence's true length.
    length = lengths_ref[0]
    pos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, (block_s,), 0)
    scores = jnp.where(pos < length, scores, NEG_INF)

    # Online softmax update.
    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, scores.max())
    correction = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                            # [block_s]
    l_new = l_ref[0] * correction + p.sum()
    acc_ref[...] = acc_ref[...] * correction + p @ v       # [D]
    m_ref[0] = m_new
    l_ref[0] = l_new

    @pl.when(s_idx == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0, :] = (acc_ref[...] / l_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s",))
def decode_attention(q, k_cache, v_cache, lengths, *, block_s: int = 64):
    """Pallas flash-decoding attention; same contract as ref.decode_attention_ref.

    q: [B, H, D]; k_cache/v_cache: [B, H, S, D]; lengths: [B] int32
    (valid positions per sequence, masked positions may hold garbage).
    Returns o: [B, H, D] in q's dtype. S must be a multiple of block_s
    only for convenience — shorter S is handled by clamping block_s.
    """
    B, H, S, D = k_cache.shape
    assert q.shape == (B, H, D), (q.shape, k_cache.shape)
    block_s = min(block_s, S)
    num_blocks = (S + block_s - 1) // block_s
    assert S % block_s == 0, (
        f"S={S} must be a multiple of block_s={block_s}; pad the cache"
    )

    grid = (B, H, num_blocks)
    return pl.pallas_call(
        functools.partial(_decode_attn_kernel, block_s=block_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),               # lengths
            pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),     # q
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, D), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),   # running max m
            pltpu.VMEM((1,), jnp.float32),   # running denom l
            pltpu.VMEM((D,), jnp.float32),   # output accumulator
        ],
        interpret=True,
    )(lengths, q, k_cache, v_cache)
