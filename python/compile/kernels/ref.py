"""Pure-jnp oracles for the Pallas kernels and the decomposed model.

These are the CORE correctness references: every Pallas kernel and every
exported HLO is validated against these functions (pytest + hypothesis).
Everything here is written in the most obvious way possible — no tiling,
no running softmax — so that a bug in the optimized paths cannot hide in a
shared trick.
"""

import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """Single-token decode attention over a ragged KV-cache (paper eq. 2-3).

    Args:
      q:        [B, H, D]   query of the latest token per sequence.
      k_cache:  [B, H, S, D] keys of all preceding tokens (padded to S).
      v_cache:  [B, H, S, D]
      lengths:  [B] int32, number of valid cache positions per sequence
                (including the latest token's K/V already appended).

    Returns:
      o: [B, H, D] attention output, in q's dtype.
    """
    B, H, S, D = k_cache.shape
    qf = q.astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(D))
    # scores: [B, H, S]
    scores = jnp.einsum("bhd,bhsd->bhs", qf, kf) * scale
    mask = jnp.arange(S)[None, :] < lengths[:, None]          # [B, S]
    scores = jnp.where(mask[:, None, :], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhs,bhsd->bhd", probs, vf)
    return o.astype(q.dtype)


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def mlp_ref(x, w_gate, w_up, w_down):
    """Llama-style gated MLP: (silu(x W_g) * (x W_u)) W_d, fp32 accumulate.

    x: [B, h]; w_gate/w_up: [h, f]; w_down: [f, h].
    """
    xf = x.astype(jnp.float32)
    g = silu(xf @ w_gate.astype(jnp.float32))
    u = xf @ w_up.astype(jnp.float32)
    return ((g * u) @ w_down.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_ref(x, w, eps=1e-5):
    """RMSNorm over the last axis, fp32 accumulate."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * (1.0 / jnp.sqrt(var + eps)) * w.astype(jnp.float32)).astype(
        x.dtype
    )


def block_decode_ref(x, k_cache, v_cache, lengths, params):
    """One full transformer-block decode step, the composition oracle.

    Must equal s_part_pre → decode_attention_ref → s_part_post exactly
    (that equality is the decomposition test for the paper's R/S split).

    x: [B, h]. params: dict with n_heads, ln1, wq, wk, wv, wo, ln2,
    w_gate, w_up, w_down. Returns (y [B, h], k_new [B, H, D],
    v_new [B, H, D]). k_cache/v_cache must NOT yet contain this token;
    lengths counts only the preceding tokens.
    """
    B, h = x.shape
    H = params["n_heads"]
    D = h // H

    xn = rmsnorm_ref(x, params["ln1"])
    q = xn.astype(jnp.float32) @ params["wq"].astype(jnp.float32)
    k = xn.astype(jnp.float32) @ params["wk"].astype(jnp.float32)
    v = xn.astype(jnp.float32) @ params["wv"].astype(jnp.float32)
    q = q.astype(x.dtype).reshape(B, H, D)
    k_new = k.astype(x.dtype).reshape(B, H, D)
    v_new = v.astype(x.dtype).reshape(B, H, D)

    # Append this token's K/V at position `lengths` (per sequence).
    kc = jnp.concatenate([k_cache, jnp.zeros_like(k_cache[:, :, :1])], axis=2)
    vc = jnp.concatenate([v_cache, jnp.zeros_like(v_cache[:, :, :1])], axis=2)
    b_idx = jnp.arange(B)
    kc = kc.at[b_idx, :, lengths].set(k_new)
    vc = vc.at[b_idx, :, lengths].set(v_new)

    o = decode_attention_ref(q, kc, vc, lengths + 1)          # [B, H, D]
    o = o.reshape(B, h)
    attn_out = o.astype(jnp.float32) @ params["wo"].astype(jnp.float32)
    x1 = (x.astype(jnp.float32) + attn_out).astype(x.dtype)

    xn2 = rmsnorm_ref(x1, params["ln2"])
    m = mlp_ref(xn2, params["w_gate"], params["w_up"], params["w_down"])
    y = (x1.astype(jnp.float32) + m.astype(jnp.float32)).astype(x.dtype)
    return y, k_new, v_new
