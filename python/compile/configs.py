"""Model configurations mirrored by rust/src/model/spec.rs.

The paper evaluates Llama-7b, Llama-13b and OPT-175b (§6.1). Like the
paper, we reduce the number of layers for experiments and extrapolate
linearly (their Fig 8 justifies this). The ``tiny`` config is small enough
to push real numerics end-to-end through PJRT-CPU from the Rust
coordinator.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    hidden: int          # feature dimension h
    n_heads: int
    n_layers: int        # full-model layer count (extrapolation target)
    ffn: int             # MLP intermediate dimension
    vocab: int
    # layers actually instantiated for experiments (paper reduces layers too)
    eval_layers: int

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.n_heads == 0
        return self.hidden // self.n_heads

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """fp16 K+V bytes appended per token per layer-stack (eq. of Fig 1)."""
        return 2 * self.hidden * self.n_layers * bytes_per_el


TINY = ModelConfig("tiny", hidden=64, n_heads=4, n_layers=2, ffn=176,
                   vocab=256, eval_layers=2)
LLAMA_7B = ModelConfig("llama7b", hidden=4096, n_heads=32, n_layers=32,
                       ffn=11008, vocab=32000, eval_layers=2)
LLAMA_13B = ModelConfig("llama13b", hidden=5120, n_heads=40, n_layers=40,
                        ffn=13824, vocab=32000, eval_layers=2)
OPT_175B = ModelConfig("opt175b", hidden=12288, n_heads=96, n_layers=96,
                       ffn=49152, vocab=50272, eval_layers=1)

CONFIGS = {c.name: c for c in (TINY, LLAMA_7B, LLAMA_13B, OPT_175B)}
