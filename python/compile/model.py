"""L2: the decomposed transformer decode step (paper §3.1).

The model is split exactly along the paper's R/S boundary:

* ``s_part_pre``   — RMSNorm + fused QKV projection (S-Part, before R).
* ``s_part_post``  — output projection + residual + RMSNorm + gated MLP
                     + residual (S-Part, after R).
* *R-Part* (decode attention over the KV-cache) is NOT in the exported
  S-Part graphs: at serving time the Rust R-workers compute it near the
  cache (rust/src/rworker/). The Pallas kernel version here exists for
  the fused single-device baseline and as a cross-check.
* ``fused_decode_step`` — the vanilla GPU-only baseline: the whole block
  including Pallas attention, in one graph.
* ``embed`` / ``logits_head`` — token embedding and final projection.

All functions take weights as explicit arguments so a single exported HLO
serves every layer (weights are runtime inputs fed by Rust).
Everything accumulates in fp32 and stores activations in the model dtype,
mirroring both the GPU baseline and the Rust mixed-precision R-worker.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.decode_attention import decode_attention
from .kernels.mlp import mlp as pallas_mlp


# ---------------------------------------------------------------------------
# S-Part graphs (exported to HLO, executed by the Rust S-worker)
# ---------------------------------------------------------------------------

def s_part_pre(x, ln1, wqkv):
    """S-Part before attention: RMSNorm + fused QKV projection.

    x: [B, h]; ln1: [h]; wqkv: [h, 3h] (Wq | Wk | Wv fused column-wise).
    Returns qkv: [B, 3h] in x's dtype — the activation tensor that is
    shipped to the R-workers (Table 3's "intermediate vectors").
    """
    xn = ref.rmsnorm_ref(x, ln1)
    qkv = xn.astype(jnp.float32) @ wqkv.astype(jnp.float32)
    return (qkv.astype(x.dtype),)


def s_part_post(x, o, wo, ln2, w_gate, w_up, w_down):
    """S-Part after attention: O-projection + residuals + gated MLP.

    x: [B, h] block input (residual stream); o: [B, h] attention output
    gathered from the R-workers. Returns y: [B, h].
    """
    attn = o.astype(jnp.float32) @ wo.astype(jnp.float32)
    x1 = (x.astype(jnp.float32) + attn).astype(x.dtype)
    xn2 = ref.rmsnorm_ref(x1, ln2)
    m = ref.mlp_ref(xn2, w_gate, w_up, w_down)
    y = (x1.astype(jnp.float32) + m.astype(jnp.float32)).astype(x.dtype)
    return (y,)


def embed(tokens, w_emb):
    """tokens: [B] int32 → x: [B, h] (model dtype of w_emb)."""
    return (jnp.take(w_emb, tokens, axis=0),)


def logits_head(x, ln_f, w_emb):
    """Final RMSNorm + tied-embedding projection → logits [B, vocab] f32."""
    xn = ref.rmsnorm_ref(x, ln_f)
    logits = xn.astype(jnp.float32) @ w_emb.astype(jnp.float32).T
    return (logits,)


# ---------------------------------------------------------------------------
# Fused single-device step (vanilla baseline; uses the L1 Pallas kernels)
# ---------------------------------------------------------------------------

def fused_decode_step(x, k_cache, v_cache, lengths, ln1, wqkv, wo, ln2,
                      w_gate, w_up, w_down, *, n_heads: int,
                      use_pallas_mlp: bool = True):
    """One whole transformer-block decode step on one device.

    k_cache/v_cache: [B, H, S, D] with this token's K/V NOT yet present;
    lengths: [B] count of preceding tokens (< S). Returns
    (y [B,h], k_new [B,H,D], v_new [B,H,D]) — the caller appends K/V.
    """
    B, h = x.shape
    H = n_heads
    D = h // H

    (qkv,) = s_part_pre(x, ln1, wqkv)
    q, k_new, v_new = jnp.split(qkv, 3, axis=1)
    q = q.reshape(B, H, D)
    k_new = k_new.reshape(B, H, D)
    v_new = v_new.reshape(B, H, D)

    # Scatter this token's K/V into the padded cache at its position.
    b_idx = jnp.arange(B)
    kc = k_cache.at[b_idx, :, lengths].set(k_new)
    vc = v_cache.at[b_idx, :, lengths].set(v_new)

    o = decode_attention(q, kc, vc, lengths + 1)            # L1 kernel
    o = o.reshape(B, h)

    attn = o.astype(jnp.float32) @ wo.astype(jnp.float32)
    x1 = (x.astype(jnp.float32) + attn).astype(x.dtype)
    xn2 = ref.rmsnorm_ref(x1, ln2)
    if use_pallas_mlp:
        m = pallas_mlp(xn2, w_gate, w_up, w_down)           # L1 kernel
    else:
        m = ref.mlp_ref(xn2, w_gate, w_up, w_down)
    y = (x1.astype(jnp.float32) + m.astype(jnp.float32)).astype(x.dtype)
    return y, k_new, v_new


# ---------------------------------------------------------------------------
# Parameter initialization (synthetic weights; DESIGN.md §2 substitution)
# ---------------------------------------------------------------------------

def init_block_params(key, cfg, dtype=jnp.float32):
    """Random block weights at the true dims, scaled for stable decode."""
    h, f = cfg.hidden, cfg.ffn
    ks = jax.random.split(key, 7)
    s = 1.0 / (h ** 0.5)
    sf = 1.0 / (f ** 0.5)
    return {
        "n_heads": cfg.n_heads,
        "ln1": jnp.ones((h,), dtype),
        "wqkv": (jax.random.normal(ks[0], (h, 3 * h)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[1], (h, h)) * s).astype(dtype),
        "ln2": jnp.ones((h,), dtype),
        "w_gate": (jax.random.normal(ks[2], (h, f)) * s).astype(dtype),
        "w_up": (jax.random.normal(ks[3], (h, f)) * s).astype(dtype),
        "w_down": (jax.random.normal(ks[4], (f, h)) * sf).astype(dtype),
    }


def split_qkv(params):
    """Unfuse wqkv into the ref.py layout (wq, wk, wv)."""
    wq, wk, wv = jnp.split(params["wqkv"], 3, axis=1)
    out = dict(params)
    out.pop("wqkv")
    out.update(wq=wq, wk=wk, wv=wv)
    return out
