"""L2 correctness: the R/S decomposition must be exact.

The paper's whole system rests on s_pre → R-Part → s_post being the same
function as the undecomposed block. These tests pin that equality, plus
the fused (Pallas) baseline path and shape contracts for every exported
graph.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import TINY, CONFIGS
from compile.kernels import ref

CFG = TINY


@pytest.fixture(scope="module")
def params():
    return model.init_block_params(jax.random.PRNGKey(0), CFG, jnp.float32)


def make_state(seed, B, S, dtype=jnp.float32):
    h, H, D = CFG.hidden, CFG.n_heads, CFG.head_dim
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    x = (jax.random.normal(k1, (B, h)) * 0.5).astype(dtype)
    kc = (jax.random.normal(k2, (B, H, S, D)) * 0.5).astype(dtype)
    vc = (jax.random.normal(k3, (B, H, S, D)) * 0.5).astype(dtype)
    lengths = jax.random.randint(k4, (B,), 0, S - 1).astype(jnp.int32)
    return x, kc, vc, lengths


@pytest.mark.parametrize("B,S", [(1, 16), (4, 32), (7, 64)])
def test_decomposition_equals_monolithic_block(params, B, S):
    """s_pre ∘ attention ∘ s_post == block_decode_ref, exactly the R/S cut."""
    x, kc, vc, lengths = make_state(1, B, S)
    H, D = CFG.n_heads, CFG.head_dim

    # Decomposed path (what FastDecode actually executes).
    (qkv,) = model.s_part_pre(x, params["ln1"], params["wqkv"])
    q, k_new, v_new = jnp.split(qkv, 3, axis=1)
    q = q.reshape(B, H, D)
    k_new, v_new = k_new.reshape(B, H, D), v_new.reshape(B, H, D)
    b_idx = jnp.arange(B)
    kc2 = kc.at[b_idx, :, lengths].set(k_new)   # R-worker append
    vc2 = vc.at[b_idx, :, lengths].set(v_new)
    o = ref.decode_attention_ref(q, kc2, vc2, lengths + 1).reshape(B, -1)
    (y,) = model.s_part_post(x, o, params["wo"], params["ln2"],
                             params["w_gate"], params["w_up"],
                             params["w_down"])

    # Monolithic oracle.
    y_ref, k_ref, v_ref = ref.block_decode_ref(
        x, kc, vc, lengths, model.split_qkv(params))

    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(k_new, k_ref, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(v_new, v_ref, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("B,S", [(2, 16), (8, 32)])
@pytest.mark.parametrize("use_pallas_mlp", [True, False])
def test_fused_step_matches_oracle(params, B, S, use_pallas_mlp):
    x, kc, vc, lengths = make_state(2, B, S)
    y, k_new, v_new = model.fused_decode_step(
        x, kc, vc, lengths, params["ln1"], params["wqkv"], params["wo"],
        params["ln2"], params["w_gate"], params["w_up"], params["w_down"],
        n_heads=CFG.n_heads, use_pallas_mlp=use_pallas_mlp)
    y_ref, k_ref, v_ref = ref.block_decode_ref(
        x, kc, vc, lengths, model.split_qkv(params))
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(k_new, k_ref, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(v_new, v_ref, rtol=1e-6, atol=1e-6)


def test_multi_step_generation_consistency(params):
    """Decode 5 tokens with the fused step; lengths/caches stay coherent."""
    B, S = 3, 16
    x, kc, vc, _ = make_state(3, B, S)
    lengths = jnp.zeros((B,), jnp.int32)
    kc = jnp.zeros_like(kc)
    vc = jnp.zeros_like(vc)
    b_idx = jnp.arange(B)
    for step in range(5):
        y, k_new, v_new = model.fused_decode_step(
            x, kc, vc, lengths, params["ln1"], params["wqkv"], params["wo"],
            params["ln2"], params["w_gate"], params["w_up"],
            params["w_down"], n_heads=CFG.n_heads, use_pallas_mlp=False)
        kc = kc.at[b_idx, :, lengths].set(k_new)
        vc = vc.at[b_idx, :, lengths].set(v_new)
        lengths = lengths + 1
        assert jnp.all(jnp.isfinite(y)), f"non-finite activations at {step}"
        x = y
    assert int(lengths[0]) == 5


def test_embed_and_logits_shapes(params):
    B = 4
    w_emb = jax.random.normal(jax.random.PRNGKey(9),
                              (CFG.vocab, CFG.hidden)).astype(jnp.float32)
    tokens = jnp.array([0, 1, 2, CFG.vocab - 1], jnp.int32)
    (x,) = model.embed(tokens, w_emb)
    assert x.shape == (B, CFG.hidden)
    np.testing.assert_allclose(x[0], w_emb[0])
    (logits,) = model.logits_head(x, jnp.ones((CFG.hidden,)), w_emb)
    assert logits.shape == (B, CFG.vocab)
    assert logits.dtype == jnp.float32


def test_greedy_next_token_is_deterministic(params):
    w_emb = jax.random.normal(jax.random.PRNGKey(10),
                              (CFG.vocab, CFG.hidden)).astype(jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(11), (2, CFG.hidden))
    (l1,) = model.logits_head(x, jnp.ones((CFG.hidden,)), w_emb)
    (l2,) = model.logits_head(x, jnp.ones((CFG.hidden,)), w_emb)
    assert jnp.array_equal(jnp.argmax(l1, -1), jnp.argmax(l2, -1))


def test_configs_sane():
    for cfg in CONFIGS.values():
        assert cfg.hidden % cfg.n_heads == 0
        assert cfg.kv_bytes_per_token() == 4 * cfg.hidden * cfg.n_layers
    assert CONFIGS["llama7b"].kv_bytes_per_token() == 4 * 4096 * 32
