"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes, dtypes and ragged lengths; fixed cases pin the
regressions we care about (block boundaries, length==1, full cache).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention
from compile.kernels.mlp import mlp as pallas_mlp
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype != jnp.float32 \
        else dict(rtol=1e-5, atol=1e-5)


def make_attn_case(seed, B, H, S, D, dtype):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    q = (jax.random.normal(k1, (B, H, D)) * 0.5).astype(dtype)
    kc = (jax.random.normal(k2, (B, H, S, D)) * 0.5).astype(dtype)
    vc = (jax.random.normal(k3, (B, H, S, D)) * 0.5).astype(dtype)
    lengths = jax.random.randint(k4, (B,), 1, S + 1).astype(jnp.int32)
    return q, kc, vc, lengths


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float16])
@pytest.mark.parametrize("B,H,S,D,block_s", [
    (1, 1, 8, 4, 8),      # single block
    (2, 2, 32, 8, 8),     # multiple blocks
    (3, 4, 64, 16, 16),   # non-power-of-two batch
    (1, 1, 16, 4, 4),     # many tiny blocks
])
def test_decode_attention_fixed(B, H, S, D, block_s, dtype):
    q, kc, vc, lengths = make_attn_case(0, B, H, S, D, dtype)
    got = decode_attention(q, kc, vc, lengths, block_s=block_s)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(got, want, **tol(dtype))


def test_decode_attention_length_one():
    """With one valid position, output must equal that position's V."""
    q, kc, vc, _ = make_attn_case(1, 2, 2, 16, 8, jnp.float32)
    lengths = jnp.ones((2,), jnp.int32)
    got = decode_attention(q, kc, vc, lengths, block_s=8)
    np.testing.assert_allclose(got, vc[:, :, 0, :], rtol=1e-5, atol=1e-6)


def test_decode_attention_full_cache():
    q, kc, vc, _ = make_attn_case(2, 2, 3, 32, 8, jnp.float32)
    lengths = jnp.full((2,), 32, jnp.int32)
    got = decode_attention(q, kc, vc, lengths, block_s=16)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_decode_attention_ignores_padding_garbage():
    """Masked cache positions must not influence the result at all."""
    q, kc, vc, lengths = make_attn_case(3, 2, 2, 32, 8, jnp.float32)
    got1 = decode_attention(q, kc, vc, lengths, block_s=8)
    mask = (jnp.arange(32)[None, :] < lengths[:, None])[:, None, :, None]
    kc2 = jnp.where(mask, kc, 1e4)   # garbage in padding
    vc2 = jnp.where(mask, vc, -1e4)
    got2 = decode_attention(q, kc2, vc2, lengths, block_s=8)
    np.testing.assert_allclose(got1, got2, rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    B=st.integers(1, 4),
    H=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    block_s=st.sampled_from([4, 8, 16]),
    D=st.sampled_from([4, 8, 16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.float16]),
)
def test_decode_attention_hypothesis(seed, B, H, s_blocks, block_s, D, dtype):
    S = s_blocks * block_s
    q, kc, vc, lengths = make_attn_case(seed, B, H, S, D, dtype)
    got = decode_attention(q, kc, vc, lengths, block_s=block_s)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(got, want, **tol(dtype))


# ---------------------------------------------------------------------------
# MLP kernel
# ---------------------------------------------------------------------------

def make_mlp_case(seed, B, h, f, dtype):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    x = (jax.random.normal(k1, (B, h)) * 0.5).astype(dtype)
    wg = (jax.random.normal(k2, (h, f)) / (h ** 0.5)).astype(dtype)
    wu = (jax.random.normal(k3, (h, f)) / (h ** 0.5)).astype(dtype)
    wd = (jax.random.normal(k4, (f, h)) / (f ** 0.5)).astype(dtype)
    return x, wg, wu, wd


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float16])
@pytest.mark.parametrize("B,h,f,bb,bf", [
    (1, 16, 48, 8, 16),    # B smaller than block
    (8, 32, 96, 4, 32),
    (5, 16, 40, 2, 16),    # ragged B and f
    (3, 8, 20, 8, 64),     # blocks larger than dims
])
def test_mlp_fixed(B, h, f, bb, bf, dtype):
    x, wg, wu, wd = make_mlp_case(0, B, h, f, dtype)
    got = pallas_mlp(x, wg, wu, wd, block_b=bb, block_f=bf)
    want = ref.mlp_ref(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, **tol(dtype))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    B=st.integers(1, 9),
    h=st.sampled_from([8, 16, 32]),
    f=st.sampled_from([12, 24, 40, 64]),
    bb=st.sampled_from([2, 4, 8]),
    bf=st.sampled_from([8, 16, 64]),
)
def test_mlp_hypothesis(seed, B, h, f, bb, bf):
    x, wg, wu, wd = make_mlp_case(seed, B, h, f, jnp.float32)
    got = pallas_mlp(x, wg, wu, wd, block_b=bb, block_f=bf)
    want = ref.mlp_ref(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
