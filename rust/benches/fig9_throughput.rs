//! Figure 9: maximum token-generation throughput — FastDecode at
//! ℬ ∈ {128, 512, 1024} vs vLLM / TensorRT-LLM / FastLLM / vanilla, on
//! the 7b and 13b models (S = 1024).
//!
//! Run: `cargo bench --bench fig9_throughput`

use fastdecode::baselines::{fastllm, tensorrt, vanilla, vllm, BaselineConfig};
use fastdecode::bench::{record_result, Table};
use fastdecode::coordinator::sim::steady_throughput;
use fastdecode::coordinator::{simulate, SimConfig};
use fastdecode::model::{ModelSpec, LLAMA_13B, LLAMA_7B};
use fastdecode::perfmodel::{CpuModel, GpuModel, A10, EPYC_7452};
use fastdecode::util::json::Json;

fn ours(spec: ModelSpec, batch: usize, seq: usize, sockets: usize) -> f64 {
    let mut cfg = SimConfig::new(
        spec,
        GpuModel::new(A10),
        CpuModel::from_device(EPYC_7452),
        sockets,
        batch,
        seq,
    );
    cfg.sls_interval = Some((seq / 32).max(1));
    cfg.steps = 3 * seq;
    steady_throughput(&simulate(&cfg), seq)
}

fn main() {
    let seq = 1024;
    let mut js = Vec::new();
    for spec in [LLAMA_7B, LLAMA_13B] {
        let mut t = Table::new(
            &format!("Fig 9: throughput, {} (S=1024, A10 + 8 Epyc sockets)", spec.name),
            &["system", "batch", "tok/s", "vs vLLM"],
        );
        let b_static = BaselineConfig::a10(spec, 1024, seq);
        let tp_vllm = vllm(&b_static).throughput();
        let b16 = BaselineConfig::a10(spec, 16, seq);
        let mut add = |name: &str, batch: String, tp: f64| {
            t.row(&[
                name.into(),
                batch,
                format!("{tp:.0}"),
                format!("{:.2}x", tp / tp_vllm),
            ]);
            js.push(
                Json::obj()
                    .set("model", spec.name)
                    .set("system", name)
                    .set("tok_per_s", tp),
            );
        };
        for b in [128usize, 512, 1024] {
            add("ours", format!("{b}"), ours(spec, b, seq, 8));
        }
        add("vLLM", "dyn".into(), tp_vllm);
        add("TensorRT-LLM", "16".into(), tensorrt(&b16).throughput());
        add("FastLLM", "16".into(), fastllm(&b16).throughput());
        add("vanilla", "16".into(), vanilla(&b16).throughput());
        t.print();
    }
    println!(
        "paper shape: ours(1024) ≈ 4x vLLM ≈ 8.7x TRT on 7b; ours(1024) ≈ 4.12x vLLM on 13b;\n\
         ours(128) ≈ 1.88–2.32x vLLM"
    );
    record_result("fig9", Json::Arr(js));
}
