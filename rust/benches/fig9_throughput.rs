//! Figure 9: maximum token-generation throughput — FastDecode at
//! ℬ ∈ {128, 512, 1024} vs vLLM / TensorRT-LLM / FastLLM / vanilla, on
//! the 7b and 13b models (S = 1024).
//!
//! "Ours" runs behind `Box<dyn Coordinator>`: the virtual-clock
//! simulator regenerates the paper-scale figure, and the same trait
//! drives the LIVE threaded engine at reduced scale — both backends are
//! reported side by side at matched scale at the end. `--real` skips
//! the paper-scale sim sweep and prints only the backend comparison.
//!
//! Run: `cargo bench --bench fig9_throughput [-- --real]`

use fastdecode::baselines::{fastllm, tensorrt, vanilla, vllm, BaselineConfig};
use fastdecode::bench::snapshot::Snapshot;
use fastdecode::bench::{real_flag, real_mini, record_result, sim_mini, Table};
use fastdecode::coordinator::sim::steady_throughput;
use fastdecode::metrics::StepTrace;
use fastdecode::coordinator::{Coordinator, SimConfig, SimCoordinator};
use fastdecode::model::{ModelSpec, LLAMA_13B, LLAMA_7B};
use fastdecode::perfmodel::{CpuModel, GpuModel, A10, EPYC_7452};
use fastdecode::util::json::Json;

fn ours(spec: ModelSpec, batch: usize, seq: usize, sockets: usize) -> f64 {
    let mut cfg = SimConfig::new(
        spec,
        GpuModel::new(A10),
        CpuModel::from_device(EPYC_7452),
        sockets,
        batch,
        seq,
    );
    cfg.sls_interval = Some((seq / 32).max(1));
    let mut c: Box<dyn Coordinator> = Box::new(SimCoordinator::new(cfg));
    let trace = c.run_steps(3 * seq).expect("sim never fails");
    steady_throughput(&trace, seq)
}

/// Both backends through the SAME trait at matched reduced scale
/// (tiny model, 2 layers): virtual clock vs live threaded pipeline.
/// Returns the LIVE engine's trace for the `BENCH_fig9.json` snapshot.
fn backend_cross_check(js: &mut Vec<Json>) -> StepTrace {
    let (batch, sockets, steps) = (16usize, 2usize, 48usize);
    let mut t = Table::new(
        "Fig 9 cross-check: sim vs live engine, matched reduced scale \
         (tiny, B=16, P=2, D=2)",
        &["backend", "tok/s", "mean step ms"],
    );
    let mut live = StepTrace::default();
    let backends =
        [sim_mini(batch, sockets, steps), real_mini(batch, sockets, 2, steps)];
    for (i, mut c) in backends.into_iter().enumerate() {
        let trace = c.run_steps(steps).expect("backend run");
        t.row(&[
            c.backend().into(),
            format!("{:.0}", trace.throughput()),
            format!("{:.3}", trace.steady_latency(0) * 1e3),
        ]);
        js.push(
            Json::obj()
                .set("backend", c.backend())
                .set("tok_per_s", trace.throughput()),
        );
        if i == 1 {
            live = trace;
        }
    }
    t.print();
    live
}

fn main() {
    let seq = 1024;
    let mut js = Vec::new();
    if !real_flag() {
        for spec in [LLAMA_7B, LLAMA_13B] {
            let mut t = Table::new(
                &format!(
                    "Fig 9: throughput, {} (S=1024, A10 + 8 Epyc sockets)",
                    spec.name
                ),
                &["system", "batch", "tok/s", "vs vLLM"],
            );
            let b_static = BaselineConfig::a10(spec, 1024, seq);
            let tp_vllm = vllm(&b_static).throughput();
            let b16 = BaselineConfig::a10(spec, 16, seq);
            let mut add = |name: &str, batch: String, tp: f64| {
                t.row(&[
                    name.into(),
                    batch,
                    format!("{tp:.0}"),
                    format!("{:.2}x", tp / tp_vllm),
                ]);
                js.push(
                    Json::obj()
                        .set("model", spec.name)
                        .set("system", name)
                        .set("tok_per_s", tp),
                );
            };
            for b in [128usize, 512, 1024] {
                add("ours", format!("{b}"), ours(spec, b, seq, 8));
            }
            add("vLLM", "dyn".into(), tp_vllm);
            add("TensorRT-LLM", "16".into(), tensorrt(&b16).throughput());
            add("FastLLM", "16".into(), fastllm(&b16).throughput());
            add("vanilla", "16".into(), vanilla(&b16).throughput());
            t.print();
        }
        println!(
            "paper shape: ours(1024) ≈ 4x vLLM ≈ 8.7x TRT on 7b; ours(1024) ≈ 4.12x vLLM on 13b;\n\
             ours(128) ≈ 1.88–2.32x vLLM"
        );
    }
    let live = backend_cross_check(&mut js);
    record_result("fig9", Json::Arr(js.clone()));
    let snap = Snapshot::from_trace(
        "fig9",
        Json::obj()
            .set("mode", "real_mini")
            .set("model", "tiny")
            .set("batch", 16usize)
            .set("sockets", 2usize)
            .set("layers", 2usize)
            .set("steps", 48usize),
        &live,
    )
    .with_extra(Json::Arr(js));
    let path = snap.write().expect("writing BENCH_fig9.json");
    println!("snapshot: {}", path.display());
}
