//! Figure 10: per-token generation latency — average plus P.01/.5/.99 —
//! for FastDecode (ℬ=128/1024) and every baseline, 7b and 13b models.
//!
//! "Ours" runs behind `Box<dyn Coordinator>`; `--real` swaps the
//! virtual-clock simulator for the live threaded engine at reduced
//! scale (tiny model — the percentile *shape* on this machine, not the
//! paper's absolute numbers).
//!
//! Run: `cargo bench --bench fig10_latency [-- --real]`

use fastdecode::baselines::{fastllm, tensorrt, vanilla, vllm, BaselineConfig};
use fastdecode::bench::{real_flag, real_mini, record_result, Table};
use fastdecode::coordinator::{Coordinator, SimConfig, SimCoordinator};
use fastdecode::metrics::{Histogram, StepTrace};
use fastdecode::model::{ModelSpec, LLAMA_13B, LLAMA_7B};
use fastdecode::perfmodel::{CpuModel, GpuModel, A10, EPYC_7452};
use fastdecode::util::json::Json;

fn hist_of(trace: &StepTrace, skip: usize) -> Histogram {
    let mut h = Histogram::new();
    for r in trace.records.iter().skip(skip) {
        h.record_secs(r.latency_s);
    }
    h
}

fn ours_trace(spec: ModelSpec, batch: usize, seq: usize) -> StepTrace {
    let mut c: Box<dyn Coordinator> = if real_flag() {
        // reduced scale: batch capped, 2 sockets, depth-2 live pipeline
        real_mini(batch.min(16), 2, 2, 3 * seq)
    } else {
        let mut cfg = SimConfig::new(
            spec,
            GpuModel::new(A10),
            CpuModel::from_device(EPYC_7452),
            8,
            batch,
            seq,
        );
        cfg.sls_interval = Some((seq / 32).max(1));
        Box::new(SimCoordinator::new(cfg))
    };
    c.run_steps(3 * seq).expect("ours trace")
}

fn main() {
    let seq = 1024;
    let mut js = Vec::new();
    for spec in [LLAMA_7B, LLAMA_13B] {
        let mut t = Table::new(
            &format!("Fig 10: per-token latency, {} (S=1024)", spec.name),
            &["system", "mean ms", "p01 ms", "p50 ms", "p99 ms"],
        );
        let mut runs: Vec<(String, Histogram)> = Vec::new();
        if real_flag() {
            // one honestly-labeled live-engine row: the real pipeline
            // runs the tiny model at B=16, S=64 — a different scale
            // than the paper-scale baselines below
            runs.push((
                "ours (REAL: tiny, B=16, S=64)".into(),
                hist_of(&ours_trace(spec, 16, 64), 64),
            ));
        } else {
            runs.push((
                "ours (128)".into(),
                hist_of(&ours_trace(spec, 128, seq), seq),
            ));
            runs.push((
                "ours (1024)".into(),
                hist_of(&ours_trace(spec, 1024, seq), seq),
            ));
        }
        runs.extend([
            (
                "vLLM".to_string(),
                hist_of(&vllm(&BaselineConfig::a10(spec, 1024, seq)), 8),
            ),
            (
                "TensorRT-LLM".to_string(),
                hist_of(&tensorrt(&BaselineConfig::a10(spec, 16, seq)), 8),
            ),
            (
                "FastLLM".to_string(),
                hist_of(&fastllm(&BaselineConfig::a10(spec, 16, seq)), 8),
            ),
            (
                "vanilla".to_string(),
                hist_of(&vanilla(&BaselineConfig::a10(spec, 16, seq)), 8),
            ),
        ]);
        for (name, h) in &runs {
            t.row(&[
                name.to_string(),
                format!("{:.1}", h.mean_us() / 1e3),
                format!("{:.1}", h.percentile_us(0.01) / 1e3),
                format!("{:.1}", h.percentile_us(0.50) / 1e3),
                format!("{:.1}", h.percentile_us(0.99) / 1e3),
            ]);
            js.push(
                Json::obj()
                    .set("model", spec.name)
                    .set("system", name.as_str())
                    .set("mean_ms", h.mean_us() / 1e3)
                    .set("p99_ms", h.percentile_us(0.99) / 1e3),
            );
        }
        t.print();
    }
    println!(
        "paper shape: TRT min latency (34.2/77.0 ms); ours(128) ≈ 2.5–3.5x TRT;\n\
         ours(1024) ≈ 3.5x ours(128); vLLM mean pushed up by rare swap spikes (P99)"
    );
    record_result("fig10", Json::Arr(js));
}
