//! Table 2: latency of R-Part and S-Part on GPU vs CPU at batch 1 and
//! 1024 (7b model) — the decomposition argument in numbers.
//!
//! GPU columns come from the calibrated A10 roofline; "CPU (Epyc×2)"
//! columns from the Table-1-parameterized CpuModel; "CPU (this host)"
//! R-Part rows are REAL measurements of the Rust mixed-precision
//! attention hot loop on this machine, scaled to the batch.
//!
//! Run: `cargo bench --bench table2_latency`

use fastdecode::bench::{fmt_time, record_result, Bench, Table};
use fastdecode::kvcache::SeqKv;
use fastdecode::model::{Precision, LLAMA_7B};
use fastdecode::perfmodel::{CpuModel, GpuModel, A10, EPYC_7452};
use fastdecode::rworker::{attend_one, AttnScratch};
use fastdecode::util::json::Json;
use fastdecode::util::Rng;

/// Measure real R-Part time for ONE 7b-dims sequence at context `ctx`
/// on one thread of this machine, per layer.
fn measure_r_one_seq(ctx: usize) -> f64 {
    let spec = LLAMA_7B;
    let (h, d) = (spec.n_heads, spec.head_dim());
    let mut kv = SeqKv::new(h, d, ctx, Precision::F16);
    let mut rng = Rng::new(1);
    let k = rng.normal_vec(h * d, 0.5);
    let v = rng.normal_vec(h * d, 0.5);
    for _ in 0..ctx {
        kv.append(&k, &v);
    }
    let q = rng.normal_vec(h * d, 0.5);
    let mut o = vec![0.0; h * d];
    let mut scratch = AttnScratch::new(d);
    let stats = Bench::quick().measure(|| {
        attend_one(&kv, &q, &mut o, &mut scratch);
    });
    stats.mean_s
}

fn main() {
    let spec = LLAMA_7B;
    let gpu = GpuModel::new(A10);
    // the paper's "two CPU nodes" = 2 Epyc sockets aggregated
    let cpu = CpuModel::from_device(EPYC_7452);
    let sockets = 2.0;
    let ctx = 512; // mid-generation context, matching Table 2's setup

    let r_real_1 = measure_r_one_seq(ctx);
    // B=1024 across all host threads: perfectly parallel per-sequence
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4) as f64;
    let r_real_1024 = r_real_1 * 1024.0 / threads;

    let mut t = Table::new(
        "Table 2: computation latency, 7b model, one transformer block (ctx=512)",
        &["operation", "batch", "A10 (model)", "Epyc x2 (model)", "this host (measured)"],
    );
    for &(b, label) in &[(1usize, "1"), (1024, "1024")] {
        let r_gpu = gpu.r_part_latency(&spec, b, ctx);
        let r_cpu = cpu.r_part_latency(&spec, b * ctx, Precision::F16) / sockets;
        let r_host = if b == 1 { r_real_1 } else { r_real_1024 };
        t.row(&[
            "R-Part (eq.2-3)".into(),
            label.into(),
            fmt_time(r_gpu),
            fmt_time(r_cpu),
            fmt_time(r_host),
        ]);
    }
    for &(b, label) in &[(1usize, "1"), (1024, "1024")] {
        let s_gpu = gpu.s_part_latency(&spec, b);
        let s_cpu = GpuModel::s_part_latency_on(EPYC_7452, &spec, b) / sockets;
        t.row(&[
            "S-Part (~16x eq.4)".into(),
            label.into(),
            fmt_time(s_gpu),
            fmt_time(s_cpu),
            "-".into(),
        ]);
    }
    t.print();

    let r_gpu_1024 = gpu.r_part_latency(&spec, 1024, ctx);
    let r_cpu_1024 = cpu.r_part_latency(&spec, 1024 * ctx, Precision::F16) / sockets;
    let s_gpu_1024 = gpu.s_part_latency(&spec, 1024);
    let s_cpu_1024 = GpuModel::s_part_latency_on(EPYC_7452, &spec, 1024) / sockets;
    println!(
        "shape checks (paper values in parens):\n  \
         R-Part B=1024 CPU/GPU = {:.2} (≈1: 8.12/8.32)\n  \
         S-Part B=1024 CPU/GPU = {:.0}x (86x: 611/7.08)",
        r_cpu_1024 / r_gpu_1024,
        s_cpu_1024 / s_gpu_1024,
    );

    record_result(
        "table2",
        Json::obj()
            .set("r_gpu_1024_ms", r_gpu_1024 * 1e3)
            .set("r_cpu_1024_ms", r_cpu_1024 * 1e3)
            .set("r_host_1024_ms", r_real_1024 * 1e3)
            .set("s_gpu_1024_ms", s_gpu_1024 * 1e3)
            .set("s_cpu_1024_ms", s_cpu_1024 * 1e3),
    );
}
