//! Open-loop serving sweep: arrival rate vs latency percentiles, per
//! admission policy (the serving counterpart of the paper's Fig 10 —
//! request-level p50/p99 TTFT and E2E, plus throughput and goodput,
//! measured on the LIVE engine at tiny scale).
//!
//! Run: `cargo bench --bench serve_openloop`

use fastdecode::bench::snapshot::Snapshot;
use fastdecode::bench::{fmt_time, record_result, Table};
use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::model::{Precision, TINY};
use fastdecode::serve::{
    AdmissionPolicy, Fifo, PrefillMode, ServeConfig, ServeEngine,
    ShortestJobFirst, SlsEarliestStart,
};
use fastdecode::util::json::Json;
use fastdecode::workload::{generate_trace, TraceConfig};

const SLOTS: usize = 4;
const W_LIM: usize = 96;
const STEPS_PER_SEC: f64 = 200.0;

fn policy_by(name: &str) -> Box<dyn AdmissionPolicy> {
    match name {
        "fifo" => Box::new(Fifo),
        "sjf" => Box::new(ShortestJobFirst),
        "sls" => Box::new(SlsEarliestStart),
        _ => unreachable!("unknown policy {name}"),
    }
}

fn main() -> anyhow::Result<()> {
    let rates = [8.0, 32.0, 128.0];
    let mut table = Table::new(
        "Open-loop serving: arrival rate vs latency (live engine, tiny)",
        &[
            "rate req/s",
            "policy",
            "served",
            "tok/s",
            "goodput req/s",
            "ttft p50",
            "ttft p99",
            "e2e p99",
            "wait steps",
        ],
    );
    let mut results = Vec::new();
    // snapshot the highest-rate FIFO run: the most loaded configuration
    let mut snap_run = None;
    for &rate in &rates {
        let trace = generate_trace(&TraceConfig {
            seed: 42,
            rate,
            prompt_len: (4, 12),
            target_len: (8, 24),
            vocab: TINY.vocab,
            count: 24,
        });
        for name in ["fifo", "sjf", "sls"] {
            let fd = FastDecode::new(
                TINY,
                FastDecodeConfig {
                    batch: SLOTS,
                    sockets: 2,
                    precision: Precision::F16,
                    capacity_per_seq: 64,
                    ..Default::default()
                },
            )?;
            let mut engine = ServeEngine::new(
                fd,
                ServeConfig {
                    w_lim: W_LIM,
                    steps_per_sec: STEPS_PER_SEC,
                    prefill: PrefillMode::Batched,
                    max_steps: 200_000,
                },
                policy_by(name),
            )?;
            let out = engine.run(&trace)?;
            let rep = &out.report;
            table.row(&[
                format!("{rate:.0}"),
                name.to_string(),
                format!("{}/{}", rep.completed, rep.requests),
                format!("{:.0}", rep.throughput()),
                format!("{:.1}", rep.goodput()),
                fmt_time(rep.ttft.percentile_us(0.50) / 1e6),
                fmt_time(rep.ttft.percentile_us(0.99) / 1e6),
                fmt_time(rep.e2e.percentile_us(0.99) / 1e6),
                format!("{:.1}", rep.mean_wait_steps),
            ]);
            results.push(
                Json::obj()
                    .set("rate", rate)
                    .set("policy", name)
                    .set("throughput", rep.throughput())
                    .set("goodput", rep.goodput())
                    .set("ttft_p50_us", rep.ttft.percentile_us(0.50))
                    .set("ttft_p99_us", rep.ttft.percentile_us(0.99))
                    .set("e2e_p99_us", rep.e2e.percentile_us(0.99))
                    .set("mean_wait_steps", rep.mean_wait_steps),
            );
            if name == "fifo" && rate == rates[rates.len() - 1] {
                snap_run = Some((rate, out.report.to_json(), out.trace));
            }
        }
    }
    table.print();
    record_result("serve_openloop", Json::obj().set("rows", results));
    if let Some((rate, report, trace)) = snap_run {
        let snap = Snapshot::from_trace(
            "serve_openloop",
            Json::obj()
                .set("model", "tiny")
                .set("policy", "fifo")
                .set("rate_req_s", rate)
                .set("slots", SLOTS)
                .set("w_lim", W_LIM)
                .set("steps_per_sec", STEPS_PER_SEC),
            &trace,
        )
        .with_extra(Json::obj().set("serve", report));
        let path = snap.write()?;
        println!("snapshot: {}", path.display());
    }
    println!(
        "\nhigher arrival rates deepen the queue: p99 TTFT grows with \
         rate while throughput saturates at the engine's decode rate"
    );
    Ok(())
}
