//! Open-loop serving sweep: arrival rate vs latency percentiles, per
//! admission policy (the serving counterpart of the paper's Fig 10 —
//! request-level p50/p99 TTFT and E2E, plus throughput and goodput,
//! measured on the LIVE engine at tiny scale).
//!
//! Run: `cargo bench --bench serve_openloop`

use fastdecode::bench::snapshot::Snapshot;
use fastdecode::bench::{fmt_time, record_result, Table};
use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::model::{Precision, TINY};
use fastdecode::serve::{
    AdmissionPolicy, Fifo, PrefillMode, ServeConfig, ServeEngine,
    ServeReport, ShortestJobFirst, SlsEarliestStart,
};
use fastdecode::util::json::Json;
use fastdecode::workload::{generate_trace, TraceConfig};

const SLOTS: usize = 4;
const W_LIM: usize = 96;
const STEPS_PER_SEC: f64 = 200.0;

fn policy_by(name: &str) -> Box<dyn AdmissionPolicy> {
    match name {
        "fifo" => Box::new(Fifo),
        "sjf" => Box::new(ShortestJobFirst),
        "sls" => Box::new(SlsEarliestStart),
        _ => unreachable!("unknown policy {name}"),
    }
}

fn main() -> anyhow::Result<()> {
    let rates = [8.0, 32.0, 128.0];
    let mut table = Table::new(
        "Open-loop serving: arrival rate vs latency (live engine, tiny)",
        &[
            "rate req/s",
            "policy",
            "served",
            "tok/s",
            "goodput req/s",
            "ttft p50",
            "ttft p99",
            "e2e p99",
            "wait steps",
        ],
    );
    let mut results = Vec::new();
    // snapshot the highest-rate FIFO run: the most loaded configuration
    let mut snap_run = None;
    for &rate in &rates {
        let trace = generate_trace(&TraceConfig {
            seed: 42,
            rate,
            prompt_len: (4, 12),
            target_len: (8, 24),
            vocab: TINY.vocab,
            count: 24,
            ..Default::default()
        });
        for name in ["fifo", "sjf", "sls"] {
            let fd = FastDecode::new(
                TINY,
                FastDecodeConfig {
                    batch: SLOTS,
                    sockets: 2,
                    precision: Precision::F16,
                    capacity_per_seq: 64,
                    ..Default::default()
                },
            )?;
            let mut engine = ServeEngine::new(
                fd,
                ServeConfig {
                    w_lim: W_LIM,
                    steps_per_sec: STEPS_PER_SEC,
                    prefill: PrefillMode::Batched,
                    max_steps: 200_000,
                    ..Default::default()
                },
                policy_by(name),
            )?;
            let out = engine.run(&trace)?;
            let rep = &out.report;
            table.row(&[
                format!("{rate:.0}"),
                name.to_string(),
                format!("{}/{}", rep.completed, rep.requests),
                format!("{:.0}", rep.throughput()),
                format!("{:.1}", rep.goodput()),
                fmt_time(rep.ttft.percentile_us(0.50) / 1e6),
                fmt_time(rep.ttft.percentile_us(0.99) / 1e6),
                fmt_time(rep.e2e.percentile_us(0.99) / 1e6),
                format!("{:.1}", rep.mean_wait_steps),
            ]);
            results.push(
                Json::obj()
                    .set("rate", rate)
                    .set("policy", name)
                    .set("throughput", rep.throughput())
                    .set("goodput", rep.goodput())
                    .set("ttft_p50_us", rep.ttft.percentile_us(0.50))
                    .set("ttft_p99_us", rep.ttft.percentile_us(0.99))
                    .set("e2e_p99_us", rep.e2e.percentile_us(0.99))
                    .set("mean_wait_steps", rep.mean_wait_steps),
            );
            if name == "fifo" && rate == rates[rates.len() - 1] {
                snap_run = Some((rate, out.report.to_json(), out.trace));
            }
        }
    }
    table.print();
    record_result("serve_openloop", Json::obj().set("rows", results));

    // ── prefix sharing: same trace, same W_lim, fork on vs off ──────
    // Every request opens with the same 24-token system prompt, so a
    // paged cache that COW-forks the resident prefix charges only the
    // divergent tail against W_lim and packs strictly more concurrent
    // sequences into the same memory budget.
    let shared_trace = generate_trace(&TraceConfig {
        seed: 7,
        rate: 400.0, // burst: the queue is always deep enough to fork
        prefix_len: 24,
        share_prob: 1.0,
        prompt_len: (2, 4),
        target_len: (6, 10),
        vocab: TINY.vocab,
        count: 16,
        ..Default::default()
    });
    let share_run = |share_prefixes: bool| -> anyhow::Result<ServeReport> {
        let fd = FastDecode::new(
            TINY,
            FastDecodeConfig {
                batch: 8,
                sockets: 2,
                precision: Precision::F16,
                capacity_per_seq: 64,
                kv_block_size: 4, // divides the 24-token shared prefix
                ..Default::default()
            },
        )?;
        let mut engine = ServeEngine::new(
            fd,
            ServeConfig {
                w_lim: 72,
                steps_per_sec: 400.0,
                prefill: PrefillMode::Batched,
                max_steps: 200_000,
                share_prefixes,
                ..Default::default()
            },
            Box::new(Fifo),
        )?;
        Ok(engine.run(&shared_trace)?.report)
    };
    let with_sharing = share_run(true)?;
    let without = share_run(false)?;
    let hit_rate =
        with_sharing.prefix_forks as f64 / with_sharing.requests as f64;
    println!(
        "\nprefix sharing @ W_lim 72: {} forks ({:.0}% of admissions), \
         peak batch {} vs {} unshared, utilization {:.2} vs {:.2}",
        with_sharing.prefix_forks,
        100.0 * hit_rate,
        with_sharing.peak_active,
        without.peak_active,
        with_sharing.kv_utilization(),
        without.kv_utilization(),
    );
    assert!(
        with_sharing.prefix_forks > 0,
        "no admission forked on a fully shared-prefix trace"
    );
    assert!(
        with_sharing.peak_active > without.peak_active
            || with_sharing.goodput() > without.goodput(),
        "prefix sharing bought neither batch size ({} vs {}) nor \
         goodput ({:.2} vs {:.2}) at the same W_lim",
        with_sharing.peak_active,
        without.peak_active,
        with_sharing.goodput(),
        without.goodput(),
    );

    if let Some((rate, report, trace)) = snap_run {
        let snap = Snapshot::from_trace(
            "serve_openloop",
            Json::obj()
                .set("model", "tiny")
                .set("policy", "fifo")
                .set("rate_req_s", rate)
                .set("slots", SLOTS)
                .set("w_lim", W_LIM)
                .set("steps_per_sec", STEPS_PER_SEC),
            &trace,
        )
        .with_extra(
            Json::obj().set("serve", report).set(
                "prefix_share",
                Json::obj()
                    .set("hit_rate", hit_rate)
                    .set("forks", with_sharing.prefix_forks)
                    .set(
                        "shared_prefix_tokens",
                        with_sharing.shared_prefix_tokens,
                    )
                    .set("peak_active_shared", with_sharing.peak_active)
                    .set("peak_active_unshared", without.peak_active)
                    .set("kv_utilization_shared", with_sharing.kv_utilization())
                    .set(
                        "kv_utilization_unshared",
                        without.kv_utilization(),
                    ),
            ),
        );
        let path = snap.write()?;
        println!("snapshot: {}", path.display());
    }
    println!(
        "\nhigher arrival rates deepen the queue: p99 TTFT grows with \
         rate while throughput saturates at the engine's decode rate"
    );
    Ok(())
}
