//! Figure 1 + Figure 3: GPU throughput/utilization vs batch size, the
//! KV-cache footprint wall, and FC-vs-attention throughput divergence.
//!
//! Run: `cargo bench --bench fig1_gpu_util`

use fastdecode::bench::{record_result, Table};
use fastdecode::model::{Precision, LLAMA_7B};
use fastdecode::perfmodel::{GpuModel, A10};
use fastdecode::util::json::Json;

fn main() {
    let spec = LLAMA_7B;
    let gpu = GpuModel::new(A10);
    let gpu_mem_gb = 24.0;

    let mut t = Table::new(
        "Fig 1: GPU throughput vs batch size vs KV footprint (7b, A10, S=512)",
        &[
            "batch",
            "T(B) ms",
            "tok/s",
            "GPU util %",
            "KV @S=512 (GB)",
            "fits 24 GB?",
        ],
    );
    let mut batches = vec![];
    let mut tputs = vec![];
    for b in [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096] {
        let t_b = gpu.s_part_latency(&spec, b);
        // full-model token rate of the S-Part alone (this figure's scope)
        let tok_s = b as f64 / (t_b * spec.n_layers as f64);
        let kv_gb = spec.kv_bytes_total(b, 512, Precision::F16) as f64 / 1e9;
        t.row(&[
            b.to_string(),
            format!("{:.3}", t_b * 1e3),
            format!("{:.0}", tok_s),
            format!("{:.1}", gpu.utilization(&spec, b) * 100.0),
            format!("{kv_gb:.2}"),
            if kv_gb < gpu_mem_gb { "yes" } else { "NO" }.to_string(),
        ]);
        batches.push(b as f64);
        tputs.push(tok_s);
    }
    t.print();
    let idx = |b: f64| batches.iter().position(|&x| x == b).unwrap();
    println!(
        "shape check: tok/s(1024)/tok/s(128) = {:.2} (paper: ~2x); \
         KV wall (24 GB) crossed at B={}",
        tputs[idx(1024.0)] / tputs[idx(128.0)],
        batches
            .iter()
            .find(|&&b| spec.kv_bytes_total(b as usize, 512, Precision::F16)
                as f64
                / 1e9
                > gpu_mem_gb)
            .copied()
            .unwrap_or(0.0)
    );

    // Fig 3: FC (S-Part) throughput scales with B; attention (R-Part,
    // batched GeMV) throughput does not.
    let mut t3 = Table::new(
        "Fig 3: FC vs attention throughput vs batch (7b, A10, ctx=512)",
        &["batch", "S-Part TFLOP/s", "R-Part TFLOP/s (GPU)"],
    );
    for b in [1usize, 8, 64, 512, 1024, 4096] {
        let s_flops = (spec.s_part_flops_per_token_layer() * b) as f64
            / gpu.s_part_latency(&spec, b)
            / 1e12;
        let r_flops = (spec.r_part_flops_per_token_layer(512) * b) as f64
            / gpu.r_part_latency(&spec, b, 512)
            / 1e12;
        t3.row(&[
            b.to_string(),
            format!("{s_flops:.2}"),
            format!("{r_flops:.3}"),
        ]);
    }
    t3.print();

    record_result(
        "fig1",
        Json::obj().set("batch", batches).set("tok_per_s", tputs),
    );
}
