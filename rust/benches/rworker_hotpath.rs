//! Hot-path micro-benchmarks of the R-worker attention loop — the
//! §5.1/§5.2 performance story: effective KV streaming bandwidth per
//! precision, and the quantization speedup. This is also the input to
//! the EXPERIMENTS.md §Perf iteration log.
//!
//! Run: `cargo bench --bench rworker_hotpath`

use fastdecode::bench::{record_result, Bench, Table};
use fastdecode::kvcache::SeqKv;
use fastdecode::model::Precision;
use fastdecode::rworker::{attend_one, AttnScratch};
use fastdecode::util::json::Json;
use fastdecode::util::Rng;

fn bench_precision(prec: Precision, ctx: usize) -> (f64, f64) {
    let (heads, d) = (8usize, 128usize);
    let mut kv = SeqKv::new(heads, d, ctx, prec);
    let mut rng = Rng::new(3);
    let k = rng.normal_vec(heads * d, 0.5);
    let v = rng.normal_vec(heads * d, 0.5);
    for _ in 0..ctx {
        kv.append(&k, &v);
    }
    let q = rng.normal_vec(heads * d, 0.5);
    let mut o = vec![0.0f32; heads * d];
    let mut scratch = AttnScratch::new(d);
    let stats = Bench::default().measure(|| {
        attend_one(&kv, &q, &mut o, &mut scratch);
        std::hint::black_box(&o);
    });
    // bytes actually streamed from the cache per call
    let payload = 2.0 * (ctx * heads * d) as f64 * prec.bits() as f64 / 8.0;
    (stats.mean_s, payload / stats.mean_s)
}

fn main() {
    let ctx = 2048;
    let mut t = Table::new(
        "R-worker attention hot path (8 heads x d=128, ctx=2048, 1 thread)",
        &["precision", "latency", "payload GB/s", "vs f16"],
    );
    let mut f16_lat = 0.0;
    let mut js = Vec::new();
    for prec in [
        Precision::F32,
        Precision::F16,
        Precision::Int8,
        Precision::Int4,
    ] {
        let (lat, bw) = bench_precision(prec, ctx);
        if prec == Precision::F16 {
            f16_lat = lat;
        }
        let speedup = if f16_lat > 0.0 { f16_lat / lat } else { 0.0 };
        t.row(&[
            prec.label().into(),
            format!("{:.3} ms", lat * 1e3),
            format!("{:.2}", bw / 1e9),
            if prec == Precision::F16 || f16_lat == 0.0 {
                "1.00x".into()
            } else {
                format!("{speedup:.2}x")
            },
        ]);
        js.push(
            Json::obj()
                .set("precision", prec.label())
                .set("latency_ms", lat * 1e3)
                .set("payload_gbps", bw / 1e9),
        );
    }
    t.print();
    println!(
        "§5.2 expectation: int8/int4 speed up roughly with the memory-size \
         ratio once the loop is memory-bound (paper: 'likely to get 4x')"
    );

    // context-length linearity (the R in eq. 10 is per-token-of-context)
    let mut t2 = Table::new(
        "R cost linearity in context length (f16)",
        &["ctx", "latency ms", "ns per ctx token"],
    );
    for ctx in [256usize, 512, 1024, 2048, 4096] {
        let (lat, _) = bench_precision(Precision::F16, ctx);
        t2.row(&[
            ctx.to_string(),
            format!("{:.3}", lat * 1e3),
            format!("{:.1}", lat * 1e9 / ctx as f64),
        ]);
    }
    t2.print();
    record_result("rworker_hotpath", Json::Arr(js));
}
