//! Figures 8, 11, 12 and 15 — the per-step latency family:
//!   default  : Fig 11 (7b & 13b): vanilla vs ours without SLS vs ours+SLS
//!   --fig8   : latency vs layer count (opt-175b)
//!   --fig12  : Fig 11 with reduced sequence length 768 (7b)
//!   --fig15  : per-op breakdown with synchronous communication (13b)
//!   --real   : Fig 11's SLS-vs-naive comparison on the LIVE threaded
//!              engine at reduced scale — fixed batch vs
//!              `drive_arrivals` admission, both behind the
//!              `Coordinator` trait, with the measured KV load W
//!
//! Run: `cargo bench --bench fig11_per_step [-- --fig8|--fig12|--fig15|--real]`

use fastdecode::baselines::{vanilla, BaselineConfig};
use fastdecode::bench::{real_flag, real_mini, record_result, sim_trace as simulate, Table};
use fastdecode::coordinator::real::{Arrival, FastDecode, FastDecodeConfig};
use fastdecode::coordinator::sim::steady_throughput;
use fastdecode::coordinator::{Coordinator, SimConfig};
use fastdecode::model::{ModelSpec, LLAMA_13B, LLAMA_7B, OPT_175B, TINY};
use fastdecode::perfmodel::{CpuModel, GpuModel, A10, EPYC_7452};
use fastdecode::util::json::Json;

fn base(spec: ModelSpec, batch: usize, seq: usize, sockets: usize) -> SimConfig {
    SimConfig::new(
        spec,
        GpuModel::new(A10),
        CpuModel::from_device(EPYC_7452),
        sockets,
        batch,
        seq,
    )
}

/// Fig 11 on the live engine (reduced scale): the same naive-vs-SLS
/// comparison with real wall-clock steps and the measured KV load.
fn fig11_real() {
    let (batch, sockets, seq) = (16usize, 2usize, 24usize);
    let mut naive = real_mini(batch, sockets, 2, seq);
    let naive_trace = naive.run_steps(seq).expect("naive run");

    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch,
            sockets,
            capacity_per_seq: seq + 2,
            layers: 2,
            depth: 2,
            ..Default::default()
        },
    )
    .expect("live engine");
    // ℬ = 16 arrives as 8 micro-batches of m = 2; W_lim at eq. 6's
    // steady-state peak ℬ(𝒮+F)/2 with F ≈ S/4
    let arrivals: Vec<Arrival> = (0..8)
        .map(|i| Arrival {
            m: 2,
            seq_len: seq,
            first_token: (i * 13 + 5) as i32,
        })
        .collect();
    let w_lim = batch * (seq + seq / 4) / 2;
    fd.drive_arrivals(&arrivals, w_lim).expect("enqueue arrivals");
    let c: &mut dyn Coordinator = &mut fd;
    let sls_trace = c.run_steps(4 * seq).expect("sls run");

    let mut t = Table::new(
        &format!(
            "Fig 11 (real, tiny, B={batch}, S={seq}, P={sockets}): naive vs \
             SLS admission, measured W_lim={w_lim}"
        ),
        &["step", "naive ms", "+SLS ms", "+SLS W (measured)"],
    );
    for s in (0..sls_trace.len()).step_by(8) {
        let n = naive_trace
            .records
            .get(s)
            .map_or("-".to_string(), |r| format!("{:.2}", r.latency_s * 1e3));
        let r = &sls_trace.records[s];
        t.row(&[
            s.to_string(),
            n,
            format!("{:.2}", r.latency_s * 1e3),
            r.total_ctx.to_string(),
        ]);
    }
    t.print();
    let peak_w = sls_trace.records.iter().map(|r| r.total_ctx).max().unwrap();
    println!(
        "measured peak W = {peak_w} ≤ W_lim = {w_lim} (admission held); \
         naive peak W = {}",
        batch * seq
    );
    record_result(
        "fig11_real",
        Json::obj()
            .set("w_lim", w_lim as f64)
            .set("peak_w", peak_w as f64),
    );
}

fn fig11(spec: ModelSpec, seq: usize) {
    let batch = 1024;
    let sockets = 8;

    let no_sls = simulate(&base(spec, batch, seq, sockets));
    let mut cfg = base(spec, batch, seq, sockets);
    cfg.sls_interval = Some((seq / 32).max(1));
    cfg.steps = 3 * seq;
    let sls = simulate(&cfg);
    // vanilla runs its (much smaller) memory-capped batch
    let van = vanilla(&BaselineConfig::a10(spec, 1024, seq));

    let mut t = Table::new(
        &format!(
            "Fig 11: per-step latency, {} (B=1024, S={seq}, P={sockets})",
            spec.name
        ),
        &["step", "vanilla ms (B_cap)", "ours no-SLS ms", "ours +SLS ms"],
    );
    for &s in [0usize, 64, 128, 256, 384, 512, 640, 768, seq - 1]
        .iter()
        .filter(|&&s| s < seq)
    {
        let sls_idx = seq + s; // steady-state window of the SLS run
        t.row(&[
            s.to_string(),
            format!("{:.1}", van.records[s].latency_s * 1e3),
            format!("{:.1}", no_sls.records[s].latency_s * 1e3),
            format!("{:.1}", sls.records[sls_idx.min(sls.len() - 1)].latency_s * 1e3),
        ]);
    }
    t.print();

    let peak = no_sls.max_latency();
    let steady = sls.steady_latency(seq);
    let tp_gain = steady_throughput(&sls, seq) / no_sls.throughput() - 1.0;
    println!(
        "{}: steady/peak latency = {:.2} (paper 0.66–0.70); SLS throughput gain = {:+.1}% (paper +8–11%)",
        spec.name,
        steady / peak,
        tp_gain * 100.0
    );
    record_result(
        "fig11",
        Json::obj()
            .set("model", spec.name)
            .set("seq", seq)
            .set("steady_over_peak", steady / peak)
            .set("sls_gain", tp_gain),
    );
}

fn fig8() {
    let mut t = Table::new(
        "Fig 8: per-step latency vs number of layers (opt-175b, B=256)",
        &["layers", "steady latency ms", "ratio vs 2 layers"],
    );
    let mut first = 0.0;
    let mut js = Vec::new();
    for layers in [2usize, 4, 8, 16, 32, 64, 96] {
        let mut cfg = base(OPT_175B, 256, 256, 2);
        cfg.layers = layers;
        let lat = simulate(&cfg).steady_latency(10);
        if layers == 2 {
            first = lat;
        }
        t.row(&[
            layers.to_string(),
            format!("{:.1}", lat * 1e3),
            format!("{:.2}", lat / first),
        ]);
        js.push(Json::obj().set("layers", layers).set("ms", lat * 1e3));
    }
    t.print();
    println!("paper shape: latency strictly linear in layer count");
    record_result("fig8", Json::Arr(js));
}

fn fig15() {
    let spec = LLAMA_13B;
    let mut cfg = base(spec, 1024, 1024, 2);
    cfg.sync_comm = true;
    cfg.steps = 256;
    let trace = simulate(&cfg);
    let r = &trace.records[200];
    let mut t = Table::new(
        "Fig 15: per-op breakdown of one step (13b, B=1024, 2 sockets, sync comm)",
        &["component", "ms", "share %"],
    );
    let total = r.latency_s;
    for (name, v) in [
        ("S-Part compute", r.s_time),
        ("R-Part compute (max socket)", r.r_time),
        ("QKV/O transfer (PCIe+net)", r.comm_time),
    ] {
        t.row(&[
            name.into(),
            format!("{:.1}", v * 1e3),
            format!("{:.0}", v / total * 100.0),
        ]);
    }
    t.row(&["total step".into(), format!("{:.1}", total * 1e3), "100".into()]);
    t.print();
    println!(
        "paper shape: comm ≈ 25% of the step when exposed; S-worker busy <50% \
         (R-workers overloaded at 2 sockets)"
    );
    record_result(
        "fig15",
        Json::obj()
            .set("s_ms", r.s_time * 1e3)
            .set("r_ms", r.r_time * 1e3)
            .set("comm_ms", r.comm_time * 1e3),
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let has = |f: &str| args.iter().any(|a| a == f);
    if has("--fig8") {
        fig8();
    } else if real_flag() {
        fig11_real();
    } else if has("--fig12") {
        // Fig 12: shorter sequences rebalance S/R (paper: gain 8%→13%)
        fig11(LLAMA_7B, 768);
    } else if has("--fig15") {
        fig15();
    } else {
        fig11(LLAMA_7B, 1024);
        fig11(LLAMA_13B, 1024);
        // run the variants too so `cargo bench` covers every figure
        fig11(LLAMA_7B, 768); // Fig 12
        fig8();
        fig15();
    }
}
