//! Table 3: sizes of per-block data and the latency to move them over
//! PCIe 4.0 ×16 and 100 Gb RoCE — the "ship activations, not KV" case.
//!
//! Run: `cargo bench --bench table3_comm`

use fastdecode::bench::{fmt_time, record_result, Table};
use fastdecode::model::{Precision, LLAMA_7B};
use fastdecode::transport::{
    o_message_bytes, qkv_message_bytes, PCIE4_X16, ROCE_100G,
};
use fastdecode::util::json::Json;

fn fmt_bytes(b: usize) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2} MB", b as f64 / (1u64 << 20) as f64)
    } else {
        format!("{:.1} KB", b as f64 / 1024.0)
    }
}

fn main() {
    let spec = LLAMA_7B;
    let mut t = Table::new(
        "Table 3: data size & transfer latency, 7b model, one block",
        &["data", "batch", "size", "PCIe 4.0 x16", "RoCE 100Gb"],
    );

    let rows: Vec<(&str, &str, usize)> = vec![
        ("model weight", "n/a", spec.block_weight_bytes()),
        (
            "KV-cache (ctx=256)",
            "1",
            spec.r_part_bytes_per_token_layer(256, Precision::F16),
        ),
        (
            "KV-cache (ctx=256)",
            "1024",
            spec.r_part_bytes_per_token_layer(256, Precision::F16) * 1024,
        ),
        (
            "intermediate vectors (ours)",
            "1",
            qkv_message_bytes(spec.hidden, 1) + o_message_bytes(spec.hidden, 1),
        ),
        (
            "intermediate vectors (ours)",
            "1024",
            qkv_message_bytes(spec.hidden, 1024)
                + o_message_bytes(spec.hidden, 1024),
        ),
    ];
    let mut js = Vec::new();
    for (name, batch, bytes) in rows {
        t.row(&[
            name.into(),
            batch.into(),
            fmt_bytes(bytes),
            fmt_time(PCIE4_X16.transfer_time(bytes)),
            fmt_time(ROCE_100G.transfer_time(bytes)),
        ]);
        js.push(
            Json::obj()
                .set("name", name)
                .set("batch", batch)
                .set("bytes", bytes)
                .set("pcie_ms", PCIE4_X16.transfer_time(bytes) * 1e3)
                .set("roce_ms", ROCE_100G.transfer_time(bytes) * 1e3),
        );
    }
    t.print();

    let kv = spec.r_part_bytes_per_token_layer(256, Precision::F16) * 1024;
    let act = qkv_message_bytes(spec.hidden, 1024)
        + o_message_bytes(spec.hidden, 1024);
    println!(
        "shape check: KV / activations at B=1024 = {:.0}x smaller to ship \
         activations (paper: 4.29 GB vs 33.5 MB = 128x)",
        kv as f64 / act as f64
    );
    record_result("table3", Json::Arr(js));
}
