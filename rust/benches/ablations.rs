//! Ablations over FastDecode's design choices (DESIGN.md §4): what each
//! mechanism buys, holding everything else fixed. 7b model, B=1024,
//! S=1024, 8 sockets unless stated.
//!
//! Run: `cargo bench --bench ablations`

use fastdecode::bench::{record_result, Table};
use fastdecode::coordinator::sim::steady_throughput;
use fastdecode::coordinator::{simulate, SimConfig};
use fastdecode::model::{Precision, LLAMA_7B};
use fastdecode::perfmodel::{CpuModel, GpuModel, A10, EPYC_7452};
use fastdecode::transport::{INFINIBAND, PCIE4_X16, ROCE_100G};
use fastdecode::util::json::Json;

fn base() -> SimConfig {
    let mut cfg = SimConfig::new(
        LLAMA_7B,
        GpuModel::new(A10),
        CpuModel::from_device(EPYC_7452),
        8,
        1024,
        1024,
    );
    cfg.sls_interval = Some(32);
    cfg.steps = 3 * 1024;
    cfg
}

fn tp(cfg: &SimConfig) -> f64 {
    steady_throughput(&simulate(cfg), cfg.seq_len)
}

fn main() {
    let reference = tp(&base());
    let mut js = Vec::new();
    let mut t = Table::new(
        "Ablations (7b, B=1024, S=1024, 8 sockets; Δ vs full system)",
        &["variant", "tok/s", "delta"],
    );
    let mut add = |name: &str, v: f64| {
        t.row(&[
            name.into(),
            format!("{v:.0}"),
            format!("{:+.1} %", (v / reference - 1.0) * 100.0),
        ]);
        js.push(Json::obj().set("variant", name).set("tok_per_s", v));
    };
    add("full system", reference);

    // 1. token-level pipeline off (S and R strictly serialized)
    let mut c = base();
    c.pipelined = false;
    add("no token pipeline (Fig 5a)", tp(&c));

    // 2. SLS off (all sequences start together; throughput over the
    //    whole triangular run)
    let mut c = base();
    c.sls_interval = None;
    c.steps = 1024;
    add("no SLS (§4.2 off)", simulate(&c).throughput());

    // 3. SLS interval sweep (eq. 5: F trades admission delay vs mixing)
    for f in [8usize, 32, 128, 512] {
        let mut c = base();
        c.sls_interval = Some(f);
        add(&format!("SLS F={f}"), tp(&c));
    }

    // 4. communication exposed instead of overlapped
    let mut c = base();
    c.sync_comm = true;
    add("sync (exposed) comm", tp(&c));

    // 5. interconnect quality
    for (name, net) in [("Infiniband", INFINIBAND), ("PCIe-only", PCIE4_X16)] {
        let mut c = base();
        c.net = net;
        c.sync_comm = true; // otherwise the link barely shows
        add(&format!("net={name} (sync comm)"), tp(&c));
    }

    // 6. KV precision (R-Part traffic term; §5.2)
    for p in [Precision::F32, Precision::Int8, Precision::Int4] {
        let mut c = base();
        c.precision = p;
        add(&format!("KV {}", p.label()), tp(&c));
    }

    // 7. socket count around the planned point
    for s in [4usize, 12, 16] {
        let mut c = base();
        c.sockets = s;
        add(&format!("{s} sockets"), tp(&c));
    }

    t.print();
    println!(
        "reading: the pipeline and socket provisioning dominate; SLS adds \
         ~10 %; F only matters at extremes (F→S degenerates to no-SLS);\n\
         quantized KV shifts the bottleneck to the S-worker (bigger gains \
         would need a bigger batch, eq. 11)."
    );
    record_result("ablations", Json::Arr(js));
}
