//! Figure 13 (strong scaling over R-worker sockets) and Figure 14
//! (scaling up with more S-workers via tensor parallelism, opt-175b).
//!
//! The socket sweep is run twice: on the virtual clock (A10/Epyc scale)
//! and REAL on this machine (thread-per-socket Rust attention over an
//! actual fp16 KV-cache) to show the same saturation shape.
//!
//! Run: `cargo bench --bench fig13_scalability [-- --fig14|--real|--tcp]`
//!
//! `--real` sweeps the socket count on the LIVE threaded engine
//! (reduced scale, behind `Box<dyn Coordinator>`) instead of the
//! virtual clock. `--tcp` sweeps the NODE count over real localhost
//! sockets: one `rnode` process per node, activations f16-framed by
//! the wire codec (`net/`), the engine driving them through
//! `RemotePool` — the multi-node R-Part deployment of the paper's §4,
//! collapsed onto one machine.

use std::time::Instant;

use fastdecode::bench::snapshot::Snapshot;
use fastdecode::bench::{real_flag, real_mini, record_result, sim_trace as simulate, Table};
use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::net::{
    spawn_rnode_process, NodeConfig, RemotePool, RnodeProcess, WireMode,
};
use fastdecode::coordinator::sim::steady_throughput;
use fastdecode::coordinator::{Coordinator, SimConfig};
use fastdecode::kvcache::SeqKv;
use fastdecode::model::{ModelSpec, Precision, LLAMA_13B, LLAMA_7B, OPT_175B, TINY};
use fastdecode::obs::{NetStats, Tracer};
use fastdecode::perfmodel::{CpuModel, GpuModel, A10, EPYC_7452};
use fastdecode::rworker::{attend_one, AttnScratch};
use fastdecode::util::json::Json;
use fastdecode::util::Rng;

fn ours_tp(spec: ModelSpec, sockets: usize, seq: usize) -> f64 {
    let mut cfg = SimConfig::new(
        spec,
        GpuModel::new(A10),
        CpuModel::from_device(EPYC_7452),
        sockets,
        1024,
        seq,
    );
    cfg.sls_interval = Some((seq / 16).max(1));
    cfg.steps = 3 * seq;
    steady_throughput(&simulate(&cfg), seq)
}

/// Socket sweep on the live engine: same trait, real threads, tiny
/// model (per-socket KV shards on this machine).
fn fig13_real_engine() {
    let (batch, steps) = (16usize, 32usize);
    let mut t = Table::new(
        "Fig 13 (real engine, tiny, B=16): throughput vs sockets",
        &["sockets", "tok/s", "speedup"],
    );
    let mut base = 0.0;
    let mut js = Vec::new();
    for p in [1usize, 2, 4] {
        let mut c = real_mini(batch, p, 2, steps);
        let trace = c.run_steps(steps).expect("real sweep");
        let tp = trace.throughput();
        if p == 1 {
            base = tp;
        }
        t.row(&[
            p.to_string(),
            format!("{tp:.0}"),
            format!("{:.2}x", tp / base),
        ]);
        js.push(Json::obj().set("sockets", p).set("tok_per_s", tp));
    }
    t.print();
    record_result("fig13_real_engine", Json::Arr(js));
}

/// One spawned `rnode` process (killed + reaped on drop).
/// `CARGO_BIN_EXE_*` is provided to bench targets at compile time.
fn spawn_rnode() -> RnodeProcess {
    spawn_rnode_process(env!("CARGO_BIN_EXE_rnode")).expect("spawning rnode")
}

/// Node-count sweep over REAL localhost TCP: per node count P, spawn P
/// `rnode` processes, shard the batch across them (f16 wire), and
/// measure decode throughput — Fig 13's strong-scaling axis with the
/// S↔R boundary as a genuine network boundary. `max_nodes` caps the
/// sweep (CI runs `--max-nodes 2` to stay within small runners); the
/// largest run's trace becomes the `BENCH_fig13_tcp.json` snapshot.
fn fig13_tcp(max_nodes: usize) {
    let (batch, steps) = (16usize, 32usize);
    // FASTDECODE_TRACE=1 turns the sweep into a traced run: the rnodes
    // record server-side spans (Configure's `trace` flag), and after
    // each run the coordinator fetches + clock-aligns them into one
    // Chrome trace (the largest run's trace survives as
    // TRACE_fig13_tcp.json, one track per node).
    let traced = Tracer::from_env().is_enabled();
    let mut t = Table::new(
        "Fig 13 (--tcp, tiny, B=16): throughput vs rnode processes (f16 wire)",
        &["nodes", "tok/s", "speedup"],
    );
    let mut base = 0.0;
    let mut js = Vec::new();
    let mut last: Option<(usize, fastdecode::metrics::StepTrace)> = None;
    let mut last_stats: Vec<NetStats> = Vec::new();
    let counts: Vec<usize> = [1usize, 2, 4]
        .into_iter()
        .filter(|&p| p <= max_nodes.max(1))
        .collect();
    for p in counts {
        let nodes: Vec<RnodeProcess> = (0..p).map(|_| spawn_rnode()).collect();
        let addrs: Vec<String> =
            nodes.iter().map(|n| n.addr.clone()).collect();
        let pool = RemotePool::connect_tcp(
            &addrs,
            NodeConfig::from_spec(
                &TINY,
                steps + 4,
                8,
                Precision::F16,
                WireMode::F16,
            )
            .with_trace(traced),
        )
        .expect("connecting rnodes");
        let mut fd = FastDecode::with_backend(
            TINY,
            FastDecodeConfig {
                batch,
                capacity_per_seq: steps + 4,
                layers: 2,
                ..Default::default()
            },
            Box::new(pool),
        )
        .expect("engine over tcp");
        let prompts =
            fastdecode::workload::fixed_batch(batch, 2, TINY.vocab, 11);
        fd.prime(&prompts, 1).expect("prime over tcp");
        let start = Instant::now();
        let trace = fd.run_steps(steps).expect("tcp sweep");
        let wall = start.elapsed().as_secs_f64();
        let tp = trace.total_tokens() as f64 / wall;
        if p == 1 {
            base = tp;
        }
        t.row(&[
            p.to_string(),
            format!("{tp:.0}"),
            format!("{:.2}x", tp / base),
        ]);
        js.push(Json::obj().set("nodes", p).set("tok_per_s", tp));
        last_stats = fd.net_stats();
        if traced {
            let merged =
                fd.merge_remote_traces().expect("fetching remote traces");
            let path =
                fastdecode::artifacts_dir().join("TRACE_fig13_tcp.json");
            fd.tracer()
                .write_chrome_trace(&path)
                .expect("writing chrome trace");
            println!(
                "trace: {} ({merged} remote spans from {p} nodes)",
                path.display()
            );
        }
        last = Some((p, trace));
        drop(fd); // disconnects before the rnode processes are killed
    }
    t.print();
    record_result("fig13_tcp", Json::Arr(js.clone()));
    if let Some((p, trace)) = last {
        let snap = Snapshot::from_trace(
            "fig13_tcp",
            Json::obj()
                .set("mode", "tcp")
                .set("model", "tiny")
                .set("batch", batch)
                .set("nodes", p)
                .set("steps", steps)
                .set("wire", "f16"),
            &trace,
        )
        // sweep points plus the largest run's measured per-node
        // profiles (EWMA tok/s, bytes/s, service percentiles) — the
        // planner's from_measured_profiles input, archived per commit
        .with_extra(
            Json::obj().set("sweep", Json::Arr(js)).set(
                "nodes",
                Json::Arr(
                    last_stats.iter().map(NetStats::to_json).collect(),
                ),
            ),
        );
        let path = snap.write().expect("writing BENCH_fig13_tcp.json");
        println!("snapshot: {}", path.display());
    }
}

fn fig13_virtual() {
    let mut js = Vec::new();
    for spec in [LLAMA_7B, LLAMA_13B] {
        let mut t = Table::new(
            &format!("Fig 13: strong scaling over sockets, {} (B=1024)", spec.name),
            &["sockets", "S=1024 tok/s", "eff %", "S=128 tok/s", "eff %"],
        );
        let base_long = ours_tp(spec, 1, 1024);
        let base_short = ours_tp(spec, 1, 128);
        for p in [1usize, 2, 4, 8] {
            let long = ours_tp(spec, p, 1024);
            let short = ours_tp(spec, p, 128);
            t.row(&[
                p.to_string(),
                format!("{long:.0}"),
                format!("{:.0}", long / (p as f64 * base_long) * 100.0),
                format!("{short:.0}"),
                format!("{:.0}", short / (p as f64 * base_short) * 100.0),
            ]);
            js.push(
                Json::obj()
                    .set("model", spec.name)
                    .set("sockets", p)
                    .set("tp_long", long)
                    .set("tp_short", short),
            );
        }
        t.print();
    }
    println!(
        "paper shape: 72.8%/84.1% efficiency at 8 sockets (7b/13b, S=1024);\n\
         at S=128 extra sockets stop helping (S-worker is the bottleneck)"
    );
    record_result("fig13_virtual", Json::Arr(js));
}

/// REAL socket scaling on this machine: N threads, each owning a shard
/// of sequences, all attending one step over true fp16 caches.
fn fig13_real() {
    let (heads, d, ctx, seqs_total) = (8usize, 128usize, 512usize, 32usize);
    let mut t = Table::new(
        "Fig 13 (real, this host): R-Part step time vs worker threads",
        &["threads", "step ms", "speedup", "eff %"],
    );
    // one shared immutable setup per thread-count to keep memory sane
    let build_shard = |n: usize, seed: u64| -> Vec<SeqKv> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let mut kv = SeqKv::new(heads, d, ctx, Precision::F16);
                let k = rng.normal_vec(heads * d, 0.5);
                let v = rng.normal_vec(heads * d, 0.5);
                for _ in 0..ctx {
                    kv.append(&k, &v);
                }
                kv
            })
            .collect()
    };
    let max_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    if max_threads == 1 {
        println!(
            "note: this host exposes 1 CPU core — real thread scaling \
             cannot be demonstrated here; the virtual-clock series above \
             carries Fig 13 (see DESIGN.md §2)."
        );
    }
    let mut base = 0.0;
    let mut js = Vec::new();
    let mut threads = 1usize;
    while threads <= max_threads {
        let per = seqs_total / threads;
        let shards: Vec<Vec<SeqKv>> =
            (0..threads).map(|i| build_shard(per, i as u64)).collect();
        let q = Rng::new(99).normal_vec(heads * d, 0.5);
        // 3 timed repetitions of one full step
        let start = Instant::now();
        let reps = 3;
        for _ in 0..reps {
            std::thread::scope(|s| {
                for shard in &shards {
                    let q = &q;
                    s.spawn(move || {
                        let mut o = vec![0.0f32; heads * d];
                        let mut scratch = AttnScratch::new(d);
                        for kv in shard {
                            attend_one(kv, q, &mut o, &mut scratch);
                        }
                        std::hint::black_box(&o);
                    });
                }
            });
        }
        let step = start.elapsed().as_secs_f64() / reps as f64;
        if threads == 1 {
            base = step;
        }
        let speedup = base / step;
        t.row(&[
            threads.to_string(),
            format!("{:.2}", step * 1e3),
            format!("{speedup:.2}x"),
            format!("{:.0}", speedup / threads as f64 * 100.0),
        ]);
        js.push(
            Json::obj()
                .set("threads", threads)
                .set("step_ms", step * 1e3)
                .set("speedup", speedup),
        );
        threads *= 2;
    }
    t.print();
    record_result("fig13_real", Json::Arr(js));
}

/// Fig 14: opt-175b — adding only CPUs vs doubling both S- and R-workers
/// with tensor parallelism (workloads of both parts divide evenly, §5.3).
fn fig14() {
    let spec = OPT_175B;
    let seq = 512;
    let batch = 512;
    let tp = |gpus: usize, sockets: usize| {
        let mut gpu = GpuModel::new(A10);
        // TP over `gpus` S-workers: each holds 1/gpus of every matmul;
        // all-reduce overhead folded into a slightly higher launch cost.
        gpu.device.flops *= gpus as f64;
        gpu.device.mem_bw *= gpus as f64;
        gpu.launch_s += 10e-6 * (gpus as f64 - 1.0);
        let mut cfg = SimConfig::new(
            spec,
            gpu,
            CpuModel::from_device(EPYC_7452),
            sockets,
            batch,
            seq,
        );
        cfg.sls_interval = Some(seq / 16);
        cfg.steps = 3 * seq;
        steady_throughput(&simulate(&cfg), seq)
    };
    let base = tp(1, 2);
    let more_cpu = tp(1, 4);
    let double = tp(2, 4);
    let mut t = Table::new(
        "Fig 14: scaling up FastDecode, opt-175b (base: 1 A10 + 2 sockets)",
        &["config", "tok/s", "vs base"],
    );
    t.row(&["1 GPU + 2 CPU".into(), format!("{base:.0}"), "1.00x".into()]);
    t.row(&[
        "1 GPU + 4 CPU (2x R only)".into(),
        format!("{more_cpu:.0}"),
        format!("{:.2}x", more_cpu / base),
    ]);
    t.row(&[
        "2 GPU + 4 CPU (2x both, TP)".into(),
        format!("{double:.0}"),
        format!("{:.2}x", double / base),
    ]);
    t.print();
    println!(
        "paper shape: 2x CPUs alone gains little; 2x both ≈ 1.84x throughput"
    );
    record_result(
        "fig14",
        Json::obj()
            .set("base", base)
            .set("more_cpu", more_cpu)
            .set("double", double),
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let max_nodes = args
        .iter()
        .position(|a| a == "--max-nodes")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(4);
    if args.iter().any(|a| a == "--fig14") {
        fig14();
    } else if args.iter().any(|a| a == "--tcp") {
        fig13_tcp(max_nodes);
    } else if real_flag() {
        fig13_real_engine();
    } else {
        fig13_virtual();
        fig13_real();
        fig13_real_engine();
        fig14();
    }
}
