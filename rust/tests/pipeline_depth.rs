//! Acceptance tests for the depth-D generalization of the threaded
//! token-level pipeline (paper §4.1 Fig 5 generalized, §7.3's deeper
//! in-flight set) and for SLS admission driving the LIVE engine
//! (§4.2, Algorithm 1 over real wall-clock steps).
//!
//! Timing methodology matches `pipeline_smoke.rs`: the per-row `s_pad`
//! and per-task `r_pad` dilations pin the stage latencies well above
//! scheduler noise, and — because they are charged per row/task, not
//! per stage — the total dilation of a step is invariant to how the
//! batch is split, so depths are directly comparable. The pads make the
//! R side dominant (R ≈ 96 ms vs S ≈ 36 ms per step), which is where
//! deeper pipelines pay off: the fill/drain bubbles at the step
//! boundaries shrink as 1/D.

use std::time::Duration;

use fastdecode::coordinator::real::{Arrival, FastDecode, FastDecodeConfig};
use fastdecode::coordinator::Coordinator;
use fastdecode::model::{Precision, TINY};
use fastdecode::runtime::{PipelineConfig, ThreadedPipeline};
use fastdecode::rworker::{RPool, RPoolConfig};
use fastdecode::sworker::{ModelWeights, NativeSWorker};
use fastdecode::workload::fixed_batch;

const BATCH: usize = 24; // divisible by 2·D for D ∈ {2, 3, 4}: balanced sockets
const STEPS: usize = 4;
const S_PAD: Duration = Duration::from_micros(500);
const R_PAD: Duration = Duration::from_millis(4);

/// Mean decode-step latency and the generated tokens at one (depth,
/// mode) point.
fn run(depth: usize, pipelined: bool) -> (f64, Vec<Vec<i32>>) {
    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch: BATCH,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 32,
            weight_seed: 3,
            layers: 2,
            pipelined,
            depth,
            s_pad: S_PAD,
            r_pad: R_PAD,
        },
    )
    .unwrap();
    let prompts = fixed_batch(BATCH, 2, TINY.vocab, 17);
    let result = fd.generate(&prompts, STEPS).unwrap();
    let n = result.trace.len() as f64;
    let lat = result.trace.records.iter().map(|r| r.latency_s).sum::<f64>() / n;
    (lat, result.tokens)
}

/// For D ∈ {2, 3, 4}: the pipelined steady-state step beats the serial
/// step, deeper pipelines are no slower than the paper's double buffer
/// (within noise pads), and the tokens are bit-identical across every
/// depth and both modes.
#[test]
fn depth_sweep_latency_and_token_identity() {
    let (lat_p2, toks_p2) = run(2, true);
    let (lat_s2, toks_s2) = run(2, false);
    let (lat_p3, toks_p3) = run(3, true);
    let (lat_s3, toks_s3) = run(3, false);
    let (lat_p4, toks_p4) = run(4, true);
    let (lat_s4, toks_s4) = run(4, false);

    // sanity: the dilation dominates scheduler noise at every depth
    for (d, lat) in [(2, lat_p2), (3, lat_p3), (4, lat_p4)] {
        assert!(lat > 50e-3, "D={d}: pipelined step {lat} below pad floor");
    }

    // overlap buys real wall-clock time at every depth (ideal
    // pipelined/serial here ≈ 0.82; 0.95 leaves noise headroom)
    for (d, p, s) in [(2, lat_p2, lat_s2), (3, lat_p3, lat_s3), (4, lat_p4, lat_s4)]
    {
        assert!(
            p <= s * 0.95,
            "D={d}: pipelined {p} not below serial {s}"
        );
    }

    // §7.3: a deeper in-flight set must not be slower than the paper's
    // two-mini-batch double buffer (ideal ratio ≤ 1.0 — the fill/drain
    // bubbles shrink as 1/D; 1.10 is the noise pad)
    assert!(
        lat_p3 <= lat_p2 * 1.10,
        "D=3 step {lat_p3} regressed vs D=2 {lat_p2}"
    );
    assert!(
        lat_p4 <= lat_p2 * 1.10,
        "D=4 step {lat_p4} regressed vs D=2 {lat_p2}"
    );

    // overlap and depth must never change a single token: D=4 (and
    // every other point) is bit-identical to D=2
    assert_eq!(toks_p2, toks_s2, "pipelining changed tokens at D=2");
    assert_eq!(toks_p2, toks_p3, "depth 3 changed tokens");
    assert_eq!(toks_p2, toks_s3, "serial depth 3 changed tokens");
    assert_eq!(toks_p2, toks_p4, "depth 4 changed tokens");
    assert_eq!(toks_p2, toks_s4, "serial depth 4 changed tokens");
}

/// SLS admission over the LIVE engine, driven through
/// `Coordinator::run_steps`: queued micro-batch arrivals are admitted
/// by `LoadControl::earliest_start` and the MEASURED aggregate KV load
/// (counted from the sockets' caches, not from the schedule) never
/// exceeds W_lim at any step.
#[test]
fn live_sls_admission_bounds_measured_kv_load() {
    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch: 4, // unused by SLS mode (the live set drives step size)
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 16,
            weight_seed: 9,
            layers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    // six micro-batches of m=2, S=8 (footprint 16) under W_lim=24:
    // full concurrency would need 2·16 = 32, so admission must stagger
    // the starts (earliest feasible overlap: age 4 at the elder's end)
    let arrivals: Vec<Arrival> = (0..6)
        .map(|i| Arrival {
            m: 2,
            seq_len: 8,
            first_token: (10 + 9 * i) as i32,
        })
        .collect();
    let w_lim = 24;
    fd.drive_arrivals(&arrivals, w_lim).unwrap();
    assert_eq!(fd.pending_arrivals(), 6);

    let c: &mut dyn Coordinator = &mut fd;
    assert_eq!(c.backend(), "real-threaded-sls");
    let trace = c.run_steps(60).unwrap();
    assert_eq!(trace.len(), 60);
    for r in &trace.records {
        assert!(
            r.total_ctx <= w_lim,
            "step {}: measured KV load {} exceeds W_lim {w_lim}",
            r.step,
            r.total_ctx
        );
    }
    // every arrival was served to completion within the horizon
    assert_eq!(trace.total_tokens(), 6 * 2 * 8);
    assert_eq!(fd.pending_arrivals(), 0);
    assert_eq!(fd.live_sequences(), 0);
    assert_eq!(fd.cache_tokens().unwrap(), 0, "finished caches not released");
    // and admission actually overlapped micro-batches (SLS steady
    // state), rather than trivially serializing them
    let peak = trace.records.iter().map(|r| r.total_ctx).max().unwrap();
    assert!(
        peak > 16,
        "micro-batches never overlapped (peak W = {peak})"
    );
}

/// A second arrival wave may be enqueued while the first is still
/// live: the engine releases every held sequence, keeps sequence ids
/// monotone across waves, and serves the new wave (regression: stale
/// placements used to panic `RPool::add_seqs` and leak KV).
#[test]
fn second_arrival_wave_resets_cleanly() {
    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            sockets: 2,
            capacity_per_seq: 16,
            ..Default::default()
        },
    )
    .unwrap();
    fd.drive_arrivals(
        &[Arrival {
            m: 2,
            seq_len: 8,
            first_token: 3,
        }],
        32,
    )
    .unwrap();
    fd.run_steps(3).unwrap(); // wave 1 still mid-flight
    assert_eq!(fd.live_sequences(), 2);

    fd.drive_arrivals(
        &[Arrival {
            m: 2,
            seq_len: 4,
            first_token: 5,
        }],
        32,
    )
    .unwrap();
    assert_eq!(fd.live_sequences(), 0, "wave 1 not released");
    let trace = fd.run_steps(6).unwrap();
    assert_eq!(trace.total_tokens(), 2 * 4);
    assert_eq!(fd.cache_tokens().unwrap(), 0);
}

/// Rejecting an arrival that could never be admitted is part of
/// `earliest_start`'s honest Option contract.
#[test]
fn infeasible_arrival_is_rejected_up_front() {
    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            sockets: 2,
            capacity_per_seq: 64,
            ..Default::default()
        },
    )
    .unwrap();
    let err = fd
        .drive_arrivals(
            &[Arrival {
                m: 4,
                seq_len: 10,
                first_token: 1,
            }],
            30,
        )
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("exceeds W_lim"),
        "wrong rejection: {err:#}"
    );
}

/// Regression for the S-thread error path: an S-Part failure mid-step
/// must surface its root cause through `step()`'s `Result` (not a bare
/// "thread died"), and the drained pipeline + R-pool must serve the
/// next step.
#[test]
fn s_failure_surfaces_cause_and_pipeline_stays_usable() {
    let spec = TINY; // 2 layers
    let weights = ModelWeights::random(spec, 2, 7);
    let sworker = NativeSWorker::new(weights);
    let mut rpool = RPool::spawn(
        &spec,
        RPoolConfig {
            sockets: 2,
            capacity_per_seq: 16,
            precision: Precision::F16,
            attend_pad: Duration::ZERO,
            ..Default::default()
        },
    );
    let ids: Vec<u64> = (1..=6).collect();
    rpool.add_seqs(&ids).unwrap();
    let mut p = ThreadedPipeline::new(
        sworker,
        rpool,
        PipelineConfig {
            depth: 3,
            ..Default::default()
        },
    );
    let tokens: Vec<i32> = (0..6).map(|i| (i * 5 + 1) as i32).collect();
    let (next, _) = p.step(&tokens, &ids).unwrap();

    // fail the 4th S op of the next step — a mid-pipeline Advance, so
    // an attend is in flight and later S responses are queued when the
    // error surfaces (both recovery drains are exercised)
    p.poison_s_op(3, "injected numerical fault").unwrap();
    let err = p.step(&next, &ids).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("injected numerical fault"),
        "error lost the root cause: {msg}"
    );

    // the failed step drained cleanly: the same pipeline and pool
    // serve the next step without respawning anything
    let (again, timing) = p.step(&next, &ids).unwrap();
    assert_eq!(again.len(), ids.len());
    assert!(timing.s_time > 0.0 && timing.r_time > 0.0);
}
