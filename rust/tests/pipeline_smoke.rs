//! Acceptance smoke test for the REAL threaded token-level pipeline
//! (paper §4.1, Fig 5a vs 5b): with two mini-batches double-buffered
//! across the S-worker thread and the R-worker sockets, the measured
//! steady-state step latency approaches max(s, r); with pipelining
//! disabled the same stages cost s + r.
//!
//! All numbers are REAL wall-clock timestamps. The `s_pad` / `r_pad`
//! dilation (a per-row sleep inside each S stage / a per-task sleep
//! inside each socket attend) pins the stage durations well above
//! scheduler noise, so the assertion bands hold on any machine; the
//! measured s_time / r_time include the same dilation, keeping the
//! comparison self-consistent.

use std::time::Duration;

use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::coordinator::Coordinator;
use fastdecode::model::{Precision, TINY};
use fastdecode::workload::fixed_batch;

// Pads are per row (S) / per task (R): with batch 4 split into two
// mini-batches of 2 rows over 2 sockets, each S stage sleeps 2×4 = 8 ms
// and each socket attend sleeps 1×8 = 8 ms — an order of magnitude
// above scheduler noise even on a loaded 2-vCPU CI runner (the bands
// compare wall latency against stage times measured inside the worker
// threads, so contention-induced drift must stay under 25 % of
// ~50-80 ms).
const S_PAD: Duration = Duration::from_millis(4);
const R_PAD: Duration = Duration::from_millis(8);
const STEPS: usize = 6;

/// Mean (latency, s_time, r_time) over the measured steps, plus the
/// generated tokens for the determinism cross-check.
fn run(pipelined: bool) -> (f64, f64, f64, Vec<Vec<i32>>) {
    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch: 4,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 32,
            weight_seed: 3,
            layers: 2,
            pipelined,
            depth: 2,
            s_pad: S_PAD,
            r_pad: R_PAD,
        },
    )
    .unwrap();
    let prompts = fixed_batch(4, 2, TINY.vocab, 17);
    let result = fd.generate(&prompts, STEPS).unwrap();
    let n = result.trace.len() as f64;
    let recs = &result.trace.records;
    let lat = recs.iter().map(|r| r.latency_s).sum::<f64>() / n;
    let s = recs.iter().map(|r| r.s_time).sum::<f64>() / n;
    let r = recs.iter().map(|r| r.r_time).sum::<f64>() / n;
    (lat, s, r, result.tokens)
}

#[test]
fn pipelined_step_is_max_of_stages_serial_is_sum() {
    let (lat_p, s_p, r_p, toks_p) = run(true);
    let (lat_s, s_s, r_s, toks_s) = run(false);

    // sanity: the dilation dominates — every stage aggregate is ≫ noise
    assert!(s_p > 20e-3 && r_p > 8e-3, "s {s_p} r {r_p}");
    assert!(s_s > 20e-3 && r_s > 8e-3, "s {s_s} r {r_s}");

    // Fig 5b: steady-state step ≈ max(s, r) within 25 %
    let ideal_p = s_p.max(r_p);
    assert!(
        (lat_p - ideal_p).abs() / ideal_p <= 0.25,
        "pipelined step {lat_p} vs max(s, r) {ideal_p}"
    );

    // Fig 5a: serial step ≈ s + r within 25 %
    let ideal_s = s_s + r_s;
    assert!(
        (lat_s - ideal_s).abs() / ideal_s <= 0.25,
        "serial step {lat_s} vs s + r {ideal_s}"
    );

    // and pipelining must actually buy real wall-clock time (ideal
    // ratio here is (s+r)/max ≈ 80ms/48ms ≈ 1.67; the 1.3 floor leaves
    // ~14 ms of absorbable scheduler drift on a loaded runner)
    assert!(
        lat_s / lat_p >= 1.3,
        "serial {lat_s} / pipelined {lat_p} = {}",
        lat_s / lat_p
    );

    // overlap must never change a single token
    assert_eq!(toks_p, toks_s, "pipelining changed the generated tokens");
}

/// The live engine drives the same Coordinator interface as the
/// virtual-clock simulator — prime once, then trace real steps.
#[test]
fn real_engine_behind_coordinator_trait() {
    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch: 4,
            sockets: 2,
            capacity_per_seq: 32,
            ..Default::default()
        },
    )
    .unwrap();
    let prompts = fixed_batch(4, 2, TINY.vocab, 9);
    fd.prime(&prompts, 1).unwrap();

    let c: &mut dyn Coordinator = &mut fd;
    assert_eq!(c.backend(), "real-threaded-pipelined");
    let trace = c.run_steps(5).unwrap();
    assert_eq!(trace.len(), 5);
    assert!(trace.records.iter().all(|r| r.latency_s > 0.0));
    assert!(trace.records.iter().all(|r| r.tokens == 4));
    // wall latency, stage times and modeled comm are all populated
    assert!(trace.records.iter().all(|r| r.s_time > 0.0));
    assert!(trace.records.iter().all(|r| r.r_time > 0.0));
    assert!(trace.records.iter().all(|r| r.comm_time > 0.0));
    // a second call continues from the last tokens
    let more = c.run_steps(3).unwrap();
    assert_eq!(more.len(), 3);
}
