//! Cross-language numeric pinning: every exported HLO graph, executed
//! from Rust through PJRT, must reproduce the golden outputs computed by
//! JAX at export time (python/compile/aot.py, fixed seeds).
//!
//! This covers the whole AOT bridge: HLO text parsing under
//! xla_extension 0.5.1, tuple packing, dtype/layout conventions — and,
//! via the `fused` artifacts, the interpret-mode *Pallas kernels* lowered
//! into plain HLO.

use std::sync::Arc;

use fastdecode::runtime::{Dtype, Engine, Tensor};

fn engine() -> Arc<Engine> {
    Arc::new(Engine::load(fastdecode::artifacts_dir()).expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    ))
}

fn load_tensor(g: &fastdecode::runtime::Golden) -> Tensor {
    match g.dtype {
        Dtype::F32 => Tensor::f32(&g.shape, g.load_f32().unwrap()),
        Dtype::I32 => Tensor::i32(&g.shape, g.load_i32().unwrap()),
        Dtype::F16 => panic!("f16 goldens unused"),
    }
}

fn check_artifact(engine: &Engine, name: &str, tol: f32) {
    let (ins, outs) = engine.manifest.goldens_for(name);
    assert!(!ins.is_empty(), "{name}: no golden inputs");
    assert!(!outs.is_empty(), "{name}: no golden outputs");
    let inputs: Vec<Tensor> = ins.iter().map(|g| load_tensor(g)).collect();
    let results = engine.run(name, &inputs).expect("execution failed");
    assert_eq!(results.len(), outs.len(), "{name}: output arity");
    for (i, (got, want_g)) in results.iter().zip(&outs).enumerate() {
        let want = load_tensor(want_g);
        match (&got, &want) {
            (Tensor::I32 { .. }, _) => {
                assert_eq!(
                    got.as_i32().unwrap(),
                    want.as_i32().unwrap(),
                    "{name} out{i}"
                );
            }
            _ => {
                let diff = got.max_abs_diff(&want).unwrap();
                assert!(
                    diff <= tol,
                    "{name} out{i}: max abs diff {diff} > {tol}"
                );
            }
        }
    }
}

#[test]
fn all_simple_graphs_match_golden() {
    let e = engine();
    for b in [1, 8] {
        for suffix in ["embed", "s_pre", "s_post", "logits"] {
            check_artifact(&e, &format!("tiny_b{b}_{suffix}"), 1e-5);
        }
    }
}

/// The fused decode step embeds the interpret-mode Pallas attention and
/// MLP kernels — this is the L1-through-the-bridge test.
#[test]
fn fused_pallas_graphs_match_golden() {
    let e = engine();
    for b in [1, 8] {
        check_artifact(&e, &format!("tiny_b{b}_fused_s128"), 5e-5);
    }
}

#[test]
fn manifest_lists_all_artifacts() {
    let e = engine();
    assert!(e.manifest.artifacts.len() >= 10);
    for a in e.manifest.artifacts.values() {
        assert!(a.path.exists(), "missing artifact file {:?}", a.path);
        assert!(!a.inputs.is_empty());
        assert!(!a.outputs.is_empty());
    }
}

#[test]
fn shape_mismatch_is_rejected() {
    let e = engine();
    let bad = vec![Tensor::zeros_f32(&[2, 2])];
    assert!(e.run("tiny_b1_s_pre", &bad).is_err());
}
