//! Cross-language numeric pinning: every exported artifact, replayed
//! through the native Rust S-Part ops, must reproduce the golden outputs
//! computed by JAX at export time (python/compile/aot.py, fixed seeds).
//!
//! The artifacts are produced by the Python toolchain (`make artifacts`)
//! and are not checked into the repository, so these tests SKIP — with a
//! note — when `artifacts/manifest.txt` is absent. When present they pin
//! the whole cross-language contract: manifest parsing, golden file
//! layout, and the Rust reimplementation of embed / s_pre / s_post /
//! logits and the fused block (dtype + dimension conventions included).

use std::path::Path;

use fastdecode::model::ModelSpec;
use fastdecode::runtime::{Dtype, Golden, Manifest, Tensor};
use fastdecode::sworker::{ops, BlockWeights, ModelWeights, NativeSWorker};

fn manifest() -> Option<Manifest> {
    let dir = fastdecode::artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!(
            "skipping golden roundtrip: no artifacts at {dir:?} \
             (run `make artifacts` with the Python toolchain to enable)"
        );
        return None;
    }
    Some(Manifest::load(&dir).expect("artifacts present but unparsable"))
}

fn load_tensor(g: &Golden) -> Tensor {
    match g.dtype {
        Dtype::F32 => Tensor::f32(&g.shape, g.load_f32().unwrap()),
        Dtype::I32 => Tensor::i32(&g.shape, g.load_i32().unwrap()),
        Dtype::F16 => panic!("f16 goldens unused"),
    }
}

/// The `<kind>` of an aot.py artifact name `<model>_b<B>_<kind>`,
/// parsed from the LAST `_b<digits>_` segment so model names that
/// themselves contain `_b` cannot shift the split point.
fn artifact_kind(name: &str) -> Option<&str> {
    let idx = name.rfind("_b")?;
    let (digits, kind) = name[idx + 2..].split_once('_')?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    Some(kind)
}

/// A spec just wide enough for the graph under test (unused dims are 1;
/// the S-Part methods never touch `n_heads`).
fn golden_spec(hidden: usize, ffn: usize, vocab: usize) -> ModelSpec {
    ModelSpec {
        name: "golden",
        hidden,
        n_heads: 1,
        n_layers: 1,
        ffn,
        vocab,
    }
}

/// One block with the given weights, zero/identity elsewhere — the
/// untouched tensors only need the right shapes.
#[allow(clippy::too_many_arguments)]
fn golden_block(
    h: usize,
    ffn: usize,
    ln1: Option<&Tensor>,
    wqkv: Option<&Tensor>,
    wo: Option<&Tensor>,
    ln2: Option<&Tensor>,
    mlp: Option<(&Tensor, &Tensor, &Tensor)>,
) -> BlockWeights {
    let ones = |n: usize| Tensor::f32(&[n], vec![1.0; n]);
    let (w_gate, w_up, w_down) = match mlp {
        Some((g, u, d)) => (g.clone(), u.clone(), d.clone()),
        None => (
            Tensor::zeros_f32(&[h, ffn]),
            Tensor::zeros_f32(&[h, ffn]),
            Tensor::zeros_f32(&[ffn, h]),
        ),
    };
    BlockWeights {
        ln1: ln1.cloned().unwrap_or_else(|| ones(h)),
        wqkv: wqkv.cloned().unwrap_or_else(|| Tensor::zeros_f32(&[h, 3 * h])),
        wo: wo.cloned().unwrap_or_else(|| Tensor::zeros_f32(&[h, h])),
        ln2: ln2.cloned().unwrap_or_else(|| ones(h)),
        w_gate,
        w_up,
        w_down,
    }
}

fn golden_worker(
    spec: ModelSpec,
    blocks: Vec<BlockWeights>,
    w_emb: Option<&Tensor>,
    ln_f: Option<&Tensor>,
) -> NativeSWorker {
    let h = spec.hidden;
    NativeSWorker::new(ModelWeights {
        spec,
        blocks,
        w_emb: w_emb
            .cloned()
            .unwrap_or_else(|| Tensor::zeros_f32(&[spec.vocab, h])),
        ln_f: ln_f.cloned().unwrap_or_else(|| Tensor::f32(&[h], vec![1.0; h])),
    })
}

/// Execute one artifact from its golden inputs through the PRODUCTION
/// `NativeSWorker` methods (the code the pipeline actually runs),
/// dispatching on the aot.py naming convention
/// (`<model>_b<B>_<kind>[_s<S>]`). The fused baseline goes through
/// `ops::fused_block_step`, which `sworker::native` tests pin against
/// the decomposed path in-crate.
fn run_native(name: &str, inputs: &[Tensor]) -> Option<Vec<Tensor>> {
    let kind = artifact_kind(name)?;
    match kind {
        "embed" => {
            let tokens = inputs[0].as_i32().unwrap();
            let (vocab, h) = (inputs[1].shape()[0], inputs[1].shape()[1]);
            let sw = golden_worker(
                golden_spec(h, 1, vocab),
                vec![],
                Some(&inputs[1]),
                None,
            );
            Some(vec![sw.embed(tokens).unwrap()])
        }
        "s_pre" => {
            let h = inputs[0].shape()[1];
            let block =
                golden_block(h, 1, Some(&inputs[1]), Some(&inputs[2]), None, None, None);
            let sw = golden_worker(golden_spec(h, 1, 1), vec![block], None, None);
            Some(vec![sw.s_pre(0, &inputs[0]).unwrap()])
        }
        "s_post" => {
            let h = inputs[0].shape()[1];
            let f = inputs[4].shape()[1];
            let block = golden_block(
                h,
                f,
                None,
                None,
                Some(&inputs[2]),
                Some(&inputs[3]),
                Some((&inputs[4], &inputs[5], &inputs[6])),
            );
            let sw = golden_worker(golden_spec(h, f, 1), vec![block], None, None);
            Some(vec![sw.s_post(0, &inputs[0], &inputs[1]).unwrap()])
        }
        "logits" => {
            let h = inputs[0].shape()[1];
            let vocab = inputs[2].shape()[0];
            let sw = golden_worker(
                golden_spec(h, 1, vocab),
                vec![],
                Some(&inputs[2]),
                Some(&inputs[1]),
            );
            Some(vec![sw.logits(&inputs[0]).unwrap()])
        }
        k if k.starts_with("fused_s") => {
            let (b, h) = (inputs[0].shape()[0], inputs[0].shape()[1]);
            let cache_shape = inputs[1].shape();
            let (heads, smax) = (cache_shape[1], cache_shape[2]);
            let f = inputs[8].shape()[1];
            let dims = ops::FusedDims {
                batch: b,
                hidden: h,
                n_heads: heads,
                smax,
                ffn: f,
            };
            let (y, kn, vn) = ops::fused_block_step(
                inputs[0].as_f32().unwrap(),
                inputs[1].as_f32().unwrap(),
                inputs[2].as_f32().unwrap(),
                inputs[3].as_i32().unwrap(),
                inputs[4].as_f32().unwrap(),
                inputs[5].as_f32().unwrap(),
                inputs[6].as_f32().unwrap(),
                inputs[7].as_f32().unwrap(),
                inputs[8].as_f32().unwrap(),
                inputs[9].as_f32().unwrap(),
                inputs[10].as_f32().unwrap(),
                dims,
            );
            let d = h / heads;
            Some(vec![
                Tensor::f32(&[b, h], y),
                Tensor::f32(&[b, heads, d], kn),
                Tensor::f32(&[b, heads, d], vn),
            ])
        }
        _ => None,
    }
}

fn check_artifact(m: &Manifest, name: &str, tol: f32) {
    let (ins, outs) = m.goldens_for(name);
    if ins.is_empty() || outs.is_empty() {
        eprintln!("skipping {name}: no goldens exported");
        return;
    }
    let inputs: Vec<Tensor> = ins.iter().map(|g| load_tensor(g)).collect();
    let results = match run_native(name, &inputs) {
        Some(r) => r,
        None => {
            eprintln!("skipping {name}: no native executor for this kind");
            return;
        }
    };
    assert_eq!(results.len(), outs.len(), "{name}: output arity");
    for (i, (got, want_g)) in results.iter().zip(&outs).enumerate() {
        let want = load_tensor(want_g);
        match &got {
            Tensor::I32 { .. } => {
                assert_eq!(
                    got.as_i32().unwrap(),
                    want.as_i32().unwrap(),
                    "{name} out{i}"
                );
            }
            _ => {
                let diff = got.max_abs_diff(&want).unwrap();
                assert!(diff <= tol, "{name} out{i}: max abs diff {diff} > {tol}");
            }
        }
    }
}

#[test]
fn all_simple_graphs_match_golden() {
    let Some(m) = manifest() else { return };
    for b in [1, 8] {
        for suffix in ["embed", "s_pre", "s_post", "logits"] {
            check_artifact(&m, &format!("tiny_b{b}_{suffix}"), 1e-4);
        }
    }
}

/// The fused decode step pins the whole-block composition (including the
/// attention semantics the Pallas kernel implements on the Python side).
#[test]
fn fused_graphs_match_golden() {
    let Some(m) = manifest() else { return };
    for b in [1, 8] {
        check_artifact(&m, &format!("tiny_b{b}_fused_s128"), 5e-4);
    }
}

#[test]
fn manifest_lists_well_formed_artifacts() {
    let Some(m) = manifest() else { return };
    assert!(!m.artifacts.is_empty());
    for a in m.artifacts.values() {
        assert!(a.path.exists(), "missing artifact file {:?}", a.path);
        assert!(!a.inputs.is_empty());
        assert!(!a.outputs.is_empty());
    }
    for g in &m.goldens {
        assert!(g.path.exists(), "missing golden file {:?}", g.path);
    }
}

/// The manifest format itself stays exercised without artifacts on disk.
#[test]
fn manifest_format_roundtrip() {
    let sample = "\
artifact;tiny_b1_s_pre;tiny_b1_s_pre.hlo.txt;in=a0:f32:1x64,a1:f32:64,a2:f32:64x192;out=o0:f32:1x192
golden;tiny_b1_s_pre;in;0;f32;1x64;golden/tiny_b1_s_pre.in0.bin
";
    let m = Manifest::parse(sample, Path::new("/art")).unwrap();
    assert_eq!(m.artifacts.len(), 1);
    assert_eq!(m.goldens.len(), 1);
    assert_eq!(m.get("tiny_b1_s_pre").unwrap().inputs.len(), 3);
}

#[test]
fn artifact_kind_parses_robustly() {
    assert_eq!(artifact_kind("tiny_b8_s_pre"), Some("s_pre"));
    assert_eq!(artifact_kind("tiny_b1_fused_s128"), Some("fused_s128"));
    // a model name containing "_b" must not shift the split point
    assert_eq!(artifact_kind("llama_base_b8_embed"), Some("embed"));
    assert_eq!(artifact_kind("noseparator"), None);
    assert_eq!(artifact_kind("tiny_bx_embed"), None);
}
