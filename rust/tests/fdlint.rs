//! The fdlint gate as a tier-1 test: `cargo test` fails on any
//! above-baseline violation of the project invariants, exactly as the
//! `fdlint` binary does in CI. This is what makes the codec-exhaustive
//! check (and every other rule) part of the build: deleting a codec
//! decode arm turns this test — and therefore the build — red.

use std::path::Path;

use fastdecode::analysis::{
    analyze, baseline_of, collect_sources, compare, parse_baseline,
};

#[test]
fn sources_have_no_above_baseline_violations() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let files = collect_sources(&manifest.join("src"))
        .expect("collecting rust/src sources");
    assert!(files.len() > 50, "source walk found only {}", files.len());
    let analysis = analyze(&files);
    let baseline_text = std::fs::read_to_string(manifest.join("fdlint.baseline"))
        .expect("reading fdlint.baseline");
    let grandfathered =
        parse_baseline(&baseline_text).expect("parsing fdlint.baseline");
    let failures = compare(
        &baseline_of(&analysis.violations),
        &grandfathered,
        &analysis.violations,
    );
    assert!(
        failures.is_empty(),
        "fdlint gate failed:\n{}",
        failures.join("\n")
    );
}
