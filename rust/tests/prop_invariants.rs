//! Cross-module property tests on coordinator invariants (routing,
//! batching, state) — the offline stand-in for a proptest suite, built
//! on util::prop's seeded generators.

use fastdecode::kvcache::{SeqKv, SocketCache};
use fastdecode::metrics::Histogram;
use fastdecode::model::{Precision, TINY};
use fastdecode::rworker::{RPool, RPoolConfig, SeqTask};
use fastdecode::sched::{LoadControl, SlsSchedule};
use fastdecode::util::prop;

/// Routing: for ANY add/drop interleaving, every live sequence is placed
/// on exactly one socket and socket loads stay balanced within one
/// round-robin turn.
#[test]
fn prop_pool_placement_balanced_under_churn() {
    prop::check("pool-placement", 20, |g| {
        let sockets = g.usize_in(1, 5);
        let mut pool = RPool::spawn(
            &TINY,
            RPoolConfig {
                sockets,
                capacity_per_seq: 8,
                precision: Precision::F16,
                ..Default::default()
            },
        );
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..6 {
            if g.bool() || live.is_empty() {
                let n = g.usize_in(1, 6);
                let ids: Vec<u64> = (0..n).map(|i| next_id + i as u64).collect();
                next_id += n as u64;
                pool.add_seqs(&ids).unwrap();
                live.extend(&ids);
            } else {
                let k = g.usize_in(1, live.len() + 1).min(live.len());
                let dropped: Vec<u64> = live.drain(..k).collect();
                pool.drop_seqs(&dropped).unwrap();
                for id in &dropped {
                    assert_eq!(pool.socket_of(*id), None);
                }
            }
        }
        for id in &live {
            let s = pool.socket_of(*id).expect("live sequence unplaced");
            assert!(s < sockets);
        }
        let stats = pool.stats().unwrap();
        let total: usize = stats.iter().map(|s| s.sequences).sum();
        assert_eq!(total, live.len(), "socket caches out of sync");
    });
}

/// State: attention outputs are independent of HOW sequences were
/// batched into attend() calls (one big batch vs arbitrary splits).
#[test]
fn prop_attend_batch_split_invariant() {
    prop::check("attend-split", 10, |g| {
        let n = TINY.hidden;
        let ids: Vec<u64> = (0..6).collect();
        let mk_tasks = |g: &mut prop::Gen| -> Vec<SeqTask> {
            ids.iter()
                .map(|&i| SeqTask {
                    seq_id: i,
                    q: g.vec_normal(n, 0.5),
                    k_new: g.vec_normal(n, 0.5),
                    v_new: g.vec_normal(n, 0.5),
                })
                .collect()
        };
        let tasks = mk_tasks(g);
        let clone_tasks = |ts: &[SeqTask]| -> Vec<SeqTask> {
            ts.iter()
                .map(|t| SeqTask {
                    seq_id: t.seq_id,
                    q: t.q.clone(),
                    k_new: t.k_new.clone(),
                    v_new: t.v_new.clone(),
                })
                .collect()
        };
        let split_at = g.usize_in(1, ids.len());

        let run = |split: Option<usize>, tasks: Vec<SeqTask>| {
            let mut pool = RPool::spawn(
                &TINY,
                RPoolConfig {
                    sockets: 2,
                    capacity_per_seq: 4,
                    precision: Precision::F32,
                    ..Default::default()
                },
            );
            pool.add_seqs(&ids).unwrap();
            match split {
                None => pool.attend(0, tasks).unwrap().outputs,
                Some(k) => {
                    let mut rest = tasks;
                    let tail = rest.split_off(k);
                    let mut out = pool.attend(0, rest).unwrap().outputs;
                    out.extend(pool.attend(0, tail).unwrap().outputs);
                    out
                }
            }
        };
        let whole = run(None, clone_tasks(&tasks));
        let split = run(Some(split_at), tasks);
        for id in &ids {
            assert_eq!(whole[id], split[id], "seq {id} differs across splits");
        }
    });
}

/// Batching: Algorithm 1's admitted schedule reproduces the closed-form
/// SLS steady load (eq. 6) when fed the SLS micro-batches.
#[test]
fn prop_loadctl_reproduces_sls_load() {
    prop::check("loadctl-vs-sls", 30, |g| {
        let seq = g.usize_in(8, 64);
        let interval = g.usize_in(1, seq / 2 + 1);
        let m = g.usize_in(1, 8);
        let sls = SlsSchedule::new(
            m * seq.div_ceil(interval),
            seq,
            interval,
        );
        assert!(sls.micro_batch_size() >= 1); // eq. 5 clamp contract
        let mut lc = LoadControl::new();
        let horizon = 3 * seq;
        let mut j = 0;
        while j * interval < horizon {
            lc.add(j * interval, m, seq);
            j += 1;
        }
        // LoadControl's exact accounting == a hand-rolled sum with the
        // same per-micro-batch size m
        for step in 0..horizon {
            let mut want = 0usize;
            let mut jj = 0usize;
            while jj * interval <= step {
                let age = step - jj * interval + 1;
                if age <= seq {
                    want += m * age;
                }
                jj += 1;
            }
            assert_eq!(lc.load_at(step), want, "step {step}");
        }
    });
}

/// KV state: any sequence of appends decodes back within precision
/// tolerance AND total_tokens accounting is exact across layers.
#[test]
fn prop_socket_cache_accounting() {
    prop::check("cache-accounting", 25, |g| {
        let layers = g.usize_in(1, 4);
        let block = g.usize_in(1, 6);
        let mut sc = SocketCache::new(2, 4, layers, 16, block, Precision::F16);
        let mut expect = 0usize;
        for id in 0..g.usize_in(1, 5) as u64 {
            sc.add_seq(id);
            let tokens = g.usize_in(0, 10);
            for _ in 0..tokens {
                for layer in 0..layers {
                    let k = g.vec_normal(8, 1.0);
                    let v = g.vec_normal(8, 1.0);
                    sc.append(id, layer, &k, &v).unwrap();
                    expect += 1;
                }
            }
        }
        assert_eq!(sc.stats().total_tokens, expect);
        // without forks, the paged store holds exactly the logical
        // tokens and never less storage than it reports logically
        let st = sc.stats();
        assert_eq!(st.physical_tokens, expect);
        assert!(st.allocated_bytes >= st.logical_bytes);
    });
}

/// SeqKv never reports more tokens than capacity, and is_full is exact.
#[test]
fn prop_seqkv_capacity_exact() {
    prop::check("seqkv-capacity", 25, |g| {
        let cap = g.usize_in(1, 12);
        let mut kv = SeqKv::new(1, 2, cap, Precision::F32);
        for i in 0..cap {
            assert!(!kv.is_full(), "full too early at {i}");
            kv.append(&[1.0, 2.0], &[3.0, 4.0]);
            assert_eq!(kv.len, i + 1);
        }
        assert!(kv.is_full());
    });
}

/// Histogram percentiles are order-consistent and bounded by min/max
/// for arbitrary inputs.
#[test]
fn prop_histogram_percentiles_monotone() {
    prop::check("hist-monotone", 40, |g| {
        let mut h = Histogram::new();
        let n = g.usize_in(1, 500);
        for _ in 0..n {
            h.record_us(g.f32_in(0.5, 5e6) as f64);
        }
        let qs = [0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 1.0];
        let mut prev = 0.0;
        for q in qs {
            let v = h.percentile_us(q);
            assert!(v >= prev, "percentile not monotone at q={q}");
            assert!(v >= h.min_us() && v <= h.max_us());
            prev = v;
        }
    });
}

/// Paged KV (tentpole): for ANY interleaving of append / COW-fork /
/// drop — at every block size (odd sizes exercise int4's packed tails)
/// and every precision — the paged `SocketCache` decodes back EXACTLY
/// what a contiguous per-sequence `SeqKv` shadow holds. Block payloads
/// reuse `SeqKv`'s quantization path, so equality is exact even for
/// int8/int4; forked children diverge immediately so copy-on-write is
/// exercised and must never leak a child's writes into its parent.
#[test]
fn prop_paged_cache_matches_contiguous_shadow() {
    use std::collections::HashMap;
    prop::check("paged-vs-contiguous", 30, |g| {
        let precs = [
            Precision::F32,
            Precision::F16,
            Precision::Int8,
            Precision::Int4,
        ];
        let prec = precs[g.usize_in(0, precs.len())];
        let (heads, d) = (2usize, 4usize); // even d: int4 packs 2/byte
        let layers = g.usize_in(1, 3);
        let cap = 24usize;
        let block = g.usize_in(1, 6);
        let mut sc = SocketCache::new(heads, d, layers, cap, block, prec);
        let mut shadow: HashMap<u64, Vec<SeqKv>> = HashMap::new();
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..14 {
            let op = if live.is_empty() { 0 } else { g.usize_in(0, 4) };
            match op {
                // add a fresh empty sequence
                0 => {
                    let id = next_id;
                    next_id += 1;
                    sc.add_seq(id);
                    shadow.insert(
                        id,
                        (0..layers)
                            .map(|_| SeqKv::new(heads, d, cap, prec))
                            .collect(),
                    );
                    live.push(id);
                }
                // append a ragged burst to one sequence, all layers
                1 => {
                    let id = live[g.usize_in(0, live.len())];
                    let have = sc.seq_len(id, 0).unwrap();
                    let n = g.usize_in(0, (cap - have).min(4) + 1);
                    for _ in 0..n {
                        for layer in 0..layers {
                            let k = g.vec_normal(heads * d, 1.0);
                            let v = g.vec_normal(heads * d, 1.0);
                            sc.append(id, layer, &k, &v).unwrap();
                            shadow.get_mut(&id).unwrap()[layer]
                                .append(&k, &v);
                        }
                    }
                }
                // COW-fork a child at a random (often mid-block) point,
                // then diverge it right away
                2 => {
                    let parent = live[g.usize_in(0, live.len())];
                    let plen = sc.seq_len(parent, 0).unwrap();
                    let upto = g.usize_in(0, plen + 1);
                    let child = next_id;
                    next_id += 1;
                    sc.fork_seq(parent, child, upto).unwrap();
                    let forked: Vec<SeqKv> = shadow[&parent]
                        .iter()
                        .map(|kv| {
                            let mut c = kv.clone();
                            c.len = upto;
                            c
                        })
                        .collect();
                    shadow.insert(child, forked);
                    live.push(child);
                    if upto < cap {
                        for layer in 0..layers {
                            let k = g.vec_normal(heads * d, 1.0);
                            let v = g.vec_normal(heads * d, 1.0);
                            sc.append(child, layer, &k, &v).unwrap();
                            shadow.get_mut(&child).unwrap()[layer]
                                .append(&k, &v);
                        }
                    }
                }
                // drop one sequence (parents may die before children:
                // refcounts must keep shared blocks alive)
                _ => {
                    let i = g.usize_in(0, live.len());
                    let id = live.swap_remove(i);
                    assert!(sc.drop_seq(id));
                    shadow.remove(&id);
                }
            }
            // full cross-check after EVERY op
            for &id in &live {
                for layer in 0..layers {
                    let len = sc.seq_len(id, layer).unwrap();
                    let sh = &shadow[&id][layer];
                    assert_eq!(len, sh.len, "seq {id} layer {layer} len");
                    let view = sc.get(id, layer).unwrap();
                    let mut a = vec![0.0f32; d];
                    let mut b = vec![0.0f32; d];
                    for head in 0..heads {
                        for t in 0..len {
                            view.decode_k(head, t, &mut a);
                            sh.decode_k(head, t, &mut b);
                            assert_eq!(
                                a, b,
                                "seq {id} layer {layer} head {head} t {t}"
                            );
                        }
                    }
                }
            }
        }
        assert_eq!(sc.stats().sequences, live.len());
    });
}
