//! Acceptance suite for CROSS-PROCESS tracing (ISSUE 9): remote rnode
//! span capture, RTT-ping clock alignment, and the merged Chrome trace.
//!
//! Pins:
//! 1. a 2-rnode TCP run with tracing enabled exports ONE Chrome trace
//!    where each node's server-side spans (queue_wait / decode / attend
//!    / kv_append / encode) appear on that node's own track,
//!    clock-aligned with the client-side submit→reply spans, and the
//!    per-node profiles carry measured throughput;
//! 2. killing a node mid-`FetchTrace` routes an error NAMING the node,
//!    and the survivors' partial traces still merge into a valid trace;
//! 3. (property) the min-RTT-midpoint clock-offset estimator recovers
//!    the true offset within ±min_rtt/2 under randomized asymmetric
//!    per-leg delays;
//! 4. (property) remapped remote spans never have negative durations
//!    and never extend past the enclosing client-side window.

use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::model::{Precision, TINY};
use fastdecode::net::{
    spawn_rnode_process, NodeConfig, RemotePool, RnodeProcess, WireMode,
};
use fastdecode::obs::{
    map_remote_span, pick_clock_sync, validate_chrome_trace_file, Tracer,
};
use fastdecode::rworker::{AttendBackend, SeqTask};
use fastdecode::util::json::Json;
use fastdecode::util::{prop, Rng};

const CAP: usize = 64;

fn engine_cfg(batch: usize) -> FastDecodeConfig {
    FastDecodeConfig {
        batch,
        sockets: 2,
        precision: Precision::F16,
        capacity_per_seq: CAP,
        layers: 2,
        ..Default::default()
    }
}

fn node_cfg(wire: WireMode) -> NodeConfig {
    NodeConfig::from_spec(&TINY, CAP, 8, Precision::F16, wire)
        .with_trace(true)
}

fn spawn_rnode() -> RnodeProcess {
    spawn_rnode_process(env!("CARGO_BIN_EXE_rnode"))
        .expect("spawning the rnode binary")
}

/// `tid → track name` from the trace's thread_name metadata events.
fn track_names(doc: &Json) -> Vec<(f64, String)> {
    doc.get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents")
        .iter()
        .filter(|e| {
            e.get("name").and_then(Json::as_str) == Some("thread_name")
        })
        .map(|e| {
            (
                e.get("tid").and_then(Json::as_f64).expect("tid"),
                e.get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(Json::as_str)
                    .expect("track name")
                    .to_string(),
            )
        })
        .collect()
}

/// `(name, ts, dur)` of every complete span on one track.
fn spans_on(doc: &Json, tid: f64) -> Vec<(String, f64, f64)> {
    doc.get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents")
        .iter()
        .filter(|e| {
            e.get("ph").and_then(Json::as_str) == Some("X")
                && e.get("tid").and_then(Json::as_f64) == Some(tid)
        })
        .map(|e| {
            (
                e.get("name").and_then(Json::as_str).unwrap().to_string(),
                e.get("ts").and_then(Json::as_f64).unwrap(),
                e.get("dur").and_then(Json::as_f64).unwrap(),
            )
        })
        .collect()
}

/// Pin 1: the full flow over real TCP — two traced rnode processes, a
/// generating engine, fetch + clock-align + merge, one valid Chrome
/// trace with one track per node, remote attend spans landing inside
/// the window of the client-side submit→reply spans.
#[test]
fn two_traced_rnodes_merge_into_one_aligned_timeline() {
    let nodes = [spawn_rnode(), spawn_rnode()];
    let addrs: Vec<String> = nodes.iter().map(|n| n.addr.clone()).collect();
    let pool = RemotePool::connect_tcp(&addrs, node_cfg(WireMode::F16))
        .expect("connecting to rnodes");
    let mut fd = FastDecode::with_backend_traced(
        TINY,
        engine_cfg(4),
        Box::new(pool),
        Tracer::enabled(),
    )
    .expect("engine over tcp");
    let prompts = fastdecode::workload::fixed_batch(4, 2, TINY.vocab, 17);
    fd.generate(&prompts, 8).expect("traced generate");

    let merged = fd.merge_remote_traces().expect("fetching remote traces");
    assert!(merged > 0, "no remote spans merged");
    // the run's measured per-node profiles carry throughput
    for st in fd.net_stats() {
        assert!(st.profile.samples() > 0, "{}: no samples", st.label);
        assert!(st.profile.tokens_per_s > 0.0);
        assert!(st.profile.bytes_per_s > 0.0);
    }

    let path = std::env::temp_dir()
        .join(format!("fd_net_trace_{}.json", std::process::id()));
    fd.tracer().write_chrome_trace(&path).expect("writing trace");
    // 2 merged node tracks + at least the 2 client-side r-node tracks
    validate_chrome_trace_file(&path, 4).expect("trace validates");

    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let tracks = track_names(&doc);
    for i in 0..2 {
        let remote_tid = tracks
            .iter()
            .find(|(_, n)| n == &format!("rnode{i}"))
            .unwrap_or_else(|| panic!("no rnode{i} track"))
            .0;
        let client_tid = tracks
            .iter()
            .find(|(_, n)| n == &format!("r-node{i}"))
            .unwrap_or_else(|| panic!("no r-node{i} track"))
            .0;
        let remote = spans_on(&doc, remote_tid);
        for want in ["queue_wait", "decode", "attend", "kv_append", "encode"]
        {
            assert!(
                remote.iter().any(|(n, _, _)| n == want),
                "rnode{i}: missing {want} span"
            );
        }
        // clock alignment: every remote attend span must land inside
        // the window covered by this node's client-side submit→reply
        // spans (offset error is bounded by min-RTT/2; allow generous
        // scheduler slack — an epoch mix-up would be off by much more)
        let client = spans_on(&doc, client_tid);
        let lo = client
            .iter()
            .map(|&(_, ts, _)| ts)
            .fold(f64::INFINITY, f64::min);
        let hi = client
            .iter()
            .map(|&(_, ts, dur)| ts + dur)
            .fold(0.0f64, f64::max);
        assert!(lo.is_finite() && hi > lo, "no client spans for node {i}");
        const SLACK_US: f64 = 10_000.0;
        let mut aligned = 0usize;
        for (name, ts, dur) in &remote {
            if name == "attend" {
                assert!(
                    *ts >= lo - SLACK_US && ts + dur <= hi + SLACK_US,
                    "rnode{i} attend [{ts}, {}] outside client window \
                     [{lo}, {hi}]",
                    ts + dur,
                );
                aligned += 1;
            }
        }
        assert!(aligned > 0, "rnode{i}: no attend spans");
    }
    std::fs::remove_file(&path).ok();
}

/// Pin 2: a node killed before `FetchTrace` yields a routed error that
/// names it, while the survivor's spans still merge — the partial
/// trace stays a valid Chrome trace with the survivor's track.
#[test]
fn killed_node_mid_fetch_names_node_and_survivors_merge() {
    let mut victim = spawn_rnode();
    let survivor = spawn_rnode();
    let addrs = vec![victim.addr.clone(), survivor.addr.clone()];
    let mut pool = RemotePool::connect_tcp(&addrs, node_cfg(WireMode::F16))
        .expect("connecting to rnodes");
    let tracer = Tracer::enabled();
    pool.install_tracer(tracer.clone());
    // 1 → node 0 (victim); 2 → node 1 (survivor)
    pool.add_seqs(&[1, 2]).unwrap();
    let mut rng = Rng::new(9);
    let mk = |rng: &mut Rng, id: u64| SeqTask {
        seq_id: id,
        q: rng.normal_vec(TINY.hidden, 1.0),
        k_new: rng.normal_vec(TINY.hidden, 1.0),
        v_new: rng.normal_vec(TINY.hidden, 1.0),
    };
    let tasks = vec![mk(&mut rng, 1), mk(&mut rng, 2)];
    assert_eq!(pool.attend(0, tasks).unwrap().outputs.len(), 2);

    victim.child.kill().expect("killing rnode");
    victim.child.wait().expect("reaping rnode");

    let err = pool.merge_remote_traces().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("node 0"), "error does not name the node: {msg}");

    // the survivor's spans landed despite the failure
    let doc = Json::parse(&tracer.chrome_trace().render()).unwrap();
    let tracks = track_names(&doc);
    let tid = tracks
        .iter()
        .find(|(_, n)| n == "rnode1")
        .expect("survivor track merged")
        .0;
    let spans = spans_on(&doc, tid);
    assert!(
        spans.iter().any(|(n, _, _)| n == "attend"),
        "survivor trace has no attend span"
    );
    assert!(
        !tracks.iter().any(|(_, n)| n == "rnode0"),
        "dead node must not contribute a merged track"
    );
    // and the partial trace is still a valid artifact
    let path = std::env::temp_dir()
        .join(format!("fd_net_trace_partial_{}.json", std::process::id()));
    tracer.write_chrome_trace(&path).unwrap();
    validate_chrome_trace_file(&path, 3).expect("partial trace validates");
    std::fs::remove_file(&path).ok();
}

/// Pin 3: under randomized asymmetric per-leg delays, the min-RTT
/// midpoint estimate is off by exactly (back − out)/2 of the winning
/// sample, so the error stays within ±min_rtt/2 of the true offset.
#[test]
fn prop_clock_offset_recovers_within_min_rtt_bound() {
    prop::check("clock-offset-min-rtt", 200, |g| {
        // true node→local clock offset, µs (either sign, up to ~0.5 s)
        let true_offset = g.f32_in(-5e5, 5e5) as f64;
        let n = g.usize_in(1, 12);
        let mut samples = Vec::with_capacity(n);
        let mut now = g.f32_in(0.0, 1e3) as f64;
        let mut min_rtt = f64::INFINITY;
        for _ in 0..n {
            let out = g.f32_in(1.0, 500.0) as f64;
            let back = g.f32_in(1.0, 500.0) as f64;
            // the node stamps its reply out µs after our send; its
            // clock reads local − offset
            let node_us = now + out - true_offset;
            samples.push((now, node_us, now + out + back));
            min_rtt = min_rtt.min(out + back);
            now += out + back + g.f32_in(1.0, 100.0) as f64;
        }
        let (mid_us, node_us, rtt) =
            pick_clock_sync(&samples).expect("burst has samples");
        assert!(
            (rtt - min_rtt).abs() < 1e-6,
            "did not pick the min-RTT sample: {rtt} vs {min_rtt}"
        );
        let est = mid_us - node_us;
        assert!(
            (est - true_offset).abs() <= rtt / 2.0 + 1e-6,
            "estimate {est} off true {true_offset} by more than \
             min_rtt/2 = {}",
            rtt / 2.0
        );
    });
}

/// Degenerate bursts are rejected, not mis-picked.
#[test]
fn clock_sync_rejects_unusable_samples() {
    assert_eq!(pick_clock_sync(&[]), None);
    // recv before send (clock misuse) and non-finite RTTs are skipped
    assert_eq!(pick_clock_sync(&[(10.0, 0.0, 5.0)]), None);
    assert_eq!(pick_clock_sync(&[(0.0, 0.0, f64::NAN)]), None);
    let ok = pick_clock_sync(&[(10.0, 0.0, 5.0), (10.0, 7.0, 14.0)]);
    assert_eq!(ok, Some((12.0, 7.0, 4.0)));
}

/// Pin 4: whatever the remote timestamps, durations and offset estimate
/// are, the remap never produces a negative duration and never lets a
/// span escape the enclosing client-side window.
#[test]
fn prop_remapped_spans_stay_inside_the_window() {
    prop::check("remote-span-window", 300, |g| {
        let lo = g.f32_in(0.0, 1e3) as f64;
        let hi = lo + g.f32_in(0.0, 1e6) as f64;
        let ts = g.f32_in(-1e6, 2e6) as f64;
        let dur = g.f32_in(-1e3, 1e6) as f64;
        let off = g.f32_in(-1e6, 1e6) as f64;
        let (s, d) = map_remote_span(ts, dur, off, (lo, hi));
        assert!(d >= 0.0, "negative duration {d}");
        assert!(
            s >= lo && s + d <= hi,
            "span [{s}, {}] escapes window [{lo}, {hi}]",
            s + d
        );
    });
}

/// The same invariant holds through `Tracer::merge_remote` with a
/// hostile offset: every merged span stays inside [0, now].
#[test]
fn merge_remote_clamps_hostile_offsets() {
    let remote = Tracer::enabled();
    let rt = remote.track("rnode");
    {
        let _s = rt.span("attend");
    }
    let spans = remote.drain_remote_spans();
    let local = Tracer::enabled();
    assert_eq!(local.merge_remote("rnode0", spans, 1e12), 1);
    let doc = Json::parse(&local.chrome_trace().render()).unwrap();
    let tracks = track_names(&doc);
    let tid = tracks.iter().find(|(_, n)| n == "rnode0").unwrap().0;
    for (_, ts, dur) in spans_on(&doc, tid) {
        assert!(ts >= 0.0 && dur >= 0.0);
        // clamped into the local timeline: no span a million seconds out
        assert!(ts + dur < 60e6, "span escaped the [0, now] window: {ts}");
    }
}
