//! End-to-end equivalence of the decomposed FastDecode pipeline.
//!
//! The paper's entire design rests on: s_pre (S-worker) → attention near
//! the KV-cache (R-workers) → s_post (S-worker) being THE SAME FUNCTION
//! as the fused single-device block. We verify it numerically,
//! multi-step, against the fused reference block (`sworker::ops`, the
//! Rust mirror of the exported HLO graph), using identical synthetic
//! weights on both paths. The decomposed side runs the REAL threaded
//! pipeline: S-worker thread + R-socket threads, double-buffered
//! mini-batches, scattered placement — none of which may change a token.

use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::model::{Precision, TINY};
use fastdecode::sworker::{ops, ModelWeights};
use fastdecode::workload::fixed_batch;

/// Mirror of the fused block's padded KV state kept by the test.
struct FusedOracle {
    weights: ModelWeights,
    /// per layer: k/v caches [B, H, S, D] + lengths [B]
    kc: Vec<Vec<f32>>,
    vc: Vec<Vec<f32>>,
    lengths: Vec<i32>,
    batch: usize,
    smax: usize,
}

impl FusedOracle {
    fn new(weights: ModelWeights, batch: usize) -> Self {
        let spec = weights.spec;
        let smax = 128;
        let n = batch * spec.n_heads * smax * spec.head_dim();
        let layers = weights.layers();
        FusedOracle {
            weights,
            kc: vec![vec![0.0; n]; layers],
            vc: vec![vec![0.0; n]; layers],
            lengths: vec![0; batch],
            batch,
            smax,
        }
    }

    /// One decode step through the fused blocks; returns x after all
    /// layers.
    fn step(&mut self, tokens: &[i32]) -> Vec<f32> {
        let spec = self.weights.spec;
        let (b, h) = (self.batch, spec.hidden);
        let (heads, d) = (spec.n_heads, spec.head_dim());

        let mut x = ops::embed_rows(
            tokens,
            self.weights.w_emb.as_f32().unwrap(),
            spec.vocab,
            h,
        );
        let dims = ops::FusedDims {
            batch: b,
            hidden: h,
            n_heads: heads,
            smax: self.smax,
            ffn: spec.ffn,
        };
        for layer in 0..self.weights.layers() {
            let w = &self.weights.blocks[layer];
            let (y, kn, vn) = ops::fused_block_step(
                &x,
                &self.kc[layer],
                &self.vc[layer],
                &self.lengths,
                w.ln1.as_f32().unwrap(),
                w.wqkv.as_f32().unwrap(),
                w.wo.as_f32().unwrap(),
                w.ln2.as_f32().unwrap(),
                w.w_gate.as_f32().unwrap(),
                w.w_up.as_f32().unwrap(),
                w.w_down.as_f32().unwrap(),
                dims,
            );
            // append k/v at each sequence's position
            for i in 0..b {
                let pos = self.lengths[i] as usize;
                for hh in 0..heads {
                    let dst = ((i * heads + hh) * self.smax + pos) * d;
                    let src = i * h + hh * d;
                    self.kc[layer][dst..dst + d]
                        .copy_from_slice(&kn[src..src + d]);
                    self.vc[layer][dst..dst + d]
                        .copy_from_slice(&vn[src..src + d]);
                }
            }
            x = y;
        }
        for l in self.lengths.iter_mut() {
            *l += 1;
        }
        x
    }

    fn next_tokens(&self, x: Vec<f32>) -> Vec<i32> {
        let spec = self.weights.spec;
        let xn = ops::rmsnorm(&x, self.weights.ln_f.as_f32().unwrap(), spec.hidden);
        let logits = ops::tied_logits(
            &xn,
            self.weights.w_emb.as_f32().unwrap(),
            spec.hidden,
            spec.vocab,
        );
        ops::argmax_rows(&logits, spec.vocab)
    }
}

/// Decomposed (FastDecode: threaded pipeline, f32 KV, 3 sockets) ≡ fused
/// reference block for 12 steps of greedy decode.
#[test]
fn decomposed_equals_fused_pipeline() {
    let seed = 0xfa57;
    let batch = 8;
    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch,
            sockets: 3,
            precision: Precision::F32, // exact-comparison mode
            capacity_per_seq: 128,
            weight_seed: seed,
            layers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    fd.start_batch(1).unwrap();
    let weights = ModelWeights::random(TINY, 2, seed);
    let mut oracle = FusedOracle::new(weights, batch);

    let mut tokens: Vec<i32> = (0..batch as i32).map(|i| i * 3 + 1).collect();
    let mut oracle_tokens = tokens.clone();
    for step in 0..12 {
        let got = fd.decode_step(&tokens).unwrap();
        let x = oracle.step(&oracle_tokens);
        let want = oracle.next_tokens(x);
        assert_eq!(got, want, "token divergence at step {step}");
        tokens = got;
        oracle_tokens = want;
    }
}

/// The fp16 KV path tracks the f32 path closely (lossless-in-practice
/// claim of §5.1): same greedy tokens for several steps on the tiny
/// model.
#[test]
fn f16_kv_matches_f32_tokens() {
    let run = |prec| {
        let mut fd = FastDecode::new(
            TINY,
            FastDecodeConfig {
                batch: 8,
                sockets: 2,
                precision: prec,
                capacity_per_seq: 64,
                weight_seed: 7,
                layers: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let prompts = fixed_batch(8, 4, TINY.vocab, 99);
        fd.generate(&prompts, 8).unwrap().tokens
    };
    let f32_toks = run(Precision::F32);
    let f16_toks = run(Precision::F16);
    // fp16 rounding may flip a near-tie occasionally; require ≥90 % match
    let total: usize = f32_toks.iter().map(|s| s.len()).sum();
    let same: usize = f32_toks
        .iter()
        .zip(&f16_toks)
        .map(|(a, b)| a.iter().zip(b).filter(|(x, y)| x == y).count())
        .sum();
    assert!(
        same * 10 >= total * 9,
        "only {same}/{total} tokens match between f16 and f32 KV"
    );
}

/// Neither the socket count nor the pipeline overlap may change results
/// at all (placement + double-buffering invariance: every transform is
/// per-sequence).
#[test]
fn results_invariant_to_sockets_and_pipelining() {
    let run = |sockets, pipelined| {
        let mut fd = FastDecode::new(
            TINY,
            FastDecodeConfig {
                batch: 8,
                sockets,
                precision: Precision::F32,
                capacity_per_seq: 64,
                weight_seed: 11,
                layers: 2,
                pipelined,
                ..Default::default()
            },
        )
        .unwrap();
        let prompts = fixed_batch(8, 3, TINY.vocab, 5);
        fd.generate(&prompts, 10).unwrap().tokens
    };
    let base = run(1, true);
    assert_eq!(base, run(4, true));
    assert_eq!(base, run(4, false));
    assert_eq!(base, run(1, false));
}

/// Cache accounting: after generate, every socket holds prompt+steps
/// tokens per sequence per layer.
#[test]
fn cache_token_accounting() {
    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch: 8,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 64,
            weight_seed: 1,
            layers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let prompts = fixed_batch(8, 4, TINY.vocab, 1);
    fd.generate(&prompts, 6).unwrap();
    // Each decode step appends one token's K/V: 3 prefill steps (the
    // last prompt token is consumed by the first generation step) + 6
    // generation steps = 9 per sequence per layer. The newest token's
    // K/V lands on the NEXT step, so it is not yet cached.
    assert_eq!(fd.cache_tokens().unwrap(), 9 * 8 * 2);
}
