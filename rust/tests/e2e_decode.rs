//! End-to-end equivalence of the decomposed FastDecode pipeline.
//!
//! The paper's entire design rests on: s_pre (GPU) → attention near the
//! KV-cache (CPU) → s_post (GPU) being THE SAME FUNCTION as the fused
//! single-device block. We verify it numerically, multi-step, against
//! the fused HLO graph (which embeds the Pallas attention kernel), using
//! identical Rust-generated weights on both paths.

use std::sync::Arc;

use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::model::{Precision, TINY};
use fastdecode::runtime::{Engine, Tensor};
use fastdecode::sworker::ModelWeights;
use fastdecode::workload::fixed_batch;

fn engine() -> Arc<Engine> {
    Arc::new(Engine::load(fastdecode::artifacts_dir()).expect(
        "artifacts missing — run `make artifacts` before `cargo test`",
    ))
}

/// Mirror of the fused graph's KV state kept by the test.
struct FusedOracle {
    engine: Arc<Engine>,
    weights: ModelWeights,
    /// per layer: k/v caches [B, H, S, D] + lengths [B]
    kc: Vec<Vec<f32>>,
    vc: Vec<Vec<f32>>,
    lengths: Vec<i32>,
    batch: usize,
    smax: usize,
}

impl FusedOracle {
    fn new(engine: Arc<Engine>, weights: ModelWeights, batch: usize) -> Self {
        let spec = weights.spec;
        let smax = 128;
        let n = batch * spec.n_heads * smax * spec.head_dim();
        let layers = weights.layers();
        FusedOracle {
            engine,
            weights,
            kc: vec![vec![0.0; n]; layers],
            vc: vec![vec![0.0; n]; layers],
            lengths: vec![0; batch],
            batch,
            smax,
        }
    }

    /// One decode step through the fused graphs; returns x after all layers.
    fn step(&mut self, tokens: &[i32]) -> Vec<f32> {
        let spec = self.weights.spec;
        let (b, h_dim) = (self.batch, spec.hidden);
        let (heads, d) = (spec.n_heads, spec.head_dim());
        let name = format!("{}_b{}_fused_s{}", spec.name, b, self.smax);

        // embed
        let mut x = self
            .engine
            .run(
                &format!("{}_b{}_embed", spec.name, b),
                &[
                    Tensor::i32(&[b], tokens.to_vec()),
                    self.weights.w_emb.clone(),
                ],
            )
            .unwrap()
            .remove(0);

        for layer in 0..self.weights.layers() {
            let w = &self.weights.blocks[layer];
            let cache_shape = [b, heads, self.smax, d];
            let outs = self
                .engine
                .run(
                    &name,
                    &[
                        x.clone(),
                        Tensor::f32(&cache_shape, self.kc[layer].clone()),
                        Tensor::f32(&cache_shape, self.vc[layer].clone()),
                        Tensor::i32(&[b], self.lengths.clone()),
                        w.ln1.clone(),
                        w.wqkv.clone(),
                        w.wo.clone(),
                        w.ln2.clone(),
                        w.w_gate.clone(),
                        w.w_up.clone(),
                        w.w_down.clone(),
                    ],
                )
                .unwrap();
            let (y, k_new, v_new) = (&outs[0], &outs[1], &outs[2]);
            // append k/v at each sequence's position
            let kn = k_new.as_f32().unwrap();
            let vn = v_new.as_f32().unwrap();
            for i in 0..b {
                let pos = self.lengths[i] as usize;
                for hh in 0..heads {
                    let dst =
                        ((i * heads + hh) * self.smax + pos) * d;
                    let src = (i * heads + hh) * d;
                    self.kc[layer][dst..dst + d]
                        .copy_from_slice(&kn[src..src + d]);
                    self.vc[layer][dst..dst + d]
                        .copy_from_slice(&vn[src..src + d]);
                }
            }
            x = y.clone();
        }
        for l in self.lengths.iter_mut() {
            *l += 1;
        }
        let _ = h_dim;
        x.into_f32().unwrap()
    }

    fn next_tokens(&self, x: Vec<f32>) -> Vec<i32> {
        let spec = self.weights.spec;
        let logits = self
            .engine
            .run(
                &format!("{}_b{}_logits", spec.name, self.batch),
                &[
                    Tensor::f32(&[self.batch, spec.hidden], x),
                    self.weights.ln_f.clone(),
                    self.weights.w_emb.clone(),
                ],
            )
            .unwrap()
            .remove(0);
        logits
            .as_f32()
            .unwrap()
            .chunks_exact(spec.vocab)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap()
            })
            .collect()
    }
}

/// Decomposed (FastDecode, f32 KV) ≡ fused (HLO + Pallas) for 12 steps.
#[test]
fn decomposed_equals_fused_pipeline() {
    let e = engine();
    let seed = 0xfa57;
    let batch = 8;
    let mut fd = FastDecode::new(
        e.clone(),
        TINY,
        FastDecodeConfig {
            batch,
            sockets: 3,
            precision: Precision::F32, // exact-comparison mode
            capacity_per_seq: 128,
            weight_seed: seed,
            layers: 2,
        },
    )
    .unwrap();
    fd.start_batch(1);
    let weights = ModelWeights::random(TINY, 2, seed);
    let mut oracle = FusedOracle::new(e, weights, batch);

    let mut tokens: Vec<i32> = (0..batch as i32).map(|i| i * 3 + 1).collect();
    let mut oracle_tokens = tokens.clone();
    for step in 0..12 {
        let got = fd.decode_step(&tokens).unwrap();
        let x = oracle.step(&oracle_tokens);
        let want = oracle.next_tokens(x);
        assert_eq!(got, want, "token divergence at step {step}");
        tokens = got;
        oracle_tokens = want;
    }
}

/// The fp16 KV path tracks the f32 path closely (lossless-in-practice
/// claim of §5.1): same greedy tokens for several steps on the tiny
/// model.
#[test]
fn f16_kv_matches_f32_tokens() {
    let e = engine();
    let run = |prec| {
        let mut fd = FastDecode::new(
            e.clone(),
            TINY,
            FastDecodeConfig {
                batch: 8,
                sockets: 2,
                precision: prec,
                capacity_per_seq: 64,
                weight_seed: 7,
                layers: 2,
            },
        )
        .unwrap();
        let prompts = fixed_batch(8, 4, TINY.vocab, 99);
        fd.generate(&prompts, 8).unwrap().tokens
    };
    let f32_toks = run(Precision::F32);
    let f16_toks = run(Precision::F16);
    // fp16 rounding may flip a near-tie occasionally; require ≥90 % match
    let total: usize = f32_toks.iter().map(|s| s.len()).sum();
    let same: usize = f32_toks
        .iter()
        .zip(&f16_toks)
        .map(|(a, b)| a.iter().zip(b).filter(|(x, y)| x == y).count())
        .sum();
    assert!(
        same * 10 >= total * 9,
        "only {same}/{total} tokens match between f16 and f32 KV"
    );
}

/// Socket count must not change results at all (placement invariance).
#[test]
fn results_invariant_to_socket_count() {
    let e = engine();
    let run = |sockets| {
        let mut fd = FastDecode::new(
            e.clone(),
            TINY,
            FastDecodeConfig {
                batch: 8,
                sockets,
                precision: Precision::F32,
                capacity_per_seq: 64,
                weight_seed: 11,
                layers: 2,
            },
        )
        .unwrap();
        let prompts = fixed_batch(8, 3, TINY.vocab, 5);
        fd.generate(&prompts, 10).unwrap().tokens
    };
    assert_eq!(run(1), run(4));
}

/// Cache accounting: after generate, every socket holds prompt+steps
/// tokens per sequence per layer.
#[test]
fn cache_token_accounting() {
    let e = engine();
    let mut fd = FastDecode::new(
        e,
        TINY,
        FastDecodeConfig {
            batch: 8,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 64,
            weight_seed: 1,
            layers: 2,
        },
    )
    .unwrap();
    let prompts = fixed_batch(8, 4, TINY.vocab, 1);
    fd.generate(&prompts, 6).unwrap();
    // Each decode step appends one token's K/V: 3 prefill steps (the
    // last prompt token is consumed by the first generation step) + 6
    // generation steps = 9 per sequence per layer. The newest token's
    // K/V lands on the NEXT step, so it is not yet cached.
    assert_eq!(fd.cache_tokens(), 9 * 8 * 2);
}
