//! Acceptance tests for the `serve/` continuous-batching subsystem
//! (ISSUE 4): request-level serving over the live engine.
//!
//! (a) every traced request completes — no starvation, including a
//!     partial tail smaller than the slot count;
//! (b) the MEASURED aggregate KV load (counted from the sockets'
//!     caches) never exceeds W_lim under the SLS-aware policy;
//! (c) for a lockstep trace, continuous-batching tokens are
//!     bit-identical to a fixed-batch `generate()` run;
//! (d) `ServeReport` percentiles are finite and ordered, and batched
//!     prefill beats token-at-a-time prefill on TTFT for prompts ≥ 16.

use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::model::{Precision, TINY};
use fastdecode::serve::{
    AdmissionPolicy, Fifo, PrefillMode, ServeConfig, ServeEngine,
    ServeOutcome, SlsEarliestStart,
};
use fastdecode::workload::{generate_trace, lockstep_trace, TraceConfig};

fn engine(
    slots: usize,
    capacity: usize,
    cfg: ServeConfig,
    policy: Box<dyn AdmissionPolicy>,
) -> ServeEngine {
    let fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch: slots,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: capacity,
            weight_seed: 0xfa57,
            layers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    ServeEngine::new(fd, cfg, policy).unwrap()
}

/// (a) An open-loop ragged trace whose size is NOT a multiple of the
/// slot count completes in full: the final partial "wave" of requests
/// backfills freed slots instead of starving (the failure mode the
/// wave-based AdmissionQueue had).
#[test]
fn every_request_completes_including_partial_tail() {
    let slots = 4;
    let trace = generate_trace(&TraceConfig {
        seed: 3,
        rate: 80.0,
        prompt_len: (2, 6),
        target_len: (4, 9),
        vocab: TINY.vocab,
        count: 10, // 10 = 2·4 + 2: a partial tail of 2
        ..Default::default()
    });
    let mut eng = engine(
        slots,
        32,
        ServeConfig {
            w_lim: 30, // < 4 concurrent peaks (peak ≤ 14): forces queueing
            steps_per_sec: 400.0,
            prefill: PrefillMode::Batched,
            max_steps: 10_000,
            ..Default::default()
        },
        Box::new(Fifo),
    );
    let out = eng.run(&trace).unwrap();
    assert_eq!(out.report.completed, trace.len(), "requests starved");
    assert_eq!(out.completions.len(), trace.len());
    for (c, r) in out.completions.iter().zip(&trace) {
        assert_eq!(c.request_id, r.id);
        assert_eq!(
            c.tokens.len(),
            r.target_len,
            "request {} produced a wrong token count",
            r.id
        );
        assert!(c.ttft_s > 0.0 && c.ttft_s <= c.e2e_s);
    }
    // the engine's KV is fully released at the end
    let mut fd = eng.into_engine();
    assert_eq!(fd.cache_tokens().unwrap(), 0, "finished caches not released");
}

/// (b) Under the SLS-aware policy the measured per-layer aggregate KV
/// load — counted from the sockets' caches after every pass, NOT from
/// the schedule — stays within W_lim at every step, while admission
/// still overlaps requests (the limit binds, the bound holds).
#[test]
fn sls_policy_bounds_measured_kv_load() {
    let slots = 6;
    let trace = generate_trace(&TraceConfig {
        seed: 5,
        rate: 300.0, // near-simultaneous arrivals: maximal pressure
        prompt_len: (3, 8),
        target_len: (6, 12),
        vocab: TINY.vocab,
        count: 14,
        ..Default::default()
    });
    let w_lim = 40; // single peak ≤ 19, six concurrent would be ~90
    let mut eng = engine(
        slots,
        32,
        ServeConfig {
            w_lim,
            steps_per_sec: 400.0,
            prefill: PrefillMode::Batched,
            max_steps: 10_000,
            ..Default::default()
        },
        Box::new(SlsEarliestStart),
    );
    let out = eng.run(&trace).unwrap();
    assert_eq!(out.report.completed, trace.len());
    assert_eq!(out.policy, "sls-earliest-start");
    let peak = out
        .trace
        .records
        .iter()
        .map(|r| r.total_ctx)
        .max()
        .unwrap();
    for r in &out.trace.records {
        assert!(
            r.total_ctx <= w_lim,
            "step {}: measured KV load {} exceeds W_lim {w_lim}",
            r.step,
            r.total_ctx
        );
    }
    // admission actually overlapped requests rather than serializing
    let max_single = trace
        .iter()
        .map(|r| r.prompt.len() + r.target_len - 1)
        .max()
        .unwrap();
    assert!(
        peak > max_single,
        "requests never overlapped (peak W = {peak})"
    );
}

/// (c) Lockstep trace (equal arrivals, equal lengths, as many requests
/// as slots): the continuous-batching engine must produce BIT-IDENTICAL
/// tokens to a fixed-batch `generate()` run on the same prompts — slot
/// assembly, batched prefill and per-request retirement change nothing.
#[test]
fn lockstep_serve_matches_fixed_batch_generate() {
    let (slots, plen, tlen) = (4, 3, 6);
    let trace = lockstep_trace(slots, plen, tlen, TINY.vocab, 21);
    let mut eng = engine(
        slots,
        32,
        ServeConfig {
            w_lim: 1024, // non-binding: all start at step 0
            steps_per_sec: 100.0,
            prefill: PrefillMode::Batched,
            max_steps: 1000,
            ..Default::default()
        },
        Box::new(Fifo),
    );
    let out = eng.run(&trace).unwrap();
    assert_eq!(out.report.completed, slots);

    // the reference: same weights, same prompts, fixed batch
    let mut fd = FastDecode::new(
        TINY,
        FastDecodeConfig {
            batch: slots,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 32,
            weight_seed: 0xfa57,
            layers: 2,
            ..Default::default()
        },
    )
    .unwrap();
    let prompts: Vec<Vec<i32>> =
        trace.iter().map(|r| r.prompt.clone()).collect();
    let reference = fd.generate(&prompts, tlen).unwrap();
    for (i, c) in out.completions.iter().enumerate() {
        assert_eq!(
            c.tokens, reference.tokens[i],
            "request {i}: continuous batching changed tokens"
        );
    }
}

/// Continuous batching must also be insensitive to ARRIVAL order when
/// shapes are equal: a staggered trace produces the same per-request
/// tokens as the lockstep one (prefill/decode interleaving in shared
/// passes never leaks across sequences).
#[test]
fn staggered_arrivals_produce_same_tokens() {
    let (slots, plen, tlen) = (3, 4, 5);
    let lockstep = lockstep_trace(slots, plen, tlen, TINY.vocab, 8);
    let mut staggered = lockstep.clone();
    for (i, r) in staggered.iter_mut().enumerate() {
        r.arrival_s = i as f64 * 0.02; // steps 0, 2, 4 at 100 steps/s
    }
    let run = |trace: &[fastdecode::workload::Request]| -> ServeOutcome {
        let mut eng = engine(
            slots,
            32,
            ServeConfig {
                w_lim: 1024,
                steps_per_sec: 100.0,
                prefill: PrefillMode::Batched,
                max_steps: 1000,
                ..Default::default()
            },
            Box::new(Fifo),
        );
        eng.run(trace).unwrap()
    };
    let a = run(&lockstep);
    let b = run(&staggered);
    for (x, y) in a.completions.iter().zip(&b.completions) {
        assert_eq!(x.request_id, y.request_id);
        assert_eq!(x.tokens, y.tokens, "arrival order changed tokens");
    }
}

/// (d) Percentiles are finite, positive and ordered; batched prefill
/// strictly beats token-at-a-time prefill on TTFT for long prompts
/// (one pipeline round trip per layer instead of one per prompt token).
#[test]
fn report_percentiles_ordered_and_batched_prefill_wins_ttft() {
    let slots = 4;
    let plen = 24; // ≥ 16 per the acceptance bar
    let trace = lockstep_trace(8, plen, 4, TINY.vocab, 13);
    let run = |mode: PrefillMode| {
        let mut eng = engine(
            slots,
            64,
            ServeConfig {
                w_lim: 256,
                steps_per_sec: 100.0,
                prefill: mode,
                max_steps: 10_000,
                ..Default::default()
            },
            Box::new(Fifo),
        );
        eng.run(&trace).unwrap()
    };
    let batched = run(PrefillMode::Batched);
    let token_at_a_time = run(PrefillMode::TokenAtATime);

    for out in [&batched, &token_at_a_time] {
        assert_eq!(out.report.completed, trace.len());
        for h in [&out.report.ttft, &out.report.e2e, &out.report.itl] {
            let (p50, p95, p99) = (
                h.percentile_us(0.50),
                h.percentile_us(0.95),
                h.percentile_us(0.99),
            );
            assert!(
                p50.is_finite() && p95.is_finite() && p99.is_finite(),
                "non-finite percentile"
            );
            assert!(p50 > 0.0, "degenerate percentile");
            assert!(p50 <= p95 && p95 <= p99, "percentiles out of order");
        }
        // both modes produce identical tokens — prefill batching is a
        // latency optimization, not a different computation
        assert_eq!(
            batched.completions[0].tokens,
            out.completions[0].tokens
        );
    }
    let (b, t) = (
        batched.report.ttft.mean_us(),
        token_at_a_time.report.ttft.mean_us(),
    );
    assert!(
        b < t,
        "batched prefill TTFT {b} µs not below token-at-a-time {t} µs \
         for {plen}-token prompts"
    );
}

/// Chunked prefill (`max_prefill_rows`) spreads a long prompt across
/// several passes without changing a single generated token: per-row
/// append/attend order is identical, only the step boundaries move.
#[test]
fn chunked_prefill_is_token_identical_to_whole_prompt() {
    let (slots, plen, tlen) = (3, 24, 5);
    let trace = lockstep_trace(slots, plen, tlen, TINY.vocab, 17);
    let run = |max_prefill_rows: usize| {
        let mut eng = engine(
            slots,
            64,
            ServeConfig {
                w_lim: 256,
                steps_per_sec: 100.0,
                prefill: PrefillMode::Batched,
                max_steps: 10_000,
                max_prefill_rows,
                ..Default::default()
            },
            Box::new(Fifo),
        );
        eng.run(&trace).unwrap()
    };
    let whole = run(0);
    let chunked = run(5); // 24 prompt rows → 5 passes of ≤ 5 rows
    assert_eq!(chunked.report.completed, trace.len());
    // the chunked run needs extra steps for the extra prefill passes
    assert!(
        chunked.report.steps > whole.report.steps,
        "chunking did not spread prefill ({} vs {} steps)",
        chunked.report.steps,
        whole.report.steps
    );
    // no pass carried more rows than the cap allows (3 slots × ≤5 rows)
    let max_rows =
        chunked.trace.records.iter().map(|r| r.tokens).max().unwrap();
    assert!(
        max_rows <= slots * 5,
        "a pass carried {max_rows} rows under a 5-row prefill cap"
    );
    // ...and the generated tokens are bit-identical
    for (a, b) in whole.completions.iter().zip(&chunked.completions) {
        assert_eq!(a.request_id, b.request_id);
        assert_eq!(a.tokens, b.tokens, "chunked prefill changed tokens");
    }
}

/// Prefix sharing is semantically invisible: a shared-prefix trace
/// produces bit-identical tokens with `share_prefixes` on or off —
/// while the ON run really does admit by COW fork (`prefix_forks`),
/// storing the common prefix's blocks once.
#[test]
fn prefix_sharing_is_token_identical_and_actually_forks() {
    let trace = generate_trace(&TraceConfig {
        vocab: TINY.vocab,
        target_len: (4, 8),
        rate: 300.0, // burst arrivals: parents stay active for children
        count: 12,
        ..TraceConfig::shared_prefix_mix(9)
    });
    let run = |share_prefixes: bool| {
        let fd = FastDecode::new(
            TINY,
            FastDecodeConfig {
                batch: 4,
                sockets: 2,
                precision: Precision::F16,
                capacity_per_seq: 64,
                weight_seed: 0xfa57,
                layers: 2,
                kv_block_size: 4, // divides the 12-token shared prefix
                ..Default::default()
            },
        )
        .unwrap();
        let mut eng = ServeEngine::new(
            fd,
            ServeConfig {
                w_lim: 48,
                steps_per_sec: 400.0,
                max_steps: 10_000,
                share_prefixes,
                ..Default::default()
            },
            Box::new(Fifo),
        )
        .unwrap();
        eng.run(&trace).unwrap()
    };
    let shared = run(true);
    let unshared = run(false);
    assert_eq!(shared.report.completed, trace.len());
    assert_eq!(unshared.report.completed, trace.len());
    for (a, b) in shared.completions.iter().zip(&unshared.completions) {
        assert_eq!(a.request_id, b.request_id);
        assert_eq!(a.tokens, b.tokens, "prefix sharing changed tokens");
    }
    assert!(
        shared.report.prefix_forks > 0,
        "no admission forked on a 75%-shared-prefix trace"
    );
    assert!(
        shared.report.shared_prefix_tokens
            >= 2 * shared.report.prefix_forks,
        "forks below MIN_FORK_LEN tokens"
    );
    assert_eq!(unshared.report.prefix_forks, 0);
    assert_eq!(unshared.report.shared_prefix_tokens, 0);
    // without sharing, logical KV can never exceed what is allocated
    assert!(unshared.report.kv_utilization() <= 1.0);
    assert!(shared.report.kv_utilization() > 0.0);
    assert!(shared.report.peak_active >= 1);
}
