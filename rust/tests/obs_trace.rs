//! Observability integration: a LIVE pipelined run with tracing on must
//! export a Chrome trace with the pipeline's spans on distinct tracks;
//! the per-step breakdown must tile the measured step latency; and the
//! whole tracing surface must be branch-cheap when disabled (the < 2 %
//! throughput-overhead acceptance bound).

use std::time::Instant;

use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::model::{Precision, TINY};
use fastdecode::obs::Tracer;
use fastdecode::rworker::{RPool, RPoolConfig};
use fastdecode::util::json::Json;
use fastdecode::workload::fixed_batch;

const SOCKETS: usize = 2;

/// The live engine with an explicit tracer (bypassing the
/// `FASTDECODE_TRACE` env default, which is cached per process).
fn traced_engine(tracer: Tracer) -> FastDecode {
    let cfg = FastDecodeConfig {
        batch: 8,
        sockets: SOCKETS,
        precision: Precision::F16,
        capacity_per_seq: 64,
        weight_seed: 3,
        layers: 2,
        ..Default::default()
    };
    let mut spec_l = TINY;
    spec_l.n_layers = cfg.layers;
    let pool = RPool::spawn(
        &spec_l,
        RPoolConfig {
            sockets: cfg.sockets,
            capacity_per_seq: cfg.capacity_per_seq,
            precision: cfg.precision,
            attend_pad: cfg.r_pad,
            ..Default::default()
        },
    );
    FastDecode::with_backend_traced(TINY, cfg, Box::new(pool), tracer)
        .expect("live engine")
}

/// Tracing on: every pipeline stage shows up in the Chrome export —
/// S compute on the S-worker track, scatter/gather on the coordinator
/// track, per-socket attend spans on their own tracks.
#[test]
fn live_trace_exports_pipeline_spans() {
    let tracer = Tracer::enabled();
    let mut fd = traced_engine(tracer.clone());
    let prompts = fixed_batch(8, 3, TINY.vocab, 5);
    fd.generate(&prompts, 8).expect("traced generate");

    let doc =
        Json::parse(&tracer.chrome_trace().render()).expect("valid JSON");
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");

    // one named track per thread/socket
    let tracks: Vec<&str> = events
        .iter()
        .filter(|e| {
            e.get("name").and_then(Json::as_str) == Some("thread_name")
        })
        .filter_map(|e| {
            e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str)
        })
        .collect();
    for want in ["sworker", "coordinator", "r-socket0", "r-socket1"] {
        assert!(tracks.contains(&want), "missing track {want}: {tracks:?}");
    }

    let tids_of = |name: &str| -> Vec<i64> {
        events
            .iter()
            .filter(|e| {
                e.get("name").and_then(Json::as_str) == Some(name)
                    && e.get("ph").and_then(Json::as_str) == Some("X")
            })
            .map(|e| {
                e.get("tid").and_then(Json::as_f64).expect("tid") as i64
            })
            .collect()
    };
    for span in ["s_start", "s_advance", "step", "scatter", "gather", "attend"]
    {
        assert!(!tids_of(span).is_empty(), "no '{span}' spans recorded");
    }
    // S compute, coordinator, and attend spans live on DISTINCT tracks;
    // attend itself spreads over both socket tracks.
    let s_tid = tids_of("s_advance")[0];
    let c_tid = tids_of("scatter")[0];
    let mut attend_tids = tids_of("attend");
    attend_tids.sort_unstable();
    attend_tids.dedup();
    assert_ne!(s_tid, c_tid);
    assert!(!attend_tids.contains(&s_tid));
    assert!(!attend_tids.contains(&c_tid));
    assert!(
        attend_tids.len() >= SOCKETS,
        "attend spans on {attend_tids:?}, want ≥ {SOCKETS} tracks"
    );
}

/// Per-step breakdown identity: the measured coordinator segments
/// (queue wait + gather wait + dispatch) tile the step latency with a
/// small residual, and per-socket attend attribution is present.
#[test]
fn step_breakdown_tiles_latency() {
    let mut fd = traced_engine(Tracer::disabled());
    let prompts = fixed_batch(8, 3, TINY.vocab, 9);
    let out = fd.generate(&prompts, 12).expect("generate");
    let mut checked = 0usize;
    for r in out.trace.records.iter().filter(|r| r.tokens > 0) {
        assert!(r.latency_s > 0.0, "step {}: no latency", r.step);
        assert_eq!(
            r.socket_busy.len(),
            SOCKETS,
            "step {}: per-socket attend attribution missing",
            r.step
        );
        assert!(r.skew_s >= 0.0);
        assert!(r.r_time >= 0.0 && r.s_time >= 0.0);
        // the disjoint segments never exceed the wall latency...
        assert!(
            r.accounted_s() <= r.latency_s + 1e-4,
            "step {}: accounted {} > latency {}",
            r.step,
            r.accounted_s(),
            r.latency_s
        );
        // ...and leave only bookkeeping unaccounted (generous bound:
        // CI machines are noisy, but the identity s+r+comm+wait ≈
        // latency must hold in shape)
        let slack = (0.5 * r.latency_s).max(500e-6);
        assert!(
            r.residual_s() <= slack,
            "step {}: residual {} exceeds {} (latency {})",
            r.step,
            r.residual_s(),
            slack,
            r.latency_s
        );
        checked += 1;
    }
    assert!(checked >= 10, "only {checked} productive steps");
}

/// Disabled tracing is one branch per op — no clock read, no
/// allocation, no lock. A pipelined step at the reduced fig9 scale
/// costs ~1 ms and touches the tracing surface O(10) times, so pinning
/// the per-op cost in the low nanoseconds bounds the tracing-off
/// throughput overhead far below the 2 % acceptance line.
#[test]
fn disabled_tracing_is_branch_cheap() {
    let off = Tracer::disabled();
    let t_off = off.track("hot");
    let iters = 400_000u32;
    let start = Instant::now();
    for i in 0..iters {
        let _s = t_off.span("x").arg("k", i as f64);
        t_off.instant("i", &[("a", 1.0)]);
    }
    let off_per_op = start.elapsed().as_secs_f64() / (iters as f64 * 2.0);

    // the same surface, enabled: clock reads + buffer pushes
    let on = Tracer::enabled();
    let t_on = on.track("hot");
    let on_iters = 50_000u32;
    let start = Instant::now();
    for i in 0..on_iters {
        let _s = t_on.span("x").arg("k", i as f64);
        t_on.instant("i", &[("a", 1.0)]);
    }
    let on_per_op = start.elapsed().as_secs_f64() / (on_iters as f64 * 2.0);

    assert!(
        off_per_op < 250e-9,
        "disabled tracing op costs {:.0} ns",
        off_per_op * 1e9
    );
    assert!(
        off_per_op < on_per_op,
        "disabled ({:.0} ns/op) not cheaper than enabled ({:.0} ns/op)",
        off_per_op * 1e9,
        on_per_op * 1e9
    );
}

/// The in-process backend reports no wire stats; the getter is the
/// uniform surface the net-backed engine fills in (covered over real
/// TCP in tests/net_remote.rs).
#[test]
fn in_process_backend_has_no_net_stats() {
    let fd = traced_engine(Tracer::disabled());
    assert!(fd.net_stats().is_empty());
}
