//! E2E pin for the live-metrics surface: drive real attends over two
//! TCP rnode PROCESSES, then run the actual `fdtop` binary against
//! them.
//!
//! Pin 1: `fdtop --once --json` shows one row per node, each alive
//! with nonzero attend tok/s and KV utilization — the self-reported
//! counters reflect traffic that really crossed the wire.
//!
//! Pin 2: after one node is killed, the same invocation still exits 0
//! and reports the dead node BY NAME (`alive: false` + the root
//! cause) while the survivor's row stays schema-valid — a dashboard
//! that dies with the node it watches is useless.

use std::process::Command;

use fastdecode::model::{Precision, TINY};
use fastdecode::net::{
    spawn_rnode_process, validate_cluster, NodeConfig, RemotePool,
    RnodeProcess, WireMode,
};
use fastdecode::rworker::{AttendBackend, SeqTask};
use fastdecode::util::json::Json;
use fastdecode::util::Rng;

fn spawn_rnode() -> RnodeProcess {
    spawn_rnode_process(env!("CARGO_BIN_EXE_rnode")).expect("spawning the rnode binary")
}

fn mk_task(rng: &mut Rng, id: u64) -> SeqTask {
    SeqTask {
        seq_id: id,
        q: rng.normal_vec(TINY.hidden, 1.0),
        k_new: rng.normal_vec(TINY.hidden, 1.0),
        v_new: rng.normal_vec(TINY.hidden, 1.0),
    }
}

/// Run the real `fdtop` binary once and parse its JSON document. The
/// exit code must be 0 even when some polled nodes are dead.
fn fdtop_once(addrs: &[String]) -> Json {
    let out = Command::new(env!("CARGO_BIN_EXE_fdtop"))
        .arg("--once")
        .arg("--json")
        .args(addrs)
        .output()
        .expect("running fdtop");
    assert!(
        out.status.success(),
        "fdtop exited nonzero: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8(out.stdout).expect("fdtop output utf8");
    Json::parse(stdout.trim()).expect("fdtop --json emits valid JSON")
}

#[test]
fn fdtop_reports_live_cluster_then_names_the_dead_node() {
    let mut victim = spawn_rnode();
    let survivor = spawn_rnode();
    let addrs = vec![victim.addr.clone(), survivor.addr.clone()];
    let cfg = NodeConfig::from_spec(&TINY, 64, 8, Precision::F32, WireMode::F32);
    let mut pool = RemotePool::connect_tcp(&addrs, cfg).expect("connecting to rnodes");
    // 1 → node 0 (victim), 2 → node 1 (survivor)
    pool.add_seqs(&[1, 2]).unwrap();
    let mut rng = Rng::new(3);
    for _ in 0..4 {
        pool.attend(0, vec![mk_task(&mut rng, 1), mk_task(&mut rng, 2)])
            .unwrap();
    }

    // Pin 1: both nodes alive, really-served traffic in the report
    let doc = fdtop_once(&addrs);
    validate_cluster(&doc).expect("cluster document schema");
    let nodes = doc.get("nodes").and_then(Json::as_arr).unwrap().to_vec();
    assert_eq!(nodes.len(), 2, "one row per asked node");
    for node in &nodes {
        let addr = node.get("addr").and_then(Json::as_str).unwrap();
        assert_eq!(
            node.get("alive").and_then(Json::as_bool),
            Some(true),
            "{addr} not alive: {node:?}"
        );
        let tok = node.get("attend_tok_per_s").and_then(Json::as_f64).unwrap();
        assert!(tok > 0.0, "{addr}: attend tok/s is {tok}");
        let util = node.get("kv_utilization").and_then(Json::as_f64).unwrap();
        assert!(util > 0.0, "{addr}: KV utilization is {util}");
        let ops = node.get("attend_ops").and_then(Json::as_f64).unwrap();
        assert!(ops >= 4.0, "{addr}: attend_ops {ops} < 4");
    }

    // Pin 2: kill one node; fdtop exits 0 and names it
    victim.child.kill().expect("killing the victim rnode");
    let _ = victim.child.wait();
    let doc = fdtop_once(&addrs);
    validate_cluster(&doc).expect("cluster schema with a dead node");
    let nodes = doc.get("nodes").and_then(Json::as_arr).unwrap().to_vec();
    assert_eq!(nodes.len(), 2, "dead node must keep its row");
    let dead: Vec<&Json> = nodes
        .iter()
        .filter(|n| n.get("alive").and_then(Json::as_bool) == Some(false))
        .collect();
    assert_eq!(dead.len(), 1, "exactly one dead row: {doc:?}");
    assert_eq!(
        dead[0].get("addr").and_then(Json::as_str),
        Some(victim.addr.as_str()),
        "dead row names the killed node"
    );
    let cause = dead[0].get("error").and_then(Json::as_str).unwrap();
    assert!(!cause.is_empty(), "dead row carries the root cause");
    let live: Vec<&Json> = nodes
        .iter()
        .filter(|n| n.get("alive").and_then(Json::as_bool) == Some(true))
        .collect();
    assert_eq!(live.len(), 1);
    assert_eq!(
        live[0].get("addr").and_then(Json::as_str),
        Some(survivor.addr.as_str()),
        "survivor keeps reporting"
    );
    assert!(live[0].get("attend_tok_per_s").and_then(Json::as_f64).unwrap() > 0.0);
    drop(pool);
}
