//! Acceptance suite for `net/` — the REAL wire transport behind the
//! pluggable attend backend.
//!
//! Pins (ISSUE 5):
//! 1. decode over `Loopback` (f32 wire) is BIT-IDENTICAL to the
//!    in-process thread backend;
//! 2. a full `ServeEngine` run completes over TCP-localhost with ≥ 2
//!    rnode processes;
//! 3. killing one node mid-step returns a routed error (not a hang)
//!    and the surviving pool stays reusable;
//! 4. the modeled byte accounting (`transport::qkv_message_bytes` /
//!    `o_message_bytes`) equals the codec's actual f16 frame payload
//!    sizes, so `LinkModel` pricing can never drift from what the wire
//!    ships.

use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::model::{Precision, TINY};
use fastdecode::net::{
    encode_request, encode_response, spawn_rnode_process, vec_payload_bytes,
    NetRequest, NetResponse, NodeConfig, RemotePool, RnodeProcess, WireMode,
};
use fastdecode::rworker::{AttendBackend, SeqTask};
use fastdecode::serve::{Fifo, PrefillMode, ServeConfig, ServeEngine};
use fastdecode::transport::{o_message_bytes, qkv_message_bytes};
use fastdecode::util::Rng;
use fastdecode::workload::lockstep_trace;

const CAP: usize = 64;

fn engine_cfg(batch: usize) -> FastDecodeConfig {
    FastDecodeConfig {
        batch,
        sockets: 2,
        precision: Precision::F16,
        capacity_per_seq: CAP,
        layers: 2,
        ..Default::default()
    }
}

fn node_cfg(wire: WireMode) -> NodeConfig {
    // TINY.n_layers == 2 == engine_cfg().layers, so the spec's layer
    // count is already the instantiated one
    NodeConfig::from_spec(&TINY, CAP, 8, Precision::F16, wire)
}

/// Pin 1: the loopback backend — every activation round-tripping
/// through the wire codec — generates EXACTLY the tokens the
/// in-process thread backend generates, when the wire mode is f32.
#[test]
fn loopback_f32_bit_identical_to_thread_backend() {
    let prompts = fastdecode::workload::fixed_batch(6, 4, TINY.vocab, 11);
    let run = |remote: bool| {
        let mut fd = if remote {
            let pool = RemotePool::loopback(node_cfg(WireMode::F32), 2)
                .expect("loopback pool");
            FastDecode::with_backend(TINY, engine_cfg(6), Box::new(pool))
                .expect("engine over loopback")
        } else {
            FastDecode::new(TINY, engine_cfg(6)).expect("in-proc engine")
        };
        fd.generate(&prompts, 12).expect("generate").tokens
    };
    let threads = run(false);
    let wire = run(true);
    assert_eq!(
        threads, wire,
        "loopback f32 wire diverged from the in-process backend"
    );
}

/// The f16 wire (the paper's fp16 intermediate vectors) serves end to
/// end; tokens may legitimately differ from f32 bitwise, but the run
/// completes and stays in-vocab.
#[test]
fn loopback_f16_wire_serves_end_to_end() {
    let pool = RemotePool::loopback(node_cfg(WireMode::F16), 3)
        .expect("loopback pool");
    let mut fd = FastDecode::with_backend(TINY, engine_cfg(5), Box::new(pool))
        .expect("engine over f16 loopback");
    let prompts = fastdecode::workload::fixed_batch(5, 3, TINY.vocab, 23);
    let out = fd.generate(&prompts, 10).expect("generate");
    assert_eq!(out.tokens.len(), 5);
    for toks in &out.tokens {
        assert_eq!(toks.len(), 10);
        assert!(toks.iter().all(|&t| (t as usize) < TINY.vocab));
    }
}

/// Pin 4: modeled wire bytes == encoded f16 frame payload bytes, for
/// both the QKV leg (scatter) and the O leg (gather), measured as the
/// frame-size delta between full and empty activation payloads.
#[test]
fn modeled_bytes_match_f16_frame_payloads() {
    let (hidden, batch) = (TINY.hidden, 7usize);
    // one decode row per sequence, `batch` sequences — Table 3's
    // "intermediate vectors" message for one mini-batch
    let attend = |elems_per_task: usize| -> usize {
        let tasks: Vec<SeqTask> = (0..batch as u64)
            .map(|id| SeqTask {
                seq_id: id,
                q: vec![0.25; elems_per_task],
                k_new: vec![0.25; elems_per_task],
                v_new: vec![0.25; elems_per_task],
            })
            .collect();
        encode_request(&NetRequest::Attend { layer: 0, tasks }, WireMode::F16)
            .len()
    };
    let qkv_payload = attend(hidden) - attend(0);
    assert_eq!(
        qkv_payload,
        qkv_message_bytes(hidden, batch),
        "modeled QKV bytes drifted from the codec's f16 payload"
    );
    assert_eq!(qkv_payload, 3 * vec_payload_bytes(hidden * batch, WireMode::F16));

    let outputs = |elems_per_out: usize| -> usize {
        let outs: Vec<(u64, Vec<f32>)> = (0..batch as u64)
            .map(|id| (id, vec![0.25; elems_per_out]))
            .collect();
        encode_response(
            &NetResponse::Outputs {
                layer: 0,
                outs,
                busy: std::time::Duration::from_micros(17),
            },
            WireMode::F16,
        )
        .len()
    };
    let o_payload = outputs(hidden) - outputs(0);
    assert_eq!(
        o_payload,
        o_message_bytes(hidden, batch),
        "modeled O bytes drifted from the codec's f16 payload"
    );
}

// ── TCP-localhost with real rnode processes ──────────────────────────

/// Launch one `rnode` process on an ephemeral localhost port
/// (`CARGO_BIN_EXE_rnode` is only available in test/bench targets, so
/// the exe path is resolved here and the rest lives in the library).
fn spawn_rnode() -> RnodeProcess {
    spawn_rnode_process(env!("CARGO_BIN_EXE_rnode"))
        .expect("spawning the rnode binary")
}

/// Pin 2: a full continuous-batching `ServeEngine` run completes over
/// TCP-localhost with TWO separate rnode processes, f16 wire.
#[test]
fn serve_engine_completes_over_two_rnode_processes() {
    let nodes = [spawn_rnode(), spawn_rnode()];
    let addrs: Vec<String> = nodes.iter().map(|n| n.addr.clone()).collect();
    let pool = RemotePool::connect_tcp(&addrs, node_cfg(WireMode::F16))
        .expect("connecting to rnodes");
    assert_eq!(pool.live_nodes(), 2);
    let fd = FastDecode::with_backend(TINY, engine_cfg(4), Box::new(pool))
        .expect("engine over tcp");
    let mut eng = ServeEngine::new(
        fd,
        ServeConfig {
            w_lim: 64,
            steps_per_sec: 200.0,
            prefill: PrefillMode::Batched,
            max_steps: 10_000,
            ..Default::default()
        },
        Box::new(Fifo),
    )
    .expect("serve engine");
    let trace = lockstep_trace(6, 4, 6, TINY.vocab, 3);
    let out = eng.run(&trace).expect("serving over tcp rnodes");
    assert_eq!(out.report.completed, 6);
    assert_eq!(out.completions.len(), 6);
    for c in &out.completions {
        assert_eq!(c.tokens.len(), 6, "request {} incomplete", c.request_id);
    }
    // KV fully released on both remote nodes
    let mut fd = eng.into_engine();
    assert_eq!(fd.cache_tokens().unwrap(), 0);
    assert_eq!(fd.pool_name(), "net-tcp");
}

/// Pin 3: killing one rnode PROCESS mid-run surfaces a routed error
/// naming the dead node — no hang — and the surviving node keeps
/// serving its sequences through the same pool.
#[test]
fn killed_rnode_process_routes_error_and_pool_survives() {
    let mut victim = spawn_rnode();
    let survivor = spawn_rnode();
    let addrs = vec![victim.addr.clone(), survivor.addr.clone()];
    let mut pool = RemotePool::connect_tcp(&addrs, node_cfg(WireMode::F16))
        .expect("connecting to rnodes");
    // 1,3 → node 0 (victim); 2,4 → node 1 (survivor)
    pool.add_seqs(&[1, 2, 3, 4]).unwrap();
    let mut rng = Rng::new(5);
    let mk = |rng: &mut Rng, id: u64| SeqTask {
        seq_id: id,
        q: rng.normal_vec(TINY.hidden, 1.0),
        k_new: rng.normal_vec(TINY.hidden, 1.0),
        v_new: rng.normal_vec(TINY.hidden, 1.0),
    };
    // a healthy step first
    let tasks: Vec<SeqTask> = (1..=4).map(|i| mk(&mut rng, i)).collect();
    assert_eq!(pool.attend(0, tasks).unwrap().outputs.len(), 4);

    // kill node 0 and wait until the process is really gone
    victim.child.kill().expect("killing rnode");
    victim.child.wait().expect("reaping rnode");

    let tasks: Vec<SeqTask> = (1..=4).map(|i| mk(&mut rng, i)).collect();
    let err = pool.attend(1, tasks).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("node 0"), "error does not name the node: {msg}");
    assert_eq!(pool.live_nodes(), 1);

    // the pool stays reusable: retire the dead node's sequences
    // (locally — their cache died with the process), place a new one on
    // the survivor, attend only surviving-node sequences
    pool.drop_seqs(&[1, 3]).unwrap();
    pool.add_seqs(&[10]).unwrap();
    assert_eq!(pool.socket_of(10), Some(1));
    let step = pool
        .attend(
            1,
            vec![mk(&mut rng, 2), mk(&mut rng, 4), mk(&mut rng, 10)],
        )
        .unwrap();
    assert_eq!(step.outputs.len(), 3);
    // stats skips dead nodes by contract: one (live) entry, no hang
    let stats = pool.stats().expect("stats over survivors");
    assert_eq!(stats.len(), 1, "dead node must be skipped in stats");
}

/// A decode task for a sequence the remote node never saw is REFUSED
/// in protocol (`NetResponse::Err` → routed error), and the node keeps
/// serving — the malformed-request counterpart of the kill test, over
/// a real TCP process.
#[test]
fn refused_request_over_tcp_is_routed_and_node_survives() {
    let node = spawn_rnode();
    let mut pool =
        RemotePool::connect_tcp(&[node.addr.clone()], node_cfg(WireMode::F32))
            .expect("connecting");
    pool.add_seqs(&[1]).unwrap();
    let mut rng = Rng::new(8);
    // forge placement so the pool sends a task the node must refuse
    let bogus = SeqTask {
        seq_id: 999,
        q: rng.normal_vec(TINY.hidden, 1.0),
        k_new: rng.normal_vec(TINY.hidden, 1.0),
        v_new: rng.normal_vec(TINY.hidden, 1.0),
    };
    // route it through the raw codec on a second connection to leave
    // the pool's own connection pristine
    let mut raw = fastdecode::net::Tcp::connect(node.addr.as_str()).unwrap();
    use fastdecode::net::Transport as _;
    raw.send(&encode_request(
        &NetRequest::Configure(node_cfg(WireMode::F32)),
        WireMode::F32,
    ))
    .unwrap();
    let ack = fastdecode::net::decode_response(
        &raw.recv().unwrap(),
        WireMode::F32,
    )
    .unwrap();
    assert_eq!(ack, NetResponse::Ack);
    raw.send(&encode_request(
        &NetRequest::Attend {
            layer: 0,
            tasks: vec![bogus],
        },
        WireMode::F32,
    ))
    .unwrap();
    let resp = fastdecode::net::decode_response(
        &raw.recv().unwrap(),
        WireMode::F32,
    )
    .unwrap();
    assert!(
        matches!(resp, NetResponse::Err(ref m) if m.contains("not placed")),
        "{resp:?}"
    );
    // the pool's connection still serves after the node refused the
    // other connection's request
    let t = SeqTask {
        seq_id: 1,
        q: rng.normal_vec(TINY.hidden, 1.0),
        k_new: rng.normal_vec(TINY.hidden, 1.0),
        v_new: rng.normal_vec(TINY.hidden, 1.0),
    };
    assert_eq!(pool.attend(0, vec![t]).unwrap().outputs.len(), 1);
    // sanity: per-connection caches are independent (one sequence here)
    let stats = pool.stats().unwrap();
    let seqs: usize = stats.iter().map(|s| s.sequences).sum();
    assert_eq!(seqs, 1);
}

/// Regression (paged-KV refactor): an `Attend` for a sequence that WAS
/// placed but has since been dropped must come back as a routed
/// `NetResponse::Err` — not a node panic — and the same connection
/// keeps serving. The pre-paging `SocketCache` panicked on unknown ids
/// inside `get`/`get_mut`, which over TCP killed the node.
#[test]
fn attend_on_dropped_seq_is_refused_and_node_keeps_serving() {
    let node = spawn_rnode();
    let mut raw = fastdecode::net::Tcp::connect(node.addr.as_str()).unwrap();
    use fastdecode::net::Transport as _;
    let wire = WireMode::F32;
    let mut rpc = |req: &NetRequest| -> NetResponse {
        raw.send(&encode_request(req, wire)).unwrap();
        fastdecode::net::decode_response(&raw.recv().unwrap(), wire).unwrap()
    };
    assert_eq!(
        rpc(&NetRequest::Configure(node_cfg(wire))),
        NetResponse::Ack
    );
    assert_eq!(rpc(&NetRequest::AddSeqs(vec![5])), NetResponse::Ack);
    let mut rng = Rng::new(31);
    let mut task = || SeqTask {
        seq_id: 5,
        q: rng.normal_vec(TINY.hidden, 1.0),
        k_new: rng.normal_vec(TINY.hidden, 1.0),
        v_new: rng.normal_vec(TINY.hidden, 1.0),
    };
    // healthy attend while the sequence lives
    let resp = rpc(&NetRequest::Attend {
        layer: 0,
        tasks: vec![task()],
    });
    assert!(
        matches!(resp, NetResponse::Outputs { ref outs, .. } if outs.len() == 1),
        "{resp:?}"
    );
    assert_eq!(rpc(&NetRequest::DropSeqs(vec![5])), NetResponse::Ack);
    // attend on the DROPPED sequence: routed refusal, cache untouched
    let resp = rpc(&NetRequest::Attend {
        layer: 0,
        tasks: vec![task()],
    });
    assert!(
        matches!(resp, NetResponse::Err(ref m) if m.contains("not placed")),
        "{resp:?}"
    );
    // the node is still serving on the same connection
    assert_eq!(rpc(&NetRequest::AddSeqs(vec![6])), NetResponse::Ack);
    let ok = rpc(&NetRequest::Attend {
        layer: 0,
        tasks: vec![SeqTask {
            seq_id: 6,
            q: rng.normal_vec(TINY.hidden, 1.0),
            k_new: rng.normal_vec(TINY.hidden, 1.0),
            v_new: rng.normal_vec(TINY.hidden, 1.0),
        }],
    });
    assert!(
        matches!(ok, NetResponse::Outputs { ref outs, .. } if outs.len() == 1),
        "{ok:?}"
    );
}
