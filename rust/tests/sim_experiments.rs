//! Integration tests over the virtual-clock experiment stack: the
//! headline comparisons of §6 must hold in *shape* (who wins, by roughly
//! what factor) every time the models change.

use fastdecode::baselines::{tensorrt, vanilla, vllm, BaselineConfig};
use fastdecode::coordinator::sim::steady_throughput;
use fastdecode::coordinator::{simulate, SimConfig};
use fastdecode::model::{LLAMA_13B, LLAMA_7B};
use fastdecode::perfmodel::{CpuModel, GpuModel, A10, EPYC_7452};

fn ours(spec: fastdecode::model::ModelSpec, batch: usize, seq: usize) -> f64 {
    let mut cfg = SimConfig::new(
        spec,
        GpuModel::new(A10),
        CpuModel::from_device(EPYC_7452),
        8,
        batch,
        seq,
    );
    cfg.sls_interval = Some((seq / 32).max(1));
    cfg.steps = 3 * seq;
    steady_throughput(&simulate(&cfg), seq)
}

/// Fig 9 headline: FastDecode ℬ=1024 ≥ ~2k tok/s on the 7b model and
/// 1.88–5.04× the strongest baseline (vLLM).
#[test]
fn fig9_headline_7b() {
    let seq = 1024;
    let fd1024 = ours(LLAMA_7B, 1024, seq);
    let base = BaselineConfig::a10(LLAMA_7B, 1024, seq);
    let tp_vllm = vllm(&base).throughput();
    let tp_trt = tensorrt(&BaselineConfig::a10(LLAMA_7B, 16, seq)).throughput();
    let tp_vanilla =
        vanilla(&BaselineConfig::a10(LLAMA_7B, 16, seq)).throughput();

    assert!(fd1024 > 1000.0, "ours(1024) = {fd1024}");
    let vs_vllm = fd1024 / tp_vllm;
    assert!(
        (1.5..=8.0).contains(&vs_vllm),
        "ours/vllm = {vs_vllm} (paper: 1.88–5.04)"
    );
    let vs_trt = fd1024 / tp_trt;
    assert!(
        (3.0..=20.0).contains(&vs_trt),
        "ours/trt = {vs_trt} (paper: 8.7)"
    );
    assert!(tp_vllm > tp_vanilla, "vLLM must be the strongest baseline");
}

/// Fig 9: smaller batch (128) still beats vLLM but by less (paper 2.32×).
#[test]
fn fig9_batch128_still_wins() {
    let seq = 1024;
    let fd128 = ours(LLAMA_7B, 128, seq);
    let fd1024 = ours(LLAMA_7B, 1024, seq);
    let tp_vllm =
        vllm(&BaselineConfig::a10(LLAMA_7B, 1024, seq)).throughput();
    assert!(fd128 > tp_vllm, "ours(128)={fd128} vllm={tp_vllm}");
    assert!(fd1024 > 1.5 * fd128, "1024 should be ≫ 128");
}

/// Fig 9 on the 13b model: ours ≈ 4× vLLM at max batch (paper 4.12×).
#[test]
fn fig9_13b() {
    let seq = 1024;
    let fd = ours(LLAMA_13B, 1024, seq);
    let tp_vllm =
        vllm(&BaselineConfig::a10(LLAMA_13B, 1024, seq)).throughput();
    // Paper: 4.12×. Our simulator is optimistic toward FastDecode on
    // 13b (it models a perfectly overlapped pipeline; the paper's §7.3
    // trace shows the S-worker idle >50 % waiting on overloaded
    // R-workers), so we accept a wider band on the winning factor.
    let ratio = fd / tp_vllm;
    assert!((2.0..=30.0).contains(&ratio), "ours/vllm 13b = {ratio}");
}

/// Fig 10: trading latency for throughput — ours(1024) latency is a few
/// × ours(128), and both are above TRT's minimum (paper: 120.8 ms vs
/// 34.2 ms for 7b).
#[test]
fn fig10_latency_ordering() {
    let mk = |b: usize| {
        let mut cfg = SimConfig::new(
            LLAMA_7B,
            GpuModel::new(A10),
            CpuModel::from_device(EPYC_7452),
            8,
            b,
            1024,
        );
        cfg.sls_interval = Some(32);
        cfg.steps = 2048;
        simulate(&cfg).steady_latency(1024)
    };
    let l128 = mk(128);
    let l1024 = mk(1024);
    assert!(
        (1.5..=6.0).contains(&(l1024 / l128)),
        "latency(1024)/latency(128) = {} (paper ≈ 3.5)",
        l1024 / l128
    );
    let trt = tensorrt(&BaselineConfig::a10(LLAMA_7B, 16, 1024))
        .steady_latency(16);
    assert!(l128 > trt, "ours(128) {l128} must exceed TRT {trt}");
    assert!(l128 / trt < 10.0, "but not absurdly (paper ≈ 3.5×)");
}

/// Fig 8: latency is linear in the number of layers.
#[test]
fn fig8_layers_linear() {
    let lat = |layers: usize| {
        let mut cfg = SimConfig::new(
            fastdecode::model::OPT_175B,
            GpuModel::new(A10),
            CpuModel::from_device(EPYC_7452),
            2,
            256,
            256,
        );
        cfg.layers = layers;
        simulate(&cfg).steady_latency(10)
    };
    let l2 = lat(2);
    let l4 = lat(4);
    let l8 = lat(8);
    assert!((l4 / l2 - 2.0).abs() < 0.15, "4/2 = {}", l4 / l2);
    assert!((l8 / l2 - 4.0).abs() < 0.3, "8/2 = {}", l8 / l2);
}

/// Fig 13 shape: strong scaling works at S=1024 but 8 sockets can LOSE
/// to 4 at S=128 on the 13b model (S-worker becomes the bottleneck).
#[test]
fn fig13_short_sequences_saturate() {
    let tp = |sockets: usize, seq: usize| {
        let mut cfg = SimConfig::new(
            LLAMA_13B,
            GpuModel::new(A10),
            CpuModel::from_device(EPYC_7452),
            sockets,
            1024,
            seq,
        );
        cfg.sls_interval = Some((seq / 16).max(1));
        cfg.steps = 3 * seq;
        steady_throughput(&simulate(&cfg), seq)
    };
    // long sequences: scaling 1→8 with decent efficiency
    let e8 = tp(8, 1024) / (8.0 * tp(1, 1024));
    assert!((0.5..=1.05).contains(&e8), "8-socket efficiency {e8}");
    // short sequences: 8 sockets ≈ 4 sockets (bounded by the S-worker)
    let gain = tp(8, 128) / tp(4, 128);
    assert!(gain < 1.35, "8 vs 4 sockets at S=128 gained {gain}");
}

/// Fig 15: with synchronous communication exposed, comm is a visible
/// but minority share (~25 % in the paper).
#[test]
fn fig15_comm_share() {
    let mut cfg = SimConfig::new(
        LLAMA_13B,
        GpuModel::new(A10),
        CpuModel::from_device(EPYC_7452),
        2,
        1024,
        1024,
    );
    cfg.sync_comm = true;
    cfg.steps = 256; // mid-generation, R-workers loaded like the trace
    let trace = simulate(&cfg);
    let tail = &trace.records[128..];
    let comm: f64 = tail.iter().map(|r| r.comm_time).sum();
    let total: f64 = tail.iter().map(|r| r.latency_s).sum();
    let share = comm / total;
    assert!(
        (0.08..=0.45).contains(&share),
        "comm share {share} (paper ≈ 0.25)"
    );
}
