//! MODELED interconnects (paper Table 3) and the activation message
//! byte accounting — the pricing side of the wire, not the wire itself.
//!
//! The paper's testbed ships QKV/O vectors over PCIe + 100 Gb RoCE /
//! Infiniband. This module answers "what WOULD that traffic cost":
//! [`LinkModel`] charges latency+bandwidth (plus scatter/gather
//! per-message overheads) against true byte counts, so the offline
//! benches reproduce Table 3 and Fig 15's ~25 % comm overhead without
//! a cluster — comm cost is bandwidth-dominated, so the model is
//! faithful at message sizes that matter (DESIGN.md §2).
//!
//! The REAL wire lives in `crate::net`: a length-prefixed binary codec
//! actually framing `RRequest`/`RResponse` over loopback or TCP to
//! `rnode` host processes. The two stay pinned to each other:
//! [`qkv_message_bytes`] / [`o_message_bytes`] (fp16, Table 3
//! "Intermediate Vectors") equal the codec's encoded f16 payload sizes
//! byte-for-byte (`tests/net_remote.rs::
//! modeled_bytes_match_f16_frame_payloads`), so the cost model can
//! never silently drift from what the transport ships. Use `net` when
//! bytes must actually move; use this module when a bench needs the
//! priced wire time of a deployment-scale link that this machine does
//! not have.

/// A point-to-point link: fixed latency + bandwidth.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkModel {
    pub name: &'static str,
    /// One-way message latency, seconds.
    pub latency_s: f64,
    /// Sustained bandwidth, bytes/second.
    pub bandwidth: f64,
}

/// PCIe 4.0 ×16 (Table 3's footnote: 32 GB/s).
pub const PCIE4_X16: LinkModel = LinkModel {
    name: "PCIe 4.0 x16",
    latency_s: 5e-6,
    bandwidth: 32.0e9,
};

/// 100 Gbps RoCE (Table 3's footnote).
pub const ROCE_100G: LinkModel = LinkModel {
    name: "RoCE 100Gb",
    latency_s: 12e-6,
    bandwidth: 12.5e9,
};

/// HDR Infiniband (the evaluation cluster's fabric, §6.1).
pub const INFINIBAND: LinkModel = LinkModel {
    name: "Infiniband",
    latency_s: 6e-6,
    bandwidth: 25.0e9,
};

/// Sender-side injection overhead per additional message in a scatter
/// wave (doorbell ring + DMA descriptor setup). Wire latency overlaps
/// across concurrent messages; this per-message fixed cost does not —
/// the NIC ingests descriptors one at a time.
pub const MSG_INJECT_S: f64 = 0.5e-6;

/// Receiver-side overhead per additional source in a gather wave (an
/// n-to-1 incast): completion handling plus buffer reassembly all land
/// on the single receiving NIC, which also absorbs the incast burst —
/// strictly costlier than the sender-side injection of the matching
/// scatter, where the fan-out work is amortized across idle peers.
pub const MSG_INCAST_S: f64 = 1.2e-6;

impl LinkModel {
    /// Wire time for `bytes` in one message.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth
    }

    /// Wire time when the payload is split into `n` concurrent messages
    /// to different peers sharing the link (scatter to 𝒫 sockets).
    ///
    /// Model: the link bandwidth is shared, so the payload term is
    /// `total_bytes / bandwidth` regardless of `n`; the one-way wire
    /// latency is paid once per concurrent wave (all messages are in
    /// flight together); each message past the first adds the
    /// sender-side injection overhead [`MSG_INJECT_S`]. At `n = 1` this
    /// degenerates to [`LinkModel::transfer_time`], and the cost is
    /// monotone in `n` — scattering to 𝒫 sockets is never priced below
    /// a unicast of the same bytes.
    pub fn scatter_time(&self, total_bytes: usize, n: usize) -> f64 {
        assert!(n > 0);
        self.latency_s
            + (n - 1) as f64 * MSG_INJECT_S
            + total_bytes as f64 / self.bandwidth
    }

    /// Wire time when `n` peers each send a share of `total_bytes` to
    /// ONE receiver (the O leg: a 𝒫-to-1 incast, the mirror of
    /// [`LinkModel::scatter_time`] — NOT the same cost).
    ///
    /// Model: the receiver's NIC is the shared bottleneck, so the
    /// payload serializes at `bandwidth` regardless of `n`; the one-way
    /// wire latency is paid once per concurrent wave; each source past
    /// the first adds the receiver-side incast overhead
    /// [`MSG_INCAST_S`]. At `n = 1` this degenerates to
    /// [`LinkModel::transfer_time`]; the cost is monotone in `n`, and
    /// because `MSG_INCAST_S > MSG_INJECT_S` an n-source gather is
    /// always priced above the matching n-peer scatter — incast
    /// serialization has no idle peers to hide behind.
    pub fn gather_time(&self, total_bytes: usize, n: usize) -> f64 {
        assert!(n > 0);
        self.latency_s
            + (n - 1) as f64 * MSG_INCAST_S
            + total_bytes as f64 / self.bandwidth
    }
}

/// Byte counts of FastDecode's per-step messages for one block
/// (Table 3 "Intermediate Vectors"): Q,K,V out + O back, fp16.
pub fn qkv_message_bytes(hidden: usize, batch: usize) -> usize {
    3 * hidden * 2 * batch
}

pub fn o_message_bytes(hidden: usize, batch: usize) -> usize {
    hidden * 2 * batch
}

/// End-to-end activation round-trip for one block at batch `b`:
/// GPU→host over PCIe, QKV scattered 1-to-𝒫 over the network, O
/// gathered 𝒫-to-1 (incast) back, then up over PCIe.
pub fn activation_roundtrip_time(
    hidden: usize,
    b: usize,
    pcie: LinkModel,
    net: LinkModel,
    sockets: usize,
) -> f64 {
    let out = qkv_message_bytes(hidden, b);
    let back = o_message_bytes(hidden, b);
    pcie.transfer_time(out)
        + net.scatter_time(out, sockets)
        + net.gather_time(back, sockets)
        + pcie.transfer_time(back)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LLAMA_7B, Precision};

    /// Table 3 pins (7b model, per block).
    #[test]
    fn table3_rows() {
        let m = &LLAMA_7B;
        // Model weight: 402 MB → PCIe 12.6 ms, RoCE 32.2 ms.
        let w = m.block_weight_bytes();
        assert!((PCIE4_X16.transfer_time(w) * 1e3 - 12.6).abs() < 0.7);
        assert!((ROCE_100G.transfer_time(w) * 1e3 - 32.2).abs() < 1.5);

        // KV-cache batch 1: 4.19 MB → 0.131 / 0.335 ms. The paper's
        // 4.19 MB = 2·h·2B·256 ctx — one block, 256-token context.
        let kv1 = m.r_part_bytes_per_token_layer(256, Precision::F16);
        assert!((kv1 as f64 / 1e6 - 4.19).abs() < 0.01, "{kv1}");
        assert!((PCIE4_X16.transfer_time(kv1) * 1e3 - 0.131).abs() < 0.01);
        assert!((ROCE_100G.transfer_time(kv1) * 1e3 - 0.335).abs() < 0.02);

        // Intermediate vectors batch 1: 32.7 KB (4·h fp16). batch 1024:
        // 33.5 MB → PCIe 1.04 ms, RoCE 2.68 ms.
        let act1 = m.activation_bytes_per_token_layer();
        assert_eq!(act1, 32768);
        let act1024 = act1 * 1024;
        assert!((PCIE4_X16.transfer_time(act1024) * 1e3 - 1.04).abs() < 0.06);
        assert!((ROCE_100G.transfer_time(act1024) * 1e3 - 2.68).abs() < 0.1);
    }

    /// The design argument: shipping activations beats shipping KV by
    /// orders of magnitude at batch 1024.
    #[test]
    fn activations_beat_kv_shipping() {
        let m = &LLAMA_7B;
        let kv = m.r_part_bytes_per_token_layer(1024, Precision::F16) * 1024;
        let act = qkv_message_bytes(m.hidden, 1024)
            + o_message_bytes(m.hidden, 1024);
        assert!(kv > 100 * act);
    }

    /// Regression: `scatter_time` used to ignore `n` entirely, pricing a
    /// 𝒫-socket scatter identically to a unicast.
    #[test]
    fn scatter_accounts_per_message_cost() {
        let b = 1 << 20;
        for link in [PCIE4_X16, ROCE_100G, INFINIBAND] {
            assert_eq!(link.scatter_time(b, 1), link.transfer_time(b));
            assert!(link.scatter_time(b, 4) >= link.scatter_time(b, 1));
            assert!(link.scatter_time(b, 8) > link.scatter_time(b, 2));
            // exact increment: one injection per extra message
            let d = link.scatter_time(b, 5) - link.scatter_time(b, 2);
            assert!((d - 3.0 * MSG_INJECT_S).abs() < 1e-12);
            // but a concurrent wave stays far cheaper than n sequential
            // unicasts of the per-peer share (latency paid n times)
            assert!(link.scatter_time(b, 4) < 4.0 * link.transfer_time(b / 4));
        }
    }

    /// Regression: the pipeline's O leg used to be priced with
    /// `scatter_time`, modeling the 𝒫-to-1 incast as a 1-to-𝒫 scatter.
    /// The gather model must be monotone in source count and strictly
    /// dearer than the matching scatter (incast asymmetry).
    #[test]
    fn gather_monotone_and_dearer_than_scatter() {
        let b = 1 << 20;
        for link in [PCIE4_X16, ROCE_100G, INFINIBAND] {
            // n = 1 degenerates to a unicast
            assert_eq!(link.gather_time(b, 1), link.transfer_time(b));
            // monotone in the number of sources
            assert!(link.gather_time(b, 4) >= link.gather_time(b, 1));
            assert!(link.gather_time(b, 8) > link.gather_time(b, 2));
            // exact increment: one incast charge per extra source
            let d = link.gather_time(b, 5) - link.gather_time(b, 2);
            assert!((d - 3.0 * MSG_INCAST_S).abs() < 1e-12);
            // asymmetry: an n-source incast costs more than an n-peer
            // scatter of the same bytes, and the gap grows with n
            for n in 2..=8 {
                assert!(
                    link.gather_time(b, n) > link.scatter_time(b, n),
                    "{}: gather({n}) not above scatter({n})",
                    link.name
                );
            }
            let gap2 = link.gather_time(b, 2) - link.scatter_time(b, 2);
            let gap8 = link.gather_time(b, 8) - link.scatter_time(b, 8);
            assert!(gap8 > gap2);
            // but still far cheaper than n sequential unicasts of the
            // per-source share (latency paid n times)
            assert!(link.gather_time(b, 4) < 4.0 * link.transfer_time(b / 4));
        }
    }

    #[test]
    fn transfer_time_monotone() {
        for link in [PCIE4_X16, ROCE_100G, INFINIBAND] {
            assert!(link.transfer_time(1) < link.transfer_time(1 << 20));
            assert!(link.transfer_time(0) == link.latency_s);
        }
    }

    /// Fig 15 cross-check: at 13b/B=1024, PCIe copy ≈ 3 ms and network
    /// ≈ 7.4 ms of a ~43 ms step — comm ≈ 25 % of the step.
    #[test]
    fn fig15_comm_fractions() {
        use crate::model::LLAMA_13B;
        let b = 1024;
        let pcie = PCIE4_X16.transfer_time(qkv_message_bytes(LLAMA_13B.hidden, b))
            + PCIE4_X16.transfer_time(o_message_bytes(LLAMA_13B.hidden, b));
        let net = ROCE_100G.scatter_time(qkv_message_bytes(LLAMA_13B.hidden, b), 2)
            + ROCE_100G.gather_time(o_message_bytes(LLAMA_13B.hidden, b), 2);
        // paper: copy 3 ms, network 7.4 ms (per token across 2 layers)
        assert!((1.0..=5.0).contains(&(pcie * 1e3)), "pcie {}", pcie * 1e3);
        assert!((3.0..=12.0).contains(&(net * 1e3)), "net {}", net * 1e3);
    }
}
