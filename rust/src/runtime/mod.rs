//! The execution runtime: host tensors, the AOT artifact manifest, and
//! the threaded token-level pipeline.
//!
//! * [`pipeline`] — the real two-stage S/R pipeline (paper Fig 5b,
//!   generalized to depth-D): the S-worker thread and the R-worker
//!   sockets rotate D in-flight mini-batches over `util::chan` channels.
//! * [`Tensor`] — f32/i32 host tensors crossing the S↔R boundary.
//! * [`Manifest`] — the `artifacts/manifest.txt` format written by
//!   `python/compile/aot.py`. The PJRT executor that consumed it was
//!   removed (the `xla_extension` native library is unavailable in the
//!   offline build); the format and the golden files remain the
//!   cross-language pinning contract — see `tests/golden_roundtrip.rs`,
//!   which replays goldens through the native S-Part ops when present.

mod manifest;
pub mod pipeline;
mod tensor;

pub use manifest::{Artifact, Dtype, Golden, Manifest, TensorMeta};
pub use pipeline::{PipelineConfig, StepTiming, ThreadedPipeline};
pub use tensor::Tensor;
