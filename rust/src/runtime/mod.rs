//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! Rust hot path.
//!
//! `make artifacts` (python, build-time only) writes `artifacts/*.hlo.txt`
//! plus `manifest.txt`; this module parses the manifest, compiles each
//! graph once on the PJRT CPU client, and exposes typed `execute` calls.
//! HLO *text* is the interchange format — xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (see /opt/xla-example/README.md).

mod engine;
mod manifest;
mod tensor;

pub use engine::{Engine, Executable};
pub use manifest::{Artifact, Dtype, Golden, Manifest, TensorMeta};
pub use tensor::Tensor;
