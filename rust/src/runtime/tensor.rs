//! A tiny host tensor type for the S-worker boundary.
//!
//! Activations on the S-worker↔R-worker path are f32 row-major buffers
//! with explicit shapes; `Tensor` carries both through the native
//! S-Part executor and the pipeline channels. (KV-cache storage uses its
//! own packed fp16/int formats in kvcache/ — this type is only for
//! graph I/O.)

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Tensor::F32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn zeros_f32(shape: &[usize]) -> Tensor {
        Tensor::f32(shape, vec![0.0; shape.iter().product()])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn element_count(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    /// Max |a-b| against another f32 tensor (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        if a.len() != b.len() {
            bail!("length mismatch {} vs {}", a.len(), b.len());
        }
        Ok(a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        let t = Tensor::f32(&[2, 3], vec![0.0; 6]);
        assert_eq!(t.element_count(), 6);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
    }

    #[test]
    #[should_panic]
    fn wrong_length_panics() {
        Tensor::f32(&[2, 3], vec![0.0; 5]);
    }

    #[test]
    fn diff() {
        let a = Tensor::f32(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::f32(&[3], vec![1.0, 2.5, 3.0]);
        assert_eq!(a.max_abs_diff(&b).unwrap(), 0.5);
    }
}
