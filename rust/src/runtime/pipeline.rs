//! The REAL token-level two-stage pipeline (paper §4.1, Fig 5b) — the
//! threaded runtime behind `coordinator::real`.
//!
//! The S-worker runs on its own thread (owning the native S-Part
//! executor); the R-workers are the `RPool` socket threads. One decode
//! step splits the batch into two mini-batches, A and B, that the two
//! sides process in alternation: while the R-sockets attend mini-batch
//! A's layer, the S-thread runs mini-batch B's matmuls, and vice versa —
//! so the steady-state step costs max(s, r) instead of s + r. QKV and O
//! activations cross the S↔R boundary over `util::chan` channels, and
//! [`crate::transport::LinkModel`] charges modeled wire time against the
//! real byte counts (recorded as `comm_time`; wall latency is measured).
//!
//! With `pipelined = false` the SAME two mini-batches run strictly
//! serially (Fig 5a with an identical stage decomposition), which is
//! what the smoke tests compare against.

use std::collections::HashMap;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::rworker::{PendingAttend, RPool, SeqTask};
use crate::sworker::NativeSWorker;
use crate::transport::{LinkModel, PCIE4_X16, ROCE_100G};
use crate::util::chan::{bounded, Receiver, Sender};

use super::tensor::Tensor;

#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Overlap the two mini-batches (Fig 5b). When false the same
    /// mini-batches run serially (Fig 5a).
    pub pipelined: bool,
    /// Artificial dilation of every S stage, slept on the S-thread and
    /// counted in `s_time`. Zero in production; smoke tests use it to
    /// pin stage latencies.
    pub s_pad: Duration,
    /// Links used to price the activation traffic (GPU→host→sockets).
    pub pcie: LinkModel,
    pub net: LinkModel,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            pipelined: true,
            s_pad: Duration::ZERO,
            pcie: PCIE4_X16,
            net: ROCE_100G,
        }
    }
}

/// Timing of one decode step, from real wall-clock timestamps.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    /// Wall time of the whole step.
    pub latency_s: f64,
    /// Σ of S-stage durations measured on the S-thread.
    pub s_time: f64,
    /// Σ over (mini-batch, layer) of the slowest socket's busy time.
    pub r_time: f64,
    /// Modeled activation wire time for the real bytes shipped.
    pub comm_time: f64,
}

/// Coordinator → S-thread.
enum SReq {
    /// Begin a step for mini-batch `mb`: embed + s_pre(layer 0).
    Start { mb: usize, tokens: Vec<i32> },
    /// O gathered for (mb, layer): s_post, then s_pre(layer+1) — or the
    /// logits head if `layer` was the last.
    Advance { mb: usize, layer: usize, o: Vec<f32> },
    Shutdown,
}

/// S-thread → coordinator.
enum SResp {
    Qkv {
        mb: usize,
        layer: usize,
        qkv: Vec<f32>,
        elapsed_s: f64,
    },
    Done {
        mb: usize,
        next: Vec<i32>,
        elapsed_s: f64,
    },
}

pub struct ThreadedPipeline {
    req_tx: Sender<SReq>,
    resp_rx: Receiver<SResp>,
    handle: Option<JoinHandle<()>>,
    rpool: RPool,
    cfg: PipelineConfig,
    hidden: usize,
    layers: usize,
    vocab: usize,
}

impl ThreadedPipeline {
    /// Spawn the S-worker thread around `sworker`; `rpool`'s socket
    /// threads are already running.
    pub fn new(
        sworker: NativeSWorker,
        rpool: RPool,
        cfg: PipelineConfig,
    ) -> ThreadedPipeline {
        let hidden = sworker.spec().hidden;
        let vocab = sworker.spec().vocab;
        let layers = sworker.layers();
        assert!(layers > 0, "pipeline needs at least one layer");
        let (req_tx, req_rx) = bounded::<SReq>(8);
        let (resp_tx, resp_rx) = bounded::<SResp>(8);
        let pad = cfg.s_pad;
        let handle = std::thread::Builder::new()
            .name("sworker".into())
            .spawn(move || s_worker_loop(sworker, pad, req_rx, resp_tx))
            .expect("spawning s-worker thread");
        ThreadedPipeline {
            req_tx,
            resp_rx,
            handle: Some(handle),
            rpool,
            cfg,
            hidden,
            layers,
            vocab,
        }
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn pipelined(&self) -> bool {
        self.cfg.pipelined
    }

    pub fn rpool(&self) -> &RPool {
        &self.rpool
    }

    pub fn rpool_mut(&mut self) -> &mut RPool {
        &mut self.rpool
    }

    /// One decode step: `tokens[i]` is the current token of sequence
    /// `seq_ids[i]`. Returns the greedily sampled next tokens in the
    /// same order, plus the measured stage timing.
    pub fn step(
        &mut self,
        tokens: &[i32],
        seq_ids: &[u64],
    ) -> Result<(Vec<i32>, StepTiming)> {
        assert_eq!(tokens.len(), seq_ids.len());
        let b = tokens.len();
        if b == 0 {
            bail!("empty decode step");
        }
        // Validate here, at the Result-returning surface: once a bad id
        // reaches the S-thread it can only surface as a thread death.
        for &t in tokens {
            if t < 0 || t as usize >= self.vocab {
                bail!("token id {t} outside vocab {}", self.vocab);
            }
        }
        let t0 = Instant::now();
        let mut timing = StepTiming::default();
        // Two mini-batches whenever the batch allows, in BOTH modes, so
        // pipelined and serial runs do identical per-stage work.
        let ranges: Vec<(usize, usize)> = if b >= 2 {
            vec![(0, b / 2), (b / 2, b)]
        } else {
            vec![(0, b)]
        };
        let next = if self.cfg.pipelined && ranges.len() == 2 {
            self.step_pipelined(tokens, seq_ids, &ranges, &mut timing)?
        } else {
            self.step_serial(tokens, seq_ids, &ranges, &mut timing)?
        };
        timing.latency_s = t0.elapsed().as_secs_f64();
        Ok((next, timing))
    }

    /// Fig 5b: strict two-mini-batch alternation. Every R stage of one
    /// mini-batch runs concurrently with an S stage of the other.
    fn step_pipelined(
        &mut self,
        tokens: &[i32],
        ids: &[u64],
        ranges: &[(usize, usize)],
        timing: &mut StepTiming,
    ) -> Result<Vec<i32>> {
        let (ra, rb) = (ranges[0], ranges[1]);
        let layers = self.layers;
        self.send_start(0, ra, tokens)?;
        let qkv_a = self.expect_qkv(0, 0, timing)?;
        let mut pend_a = self.dispatch(0, ra, ids, &qkv_a, timing);
        self.send_start(1, rb, tokens)?; // S(B) ∥ R(A, 0)

        let mut next_a = Vec::new();
        let mut next_b = Vec::new();
        for layer in 0..layers {
            let qkv_b = self.expect_qkv(1, layer, timing)?;
            let o_a = self.gather(pend_a, ra, ids, timing);
            self.send_advance(0, layer, o_a)?;
            let pend_b = self.dispatch(layer, rb, ids, &qkv_b, timing);
            // now: S(A, layer→layer+1) ∥ R(B, layer)
            if layer + 1 < layers {
                let qkv_a = self.expect_qkv(0, layer + 1, timing)?;
                let o_b = self.gather(pend_b, rb, ids, timing);
                self.send_advance(1, layer, o_b)?;
                pend_a = self.dispatch(layer + 1, ra, ids, &qkv_a, timing);
                // next iteration: S(B, layer+1) ∥ R(A, layer+1)
            } else {
                next_a = self.expect_done(0, timing)?;
                let o_b = self.gather(pend_b, rb, ids, timing);
                self.send_advance(1, layer, o_b)?;
                next_b = self.expect_done(1, timing)?;
            }
        }
        next_a.extend(next_b);
        Ok(next_a)
    }

    /// Fig 5a: the same mini-batches, strictly serial (no S/R overlap).
    fn step_serial(
        &mut self,
        tokens: &[i32],
        ids: &[u64],
        ranges: &[(usize, usize)],
        timing: &mut StepTiming,
    ) -> Result<Vec<i32>> {
        let layers = self.layers;
        let mut next = Vec::with_capacity(tokens.len());
        for (mb, &range) in ranges.iter().enumerate() {
            self.send_start(mb, range, tokens)?;
            let mut qkv = self.expect_qkv(mb, 0, timing)?;
            for layer in 0..layers {
                let pend = self.dispatch(layer, range, ids, &qkv, timing);
                let o = self.gather(pend, range, ids, timing);
                self.send_advance(mb, layer, o)?;
                if layer + 1 < layers {
                    qkv = self.expect_qkv(mb, layer + 1, timing)?;
                } else {
                    next.extend(self.expect_done(mb, timing)?);
                }
            }
        }
        Ok(next)
    }

    fn send_start(
        &mut self,
        mb: usize,
        (lo, hi): (usize, usize),
        tokens: &[i32],
    ) -> Result<()> {
        self.req_tx
            .send(SReq::Start {
                mb,
                tokens: tokens[lo..hi].to_vec(),
            })
            .map_err(|_| anyhow!("s-worker thread died"))
    }

    fn send_advance(&mut self, mb: usize, layer: usize, o: Vec<f32>) -> Result<()> {
        self.req_tx
            .send(SReq::Advance { mb, layer, o })
            .map_err(|_| anyhow!("s-worker thread died"))
    }

    /// Split one mini-batch's fused QKV rows into per-sequence tasks,
    /// charge the modeled wire time for the real bytes, and scatter to
    /// the sockets without waiting.
    fn dispatch(
        &mut self,
        layer: usize,
        (lo, hi): (usize, usize),
        ids: &[u64],
        qkv: &[f32],
        timing: &mut StepTiming,
    ) -> PendingAttend {
        let h = self.hidden;
        debug_assert_eq!(qkv.len(), (hi - lo) * 3 * h);
        let tasks: Vec<SeqTask> = (lo..hi)
            .enumerate()
            .map(|(i, s)| {
                let row = &qkv[i * 3 * h..(i + 1) * 3 * h];
                SeqTask {
                    seq_id: ids[s],
                    q: row[..h].to_vec(),
                    k_new: row[h..2 * h].to_vec(),
                    v_new: row[2 * h..].to_vec(),
                }
            })
            .collect();
        // Modeled comm for the actual payload: QKV down over PCIe then
        // scattered across the sockets; O back the same way.
        let qkv_bytes = qkv.len() * 4;
        let o_bytes = (hi - lo) * h * 4;
        let sockets = self.rpool.sockets();
        timing.comm_time += self.cfg.pcie.transfer_time(qkv_bytes)
            + self.cfg.net.scatter_time(qkv_bytes, sockets)
            + self.cfg.net.scatter_time(o_bytes, sockets)
            + self.cfg.pcie.transfer_time(o_bytes);
        self.rpool.submit_attend(layer, tasks)
    }

    /// Gather one mini-batch's attention outputs in sequence order.
    fn gather(
        &mut self,
        pending: PendingAttend,
        (lo, hi): (usize, usize),
        ids: &[u64],
        timing: &mut StepTiming,
    ) -> Vec<f32> {
        let step = self.rpool.wait_attend(pending);
        timing.r_time += step.max_busy.as_secs_f64();
        let mut o = Vec::with_capacity((hi - lo) * self.hidden);
        for s in lo..hi {
            o.extend_from_slice(&step.outputs[&ids[s]]);
        }
        o
    }

    fn recv_s(&mut self, timing: &mut StepTiming) -> Result<SResp> {
        match self.resp_rx.recv() {
            Ok(resp) => {
                timing.s_time += match &resp {
                    SResp::Qkv { elapsed_s, .. } => *elapsed_s,
                    SResp::Done { elapsed_s, .. } => *elapsed_s,
                };
                Ok(resp)
            }
            Err(_) => bail!("s-worker thread died"),
        }
    }

    fn expect_qkv(
        &mut self,
        mb: usize,
        layer: usize,
        timing: &mut StepTiming,
    ) -> Result<Vec<f32>> {
        match self.recv_s(timing)? {
            SResp::Qkv {
                mb: m,
                layer: l,
                qkv,
                ..
            } if m == mb && l == layer => Ok(qkv),
            SResp::Qkv { mb: m, layer: l, .. } => bail!(
                "pipeline protocol violation: got qkv({m}, {l}), \
                 wanted qkv({mb}, {layer})"
            ),
            SResp::Done { mb: m, .. } => bail!(
                "pipeline protocol violation: got done({m}), \
                 wanted qkv({mb}, {layer})"
            ),
        }
    }

    fn expect_done(
        &mut self,
        mb: usize,
        timing: &mut StepTiming,
    ) -> Result<Vec<i32>> {
        match self.recv_s(timing)? {
            SResp::Done { mb: m, next, .. } if m == mb => Ok(next),
            _ => bail!("pipeline protocol violation: wanted done({mb})"),
        }
    }
}

impl Drop for ThreadedPipeline {
    fn drop(&mut self) {
        let _ = self.req_tx.send(SReq::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// S-worker thread body: serve Start/Advance requests FIFO, holding the
/// per-mini-batch residual stream between phases.
fn s_worker_loop(
    sworker: NativeSWorker,
    pad: Duration,
    rx: Receiver<SReq>,
    tx: Sender<SResp>,
) {
    let layers = sworker.layers();
    let h = sworker.spec().hidden;
    let mut resid: HashMap<usize, Tensor> = HashMap::new();
    while let Ok(req) = rx.recv() {
        let t0 = Instant::now();
        enum Payload {
            Qkv(usize, usize, Vec<f32>),
            Done(usize, Vec<i32>),
        }
        let payload = match req {
            SReq::Shutdown => return,
            SReq::Start { mb, tokens } => {
                let x = sworker.embed(&tokens).expect("s-worker embed");
                let qkv = sworker.s_pre(0, &x).expect("s-worker s_pre");
                resid.insert(mb, x);
                Payload::Qkv(mb, 0, qkv.into_f32().expect("qkv dtype"))
            }
            SReq::Advance { mb, layer, o } => {
                let x = resid.remove(&mb).expect("no residual for mini-batch");
                let n = o.len() / h;
                let o_t = Tensor::f32(&[n, h], o);
                let y = sworker.s_post(layer, &x, &o_t).expect("s-worker s_post");
                if layer + 1 < layers {
                    let qkv =
                        sworker.s_pre(layer + 1, &y).expect("s-worker s_pre");
                    resid.insert(mb, y);
                    Payload::Qkv(mb, layer + 1, qkv.into_f32().expect("qkv"))
                } else {
                    let logits = sworker.logits(&y).expect("s-worker logits");
                    let next = sworker.argmax(&logits).expect("argmax");
                    Payload::Done(mb, next)
                }
            }
        };
        if !pad.is_zero() {
            std::thread::sleep(pad);
        }
        let elapsed_s = t0.elapsed().as_secs_f64();
        let resp = match payload {
            Payload::Qkv(mb, layer, qkv) => SResp::Qkv {
                mb,
                layer,
                qkv,
                elapsed_s,
            },
            Payload::Done(mb, next) => SResp::Done {
                mb,
                next,
                elapsed_s,
            },
        };
        if tx.send(resp).is_err() {
            return;
        }
    }
}
