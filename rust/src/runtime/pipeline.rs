//! The REAL token-level two-stage pipeline (paper §4.1, Fig 5), the
//! threaded runtime behind `coordinator::real` — generalized from the
//! paper's two-mini-batch double buffer (Fig 5b) to a configurable
//! depth-D rotation.
//!
//! The S-worker runs on its own thread (owning the native S-Part
//! executor); the R-workers are the `RPool` socket threads. One decode
//! step splits the batch into D = [`PipelineConfig::depth`] mini-batches
//! driven as a rotating in-flight set: the R stage (attend) of one
//! mini-batch overlaps the S stages (matmuls) of the others. The S
//! thread and the R sockets are both FIFO servers, so the rotation is a
//! static software-pipeline schedule — R stages run in the order
//! (mb 0, layer 0), (mb 1, layer 0), …, (mb D−1, layer 0),
//! (mb 0, layer 1), … while the S thread stays exactly one stage ahead
//! of the mini-batch whose attend is in flight. In steady state the
//! step costs ≈ max(Σs, Σr) instead of Σs + Σr, and deeper D shrinks
//! the fill/drain bubbles at the step boundaries (paper §7.3 reports
//! S-worker idle above 50 % with only two in-flight mini-batches).
//!
//! D = 2 reproduces Fig 5b exactly. QKV and O activations cross the
//! S↔R boundary over `util::chan` channels, and
//! [`crate::transport::LinkModel`] charges modeled wire time against the
//! real byte counts: the QKV leg as a 1-to-𝒫 scatter, the O leg as a
//! 𝒫-to-1 gather/incast (recorded as `comm_time`; wall latency is
//! measured).
//!
//! With `pipelined = false` the SAME D mini-batches run strictly
//! serially (Fig 5a with an identical stage decomposition), which is
//! what the smoke and depth tests compare against. Splitting is
//! per-row-independent math, so the generated tokens are bit-identical
//! across every depth and both modes.
//!
//! [`ThreadedPipeline::forward`] generalizes the decode step to RAGGED
//! rows: a sequence may own several consecutive rows — consecutive
//! token positions processed causally in one pass (the R-worker
//! appends+attends them row by row near the cache). That is batched
//! prefill: a whole prompt crosses the S↔R boundary in a single round
//! trip per layer instead of one round trip per token, and it composes
//! freely with one-row decode sequences in the same pass (continuous
//! batching).
//!
//! R-Part runs behind the pluggable [`AttendBackend`] trait: the same
//! pipeline drives in-process socket threads (`RPool`), an in-process
//! wire loopback, or real TCP connections to `rnode` processes
//! (`crate::net::RemotePool`) — the backend is chosen at construction
//! ([`ThreadedPipeline::with_backend`]) and the schedule never knows
//! the difference.
//!
//! Error handling: any S-Part failure is routed back over the response
//! channel as `SResp::Err` (never a bare thread death), and any R-Part
//! failure — a dead socket thread, a killed remote node, a malformed
//! frame — comes back as a routed `Err` from the backend. `step()`
//! surfaces the root cause in its `Result`, and the in-flight attend is
//! drained so the backend stays reusable for the next step. A failed
//! step may leave partially-appended K/V for the poisoned step behind —
//! the pool is *reusable*, not rolled back.

use std::collections::HashMap;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context as _, Result};

use crate::obs::{Tracer, Track};
use crate::rworker::{AttendBackend, PendingAttend, RPool, SeqTask};
use crate::sworker::NativeSWorker;
use crate::transport::{LinkModel, PCIE4_X16, ROCE_100G};
use crate::util::chan::{bounded, Receiver, Sender};

use super::tensor::Tensor;

#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Overlap the in-flight mini-batches (Fig 5b generalized). When
    /// false the same mini-batches run serially (Fig 5a).
    pub pipelined: bool,
    /// Number of in-flight mini-batches D (≥ 1). The batch is split
    /// into min(D, batch) contiguous mini-batches in BOTH modes, so
    /// pipelined and serial runs do identical per-stage work. D = 2 is
    /// the paper's double buffer.
    pub depth: usize,
    /// Artificial dilation of every S stage, slept on the S-thread PER
    /// ROW of the stage's mini-batch and counted in `s_time`. Zero in
    /// production; smoke/depth tests use it to pin stage latencies
    /// independently of how the batch is split.
    pub s_pad: Duration,
    /// Links used to price the activation traffic (GPU→host→sockets).
    pub pcie: LinkModel,
    pub net: LinkModel,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            pipelined: true,
            depth: 2,
            s_pad: Duration::ZERO,
            pcie: PCIE4_X16,
            net: ROCE_100G,
        }
    }
}

/// Timing of one decode step, from real wall-clock timestamps.
///
/// `s_time`/`r_time`/`comm_time` are *attributed* stage times (they
/// overlap in a pipelined step); `queue_wait_s`/`gather_wait_s`/
/// `dispatch_s` are *measured* disjoint coordinator-thread segments
/// that tile `latency_s` (the breakdown identity asserted by
/// `tests/obs_trace.rs`).
#[derive(Clone, Debug, Default)]
pub struct StepTiming {
    /// Wall time of the whole step.
    pub latency_s: f64,
    /// Σ of S-stage durations measured on the S-thread.
    pub s_time: f64,
    /// Σ over (mini-batch, layer) of the slowest socket's busy time.
    pub r_time: f64,
    /// Modeled activation wire time for the real bytes shipped.
    pub comm_time: f64,
    /// Coordinator blocked on S-thread responses (queue-wait).
    pub queue_wait_s: f64,
    /// O-gather incast wait: `wait_attend` plus output reassembly.
    pub gather_wait_s: f64,
    /// QKV per-sequence split plus scatter submit.
    pub dispatch_s: f64,
    /// Σ over gathers of (max − min) socket busy — straggler skew.
    pub skew_s: f64,
    /// Per-socket busy seconds accumulated over the step's gathers.
    pub socket_busy: Vec<f64>,
}

/// Coordinator → S-thread.
enum SReq {
    /// Begin a step for mini-batch `mb`: embed + s_pre(layer 0).
    Start { mb: usize, tokens: Vec<i32> },
    /// O gathered for (mb, layer): s_post, then s_pre(layer+1) — or the
    /// logits head if `layer` was the last.
    Advance { mb: usize, layer: usize, o: Vec<f32> },
    /// Test hook: fail the `countdown`-th subsequent Start/Advance with
    /// `msg` as the root cause (see [`ThreadedPipeline::poison_s_op`]).
    Poison { countdown: usize, msg: String },
    Shutdown,
}

/// S-thread → coordinator. Every Start/Advance produces exactly one
/// response (Qkv, Done or Err), which is what lets the coordinator
/// drain a failed step deterministically.
enum SResp {
    Qkv {
        mb: usize,
        layer: usize,
        qkv: Vec<f32>,
        elapsed_s: f64,
    },
    Done {
        mb: usize,
        next: Vec<i32>,
        elapsed_s: f64,
    },
    /// An S-Part op failed; `msg` carries the full cause chain.
    Err { msg: String },
}

/// One attend scattered to the sockets but not yet gathered. At most
/// one is in flight at a time (the sockets are shared by every
/// mini-batch), so recovery after an S failure has exactly one handle
/// to drain.
struct Inflight {
    mb: usize,
    layer: usize,
    lo: usize,
    hi: usize,
    pending: PendingAttend,
}

pub struct ThreadedPipeline {
    req_tx: Sender<SReq>,
    resp_rx: Receiver<SResp>,
    handle: Option<JoinHandle<()>>,
    pool: Box<dyn AttendBackend>,
    cfg: PipelineConfig,
    hidden: usize,
    layers: usize,
    vocab: usize,
    /// Start/Advance requests sent but not yet answered — what `recover`
    /// must drain after a failed step.
    s_outstanding: usize,
    inflight: Option<Inflight>,
    tracer: Tracer,
    /// The coordinator thread's trace track (scatter/gather/step spans).
    track: Track,
}

impl ThreadedPipeline {
    /// Spawn the S-worker thread around `sworker`; `rpool`'s socket
    /// threads are already running. Shorthand for
    /// [`ThreadedPipeline::with_backend`] over the in-process thread
    /// pool.
    pub fn new(
        sworker: NativeSWorker,
        rpool: RPool,
        cfg: PipelineConfig,
    ) -> ThreadedPipeline {
        ThreadedPipeline::with_backend(sworker, Box::new(rpool), cfg)
    }

    /// Spawn the S-worker thread around `sworker`, running R-Part over
    /// ANY [`AttendBackend`]: in-process socket threads (`RPool`), or
    /// `crate::net::RemotePool` speaking the wire codec over loopback
    /// or TCP to `rnode` hosts. The backend must already hold the
    /// model's layer count and KV capacity.
    pub fn with_backend(
        sworker: NativeSWorker,
        pool: Box<dyn AttendBackend>,
        cfg: PipelineConfig,
    ) -> ThreadedPipeline {
        ThreadedPipeline::with_backend_traced(
            sworker,
            pool,
            cfg,
            Tracer::from_env(),
        )
    }

    /// [`ThreadedPipeline::with_backend`] with an explicit tracer: the
    /// S-thread, the coordinator and (via
    /// [`AttendBackend::install_tracer`]) every R socket/node get their
    /// own track. Pass [`Tracer::disabled`] for zero overhead.
    pub fn with_backend_traced(
        sworker: NativeSWorker,
        mut pool: Box<dyn AttendBackend>,
        cfg: PipelineConfig,
        tracer: Tracer,
    ) -> ThreadedPipeline {
        let hidden = sworker.spec().hidden;
        let vocab = sworker.spec().vocab;
        let layers = sworker.layers();
        assert!(layers > 0, "pipeline needs at least one layer");
        assert!(cfg.depth > 0, "pipeline depth must be ≥ 1");
        pool.install_tracer(tracer.clone());
        let s_track = tracer.track("sworker");
        let track = tracer.track("coordinator");
        // Capacity scales with depth: the prologue queues one Start per
        // mini-batch, and the S thread may run up to a full channel of
        // responses ahead. 2D+4 on both sides keeps every send in the
        // steady-state schedule non-blocking (no req-full/resp-full
        // deadlock cycle is reachable).
        let cap = 2 * cfg.depth + 4;
        let (req_tx, req_rx) = bounded::<SReq>(cap);
        let (resp_tx, resp_rx) = bounded::<SResp>(cap);
        let pad = cfg.s_pad;
        let handle = std::thread::Builder::new()
            .name("sworker".into())
            .spawn(move || s_worker_loop(sworker, pad, req_rx, resp_tx, s_track))
            // fdlint: allow(no-unwrap-in-routed): thread spawn fails only on OS resource exhaustion, before any request is accepted
            .expect("spawning s-worker thread");
        ThreadedPipeline {
            req_tx,
            resp_rx,
            handle: Some(handle),
            pool,
            cfg,
            hidden,
            layers,
            vocab,
            s_outstanding: 0,
            inflight: None,
            tracer,
            track,
        }
    }

    /// The tracer threaded through this pipeline (disabled unless
    /// `FASTDECODE_TRACE` was set or an enabled tracer was passed in).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// The coordinator thread's track — callers driving the pipeline
    /// (admission, serving) record their decisions next to the
    /// scatter/gather spans.
    pub fn track(&self) -> &Track {
        &self.track
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn pipelined(&self) -> bool {
        self.cfg.pipelined
    }

    /// Configured pipeline depth D (a step over a batch of b < D rows
    /// degrades to b mini-batches).
    pub fn depth(&self) -> usize {
        self.cfg.depth
    }

    pub fn pool(&self) -> &dyn AttendBackend {
        self.pool.as_ref()
    }

    pub fn pool_mut(&mut self) -> &mut dyn AttendBackend {
        self.pool.as_mut()
    }

    /// Test hook: make the S-thread fail the `nth` (0-based)
    /// Start/Advance it processes from now on, reporting `msg` as the
    /// root cause. Used by the error-path regression tests; production
    /// code never calls it.
    pub fn poison_s_op(&mut self, nth: usize, msg: &str) -> Result<()> {
        self.req_tx
            .send(SReq::Poison {
                countdown: nth,
                msg: msg.to_string(),
            })
            .map_err(|_| anyhow!("s-worker thread died"))
    }

    /// One decode step: `tokens[i]` is the current token of sequence
    /// `seq_ids[i]` (ids unique — one row per sequence). Returns the
    /// greedily sampled next tokens in the same order, plus the
    /// measured stage timing.
    ///
    /// On error the step is drained (in-flight attend gathered, S
    /// responses consumed) so the pipeline and pool stay reusable; the
    /// returned error carries the underlying S-Part cause.
    pub fn step(
        &mut self,
        tokens: &[i32],
        seq_ids: &[u64],
    ) -> Result<(Vec<i32>, StepTiming)> {
        self.forward(tokens, seq_ids)
    }

    /// One forward pass over ragged rows: `row_seqs[i]` is the sequence
    /// owning row `i`, and a sequence may own SEVERAL consecutive rows
    /// — consecutive token positions fed in one causal multi-row pass
    /// (batched prefill). Decode is the one-row-per-sequence special
    /// case ([`ThreadedPipeline::step`]). Returns the greedily sampled
    /// next token of every ROW in order; for a multi-row sequence only
    /// its last row's token is meaningful (earlier rows' samples are
    /// the model continuing the prompt mid-way).
    ///
    /// A sequence's rows must form exactly one contiguous run; rows of
    /// different sequences may interleave freely at run granularity.
    /// The mini-batch split is row-based, so a long prefill may span
    /// mini-batches — causality holds because attends are gathered in
    /// submission order and each socket serves FIFO.
    pub fn forward(
        &mut self,
        tokens: &[i32],
        row_seqs: &[u64],
    ) -> Result<(Vec<i32>, StepTiming)> {
        assert_eq!(tokens.len(), row_seqs.len());
        let b = tokens.len();
        if b == 0 {
            bail!("empty forward pass");
        }
        // Validate here, at the Result-returning surface, to keep bad
        // ids out of the pipeline entirely (an S-thread failure is
        // recoverable but costs a drained step).
        for &t in tokens {
            if t < 0 || t as usize >= self.vocab {
                bail!("token id {t} outside vocab {}", self.vocab);
            }
        }
        // one contiguous run per sequence (a second run would split the
        // sequence across two tasks of one attend, colliding in the
        // seq-keyed gather); allocation-free — this runs on every
        // decode step, and run counts are small (≤ batch)
        for (i, &id) in row_seqs.iter().enumerate() {
            let run_start = i > 0 && row_seqs[i - 1] != id;
            if run_start && row_seqs[..i].contains(&id) {
                bail!("sequence {id} owns non-contiguous rows");
            }
        }
        let t0 = Instant::now();
        let mut timing = StepTiming::default();
        // D near-equal contiguous mini-batches in BOTH modes, so
        // pipelined and serial runs do identical per-stage work.
        let d = self.cfg.depth.min(b);
        let ranges: Vec<(usize, usize)> =
            (0..d).map(|i| (i * b / d, (i + 1) * b / d)).collect();
        let res = if self.cfg.pipelined && ranges.len() >= 2 {
            self.step_pipelined(tokens, row_seqs, &ranges, &mut timing)
        } else {
            self.step_serial(tokens, row_seqs, &ranges, &mut timing)
        };
        if res.is_err() {
            self.recover();
        }
        let next = res?;
        timing.latency_s = t0.elapsed().as_secs_f64();
        let m = crate::obs::Metrics::global();
        if m.is_enabled() {
            m.observe_secs("pipeline_step_latency", &[], timing.latency_s);
            m.set_gauge("pipeline_s_time_s", &[], timing.s_time);
            m.set_gauge("pipeline_r_time_s", &[], timing.r_time);
            m.set_gauge("pipeline_comm_time_s", &[], timing.comm_time);
            m.set_gauge("pipeline_queue_wait_s", &[], timing.queue_wait_s);
            m.set_gauge("pipeline_gather_wait_s", &[], timing.gather_wait_s);
            m.set_gauge("pipeline_dispatch_s", &[], timing.dispatch_s);
            m.set_gauge("pipeline_skew_s", &[], timing.skew_s);
            m.sample("pipeline_step_latency_s", &[], timing.latency_s);
        }
        self.track.record(
            "step",
            t0,
            Instant::now(),
            &[("rows", b as f64), ("depth", d as f64)],
        );
        Ok((next, timing))
    }

    /// Fig 5b generalized: D-mini-batch rotation. R stages run in
    /// stage order (mb = k mod D, layer = k div D); every R stage
    /// overlaps S stages of the other mini-batches.
    fn step_pipelined(
        &mut self,
        tokens: &[i32],
        ids: &[u64],
        ranges: &[(usize, usize)],
        timing: &mut StepTiming,
    ) -> Result<Vec<i32>> {
        let d = ranges.len();
        let layers = self.layers;
        // Prologue: queue every mini-batch's Start; the S thread fills
        // the pipeline (its responses arrive FIFO in mb order).
        for (mb, &range) in ranges.iter().enumerate() {
            self.send_start(mb, range, tokens)?;
        }
        for k in 0..d * layers {
            let (mb, layer) = (k % d, k / d);
            let qkv = self.expect_qkv(mb, layer, timing)?;
            // Hand the previous attend's O back to S before occupying
            // the sockets with the next one: S(prev, layer+1) then runs
            // concurrently with R(mb, layer).
            if self.inflight.is_some() {
                let (pmb, pl, o) = self.gather_inflight(ids, timing)?;
                self.send_advance(pmb, pl, o)?;
            }
            self.dispatch(mb, layer, ranges[mb], ids, &qkv, timing)?;
        }
        // Epilogue: drain the last attend, then collect the per-mb
        // sampled tokens (the logits-head Advances were sent in mb
        // order, so the Dones arrive in mb order).
        if self.inflight.is_some() {
            let (pmb, pl, o) = self.gather_inflight(ids, timing)?;
            self.send_advance(pmb, pl, o)?;
        }
        let mut next = Vec::with_capacity(tokens.len());
        for mb in 0..d {
            next.extend(self.expect_done(mb, timing)?);
        }
        Ok(next)
    }

    /// Fig 5a: the same mini-batches, strictly serial (no S/R overlap).
    fn step_serial(
        &mut self,
        tokens: &[i32],
        ids: &[u64],
        ranges: &[(usize, usize)],
        timing: &mut StepTiming,
    ) -> Result<Vec<i32>> {
        let layers = self.layers;
        let mut next = Vec::with_capacity(tokens.len());
        for (mb, &range) in ranges.iter().enumerate() {
            self.send_start(mb, range, tokens)?;
            for layer in 0..layers {
                let qkv = self.expect_qkv(mb, layer, timing)?;
                self.dispatch(mb, layer, range, ids, &qkv, timing)?;
                let (pmb, pl, o) = self.gather_inflight(ids, timing)?;
                self.send_advance(pmb, pl, o)?;
            }
            next.extend(self.expect_done(mb, timing)?);
        }
        Ok(next)
    }

    /// Drain a failed step so the next one starts clean: gather the
    /// in-flight attend (the R work itself succeeded — its K/V appends
    /// stand) and consume every outstanding S response, including the
    /// `SResp::Err` siblings of the one that surfaced the failure. The
    /// S thread's leftover residuals are overwritten by the next step's
    /// Starts.
    fn recover(&mut self) {
        if let Some(inf) = self.inflight.take() {
            let _ = self.pool.wait_attend(inf.pending);
        }
        while self.s_outstanding > 0 {
            match self.resp_rx.recv() {
                Ok(_) => self.s_outstanding -= 1,
                Err(_) => break, // thread really died; nothing to drain
            }
        }
    }

    fn send_start(
        &mut self,
        mb: usize,
        (lo, hi): (usize, usize),
        tokens: &[i32],
    ) -> Result<()> {
        self.req_tx
            .send(SReq::Start {
                mb,
                tokens: tokens[lo..hi].to_vec(),
            })
            .map_err(|_| anyhow!("s-worker thread died"))?;
        self.s_outstanding += 1;
        Ok(())
    }

    fn send_advance(&mut self, mb: usize, layer: usize, o: Vec<f32>) -> Result<()> {
        self.req_tx
            .send(SReq::Advance { mb, layer, o })
            .map_err(|_| anyhow!("s-worker thread died"))?;
        self.s_outstanding += 1;
        Ok(())
    }

    /// Split one mini-batch's fused QKV rows into per-sequence tasks
    /// (consecutive rows of one sequence fuse into a single multi-row
    /// prefill task), charge the modeled wire time for the real bytes,
    /// and scatter to the sockets without waiting (the handle is held
    /// in `inflight`).
    fn dispatch(
        &mut self,
        mb: usize,
        layer: usize,
        (lo, hi): (usize, usize),
        ids: &[u64],
        qkv: &[f32],
        timing: &mut StepTiming,
    ) -> Result<()> {
        debug_assert!(self.inflight.is_none(), "attend already in flight");
        let t_d = Instant::now();
        let h = self.hidden;
        debug_assert_eq!(qkv.len(), (hi - lo) * 3 * h);
        let mut tasks: Vec<SeqTask> = Vec::new();
        let mut i = lo;
        while i < hi {
            let id = ids[i];
            let mut j = i + 1;
            while j < hi && ids[j] == id {
                j += 1;
            }
            let rows = j - i;
            let mut q = Vec::with_capacity(rows * h);
            let mut k_new = Vec::with_capacity(rows * h);
            let mut v_new = Vec::with_capacity(rows * h);
            for r in i..j {
                let row = &qkv[(r - lo) * 3 * h..(r - lo + 1) * 3 * h];
                q.extend_from_slice(&row[..h]);
                k_new.extend_from_slice(&row[h..2 * h]);
                v_new.extend_from_slice(&row[2 * h..]);
            }
            tasks.push(SeqTask {
                seq_id: id,
                q,
                k_new,
                v_new,
            });
            i = j;
        }
        // Modeled comm for the actual payload: QKV down over PCIe then
        // scattered across the sockets (1-to-𝒫); O back as a 𝒫-to-1
        // incast at the S-worker's NIC, then up over PCIe.
        let qkv_bytes = qkv.len() * 4;
        let o_bytes = (hi - lo) * h * 4;
        let sockets = self.pool.sockets();
        timing.comm_time += self.cfg.pcie.transfer_time(qkv_bytes)
            + self.cfg.net.scatter_time(qkv_bytes, sockets)
            + self.cfg.net.gather_time(o_bytes, sockets)
            + self.cfg.pcie.transfer_time(o_bytes);
        let pending = self
            .pool
            .submit_attend(layer, tasks)
            .context("scattering attend to the r-pool")?;
        self.inflight = Some(Inflight {
            mb,
            layer,
            lo,
            hi,
            pending,
        });
        timing.dispatch_s += t_d.elapsed().as_secs_f64();
        self.track.record(
            "scatter",
            t_d,
            Instant::now(),
            &[
                ("mb", mb as f64),
                ("layer", layer as f64),
                ("rows", (hi - lo) as f64),
            ],
        );
        Ok(())
    }

    /// Gather the in-flight attend's outputs in row order (a multi-row
    /// task's output covers all of its rows at once), returning
    /// `(mb, layer, o)` for the matching Advance.
    fn gather_inflight(
        &mut self,
        ids: &[u64],
        timing: &mut StepTiming,
    ) -> Result<(usize, usize, Vec<f32>)> {
        let t_g = Instant::now();
        let Some(inf) = self.inflight.take() else {
            // a gather with nothing scattered is a pipeline-sequencing
            // bug, but the pool is healthy — route it instead of
            // poisoning the S-thread
            bail!("gather with no attend in flight");
        };
        let step = self
            .pool
            .wait_attend(inf.pending)
            .context("gathering attend from the r-pool")?;
        timing.r_time += step.max_busy.as_secs_f64();
        // Per-socket attribution: accumulate each socket's busy time and
        // the straggler skew (max − min) of this gather.
        if !step.socket_busy.is_empty() {
            let sockets = self.pool.sockets();
            if timing.socket_busy.len() < sockets {
                timing.socket_busy.resize(sockets, 0.0);
            }
            let mut min_b = f64::INFINITY;
            let mut max_b = 0.0f64;
            for &(s, busy) in &step.socket_busy {
                let b = busy.as_secs_f64();
                if let Some(slot) = timing.socket_busy.get_mut(s) {
                    *slot += b;
                }
                min_b = min_b.min(b);
                max_b = max_b.max(b);
            }
            if step.socket_busy.len() >= 2 {
                timing.skew_s += max_b - min_b;
            }
        }
        let mut o = Vec::with_capacity((inf.hi - inf.lo) * self.hidden);
        let mut s = inf.lo;
        while s < inf.hi {
            let id = ids[s];
            let mut j = s + 1;
            while j < inf.hi && ids[j] == id {
                j += 1;
            }
            o.extend_from_slice(&step.outputs[&id]);
            s = j;
        }
        debug_assert_eq!(o.len(), (inf.hi - inf.lo) * self.hidden);
        timing.gather_wait_s += t_g.elapsed().as_secs_f64();
        self.track.record(
            "gather",
            t_g,
            Instant::now(),
            &[("mb", inf.mb as f64), ("layer", inf.layer as f64)],
        );
        Ok((inf.mb, inf.layer, o))
    }

    fn recv_s(&mut self, timing: &mut StepTiming) -> Result<SResp> {
        let t_w = Instant::now();
        let received = self.resp_rx.recv();
        timing.queue_wait_s += t_w.elapsed().as_secs_f64();
        self.track.record("s_wait", t_w, Instant::now(), &[]);
        match received {
            Ok(resp) => {
                self.s_outstanding -= 1;
                match resp {
                    SResp::Err { msg } => bail!("s-worker step failed: {msg}"),
                    other => {
                        timing.s_time += match &other {
                            SResp::Qkv { elapsed_s, .. } => *elapsed_s,
                            SResp::Done { elapsed_s, .. } => *elapsed_s,
                            SResp::Err { .. } => unreachable!(),
                        };
                        Ok(other)
                    }
                }
            }
            Err(_) => bail!("s-worker thread died"),
        }
    }

    fn expect_qkv(
        &mut self,
        mb: usize,
        layer: usize,
        timing: &mut StepTiming,
    ) -> Result<Vec<f32>> {
        match self.recv_s(timing)? {
            SResp::Qkv {
                mb: m,
                layer: l,
                qkv,
                ..
            } if m == mb && l == layer => Ok(qkv),
            SResp::Qkv { mb: m, layer: l, .. } => bail!(
                "pipeline protocol violation: got qkv({m}, {l}), \
                 wanted qkv({mb}, {layer})"
            ),
            _ => bail!(
                "pipeline protocol violation: wanted qkv({mb}, {layer})"
            ),
        }
    }

    fn expect_done(
        &mut self,
        mb: usize,
        timing: &mut StepTiming,
    ) -> Result<Vec<i32>> {
        match self.recv_s(timing)? {
            SResp::Done { mb: m, next, .. } if m == mb => Ok(next),
            _ => bail!("pipeline protocol violation: wanted done({mb})"),
        }
    }
}

impl Drop for ThreadedPipeline {
    fn drop(&mut self) {
        let _ = self.req_tx.send(SReq::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// S-worker thread body: serve Start/Advance requests FIFO, holding the
/// per-mini-batch residual stream between phases. Op failures are
/// reported as `SResp::Err` with the full cause chain — the thread
/// stays alive and keeps serving, so a poisoned step never strands the
/// coordinator on a dead channel.
fn s_worker_loop(
    sworker: NativeSWorker,
    pad: Duration,
    rx: Receiver<SReq>,
    tx: Sender<SResp>,
    track: Track,
) {
    let layers = sworker.layers();
    let h = sworker.spec().hidden;
    let mut resid: HashMap<usize, Tensor> = HashMap::new();
    let mut poison: Option<(usize, String)> = None;
    while let Ok(req) = rx.recv() {
        let t0 = Instant::now();
        enum Payload {
            /// (mb, layer, qkv, rows)
            Qkv(usize, usize, Vec<f32>, usize),
            /// (mb, next tokens, rows)
            Done(usize, Vec<i32>, usize),
        }
        let (mb, is_start) = match &req {
            SReq::Shutdown => return,
            SReq::Poison { countdown, msg } => {
                poison = Some((*countdown, msg.clone()));
                continue;
            }
            SReq::Start { mb, .. } => (*mb, true),
            SReq::Advance { mb, .. } => (*mb, false),
        };
        let injected: Option<String> = match poison.take() {
            Some((0, msg)) => Some(msg),
            Some((n, msg)) => {
                poison = Some((n - 1, msg));
                None
            }
            None => None,
        };
        let result: Result<Payload> = if let Some(msg) = injected {
            Err(anyhow!(msg)).with_context(|| {
                format!(
                    "injected fault on mb {mb} {}",
                    if is_start { "start" } else { "advance" }
                )
            })
        } else {
            match req {
                SReq::Start { mb, tokens } => (|| -> Result<Payload> {
                    let rows = tokens.len();
                    let x = sworker.embed(&tokens)?;
                    let qkv = sworker.s_pre(0, &x)?;
                    resid.insert(mb, x);
                    Ok(Payload::Qkv(mb, 0, qkv.into_f32()?, rows))
                })()
                .with_context(|| format!("start of mini-batch {mb}")),
                SReq::Advance { mb, layer, o } => (|| -> Result<Payload> {
                    let x = resid
                        .remove(&mb)
                        .with_context(|| format!("no residual for mini-batch {mb}"))?;
                    let n = o.len() / h;
                    let o_t = Tensor::f32(&[n, h], o);
                    let y = sworker.s_post(layer, &x, &o_t)?;
                    if layer + 1 < layers {
                        let qkv = sworker.s_pre(layer + 1, &y)?;
                        resid.insert(mb, y);
                        Ok(Payload::Qkv(mb, layer + 1, qkv.into_f32()?, n))
                    } else {
                        let logits = sworker.logits(&y)?;
                        let next = sworker.argmax(&logits)?;
                        Ok(Payload::Done(mb, next, n))
                    }
                })()
                .with_context(|| format!("advance of mini-batch {mb} at layer {layer}")),
                // fdlint: allow(no-panic-in-worker-loop): both arms are consumed by the dispatch match above; this inner match sees Advance only
                SReq::Poison { .. } | SReq::Shutdown => unreachable!(),
            }
        };
        let resp = match result {
            Ok(payload) => {
                let rows = match &payload {
                    Payload::Qkv(.., rows) => *rows,
                    Payload::Done(.., rows) => *rows,
                };
                if !pad.is_zero() && rows > 0 {
                    std::thread::sleep(pad * rows as u32);
                }
                let elapsed_s = t0.elapsed().as_secs_f64();
                let (span, layer_arg) = match &payload {
                    Payload::Qkv(_, layer, ..) => (
                        if is_start { "s_start" } else { "s_advance" },
                        *layer as f64,
                    ),
                    // the logits head runs past the last layer
                    Payload::Done(..) => ("s_advance", layers as f64),
                };
                track.record(
                    span,
                    t0,
                    Instant::now(),
                    &[
                        ("mb", mb as f64),
                        ("layer", layer_arg),
                        ("rows", rows as f64),
                    ],
                );
                match payload {
                    Payload::Qkv(mb, layer, qkv, _) => SResp::Qkv {
                        mb,
                        layer,
                        qkv,
                        elapsed_s,
                    },
                    Payload::Done(mb, next, _) => SResp::Done {
                        mb,
                        next,
                        elapsed_s,
                    },
                }
            }
            Err(e) => SResp::Err {
                msg: format!("{e:#}"),
            },
        };
        if tx.send(resp).is_err() {
            return;
        }
    }
}
