//! Parser for `artifacts/manifest.txt` (written by python/compile/aot.py).
//!
//! Line formats:
//!   artifact;NAME;FILE;in=a0:f32:8x64,...;out=o0:f32:8x192,...
//!   golden;NAME;ROLE;INDEX;DTYPE;SHAPE;FILE
//! '#' starts a comment. Shapes are 'x'-separated dims or 'scalar'.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F16,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "f16" => Dtype::F16,
            "i32" => Dtype::I32,
            _ => bail!("unknown dtype {s:?}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::F16 => 2,
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl TensorMeta {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(s: &str) -> Result<TensorMeta> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() != 3 {
            bail!("bad tensor spec {s:?}");
        }
        Ok(TensorMeta {
            name: parts[0].to_string(),
            dtype: Dtype::parse(parts[1])?,
            shape: parse_shape(parts[2])?,
        })
    }
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(vec![]);
    }
    s.split('x')
        .map(|d| d.parse::<usize>().context("bad dim"))
        .collect()
}

/// One exported HLO graph.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    /// Path to the .hlo.txt file, absolute.
    pub path: PathBuf,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

/// One golden tensor (raw little-endian file) for cross-language tests.
#[derive(Clone, Debug)]
pub struct Golden {
    pub artifact: String,
    /// "in" or "out".
    pub role: String,
    pub index: usize,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub path: PathBuf,
}

impl Golden {
    /// Load as f32 (i32 files are refused).
    pub fn load_f32(&self) -> Result<Vec<f32>> {
        let bytes = std::fs::read(&self.path)
            .with_context(|| format!("reading {:?}", self.path))?;
        match self.dtype {
            Dtype::F32 => Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            _ => bail!("golden {:?} is not f32", self.path),
        }
    }

    pub fn load_i32(&self) -> Result<Vec<i32>> {
        let bytes = std::fs::read(&self.path)?;
        match self.dtype {
            Dtype::I32 => Ok(bytes
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect()),
            _ => bail!("golden {:?} is not i32", self.path),
        }
    }
}

/// The parsed artifact index.
#[derive(Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, Artifact>,
    pub goldens: Vec<Golden>,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`; all paths are resolved against `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("no manifest in {dir:?} — run `make artifacts`"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut m = Manifest::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split(';').collect();
            let ctx = || format!("manifest line {}: {line:?}", lineno + 1);
            match fields[0] {
                "artifact" => {
                    if fields.len() != 5 {
                        bail!("{}: want 5 fields", ctx());
                    }
                    let name = fields[1].to_string();
                    let inputs = parse_tensor_list(fields[3], "in=")
                        .with_context(ctx)?;
                    let outputs = parse_tensor_list(fields[4], "out=")
                        .with_context(ctx)?;
                    m.artifacts.insert(
                        name.clone(),
                        Artifact {
                            name,
                            path: dir.join(fields[2]),
                            inputs,
                            outputs,
                        },
                    );
                }
                "golden" => {
                    if fields.len() != 7 {
                        bail!("{}: want 7 fields", ctx());
                    }
                    m.goldens.push(Golden {
                        artifact: fields[1].to_string(),
                        role: fields[2].to_string(),
                        index: fields[3].parse().with_context(ctx)?,
                        dtype: Dtype::parse(fields[4]).with_context(ctx)?,
                        shape: parse_shape(fields[5]).with_context(ctx)?,
                        path: dir.join(fields[6]),
                    });
                }
                other => bail!("{}: unknown record {other:?}", ctx()),
            }
        }
        Ok(m)
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    /// Golden tensors of one artifact, (inputs, outputs), index-ordered.
    pub fn goldens_for(&self, name: &str) -> (Vec<&Golden>, Vec<&Golden>) {
        let mut ins: Vec<&Golden> = self
            .goldens
            .iter()
            .filter(|g| g.artifact == name && g.role == "in")
            .collect();
        let mut outs: Vec<&Golden> = self
            .goldens
            .iter()
            .filter(|g| g.artifact == name && g.role == "out")
            .collect();
        ins.sort_by_key(|g| g.index);
        outs.sort_by_key(|g| g.index);
        (ins, outs)
    }
}

fn parse_tensor_list(field: &str, prefix: &str) -> Result<Vec<TensorMeta>> {
    let body = field
        .strip_prefix(prefix)
        .with_context(|| format!("field {field:?} missing {prefix:?}"))?;
    if body.is_empty() {
        return Ok(vec![]);
    }
    body.split(',').map(TensorMeta::parse).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
artifact;tiny_b1_s_pre;tiny_b1_s_pre.hlo.txt;in=a0:f32:1x64,a1:f32:64,a2:f32:64x192;out=o0:f32:1x192
golden;tiny_b1_s_pre;in;0;f32;1x64;golden/tiny_b1_s_pre.in0.bin
golden;tiny_b1_s_pre;out;0;f32;1x192;golden/tiny_b1_s_pre.out0.bin
artifact;tiny_b1_embed;tiny_b1_embed.hlo.txt;in=a0:i32:1,a1:f32:256x64;out=o0:f32:1x64
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/art")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.get("tiny_b1_s_pre").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[2].shape, vec![64, 192]);
        assert_eq!(a.inputs[2].dtype, Dtype::F32);
        assert_eq!(a.outputs[0].element_count(), 192);
        assert_eq!(a.path, Path::new("/art/tiny_b1_s_pre.hlo.txt"));
        let e = m.get("tiny_b1_embed").unwrap();
        assert_eq!(e.inputs[0].dtype, Dtype::I32);
        assert_eq!(e.inputs[0].shape, vec![1]);
        let (ins, outs) = m.goldens_for("tiny_b1_s_pre");
        assert_eq!((ins.len(), outs.len()), (1, 1));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("artifact;x;y", Path::new(".")).is_err());
        assert!(Manifest::parse("bogus;x", Path::new(".")).is_err());
        assert!(
            Manifest::parse("artifact;n;f;in=a:zz:1;out=o:f32:1", Path::new("."))
                .is_err()
        );
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.get("nope").is_err());
    }
}
