//! The PJRT engine: compile-once / execute-many over manifest artifacts.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Graphs are lowered with
//! `return_tuple=True`, so results unwrap via `decompose_tuple`.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use super::manifest::{Artifact, Dtype, Manifest};
use super::tensor::Tensor;

/// One compiled graph, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub artifact: Artifact,
}

impl Executable {
    /// Execute with host tensors; validates shapes against the manifest.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let meta = &self.artifact;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{}: {} inputs given, {} expected",
                meta.name,
                inputs.len(),
                meta.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (t, m)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if t.shape() != m.shape.as_slice() {
                bail!(
                    "{}: input {i} shape {:?} != manifest {:?}",
                    meta.name,
                    t.shape(),
                    m.shape
                );
            }
            literals.push(to_literal(t)?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", meta.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = tuple
            .decompose_tuple()
            .context("decomposing result tuple")?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "{}: {} outputs, {} expected",
                meta.name,
                parts.len(),
                meta.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&meta.outputs)
            .map(|(lit, m)| from_literal(&lit, &m.shape, m.dtype))
            .collect()
    }
}

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    let lit = match t {
        Tensor::F32 { data, .. } => xla::Literal::vec1(data),
        Tensor::I32 { data, .. } => xla::Literal::vec1(data),
    };
    Ok(lit.reshape(&dims)?)
}

fn from_literal(
    lit: &xla::Literal,
    shape: &[usize],
    dtype: Dtype,
) -> Result<Tensor> {
    match dtype {
        Dtype::F32 => Ok(Tensor::f32(shape, lit.to_vec::<f32>()?)),
        Dtype::I32 => Ok(Tensor::i32(shape, lit.to_vec::<i32>()?)),
        Dtype::F16 => bail!("f16 graph outputs are not used on this path"),
    }
}

/// Compile-once cache over a manifest directory.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Engine {
    /// Create a CPU PJRT client and load the manifest from `artifacts_dir`.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT client")?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for a manifest artifact.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let artifact = self.manifest.get(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(
            artifact.path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", artifact.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", artifact.name))?;
        let e = std::sync::Arc::new(Executable { exe, artifact });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// One-shot convenience: compile (cached) + run.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.executable(name)?.run(inputs)
    }
}
