//! The `rnode` host: serves R-sockets over a [`Transport`].
//!
//! One connection = one R-socket. The first frame must be
//! `Configure`; the node provisions a `SocketCache` for it and then
//! serves `AddSeqs` / `DropSeqs` / `Attend` / `Stats` until the client
//! sends `Shutdown` or disconnects. A listener serves any number of
//! connections concurrently (one thread each), so a single `rnode`
//! process can host several sockets — or several processes can host
//! one each (the multi-node deployment the paper's §4 aggregates).
//!
//! Fault discipline (the remote counterpart of PR 3's `SResp::Err`):
//! a request the node cannot honor — unknown sequence, capacity
//! overflow, malformed task shapes, undecodable frame — is answered
//! with `NetResponse::Err` carrying the cause, WITHOUT touching the
//! cache (an invalid `Attend` appends nothing) and WITHOUT killing the
//! connection: framing is length-prefixed, so the stream stays
//! synchronized and the node keeps serving. Only a transport failure
//! (client gone) ends the loop.
//!
//! Live self-reporting: every listener owns one [`NodeShared`] —
//! counters shared by ALL of its connections (attend ops/rows/errors,
//! queue wait, busy time, a service-time histogram, payload-drift
//! bytes, per-connection cache occupancy). Any connection can ask for
//! the merged snapshot with `NetRequest::NodeStats`; a connection
//! whose FIRST frame is `NodeStats` (or `Ping`) enters **monitor
//! mode** — it is never configured, provisions no cache, and only
//! serves `NodeStats`/`Ping`/`Shutdown`. That is how `fdtop` polls a
//! serving node without disturbing it.

use std::collections::BTreeMap;
use std::net::{TcpListener, ToSocketAddrs};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _, Result};

use crate::kvcache::{CacheStats, SocketCache};
use crate::metrics::Histogram;
use crate::obs::{Tracer, Track};
use crate::rworker::{attend_paged, AttnScratch, SeqTask};

use super::codec::{
    attend_request_overhead_bytes, decode_request, encode_response,
    NetRequest, NetResponse, NodeStatsReport, WireMode,
};
use super::transport::{Tcp, Transport};

/// Per-listener shared state behind `NetRequest::NodeStats`: cumulative
/// counters across every connection the listener has served, plus a
/// per-connection cache-occupancy snapshot (updated by the owning
/// connection thread after each cache-mutating op, merged at report
/// time). Mutex poisoning is absorbed (`into_inner`): self-reporting is
/// advisory and must survive a panicking sibling thread.
pub struct NodeShared {
    started: Instant,
    state: Mutex<SharedState>,
}

#[derive(Default)]
struct SharedState {
    next_conn_id: u64,
    connections: u64,
    attend_ops: u64,
    attend_rows: u64,
    attend_errors: u64,
    queue_wait_us: u64,
    busy_us: u64,
    modeled_payload_bytes: u64,
    measured_payload_bytes: u64,
    service: Histogram,
    /// conn id → (cache stats, blocks used, blocks free).
    caches: BTreeMap<u64, (CacheStats, u64, u64)>,
}

impl Default for NodeShared {
    fn default() -> NodeShared {
        NodeShared::new()
    }
}

impl NodeShared {
    pub fn new() -> NodeShared {
        NodeShared {
            started: Instant::now(),
            state: Mutex::new(SharedState::default()),
        }
    }

    fn lock(&self) -> MutexGuard<'_, SharedState> {
        match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn register_conn(&self) -> u64 {
        let mut st = self.lock();
        st.connections += 1;
        st.next_conn_id += 1;
        st.next_conn_id
    }

    fn unregister_conn(&self, id: u64) {
        let mut st = self.lock();
        st.connections = st.connections.saturating_sub(1);
        st.caches.remove(&id);
    }

    fn update_cache(&self, id: u64, stats: CacheStats, used: u64, free: u64) {
        self.lock().caches.insert(id, (stats, used, free));
    }

    fn on_queue_wait(&self, wait: Duration) {
        self.lock().queue_wait_us += wait.as_micros() as u64;
    }

    fn on_attend(&self, rows: u64, busy: Duration, modeled: u64, measured: u64) {
        let mut st = self.lock();
        st.attend_ops += 1;
        st.attend_rows += rows;
        st.busy_us += busy.as_micros() as u64;
        st.modeled_payload_bytes += modeled;
        st.measured_payload_bytes += measured;
        st.service.record_secs(busy.as_secs_f64());
    }

    fn on_error(&self) {
        self.lock().attend_errors += 1;
    }

    /// The merged live snapshot `NetRequest::NodeStats` answers with.
    pub fn report(&self) -> NodeStatsReport {
        let st = self.lock();
        let mut cache = CacheStats::default();
        let (mut used, mut free) = (0u64, 0u64);
        for (cs, u, f) in st.caches.values() {
            cache.merge(cs);
            used += u;
            free += f;
        }
        let (p50, p99) = if st.service.count() == 0 {
            (0, 0)
        } else {
            (
                st.service.percentile_us(0.50) as u64,
                st.service.percentile_us(0.99) as u64,
            )
        };
        NodeStatsReport {
            uptime_us: self.started.elapsed().as_micros() as u64,
            connections: st.connections,
            attend_ops: st.attend_ops,
            attend_rows: st.attend_rows,
            attend_errors: st.attend_errors,
            queue_wait_us: st.queue_wait_us,
            busy_us: st.busy_us,
            service_p50_us: p50,
            service_p99_us: p99,
            modeled_payload_bytes: st.modeled_payload_bytes,
            measured_payload_bytes: st.measured_payload_bytes,
            blocks_used: used,
            blocks_free: free,
            cache,
        }
    }
}

/// Decrements the connection count (and drops the connection's cache
/// snapshot) on EVERY exit path of a serving loop, error or clean.
struct ConnGuard {
    shared: Arc<NodeShared>,
    id: u64,
}

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.shared.unregister_conn(self.id);
    }
}

/// Serve one R-socket connection to completion over its own private
/// [`NodeShared`] (standalone use: loopback pools, tests). Listener
/// paths share one `NodeShared` across connections via
/// [`serve_connection_shared`] so `NodeStats` reports cover the node.
pub fn serve_connection<T: Transport>(t: T) -> Result<()> {
    serve_connection_shared(t, Arc::new(NodeShared::new()))
}

/// Serve one connection against the listener-wide shared counters.
/// Returns `Ok` on a clean end (client `Shutdown` or disconnect after
/// configuration), `Err` if the connection violated the protocol
/// before it was even configured or the transport failed mid-reply.
///
/// A connection whose first frame is `NodeStats` or `Ping` enters
/// monitor mode ([`serve_monitor`]) instead of configuring a cache.
pub fn serve_connection_shared<T: Transport>(
    mut t: T,
    shared: Arc<NodeShared>,
) -> Result<()> {
    let conn_id = shared.register_conn();
    // dropped on every exit path below — keeps the connection count
    // and the per-connection cache snapshot honest
    let _guard = ConnGuard {
        shared: Arc::clone(&shared),
        id: conn_id,
    };
    // handshake: Configure fixes dimensions and the wire mode.
    // Configure frames carry no activations, so the decode mode is
    // immaterial here.
    let first = t.recv().context("awaiting Configure")?;
    let cfg = match decode_request(&first, WireMode::F32) {
        Ok(NetRequest::Configure(cfg)) => cfg,
        // a monitor connection: never configured, no cache — serves
        // NodeStats/Ping/Shutdown only (how `fdtop` polls a live node)
        Ok(NetRequest::NodeStats) | Ok(NetRequest::Ping) => {
            return serve_monitor(t, &shared, &first);
        }
        Ok(other) => {
            let msg = format!(
                "protocol violation: first frame must be Configure, got \
                 {other:?}"
            );
            let _ = t.send(&encode_response(
                &NetResponse::Err(msg.clone()),
                WireMode::F32,
            ));
            bail!(msg);
        }
        Err(e) => {
            let msg = format!("malformed Configure frame: {e:#}");
            let _ = t.send(&encode_response(
                &NetResponse::Err(msg.clone()),
                WireMode::F32,
            ));
            bail!(msg);
        }
    };
    if cfg.n_heads == 0
        || cfg.head_dim == 0
        || cfg.n_layers == 0
        || cfg.capacity_per_seq == 0
        || cfg.block_size == 0
    {
        let msg = format!("degenerate NodeConfig {cfg:?}");
        let _ = t
            .send(&encode_response(&NetResponse::Err(msg.clone()), cfg.wire));
        bail!(msg);
    }
    let wire = cfg.wire;
    let mut cache = SocketCache::new(
        cfg.n_heads,
        cfg.head_dim,
        cfg.n_layers,
        cfg.capacity_per_seq,
        cfg.block_size,
        cfg.precision,
    );
    let mut scratch = AttnScratch::new(cfg.head_dim);
    // The node's own trace session: pinned to the connection-accept
    // instant so the same epoch anchors both recorded spans and the
    // `Ping` clock-sync replies the client uses to align them.
    let epoch = Instant::now();
    let tracer = if cfg.trace {
        Tracer::enabled_with_epoch(epoch)
    } else {
        Tracer::disabled()
    };
    let track = tracer.track("rnode");
    t.send(&encode_response(&NetResponse::Ack, wire))
        .context("acking Configure")?;

    let width = cfg.n_heads * cfg.head_dim;
    loop {
        // time blocked waiting for the next request frame — the
        // server-side queue-wait the client's submit→reply span hides
        let idle_from = Instant::now();
        let frame = match t.recv() {
            Ok(f) => f,
            Err(_) => return Ok(()), // client gone: normal end of life
        };
        let recv_at = Instant::now();
        track.record("queue_wait", idle_from, recv_at, &[]);
        shared.on_queue_wait(recv_at - idle_from);
        let decoded = {
            let _s = track
                .span("decode")
                .arg("frame_bytes", frame.len() as f64);
            decode_request(&frame, wire)
        };
        // does this request mutate the cache on success? (drives the
        // shared occupancy snapshot refresh below)
        let mutates = matches!(
            decoded,
            Ok(NetRequest::AddSeqs(_))
                | Ok(NetRequest::DropSeqs(_))
                | Ok(NetRequest::Attend { .. })
                | Ok(NetRequest::ForkSeq { .. })
        );
        let resp = match decoded {
            Err(e) => NetResponse::Err(format!("malformed frame: {e:#}")),
            Ok(NetRequest::Shutdown) => return Ok(()),
            Ok(NetRequest::Configure(_)) => NetResponse::Err(
                "protocol violation: connection already configured".into(),
            ),
            Ok(NetRequest::AddSeqs(ids)) => add_seqs(&mut cache, &ids),
            Ok(NetRequest::DropSeqs(ids)) => {
                for id in ids {
                    cache.drop_seq(id);
                }
                NetResponse::Ack
            }
            Ok(NetRequest::Attend { layer, tasks }) => {
                // payload accounting BEFORE the tasks move: modeled =
                // what the LinkModel charges (3 activation vectors per
                // row), measured = frame minus framing overhead
                let elems: usize = tasks.iter().map(|t| t.q.len()).sum();
                let rows = (elems / width) as u64;
                let modeled = (3 * elems * wire.bytes_per_elem()) as u64;
                let measured = frame
                    .len()
                    .saturating_sub(attend_request_overhead_bytes(tasks.len()))
                    as u64;
                let resp =
                    attend(&mut cache, &mut scratch, layer, tasks, &track);
                if let NetResponse::Outputs { busy, .. } = &resp {
                    shared.on_attend(rows, *busy, modeled, measured);
                }
                resp
            }
            Ok(NetRequest::ForkSeq { parent, child, upto }) => {
                // fork_seq validates before it mutates, so a refusal
                // (unknown parent, child collision, upto too long)
                // leaves the cache untouched
                match cache.fork_seq(parent, child, upto) {
                    Ok(()) => NetResponse::Ack,
                    Err(e) => NetResponse::Err(format!("{e:#}")),
                }
            }
            Ok(NetRequest::Stats) => NetResponse::Stats(cache.stats()),
            // the listener-wide live snapshot (all connections merged)
            Ok(NetRequest::NodeStats) => {
                NetResponse::NodeStats(shared.report())
            }
            // clock-sync probe: answer with the node's epoch-relative
            // time so the client can estimate the offset between the
            // two monotonic clocks from the RTT midpoint
            Ok(NetRequest::Ping) => NetResponse::Pong {
                node_us: epoch.elapsed().as_secs_f64() * 1e6,
            },
            // drain-and-ship: buffers come back empty, so each fetch
            // returns only spans recorded since the previous one
            Ok(NetRequest::FetchTrace) => {
                NetResponse::Trace(tracer.drain_remote_spans())
            }
        };
        if matches!(resp, NetResponse::Err(_)) {
            shared.on_error();
        } else if mutates {
            shared.update_cache(
                conn_id,
                cache.stats(),
                cache.live_blocks() as u64,
                cache.free_blocks() as u64,
            );
        }
        let reply = {
            let _s = track.span("encode");
            encode_response(&resp, wire)
        };
        t.send(&reply).context("sending reply")?;
    }
}

/// The monitor loop: a connection that never configured (its first
/// frame was `NodeStats` or `Ping`) serves live snapshots and clock
/// probes until `Shutdown` or disconnect. No cache, no activations —
/// frames decode under `F32` by construction. Any other request is
/// answered with a routed `Err` and the loop keeps serving.
fn serve_monitor<T: Transport>(
    mut t: T,
    shared: &NodeShared,
    first: &[u8],
) -> Result<()> {
    let epoch = Instant::now();
    let wire = WireMode::F32;
    let mut frame = first.to_vec();
    loop {
        let resp = match decode_request(&frame, wire) {
            Err(e) => NetResponse::Err(format!("malformed frame: {e:#}")),
            Ok(NetRequest::NodeStats) => {
                NetResponse::NodeStats(shared.report())
            }
            Ok(NetRequest::Ping) => NetResponse::Pong {
                node_us: epoch.elapsed().as_secs_f64() * 1e6,
            },
            Ok(NetRequest::Shutdown) => return Ok(()),
            Ok(other) => NetResponse::Err(format!(
                "protocol violation: monitor connection only serves \
                 NodeStats/Ping/Shutdown, got {other:?}"
            )),
        };
        t.send(&encode_response(&resp, wire))
            .context("sending monitor reply")?;
        frame = match t.recv() {
            Ok(f) => f,
            Err(_) => return Ok(()), // monitor gone: normal end of life
        };
    }
}

fn add_seqs(cache: &mut SocketCache, ids: &[u64]) -> NetResponse {
    // validate-then-apply: a refused request must not mutate
    for &id in ids {
        if cache.contains(id) {
            return NetResponse::Err(format!(
                "sequence {id} already placed on this node"
            ));
        }
    }
    for &id in ids {
        cache.add_seq(id);
    }
    NetResponse::Ack
}

/// The node-side attend: validate EVERY task, then append+attend row
/// by row exactly like the in-process `RWorker` loop — same math, same
/// causal row order, so loopback f32 is bit-identical to threads.
///
/// Traced as an `attend` span (layer / rows / tasks args) with a
/// nested `kv_append` span carrying the time spent appending KV rows;
/// the causal row order (append row r, attend row r) forbids
/// separating the phases, so the append time is accumulated across
/// rows and recorded as one sub-span.
fn attend(
    cache: &mut SocketCache,
    scratch: &mut AttnScratch,
    layer: usize,
    tasks: Vec<SeqTask>,
    track: &Track,
) -> NetResponse {
    if layer >= cache.n_layers {
        return NetResponse::Err(format!(
            "layer {layer} out of range ({} layers)",
            cache.n_layers
        ));
    }
    let width = cache.n_heads * cache.head_dim;
    // fdlint: allow(deterministic-iteration): membership-only duplicate check, never iterated
    let mut seen = std::collections::HashSet::with_capacity(tasks.len());
    for task in &tasks {
        if !cache.contains(task.seq_id) {
            return NetResponse::Err(format!(
                "sequence {} not placed on this node",
                task.seq_id
            ));
        }
        if !seen.insert(task.seq_id) {
            return NetResponse::Err(format!(
                "duplicate task for sequence {} in one attend",
                task.seq_id
            ));
        }
        if task.q.is_empty()
            || task.q.len() % width != 0
            || task.k_new.len() != task.q.len()
            || task.v_new.len() != task.q.len()
        {
            return NetResponse::Err(format!(
                "seq {}: malformed task (q {} k {} v {}, width {width})",
                task.seq_id,
                task.q.len(),
                task.k_new.len(),
                task.v_new.len(),
            ));
        }
        // contains() passed above, so seq_len can only fail on the
        // layer bound — already checked; still route it, never panic
        let len = match cache.seq_len(task.seq_id, layer) {
            Ok(len) => len,
            Err(e) => return NetResponse::Err(format!("{e:#}")),
        };
        let rows = task.q.len() / width;
        if rows > cache.capacity_per_seq - len {
            return NetResponse::Err(format!(
                "seq {}: {rows}-row prefill overflows KV cache \
                 ({} of {} slots used)",
                task.seq_id, len, cache.capacity_per_seq,
            ));
        }
    }
    // all valid: apply (identical loop to rworker::worker::run_loop)
    let traced = track.is_enabled();
    let start = Instant::now();
    let mut append_time = Duration::ZERO;
    let mut total_rows = 0usize;
    let mut outs = Vec::with_capacity(tasks.len());
    for task in &tasks {
        let rows = task.q.len() / width;
        total_rows += rows;
        let mut o = vec![0.0f32; task.q.len()];
        for r in 0..rows {
            let s = r * width..(r + 1) * width;
            let t0 = traced.then(Instant::now);
            // validated above: only a pool-level invariant breach could
            // fail here, and that must still be routed, not a panic
            if let Err(e) = cache.append(
                task.seq_id,
                layer,
                &task.k_new[s.clone()],
                &task.v_new[s.clone()],
            ) {
                return NetResponse::Err(format!("{e:#}"));
            }
            if let Some(t0) = t0 {
                append_time += t0.elapsed();
            }
            let kv = match cache.get(task.seq_id, layer) {
                Ok(kv) => kv,
                Err(e) => return NetResponse::Err(format!("{e:#}")),
            };
            attend_paged(&kv, &task.q[s.clone()], &mut o[s.clone()], scratch);
        }
        outs.push((task.seq_id, o));
    }
    let busy = start.elapsed();
    track.record(
        "kv_append",
        start,
        start + append_time,
        &[("layer", layer as f64), ("rows", total_rows as f64)],
    );
    track.record(
        "attend",
        start,
        start + busy,
        &[
            ("layer", layer as f64),
            ("rows", total_rows as f64),
            ("tasks", tasks.len() as f64),
        ],
    );
    NetResponse::Outputs { layer, outs, busy }
}

/// Accept loop: every connection gets its own serving thread (one
/// R-socket each), all sharing ONE [`NodeShared`] — so a `NodeStats`
/// request on any connection (monitor connections included) reports
/// the whole node. Runs until the listener errors (or forever).
pub fn serve_listener(listener: TcpListener) -> Result<()> {
    let shared = Arc::new(NodeShared::new());
    for conn in listener.incoming() {
        match conn.and_then(|s| {
            s.peer_addr().map(|a| (s, a)) // name the thread after the peer
        }) {
            Ok((stream, peer)) => {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("rnode-{peer}"))
                    .spawn(move || match Tcp::from_stream(stream) {
                        Ok(t) => {
                            if let Err(e) =
                                serve_connection_shared(t, shared)
                            {
                                crate::obs::log!(
                                    Warn,
                                    "connection {peer}: {e:#}"
                                );
                            }
                        }
                        Err(e) => {
                            crate::obs::log!(Warn, "accepting {peer}: {e:#}")
                        }
                    })
                    .context("spawning connection thread")?;
            }
            Err(e) => crate::obs::log!(Error, "accept failed: {e}"),
        }
    }
    Ok(())
}

/// An in-process rnode listening on a real localhost TCP port — the
/// zero-process way to exercise the full wire path (benches, tests).
/// The accept thread is detached; it lives until process exit.
pub struct LocalRnode {
    pub addr: std::net::SocketAddr,
}

/// Bind `127.0.0.1:0` (ephemeral port) and serve connections on a
/// background thread. Real sockets, real frames — only the process
/// boundary is elided; the `rnode` binary is the same loop behind a
/// CLI.
pub fn spawn_local_listener() -> Result<LocalRnode> {
    let listener = TcpListener::bind(("127.0.0.1", 0))
        .context("binding rnode listener on localhost")?;
    let addr = listener.local_addr().context("resolving bound address")?;
    std::thread::Builder::new()
        .name(format!("rnode-listener-{addr}"))
        .spawn(move || {
            let _ = serve_listener(listener);
        })
        .context("spawning rnode listener thread")?;
    Ok(LocalRnode { addr })
}

/// A spawned `rnode` CHILD PROCESS (killed and reaped on drop) plus
/// its announced listen address — the shared process-management helper
/// behind `tests/net_remote.rs` and the fig13 `--tcp` sweep.
///
/// The executable path comes from the caller
/// (`env!("CARGO_BIN_EXE_rnode")`): cargo only sets that variable when
/// compiling integration tests and benches, so the library cannot read
/// it itself.
pub struct RnodeProcess {
    pub child: std::process::Child,
    pub addr: String,
}

impl Drop for RnodeProcess {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Launch `exe --listen 127.0.0.1:0` and parse the announced ephemeral
/// address from its stdout handshake line.
pub fn spawn_rnode_process(exe: &str) -> Result<RnodeProcess> {
    use std::io::BufRead as _;
    let mut child = std::process::Command::new(exe)
        .args(["--listen", "127.0.0.1:0"])
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .with_context(|| format!("spawning rnode at {exe}"))?;
    let stdout = child.stdout.take().context("rnode stdout not piped")?;
    let mut line = String::new();
    std::io::BufReader::new(stdout)
        .read_line(&mut line)
        .context("reading the rnode announce line")?;
    if !line.contains("rnode listening on") {
        let _ = child.kill();
        bail!("unexpected rnode announce line: {line:?}");
    }
    let addr = line
        .trim()
        .rsplit(' ')
        .next()
        .context("address missing from announce line")?
        .to_string();
    Ok(RnodeProcess { child, addr })
}

/// Bind-and-serve entry point shared by the `rnode` binary: binds
/// `addr`, announces the resolved address on stdout (so callers that
/// asked for port 0 learn the real port), then serves forever.
pub fn run_rnode<A: ToSocketAddrs + std::fmt::Debug>(addr: A) -> Result<()> {
    let listener = TcpListener::bind(&addr)
        .with_context(|| format!("binding rnode listener on {addr:?}"))?;
    let local = listener.local_addr().context("resolving bound address")?;
    // the "listening on" line is the machine-readable handshake the
    // tests and the fig13 --tcp sweep parse — keep the format stable
    println!("rnode listening on {local}");
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    serve_listener(listener)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Precision;
    use crate::net::codec::{encode_request, NodeConfig};
    use crate::net::transport::loopback_pair;

    fn cfg(wire: WireMode) -> NodeConfig {
        NodeConfig {
            n_heads: 2,
            head_dim: 4,
            n_layers: 1,
            capacity_per_seq: 8,
            block_size: 4,
            precision: Precision::F32,
            wire,
            trace: false,
        }
    }

    fn rpc(t: &mut impl Transport, req: &NetRequest, wire: WireMode) -> NetResponse {
        t.send(&encode_request(req, wire)).unwrap();
        super::super::codec::decode_response(&t.recv().unwrap(), wire).unwrap()
    }

    /// A node answers Err to a refused request and KEEPS SERVING —
    /// including after an undecodable frame (length-prefix framing
    /// keeps the stream synchronized).
    #[test]
    fn node_survives_refusals_and_malformed_frames() {
        let (server, mut client) = loopback_pair("rnode-test");
        let h = std::thread::spawn(move || serve_connection(server));
        let wire = WireMode::F32;
        assert_eq!(
            rpc(&mut client, &NetRequest::Configure(cfg(wire)), wire),
            NetResponse::Ack
        );
        // attend for an unplaced sequence → routed Err, nothing cached
        let bad = NetRequest::Attend {
            layer: 0,
            tasks: vec![SeqTask {
                seq_id: 7,
                q: vec![1.0; 8],
                k_new: vec![1.0; 8],
                v_new: vec![1.0; 8],
            }],
        };
        let NetResponse::Err(msg) = rpc(&mut client, &bad, wire) else {
            panic!("expected a routed error");
        };
        assert!(msg.contains("not placed"), "{msg}");
        // raw garbage → routed Err, still serving
        client.send(&[0xde, 0xad, 0xbe, 0xef]).unwrap();
        let resp = super::super::codec::decode_response(
            &client.recv().unwrap(),
            wire,
        )
        .unwrap();
        assert!(matches!(resp, NetResponse::Err(m) if m.contains("malformed")));
        // the node still works end to end
        assert_eq!(
            rpc(&mut client, &NetRequest::AddSeqs(vec![7]), wire),
            NetResponse::Ack
        );
        let NetResponse::Outputs { outs, .. } = rpc(&mut client, &bad, wire)
        else {
            panic!("expected outputs after placing the sequence");
        };
        assert_eq!(outs.len(), 1);
        // first token ⇒ o == v_new exactly (f32 cache, f32 wire)
        assert_eq!(outs[0].1, vec![1.0; 8]);
        // a rejected overflow appends NOTHING: capacity 8, one row used,
        // a 9-row task must leave the cache at 1 token
        let huge = NetRequest::Attend {
            layer: 0,
            tasks: vec![SeqTask {
                seq_id: 7,
                q: vec![1.0; 9 * 8],
                k_new: vec![1.0; 9 * 8],
                v_new: vec![1.0; 9 * 8],
            }],
        };
        assert!(matches!(
            rpc(&mut client, &huge, wire),
            NetResponse::Err(m) if m.contains("overflows")
        ));
        let NetResponse::Stats(st) =
            rpc(&mut client, &NetRequest::Stats, wire)
        else {
            panic!("expected stats");
        };
        assert_eq!(st.total_tokens, 1);
        rpc_shutdown(&mut client, wire);
        h.join().unwrap().unwrap();
    }

    fn rpc_shutdown(t: &mut impl Transport, wire: WireMode) {
        t.send(&encode_request(&NetRequest::Shutdown, wire)).unwrap();
    }

    /// One in-process TCP listener ([`spawn_local_listener`]) serves
    /// SEVERAL R-sockets — one per connection — through a full
    /// `RemotePool` round trip over real localhost sockets.
    #[test]
    fn local_listener_serves_multiple_sockets_per_listener() {
        use crate::net::remote::RemotePool;
        use crate::rworker::AttendBackend;
        let node = spawn_local_listener().unwrap();
        let addr = node.addr.to_string();
        let mut pool = RemotePool::connect_tcp(
            &[addr.clone(), addr],
            cfg(WireMode::F32),
        )
        .unwrap();
        // 1,3 → connection 0; 2,4 → connection 1 — two independent
        // SocketCaches behind ONE listener
        pool.add_seqs(&[1, 2, 3, 4]).unwrap();
        let tasks: Vec<SeqTask> = (1..=4)
            .map(|id| SeqTask {
                seq_id: id,
                q: vec![1.0; 8],
                k_new: vec![1.0; 8],
                v_new: vec![1.0; 8],
            })
            .collect();
        let step = pool.attend(0, tasks).unwrap();
        assert_eq!(step.outputs.len(), 4);
        // first token ⇒ o == v_new exactly (f32 cache, f32 wire)
        assert_eq!(step.outputs[&1], vec![1.0; 8]);
        let stats = pool.stats().unwrap();
        assert_eq!(stats.len(), 2);
        assert!(stats.iter().all(|s| s.sequences == 2), "{stats:?}");
    }

    /// A trace-enabled connection answers `Ping` with nondecreasing
    /// epoch-relative time and `FetchTrace` with the server-side spans
    /// (decode / attend / kv_append / encode / queue_wait) recorded
    /// since the last fetch — and a second fetch starts empty.
    #[test]
    fn traced_connection_serves_pings_and_trace_fetches() {
        let (server, mut client) = loopback_pair("rnode-trace");
        let h = std::thread::spawn(move || serve_connection(server));
        let wire = WireMode::F32;
        let config = NodeConfig {
            trace: true,
            ..cfg(wire)
        };
        assert_eq!(
            rpc(&mut client, &NetRequest::Configure(config), wire),
            NetResponse::Ack
        );
        let NetResponse::Pong { node_us: t1 } =
            rpc(&mut client, &NetRequest::Ping, wire)
        else {
            panic!("expected Pong");
        };
        assert_eq!(
            rpc(&mut client, &NetRequest::AddSeqs(vec![1]), wire),
            NetResponse::Ack
        );
        let attend = NetRequest::Attend {
            layer: 0,
            tasks: vec![SeqTask {
                seq_id: 1,
                q: vec![1.0; 8],
                k_new: vec![1.0; 8],
                v_new: vec![1.0; 8],
            }],
        };
        assert!(matches!(
            rpc(&mut client, &attend, wire),
            NetResponse::Outputs { .. }
        ));
        let NetResponse::Pong { node_us: t2 } =
            rpc(&mut client, &NetRequest::Ping, wire)
        else {
            panic!("expected Pong");
        };
        assert!(t2 >= t1, "node clock must be monotone: {t1} then {t2}");
        let NetResponse::Trace(spans) =
            rpc(&mut client, &NetRequest::FetchTrace, wire)
        else {
            panic!("expected Trace");
        };
        for name in ["queue_wait", "decode", "attend", "kv_append", "encode"] {
            assert!(
                spans.iter().any(|s| s.name == name),
                "missing {name} span in {spans:?}"
            );
        }
        let a = spans
            .iter()
            .find(|s| s.name == "attend")
            .expect("attend span");
        assert!(a
            .args
            .iter()
            .any(|(k, v)| k == "rows" && *v == 1.0), "{a:?}");
        assert!(spans.iter().all(|s| s.track == "rnode"));
        assert!(spans.iter().all(|s| s.ts_us >= 0.0 && s.dur_us >= 0.0));
        // drained: a second fetch only carries spans recorded since
        let NetResponse::Trace(again) =
            rpc(&mut client, &NetRequest::FetchTrace, wire)
        else {
            panic!("expected Trace");
        };
        assert!(
            !again.iter().any(|s| s.name == "attend"),
            "attend spans must not be re-shipped: {again:?}"
        );
        rpc_shutdown(&mut client, wire);
        h.join().unwrap().unwrap();
    }

    /// An untraced connection still answers Ping (clock sync works
    /// without tracing) and FetchTrace returns an empty batch.
    #[test]
    fn untraced_connection_pings_but_ships_no_spans() {
        let (server, mut client) = loopback_pair("rnode-untraced");
        let h = std::thread::spawn(move || serve_connection(server));
        let wire = WireMode::F32;
        assert_eq!(
            rpc(&mut client, &NetRequest::Configure(cfg(wire)), wire),
            NetResponse::Ack
        );
        assert!(matches!(
            rpc(&mut client, &NetRequest::Ping, wire),
            NetResponse::Pong { node_us } if node_us >= 0.0
        ));
        assert_eq!(
            rpc(&mut client, &NetRequest::FetchTrace, wire),
            NetResponse::Trace(Vec::new())
        );
        rpc_shutdown(&mut client, wire);
        h.join().unwrap().unwrap();
    }

    /// A monitor connection (first frame `NodeStats`, never
    /// configured) reads the LISTENER-WIDE live counters: attends
    /// served on a different, configured connection show up in the
    /// report, with cache occupancy and block accounting merged.
    #[test]
    fn monitor_connection_reports_listener_wide_counters() {
        use super::super::transport::Tcp;
        let node = spawn_local_listener().unwrap();
        let wire = WireMode::F32;
        // connection 1: a normal configured R-socket doing real work
        let mut worker = Tcp::connect(node.addr).unwrap();
        assert_eq!(
            rpc(&mut worker, &NetRequest::Configure(cfg(wire)), wire),
            NetResponse::Ack
        );
        assert_eq!(
            rpc(&mut worker, &NetRequest::AddSeqs(vec![1, 2]), wire),
            NetResponse::Ack
        );
        let attend = NetRequest::Attend {
            layer: 0,
            tasks: vec![SeqTask {
                seq_id: 1,
                q: vec![1.0; 2 * 8], // 2 rows of width 8
                k_new: vec![1.0; 2 * 8],
                v_new: vec![1.0; 2 * 8],
            }],
        };
        assert!(matches!(
            rpc(&mut worker, &attend, wire),
            NetResponse::Outputs { .. }
        ));
        // an unknown sequence → routed Err, counted as an error
        let bad = NetRequest::Attend {
            layer: 0,
            tasks: vec![SeqTask {
                seq_id: 99,
                q: vec![1.0; 8],
                k_new: vec![1.0; 8],
                v_new: vec![1.0; 8],
            }],
        };
        assert!(matches!(rpc(&mut worker, &bad, wire), NetResponse::Err(_)));
        // connection 2: a monitor that never configures
        let mut mon = Tcp::connect(node.addr).unwrap();
        let NetResponse::NodeStats(r) =
            rpc(&mut mon, &NetRequest::NodeStats, wire)
        else {
            panic!("expected NodeStats");
        };
        assert_eq!(r.connections, 2, "{r:?}");
        assert_eq!(r.attend_ops, 1, "{r:?}");
        assert_eq!(r.attend_rows, 2, "{r:?}");
        assert_eq!(r.attend_errors, 1, "{r:?}");
        assert_eq!(r.cache.sequences, 2, "{r:?}");
        assert_eq!(r.cache.total_tokens, 2, "{r:?}");
        assert!(r.blocks_used >= 1, "{r:?}");
        assert!(r.uptime_us > 0, "{r:?}");
        assert!(r.service_p99_us >= r.service_p50_us, "{r:?}");
        // drift-free by the pinned overhead formulas
        assert_eq!(
            r.modeled_payload_bytes, r.measured_payload_bytes,
            "{r:?}"
        );
        assert!(r.modeled_payload_bytes > 0, "{r:?}");
        // the monitor also answers Ping, and refuses real work
        assert!(matches!(
            rpc(&mut mon, &NetRequest::Ping, wire),
            NetResponse::Pong { .. }
        ));
        assert!(matches!(
            rpc(&mut mon, &NetRequest::Stats, wire),
            NetResponse::Err(m) if m.contains("monitor")
        ));
        // dropping the worker shrinks the connection count and removes
        // its cache from the merge
        rpc_shutdown(&mut worker, wire);
        drop(worker);
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let NetResponse::NodeStats(r2) =
                rpc(&mut mon, &NetRequest::NodeStats, wire)
            else {
                panic!("expected NodeStats");
            };
            if r2.connections == 1 && r2.cache.sequences == 0 {
                // cumulative counters survive the connection
                assert_eq!(r2.attend_ops, 1, "{r2:?}");
                break;
            }
            assert!(
                Instant::now() < deadline,
                "worker teardown never reflected: {r2:?}"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// First frame must be Configure; anything else is refused and the
    /// connection is torn down with the cause.
    #[test]
    fn unconfigured_connection_is_refused() {
        let (server, mut client) = loopback_pair("rnode-test");
        let h = std::thread::spawn(move || serve_connection(server));
        client
            .send(&encode_request(&NetRequest::Stats, WireMode::F32))
            .unwrap();
        let resp = super::super::codec::decode_response(
            &client.recv().unwrap(),
            WireMode::F32,
        )
        .unwrap();
        assert!(matches!(resp, NetResponse::Err(m) if m.contains("Configure")));
        let err = h.join().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("Configure"), "{err:#}");
    }
}
