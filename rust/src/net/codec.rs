//! Hand-rolled length-prefixed binary wire codec for the S↔R boundary
//! (no serde in the offline build).
//!
//! A frame is the body encoded here; the [`super::Transport`] adds the
//! `u32` little-endian length prefix on the wire. Bodies are
//! `[u8 tag][fields…]` with fixed-width little-endian integers and
//! `u32`-counted vectors.
//!
//! Activation payloads (`q`/`k_new`/`v_new`/`o`) are encoded per the
//! connection's [`WireMode`]:
//!
//! * `F32` — raw `f32::to_bits` little-endian (4 B/elem). Decode is
//!   bit-identical to what an in-process backend would have passed by
//!   reference, which is what pins loopback == threads.
//! * `F16` — `util::f16::f32_to_f16_bits` little-endian (2 B/elem),
//!   the paper's fp16 intermediate-vector format (Table 3): the frame
//!   payload is byte-for-byte the size `transport::qkv_message_bytes` /
//!   `o_message_bytes` charge, so modeled cost and shipped bytes
//!   cannot drift (pinned in `tests/net_remote.rs`).
//!
//! Every decoder is total: truncated buffers, unknown tags, absurd
//! counts and trailing garbage return `Err` (→ a routed error at the
//! pool/node layer), never a panic or an out-of-bounds read.

use std::time::Duration;

use anyhow::{bail, Context as _, Result};

use crate::kvcache::CacheStats;
use crate::model::Precision;
use crate::obs::TraceSpan;
use crate::rworker::SeqTask;
use crate::util::f16::{f16_bits_to_f32_slow, f32_to_f16_bits, F16};

/// Hard ceiling on one frame body — a length prefix above this is a
/// malformed (or hostile) frame, not an allocation request.
pub const MAX_FRAME_BYTES: usize = 1 << 28; // 256 MiB

/// How activation vectors are packed on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireMode {
    /// Raw f32 bits — bit-identical to in-process hand-off.
    F32,
    /// IEEE binary16 — the paper's fp16 intermediate vectors; halves
    /// the activation bytes at ≤ 2⁻¹¹ relative rounding per element.
    F16,
}

impl WireMode {
    pub fn bytes_per_elem(self) -> usize {
        match self {
            WireMode::F32 => 4,
            WireMode::F16 => 2,
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            WireMode::F32 => 0,
            WireMode::F16 => 1,
        }
    }

    fn from_u8(b: u8) -> Result<WireMode> {
        match b {
            0 => Ok(WireMode::F32),
            1 => Ok(WireMode::F16),
            other => bail!("unknown wire mode {other}"),
        }
    }
}

/// Encoded payload bytes of one activation vector of `elems` f32
/// elements (excluding its `u32` length header) — the codec-side
/// ground truth the `LinkModel` byte accounting is pinned against.
pub fn vec_payload_bytes(elems: usize, mode: WireMode) -> usize {
    elems * mode.bytes_per_elem()
}

/// Framing bytes of an `Attend` request with `n_tasks` tasks —
/// everything in the frame that is NOT activation payload (tag, layer,
/// task count, per-task seq id + three vector headers). Frame length −
/// this = payload bytes, which is what the `RemotePool` drift detector
/// compares against the `LinkModel`-modeled bytes.
pub fn attend_request_overhead_bytes(n_tasks: usize) -> usize {
    1 + 4 + 4 + n_tasks * (8 + 3 * 4)
}

/// Framing bytes of an `Outputs` response with `n_outs` outputs (tag,
/// layer, busy nanos, out count, per-output seq id + vector header).
pub fn outputs_response_overhead_bytes(n_outs: usize) -> usize {
    1 + 4 + 8 + 4 + n_outs * (8 + 4)
}

/// Everything an `rnode` needs to provision one R-socket. Sent as the
/// first frame on every connection; the node replies `Ack` and the
/// connection's wire mode is fixed from then on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NodeConfig {
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_layers: usize,
    pub capacity_per_seq: usize,
    /// Tokens per KV block in the node's paged allocator — part of the
    /// handshake so every node in a pool pages identically and fork
    /// points mean the same thing everywhere.
    pub block_size: usize,
    /// KV-cache storage precision ON the node (independent of the wire
    /// mode the activations travel in).
    pub precision: Precision,
    pub wire: WireMode,
    /// Enable the node's server-side tracer for this connection: the
    /// node records queue-wait/decode/append+attend/encode spans
    /// against its own epoch, fetched later via
    /// `NetRequest::FetchTrace`.
    pub trace: bool,
}

impl NodeConfig {
    /// Node provisioning matching an in-process `RPool::spawn` for
    /// `spec` (whose `n_layers` must already be the instantiated layer
    /// count, as `FastDecode` does).
    pub fn from_spec(
        spec: &crate::model::ModelSpec,
        capacity_per_seq: usize,
        block_size: usize,
        precision: Precision,
        wire: WireMode,
    ) -> NodeConfig {
        NodeConfig {
            n_heads: spec.n_heads,
            head_dim: spec.head_dim(),
            n_layers: spec.n_layers,
            capacity_per_seq,
            block_size,
            precision,
            wire,
            trace: false,
        }
    }

    /// Builder-style toggle for server-side tracing.
    pub fn with_trace(mut self, trace: bool) -> NodeConfig {
        self.trace = trace;
        self
    }
}

/// A node's live self-reported snapshot — the reply to
/// [`NetRequest::NodeStats`], and what the `fdtop` poller renders.
/// All counters are cumulative since the LISTENER started (one report
/// covers every connection the node has served, cache occupancy merged
/// across live connections), so a monitor connection sees the whole
/// node, not just itself.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NodeStatsReport {
    /// Microseconds since the node's listener started.
    pub uptime_us: u64,
    /// Connections currently open (monitor connections included).
    pub connections: u64,
    /// Attend requests served successfully.
    pub attend_ops: u64,
    /// Rows (tokens) appended+attended across all attends.
    pub attend_rows: u64,
    /// Requests answered with a routed `Err`.
    pub attend_errors: u64,
    /// Σ idle time between finishing one frame and receiving the next.
    pub queue_wait_us: u64,
    /// Σ attend busy time (the `Outputs::busy` the node reported).
    pub busy_us: u64,
    /// p50 of per-attend service time (µs, 0 until the first attend).
    pub service_p50_us: u64,
    /// p99 of per-attend service time (µs, 0 until the first attend).
    pub service_p99_us: u64,
    /// Activation bytes the `LinkModel` WOULD charge for the attends
    /// served (3 vectors × elems × wire bytes/elem).
    pub modeled_payload_bytes: u64,
    /// Activation bytes actually received (frame − framing overhead).
    pub measured_payload_bytes: u64,
    /// KV blocks currently live across the node's caches.
    pub blocks_used: u64,
    /// Freed block slots available for reuse (arena free list).
    pub blocks_free: u64,
    /// Cache occupancy merged across the node's live connections.
    pub cache: CacheStats,
}

impl NodeStatsReport {
    /// Logical/allocated KV utilization (see [`CacheStats::utilization`]).
    pub fn kv_utilization(&self) -> f64 {
        self.cache.utilization()
    }

    /// Relative payload drift measured/modeled − 1 (0.0 when nothing
    /// has shipped); nonzero means the byte accounting lies.
    pub fn payload_drift(&self) -> f64 {
        if self.modeled_payload_bytes == 0 {
            0.0
        } else {
            self.measured_payload_bytes as f64
                / self.modeled_payload_bytes as f64
                - 1.0
        }
    }

    /// Attend rows per second of uptime — the coarse live throughput
    /// `fdtop --once` shows (interval polling uses deltas instead).
    pub fn rows_per_uptime_s(&self) -> f64 {
        if self.uptime_us == 0 {
            0.0
        } else {
            self.attend_rows as f64 / (self.uptime_us as f64 / 1e6)
        }
    }
}

/// Client → node. Mirrors `rworker::RRequest` plus the connection
/// handshake.
#[derive(Clone, Debug, PartialEq)]
pub enum NetRequest {
    Configure(NodeConfig),
    AddSeqs(Vec<u64>),
    DropSeqs(Vec<u64>),
    Attend { layer: usize, tasks: Vec<SeqTask> },
    /// COW-fork `child` off `parent`'s first `upto` tokens (all
    /// layers) — prefix sharing across the wire.
    ForkSeq { parent: u64, child: u64, upto: usize },
    Stats,
    /// Clock-sync probe: the node answers `Pong` with its epoch-
    /// relative time in µs. The client timestamps send and receive;
    /// the minimum-RTT sample's midpoint estimates the clock offset
    /// that maps remote trace spans onto the local timeline.
    Ping,
    /// Drain the node's server-side trace buffer (`Trace` reply).
    /// Spans are consumed: a second fetch returns only new ones.
    FetchTrace,
    /// Ask the node for its live self-report (`NodeStats` reply).
    /// Unlike every other request, this one (and `Ping`) is also legal
    /// as the FIRST frame of a connection — a monitor connection that
    /// never configures, which is how `fdtop` polls a serving node
    /// without disturbing it.
    NodeStats,
    Shutdown,
}

/// Node → client. Mirrors `rworker::RResponse` plus the routed error
/// variant: a node that refuses a request (unknown sequence, capacity
/// overflow, malformed frame) answers `Err` and KEEPS SERVING — the
/// remote counterpart of PR 3's `SResp::Err` discipline.
#[derive(Clone, Debug, PartialEq)]
pub enum NetResponse {
    Ack,
    Outputs {
        layer: usize,
        outs: Vec<(u64, Vec<f32>)>,
        busy: Duration,
    },
    Stats(CacheStats),
    /// Reply to `Ping`: microseconds since the node's tracer epoch
    /// (its connection-accept instant) at the moment the request was
    /// handled.
    Pong { node_us: f64 },
    /// Reply to `FetchTrace`: the node's drained span batch, still
    /// timestamped against the NODE's epoch — `Tracer::merge_remote`
    /// remaps them client-side.
    Trace(Vec<TraceSpan>),
    /// Reply to `NodeStats`: the node's live self-reported counters
    /// (listener-wide, cache merged across connections).
    NodeStats(NodeStatsReport),
    Err(String),
}

// ── request/response tags ────────────────────────────────────────────

const REQ_CONFIGURE: u8 = 1;
const REQ_ADD_SEQS: u8 = 2;
const REQ_DROP_SEQS: u8 = 3;
const REQ_ATTEND: u8 = 4;
const REQ_STATS: u8 = 5;
const REQ_SHUTDOWN: u8 = 6;
const REQ_FORK_SEQ: u8 = 7;
const REQ_PING: u8 = 8;
const REQ_FETCH_TRACE: u8 = 9;
const REQ_NODE_STATS: u8 = 10;

const RESP_ACK: u8 = 1;
const RESP_OUTPUTS: u8 = 2;
const RESP_STATS: u8 = 3;
const RESP_ERR: u8 = 4;
const RESP_PONG: u8 = 5;
const RESP_TRACE: u8 = 6;
const RESP_NODE_STATS: u8 = 7;

fn precision_to_u8(p: Precision) -> u8 {
    match p {
        Precision::F32 => 0,
        Precision::F16 => 1,
        Precision::Int8 => 2,
        Precision::Int4 => 3,
    }
}

fn precision_from_u8(b: u8) -> Result<Precision> {
    match b {
        0 => Ok(Precision::F32),
        1 => Ok(Precision::F16),
        2 => Ok(Precision::Int8),
        3 => Ok(Precision::Int4),
        other => bail!("unknown precision {other}"),
    }
}

// ── little-endian primitives ─────────────────────────────────────────

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// f64 as raw IEEE bits — trace timestamps/args cross bit-exactly.
fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Bounds-checked cursor over a frame body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let s = &self.buf[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => bail!(
                "truncated frame: wanted {n} bytes at offset {} of {}",
                self.pos,
                self.buf.len()
            ),
        }
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        // fdlint: allow(no-unwrap-in-routed): take(4) guarantees a 4-byte slice, the try_into is infallible
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        // fdlint: allow(no-unwrap-in-routed): take(8) guarantees an 8-byte slice, the try_into is infallible
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A `u32` element count that still has to fit in the remaining
    /// bytes at `min_elem_bytes` each — rejects absurd counts before
    /// any allocation.
    fn count(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let remaining = self.buf.len() - self.pos;
        if n.saturating_mul(min_elem_bytes) > remaining {
            bail!(
                "malformed frame: count {n} needs ≥ {} bytes, {} remain",
                n * min_elem_bytes,
                remaining
            );
        }
        Ok(n)
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "malformed frame: {} trailing bytes",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

// ── f32 vectors in the connection's wire mode ────────────────────────

fn put_f32_vec(buf: &mut Vec<u8>, v: &[f32], mode: WireMode) {
    put_u32(buf, v.len() as u32);
    match mode {
        WireMode::F32 => {
            for &x in v {
                buf.extend_from_slice(&x.to_bits().to_le_bytes());
            }
        }
        WireMode::F16 => {
            for &x in v {
                buf.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
            }
        }
    }
}

fn get_f32_vec(c: &mut Cursor, mode: WireMode) -> Result<Vec<f32>> {
    let n = c.count(mode.bytes_per_elem())?;
    let raw = c.take(n * mode.bytes_per_elem())?;
    Ok(match mode {
        WireMode::F32 => raw
            .chunks_exact(4)
            // fdlint: allow(no-unwrap-in-routed): chunks_exact(4) yields 4-byte slices, the try_into is infallible
            .map(|b| f32::from_bits(u32::from_le_bytes(b.try_into().unwrap())))
            .collect(),
        WireMode::F16 => raw
            .chunks_exact(2)
            .map(|b| {
                // LUT-free decode: frames may legally carry inf/nan
                // (an upstream overflow), which `to_f32_finite` would
                // mangle
                f16_bits_to_f32_slow(u16::from_le_bytes(
                    // fdlint: allow(no-unwrap-in-routed): chunks_exact(2) yields 2-byte slices, the try_into is infallible
                    b.try_into().unwrap(),
                ))
            })
            .collect(),
    })
}

fn put_u64_vec(buf: &mut Vec<u8>, v: &[u64]) {
    put_u32(buf, v.len() as u32);
    for &x in v {
        put_u64(buf, x);
    }
}

fn get_u64_vec(c: &mut Cursor) -> Result<Vec<u64>> {
    let n = c.count(8)?;
    (0..n).map(|_| c.u64()).collect()
}

fn get_f64(c: &mut Cursor) -> Result<f64> {
    Ok(f64::from_bits(c.u64()?))
}

fn get_str(c: &mut Cursor) -> Result<String> {
    let n = c.count(1)?;
    Ok(String::from_utf8_lossy(c.take(n)?).into_owned())
}

// ── trace spans on the wire ──────────────────────────────────────────

fn put_trace_span(buf: &mut Vec<u8>, s: &TraceSpan) {
    put_str(buf, &s.track);
    put_str(buf, &s.name);
    buf.push(s.instant as u8);
    put_f64(buf, s.ts_us);
    put_f64(buf, s.dur_us);
    put_u32(buf, s.args.len() as u32);
    for (k, v) in &s.args {
        put_str(buf, k);
        put_f64(buf, *v);
    }
}

fn get_trace_span(c: &mut Cursor) -> Result<TraceSpan> {
    let track = get_str(c)?;
    let name = get_str(c)?;
    let instant = c.u8()? != 0;
    let ts_us = get_f64(c)?;
    let dur_us = get_f64(c)?;
    // an arg is ≥ 4 (key header) + 8 (f64) bytes
    let n = c.count(12)?;
    let mut args = Vec::with_capacity(n);
    for _ in 0..n {
        let k = get_str(c)?;
        let v = get_f64(c)?;
        args.push((k, v));
    }
    Ok(TraceSpan {
        track,
        name,
        instant,
        ts_us,
        dur_us,
        args,
    })
}

// ── requests ─────────────────────────────────────────────────────────

/// Encode one request body (the transport adds the length prefix).
pub fn encode_request(req: &NetRequest, mode: WireMode) -> Vec<u8> {
    let mut buf = Vec::new();
    match req {
        NetRequest::Configure(c) => {
            buf.push(REQ_CONFIGURE);
            put_u32(&mut buf, c.n_heads as u32);
            put_u32(&mut buf, c.head_dim as u32);
            put_u32(&mut buf, c.n_layers as u32);
            put_u32(&mut buf, c.capacity_per_seq as u32);
            put_u32(&mut buf, c.block_size as u32);
            buf.push(precision_to_u8(c.precision));
            buf.push(c.wire.to_u8());
            buf.push(c.trace as u8);
        }
        NetRequest::AddSeqs(ids) => {
            buf.push(REQ_ADD_SEQS);
            put_u64_vec(&mut buf, ids);
        }
        NetRequest::DropSeqs(ids) => {
            buf.push(REQ_DROP_SEQS);
            put_u64_vec(&mut buf, ids);
        }
        NetRequest::Attend { layer, tasks } => {
            buf.push(REQ_ATTEND);
            put_u32(&mut buf, *layer as u32);
            put_u32(&mut buf, tasks.len() as u32);
            for t in tasks {
                put_u64(&mut buf, t.seq_id);
                put_f32_vec(&mut buf, &t.q, mode);
                put_f32_vec(&mut buf, &t.k_new, mode);
                put_f32_vec(&mut buf, &t.v_new, mode);
            }
        }
        NetRequest::ForkSeq { parent, child, upto } => {
            buf.push(REQ_FORK_SEQ);
            put_u64(&mut buf, *parent);
            put_u64(&mut buf, *child);
            put_u64(&mut buf, *upto as u64);
        }
        NetRequest::Stats => buf.push(REQ_STATS),
        NetRequest::Ping => buf.push(REQ_PING),
        NetRequest::FetchTrace => buf.push(REQ_FETCH_TRACE),
        NetRequest::NodeStats => buf.push(REQ_NODE_STATS),
        NetRequest::Shutdown => buf.push(REQ_SHUTDOWN),
    }
    buf
}

/// Decode one request body. `mode` governs the activation payloads
/// (fixed per connection by the `Configure` handshake, which itself
/// carries no activations and decodes identically under either mode).
pub fn decode_request(buf: &[u8], mode: WireMode) -> Result<NetRequest> {
    let mut c = Cursor::new(buf);
    let req = match c.u8().context("empty frame")? {
        REQ_CONFIGURE => NetRequest::Configure(NodeConfig {
            n_heads: c.u32()? as usize,
            head_dim: c.u32()? as usize,
            n_layers: c.u32()? as usize,
            capacity_per_seq: c.u32()? as usize,
            block_size: c.u32()? as usize,
            precision: precision_from_u8(c.u8()?)?,
            wire: WireMode::from_u8(c.u8()?)?,
            trace: c.u8()? != 0,
        }),
        REQ_ADD_SEQS => NetRequest::AddSeqs(get_u64_vec(&mut c)?),
        REQ_DROP_SEQS => NetRequest::DropSeqs(get_u64_vec(&mut c)?),
        REQ_ATTEND => {
            let layer = c.u32()? as usize;
            // a task is ≥ 8 (seq id) + 3 × 4 (vector headers) bytes
            let n = c.count(20)?;
            let mut tasks = Vec::with_capacity(n);
            for _ in 0..n {
                tasks.push(SeqTask {
                    seq_id: c.u64()?,
                    q: get_f32_vec(&mut c, mode)?,
                    k_new: get_f32_vec(&mut c, mode)?,
                    v_new: get_f32_vec(&mut c, mode)?,
                });
            }
            NetRequest::Attend { layer, tasks }
        }
        REQ_FORK_SEQ => NetRequest::ForkSeq {
            parent: c.u64()?,
            child: c.u64()?,
            upto: c.u64()? as usize,
        },
        REQ_STATS => NetRequest::Stats,
        REQ_PING => NetRequest::Ping,
        REQ_FETCH_TRACE => NetRequest::FetchTrace,
        REQ_NODE_STATS => NetRequest::NodeStats,
        REQ_SHUTDOWN => NetRequest::Shutdown,
        tag => bail!("unknown request tag {tag}"),
    };
    c.finish()?;
    Ok(req)
}

// ── responses ────────────────────────────────────────────────────────

/// Encode one response body.
pub fn encode_response(resp: &NetResponse, mode: WireMode) -> Vec<u8> {
    let mut buf = Vec::new();
    match resp {
        NetResponse::Ack => buf.push(RESP_ACK),
        NetResponse::Outputs { layer, outs, busy } => {
            buf.push(RESP_OUTPUTS);
            put_u32(&mut buf, *layer as u32);
            put_u64(&mut buf, busy.as_nanos() as u64);
            put_u32(&mut buf, outs.len() as u32);
            for (id, o) in outs {
                put_u64(&mut buf, *id);
                put_f32_vec(&mut buf, o, mode);
            }
        }
        NetResponse::Stats(st) => {
            buf.push(RESP_STATS);
            put_u64(&mut buf, st.sequences as u64);
            put_u64(&mut buf, st.total_tokens as u64);
            put_u64(&mut buf, st.physical_tokens as u64);
            put_u64(&mut buf, st.allocated_bytes as u64);
            put_u64(&mut buf, st.logical_bytes as u64);
        }
        NetResponse::Pong { node_us } => {
            buf.push(RESP_PONG);
            put_f64(&mut buf, *node_us);
        }
        NetResponse::Trace(spans) => {
            buf.push(RESP_TRACE);
            put_u32(&mut buf, spans.len() as u32);
            for s in spans {
                put_trace_span(&mut buf, s);
            }
        }
        NetResponse::NodeStats(r) => {
            buf.push(RESP_NODE_STATS);
            put_u64(&mut buf, r.uptime_us);
            put_u64(&mut buf, r.connections);
            put_u64(&mut buf, r.attend_ops);
            put_u64(&mut buf, r.attend_rows);
            put_u64(&mut buf, r.attend_errors);
            put_u64(&mut buf, r.queue_wait_us);
            put_u64(&mut buf, r.busy_us);
            put_u64(&mut buf, r.service_p50_us);
            put_u64(&mut buf, r.service_p99_us);
            put_u64(&mut buf, r.modeled_payload_bytes);
            put_u64(&mut buf, r.measured_payload_bytes);
            put_u64(&mut buf, r.blocks_used);
            put_u64(&mut buf, r.blocks_free);
            put_u64(&mut buf, r.cache.sequences as u64);
            put_u64(&mut buf, r.cache.total_tokens as u64);
            put_u64(&mut buf, r.cache.physical_tokens as u64);
            put_u64(&mut buf, r.cache.allocated_bytes as u64);
            put_u64(&mut buf, r.cache.logical_bytes as u64);
        }
        NetResponse::Err(msg) => {
            buf.push(RESP_ERR);
            let bytes = msg.as_bytes();
            put_u32(&mut buf, bytes.len() as u32);
            buf.extend_from_slice(bytes);
        }
    }
    buf
}

/// Decode one response body.
pub fn decode_response(buf: &[u8], mode: WireMode) -> Result<NetResponse> {
    let mut c = Cursor::new(buf);
    let resp = match c.u8().context("empty frame")? {
        RESP_ACK => NetResponse::Ack,
        RESP_OUTPUTS => {
            let layer = c.u32()? as usize;
            let busy = Duration::from_nanos(c.u64()?);
            // an output is ≥ 8 (seq id) + 4 (vector header) bytes
            let n = c.count(12)?;
            let mut outs = Vec::with_capacity(n);
            for _ in 0..n {
                let id = c.u64()?;
                outs.push((id, get_f32_vec(&mut c, mode)?));
            }
            NetResponse::Outputs { layer, outs, busy }
        }
        RESP_STATS => NetResponse::Stats(CacheStats {
            sequences: c.u64()? as usize,
            total_tokens: c.u64()? as usize,
            physical_tokens: c.u64()? as usize,
            allocated_bytes: c.u64()? as usize,
            logical_bytes: c.u64()? as usize,
        }),
        RESP_PONG => NetResponse::Pong { node_us: get_f64(&mut c)? },
        RESP_TRACE => {
            // a span is ≥ 2 string headers + instant + ts + dur + arg
            // count = 4 + 4 + 1 + 8 + 8 + 4 bytes
            let n = c.count(29)?;
            let mut spans = Vec::with_capacity(n);
            for _ in 0..n {
                spans.push(get_trace_span(&mut c)?);
            }
            NetResponse::Trace(spans)
        }
        RESP_NODE_STATS => NetResponse::NodeStats(NodeStatsReport {
            uptime_us: c.u64()?,
            connections: c.u64()?,
            attend_ops: c.u64()?,
            attend_rows: c.u64()?,
            attend_errors: c.u64()?,
            queue_wait_us: c.u64()?,
            busy_us: c.u64()?,
            service_p50_us: c.u64()?,
            service_p99_us: c.u64()?,
            modeled_payload_bytes: c.u64()?,
            measured_payload_bytes: c.u64()?,
            blocks_used: c.u64()?,
            blocks_free: c.u64()?,
            cache: CacheStats {
                sequences: c.u64()? as usize,
                total_tokens: c.u64()? as usize,
                physical_tokens: c.u64()? as usize,
                allocated_bytes: c.u64()? as usize,
                logical_bytes: c.u64()? as usize,
            },
        }),
        RESP_ERR => {
            let n = c.count(1)?;
            let msg = String::from_utf8_lossy(c.take(n)?).into_owned();
            NetResponse::Err(msg)
        }
        tag => bail!("unknown response tag {tag}"),
    };
    c.finish()?;
    Ok(resp)
}

/// What one f32 value becomes after an f16 wire crossing — the exact
/// lossy map `WireMode::F16` applies, for tests that predict decoded
/// payloads.
pub fn f16_wire_roundtrip(x: f32) -> f32 {
    F16(f32_to_f16_bits(x)).to_f32()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn task(g: &mut prop::Gen, seq_id: u64, rows: usize, width: usize) -> SeqTask {
        SeqTask {
            seq_id,
            q: g.vec_normal(rows * width, 1.0),
            k_new: g.vec_normal(rows * width, 1.0),
            v_new: g.vec_normal(rows * width, 1.0),
        }
    }

    fn req_roundtrip(req: &NetRequest, mode: WireMode) -> NetRequest {
        decode_request(&encode_request(req, mode), mode).expect("decode")
    }

    fn resp_roundtrip(resp: &NetResponse, mode: WireMode) -> NetResponse {
        decode_response(&encode_response(resp, mode), mode).expect("decode")
    }

    /// Property: EVERY request variant round-trips bit-identically in
    /// f32 wire mode, over ragged multi-row tasks (decode rows, T > 1
    /// prefill rows, empty task lists) and extreme sequence ids.
    #[test]
    fn prop_request_roundtrip_f32_exact() {
        prop::check("net-req-roundtrip-f32", 40, |g| {
            let width = *g.pick(&[4usize, 8, 24]);
            let n_tasks = g.usize_in(0, 5);
            let tasks: Vec<SeqTask> = (0..n_tasks)
                .map(|i| {
                    let id = if g.bool() {
                        g.u64_in(0, 1 << 40)
                    } else {
                        u64::MAX - i as u64 // max-range ids must survive
                    };
                    let rows = g.usize_in(1, 7); // ragged: 1..=6 rows
                    task(g, id, rows, width)
                })
                .collect();
            let reqs = [
                NetRequest::Attend {
                    layer: g.usize_in(0, 1 << 16),
                    tasks,
                },
                NetRequest::AddSeqs(vec![0, 7, u64::MAX]),
                NetRequest::DropSeqs(vec![]),
                NetRequest::ForkSeq {
                    parent: g.u64_in(0, u64::MAX),
                    child: g.u64_in(0, u64::MAX),
                    upto: g.usize_in(0, 1 << 20),
                },
                NetRequest::Stats,
                NetRequest::Ping,
                NetRequest::FetchTrace,
                NetRequest::NodeStats,
                NetRequest::Shutdown,
                NetRequest::Configure(NodeConfig {
                    n_heads: g.usize_in(1, 64),
                    head_dim: g.usize_in(1, 256),
                    n_layers: g.usize_in(1, 80),
                    capacity_per_seq: g.usize_in(1, 1 << 20),
                    block_size: g.usize_in(1, 1 << 10),
                    precision: *g.pick(&[
                        Precision::F32,
                        Precision::F16,
                        Precision::Int8,
                        Precision::Int4,
                    ]),
                    wire: *g.pick(&[WireMode::F32, WireMode::F16]),
                    trace: g.bool(),
                }),
            ];
            for req in &reqs {
                assert_eq!(&req_roundtrip(req, WireMode::F32), req);
            }
        });
    }

    /// Property: f16 wire mode loses exactly `f16_wire_roundtrip` per
    /// element — no more (the codec adds no error of its own), and a
    /// second crossing is the identity (f16 values are f16-exact).
    #[test]
    fn prop_request_roundtrip_f16_is_f16_quantization() {
        prop::check("net-req-roundtrip-f16", 40, |g| {
            let width = *g.pick(&[4usize, 16]);
            let rows = g.usize_in(1, 5);
            let id = g.u64_in(0, u64::MAX);
            let t = task(g, id, rows, width);
            let req = NetRequest::Attend {
                layer: 3,
                tasks: vec![t.clone()],
            };
            let once = req_roundtrip(&req, WireMode::F16);
            let NetRequest::Attend { tasks, .. } = &once else {
                panic!("variant changed");
            };
            for (wire, orig) in [
                (&tasks[0].q, &t.q),
                (&tasks[0].k_new, &t.k_new),
                (&tasks[0].v_new, &t.v_new),
            ] {
                assert_eq!(wire.len(), orig.len());
                for (w, o) in wire.iter().zip(orig) {
                    assert_eq!(*w, f16_wire_roundtrip(*o));
                }
            }
            // idempotent: crossing the wire again changes nothing
            assert_eq!(req_roundtrip(&once, WireMode::F16), once);
        });
    }

    /// Property: every response variant round-trips, incl. `Err` (the
    /// routed-error path) and multi-row outputs.
    #[test]
    fn prop_response_roundtrip() {
        prop::check("net-resp-roundtrip", 40, |g| {
            let n = g.usize_in(0, 4);
            let outs: Vec<(u64, Vec<f32>)> = (0..n)
                .map(|_| {
                    (
                        g.u64_in(0, u64::MAX),
                        g.vec_normal(g.usize_in(1, 4) * 8, 1.0),
                    )
                })
                .collect();
            let resps = [
                NetResponse::Ack,
                NetResponse::Outputs {
                    layer: g.usize_in(0, 100),
                    outs,
                    busy: Duration::from_nanos(g.u64_in(0, u64::MAX >> 1)),
                },
                NetResponse::Stats(CacheStats {
                    sequences: g.usize_in(0, 1 << 30),
                    total_tokens: g.usize_in(0, 1 << 40),
                    physical_tokens: g.usize_in(0, 1 << 40),
                    allocated_bytes: g.usize_in(0, 1 << 40),
                    logical_bytes: g.usize_in(0, 1 << 40),
                }),
                NetResponse::Pong {
                    node_us: g.u64_in(0, 1 << 50) as f64 / 8.0,
                },
                NetResponse::Trace(
                    (0..g.usize_in(0, 4))
                        .map(|i| TraceSpan {
                            track: format!("rnode{i}"),
                            name: "attend".into(),
                            instant: g.bool(),
                            ts_us: g.u64_in(0, 1 << 40) as f64 / 4.0,
                            dur_us: g.u64_in(0, 1 << 30) as f64 / 4.0,
                            args: vec![
                                ("layer".to_string(), 3.0),
                                ("rows \u{1F4A3}".to_string(), -1.5),
                            ],
                        })
                        .collect(),
                ),
                NetResponse::NodeStats(NodeStatsReport {
                    uptime_us: g.u64_in(0, 1 << 50),
                    connections: g.u64_in(0, 1 << 10),
                    attend_ops: g.u64_in(0, 1 << 40),
                    attend_rows: g.u64_in(0, 1 << 40),
                    attend_errors: g.u64_in(0, 1 << 20),
                    queue_wait_us: g.u64_in(0, 1 << 50),
                    busy_us: g.u64_in(0, 1 << 50),
                    service_p50_us: g.u64_in(0, 1 << 30),
                    service_p99_us: g.u64_in(0, 1 << 30),
                    modeled_payload_bytes: g.u64_in(0, 1 << 40),
                    measured_payload_bytes: g.u64_in(0, 1 << 40),
                    blocks_used: g.u64_in(0, 1 << 30),
                    blocks_free: g.u64_in(0, 1 << 30),
                    cache: CacheStats {
                        sequences: g.usize_in(0, 1 << 30),
                        total_tokens: g.usize_in(0, 1 << 40),
                        physical_tokens: g.usize_in(0, 1 << 40),
                        allocated_bytes: g.usize_in(0, 1 << 40),
                        logical_bytes: g.usize_in(0, 1 << 40),
                    },
                }),
                NetResponse::Err(
                    "node 1 refused: seq 9 not placed \u{1F4A3}".into(),
                ),
            ];
            for resp in &resps {
                assert_eq!(&resp_roundtrip(resp, WireMode::F32), resp);
            }
        });
    }

    /// Property: mutilated frames (truncation at every length, tag
    /// corruption, trailing garbage, hostile counts) decode to `Err`,
    /// never a panic.
    #[test]
    fn prop_malformed_frames_error_cleanly() {
        prop::check("net-malformed", 30, |g| {
            let t = task(g, 42, 2, 8);
            let frame = encode_request(
                &NetRequest::Attend {
                    layer: 1,
                    tasks: vec![t],
                },
                WireMode::F16,
            );
            // every proper prefix is truncated → must error (empty
            // frame included)
            let cut = g.usize_in(0, frame.len());
            assert!(decode_request(&frame[..cut], WireMode::F16).is_err());
            // unknown tag
            let mut bad = frame.clone();
            bad[0] = 0xee;
            assert!(decode_request(&bad, WireMode::F16).is_err());
            // trailing garbage after a valid body
            let mut long = frame.clone();
            long.push(0);
            assert!(decode_request(&long, WireMode::F16).is_err());
            // hostile count: patch the task count to u32::MAX
            let mut hostile = frame;
            hostile[5..9].copy_from_slice(&u32::MAX.to_le_bytes());
            assert!(decode_request(&hostile, WireMode::F16).is_err());
        });
    }

    /// Decoding a frame under the WRONG wire mode must error — never
    /// panic. Fixed payload of 1.0s: read as f32, the first misaligned
    /// vector header becomes 0x3C003C00 (two fp16 1.0s), an absurd
    /// count the cursor rejects before allocating.
    #[test]
    fn wrong_mode_decode_is_an_error_not_a_panic() {
        let t = SeqTask {
            seq_id: 1,
            q: vec![1.0; 8],
            k_new: vec![1.0; 8],
            v_new: vec![1.0; 8],
        };
        let f16_frame = encode_request(
            &NetRequest::Attend {
                layer: 0,
                tasks: vec![t],
            },
            WireMode::F16,
        );
        assert!(decode_request(&f16_frame, WireMode::F32).is_err());
    }

    /// The f16 payload sizing the byte-accounting pin builds on.
    #[test]
    fn payload_bytes_by_mode() {
        assert_eq!(vec_payload_bytes(100, WireMode::F32), 400);
        assert_eq!(vec_payload_bytes(100, WireMode::F16), 200);
        // an Attend's activation payload is exactly 3 vectors of
        // rows×width elements: frame growth per element is 3× the
        // per-elem wire size
        let mk = |elems: usize, mode| {
            encode_request(
                &NetRequest::Attend {
                    layer: 0,
                    tasks: vec![SeqTask {
                        seq_id: 1,
                        q: vec![0.5; elems],
                        k_new: vec![0.5; elems],
                        v_new: vec![0.5; elems],
                    }],
                },
                mode,
            )
            .len()
        };
        for mode in [WireMode::F32, WireMode::F16] {
            let overhead = mk(0, mode);
            assert_eq!(
                mk(64, mode),
                overhead + 3 * vec_payload_bytes(64, mode)
            );
        }
    }

    /// The deterministic framing-overhead formulas the runtime drift
    /// detector subtracts are pinned against the actual encoders: for
    /// any task/output count, frame length = overhead + payload.
    #[test]
    fn framing_overhead_matches_encoders() {
        for mode in [WireMode::F32, WireMode::F16] {
            for n in [0usize, 1, 3, 7] {
                let elems = 24;
                let tasks: Vec<SeqTask> = (0..n)
                    .map(|i| SeqTask {
                        seq_id: i as u64,
                        q: vec![0.25; elems],
                        k_new: vec![0.25; elems],
                        v_new: vec![0.25; elems],
                    })
                    .collect();
                let frame =
                    encode_request(&NetRequest::Attend { layer: 2, tasks }, mode);
                assert_eq!(
                    frame.len(),
                    attend_request_overhead_bytes(n)
                        + n * 3 * vec_payload_bytes(elems, mode),
                    "attend overhead, {mode:?} n={n}"
                );

                let outs: Vec<(u64, Vec<f32>)> =
                    (0..n).map(|i| (i as u64, vec![0.25f32; elems])).collect();
                let frame = encode_response(
                    &NetResponse::Outputs {
                        layer: 2,
                        outs,
                        busy: std::time::Duration::from_micros(5),
                    },
                    mode,
                );
                assert_eq!(
                    frame.len(),
                    outputs_response_overhead_bytes(n)
                        + n * vec_payload_bytes(elems, mode),
                    "outputs overhead, {mode:?} n={n}"
                );
            }
        }
    }
}
