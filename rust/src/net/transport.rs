//! Frame transports: how encoded codec frames cross the S↔R boundary.
//!
//! A [`Transport`] moves opaque frame bodies; framing on a byte stream
//! is a `u32` little-endian length prefix. Two implementations:
//!
//! * [`Loopback`] — an in-process pair of bounded byte channels. Every
//!   message still round-trips through the wire codec (encode → bytes
//!   → decode), so loopback exercises the exact serialization a TCP
//!   deployment ships while staying deterministic and dependency-free.
//! * [`Tcp`] — `std::net` over localhost (or any reachable host). The
//!   stream runs with `TCP_NODELAY` (the pipeline's frames are small
//!   and latency-bound, Table 3's "intermediate vectors").
//!
//! Disconnects are errors, not hangs: a dropped loopback peer or a
//! closed/reset TCP stream surfaces from `send`/`recv` with the peer
//! in the message, and the caller (`RemotePool`) turns it into a
//! routed error naming the node.

use std::io::{Read as _, Write as _};
use std::net::{TcpStream, ToSocketAddrs};

use anyhow::{anyhow, bail, Context as _, Result};

use crate::obs::TransportCounters;
use crate::util::chan::{bounded, Receiver, Sender};

use super::codec::MAX_FRAME_BYTES;

/// A bidirectional frame pipe. `send`/`recv` move whole frame bodies;
/// implementations add their own framing (length prefix) where the
/// medium is a byte stream.
pub trait Transport: Send {
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    fn recv(&mut self) -> Result<Vec<u8>>;
    /// Human-readable peer name for error messages ("loopback#3",
    /// "127.0.0.1:40213").
    fn peer(&self) -> &str;
    /// Transport kind label for backend names.
    fn kind(&self) -> &'static str;
    /// Frame/byte totals for this connection (frame bodies, excluding
    /// stream framing). Default: a transport that doesn't count
    /// reports zeros.
    fn counters(&self) -> TransportCounters {
        TransportCounters::default()
    }
}

/// In-process transport endpoint: frames travel as `Vec<u8>` over
/// bounded channels, byte-faithful to what TCP would carry.
pub struct Loopback {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    peer: String,
    counters: TransportCounters,
}

/// Create a connected pair of loopback endpoints `(server, client)` —
/// hand the first to the serving loop, keep the second. Each side's
/// `peer()` names the OTHER end, which is what error messages report.
pub fn loopback_pair(label: &str) -> (Loopback, Loopback) {
    let (a_tx, a_rx) = bounded::<Vec<u8>>(16);
    let (b_tx, b_rx) = bounded::<Vec<u8>>(16);
    (
        Loopback {
            tx: a_tx,
            rx: b_rx,
            peer: format!("loopback:{label}:client"),
            counters: TransportCounters::default(),
        },
        Loopback {
            tx: b_tx,
            rx: a_rx,
            peer: format!("loopback:{label}:server"),
            counters: TransportCounters::default(),
        },
    )
}

impl Transport for Loopback {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        // same frame-size contract as TCP, so loopback never accepts a
        // message a real deployment would reject
        if frame.len() > MAX_FRAME_BYTES {
            bail!(
                "frame of {} bytes exceeds the {} byte wire limit",
                frame.len(),
                MAX_FRAME_BYTES
            );
        }
        self.tx
            .send(frame.to_vec())
            .map_err(|_| anyhow!("{} disconnected", self.peer))?;
        self.counters.on_send(frame.len());
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let frame = self
            .rx
            .recv()
            .map_err(|_| anyhow!("{} disconnected", self.peer))?;
        self.counters.on_recv(frame.len());
        Ok(frame)
    }

    fn peer(&self) -> &str {
        &self.peer
    }

    fn kind(&self) -> &'static str {
        "loopback"
    }

    fn counters(&self) -> TransportCounters {
        self.counters
    }
}

/// TCP transport: `u32` little-endian length prefix + frame body per
/// message.
pub struct Tcp {
    stream: TcpStream,
    peer: String,
    counters: TransportCounters,
}

impl Tcp {
    /// Connect to a listening `rnode`.
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(addr: A) -> Result<Tcp> {
        let stream = TcpStream::connect(&addr)
            .with_context(|| format!("connecting to rnode at {addr:?}"))?;
        Tcp::from_stream(stream)
    }

    /// Wrap an accepted connection (server side).
    pub fn from_stream(stream: TcpStream) -> Result<Tcp> {
        stream.set_nodelay(true).context("setting TCP_NODELAY")?;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "<unknown peer>".to_string());
        Ok(Tcp {
            stream,
            peer,
            counters: TransportCounters::default(),
        })
    }
}

impl Transport for Tcp {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        if frame.len() > MAX_FRAME_BYTES {
            bail!(
                "frame of {} bytes exceeds the {} byte wire limit",
                frame.len(),
                MAX_FRAME_BYTES
            );
        }
        let len = (frame.len() as u32).to_le_bytes();
        self.stream
            .write_all(&len)
            .and_then(|_| self.stream.write_all(frame))
            .and_then(|_| self.stream.flush())
            .with_context(|| format!("sending frame to {}", self.peer))?;
        self.counters.on_send(frame.len());
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut len = [0u8; 4];
        self.stream
            .read_exact(&mut len)
            .with_context(|| format!("receiving frame from {}", self.peer))?;
        let n = u32::from_le_bytes(len) as usize;
        if n > MAX_FRAME_BYTES {
            bail!(
                "{} announced a {} byte frame (limit {}): malformed or \
                 desynchronized stream",
                self.peer,
                n,
                MAX_FRAME_BYTES
            );
        }
        let mut frame = vec![0u8; n];
        self.stream
            .read_exact(&mut frame)
            .with_context(|| format!("receiving frame from {}", self.peer))?;
        self.counters.on_recv(frame.len());
        Ok(frame)
    }

    fn peer(&self) -> &str {
        &self.peer
    }

    fn kind(&self) -> &'static str {
        "tcp"
    }

    fn counters(&self) -> TransportCounters {
        self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_roundtrips_frames_in_order() {
        let (mut server, mut client) = loopback_pair("t");
        client.send(&[1, 2, 3]).unwrap();
        client.send(&[]).unwrap();
        assert_eq!(server.recv().unwrap(), vec![1, 2, 3]);
        assert_eq!(server.recv().unwrap(), Vec::<u8>::new());
        server.send(&[9]).unwrap();
        assert_eq!(client.recv().unwrap(), vec![9]);
    }

    #[test]
    fn counters_track_frames_and_bytes() {
        let (mut server, mut client) = loopback_pair("t");
        client.send(&[1, 2, 3]).unwrap();
        client.send(&[4]).unwrap();
        server.recv().unwrap();
        let c = client.counters();
        assert_eq!(c.frames_sent, 2);
        assert_eq!(c.bytes_sent, 4);
        assert_eq!(c.frames_recv, 0);
        let s = server.counters();
        assert_eq!(s.frames_recv, 1);
        assert_eq!(s.bytes_recv, 3);
    }

    #[test]
    fn loopback_disconnect_is_error_not_hang() {
        let (server, mut client) = loopback_pair("t");
        drop(server);
        let err = client.recv().unwrap_err();
        assert!(format!("{err:#}").contains("disconnected"), "{err:#}");
        assert!(client.send(&[1]).is_err());
    }

    #[test]
    fn tcp_roundtrip_and_eof() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let mut t = Tcp::from_stream(s).unwrap();
            let f = t.recv().unwrap();
            t.send(&f).unwrap(); // echo once, then close
        });
        let mut c = Tcp::connect(addr).unwrap();
        c.send(&[7; 1000]).unwrap();
        assert_eq!(c.recv().unwrap(), vec![7; 1000]);
        server.join().unwrap();
        // peer closed: next recv is an error naming the peer, not a hang
        let err = c.recv().unwrap_err();
        assert!(
            format!("{err:#}").contains("receiving frame"),
            "{err:#}"
        );
    }

    #[test]
    fn tcp_rejects_hostile_length_prefix() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            use std::io::Write as _;
            let (mut s, _) = listener.accept().unwrap();
            // announce a 2 GiB frame
            s.write_all(&(u32::MAX).to_le_bytes()).unwrap();
            s.flush().unwrap();
            // hold the connection open so recv must act on the prefix
            std::thread::sleep(std::time::Duration::from_millis(200));
        });
        let mut c = Tcp::connect(addr).unwrap();
        let err = c.recv().unwrap_err();
        assert!(format!("{err:#}").contains("limit"), "{err:#}");
        server.join().unwrap();
    }
}
