//! `RemotePool`: the client side of the wire — shards sequences
//! round-robin across remote R-sockets and speaks the codec over any
//! [`Transport`]. Implements [`AttendBackend`], so the threaded
//! pipeline, `FastDecode` and `serve::ServeEngine` drive remote nodes
//! exactly as they drive in-process threads.
//!
//! Fault model: a node whose transport fails (killed process, dropped
//! loopback peer, desynced stream) is marked DEAD with its root cause.
//! The failing call returns a routed error — after draining every
//! other node involved in the same scatter, so replies can never cross
//! into the next step — and the pool itself stays usable: sequences on
//! dead nodes can be dropped (their cache died with the node), new
//! sequences place onto live nodes only, and attends touching only
//! live nodes keep working. A node that merely REFUSES a request
//! (`NetResponse::Err`) is still alive and in sync: the error is
//! routed up without marking the node dead.

// fdlint: allow(deterministic-iteration): HashSet here is membership-only (duplicate detection), never iterated
use std::collections::{BTreeMap, HashSet};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context as _, Result};

use crate::kvcache::CacheStats;
use crate::obs::{
    pick_clock_sync, Metrics, NetStats, NodeProfile, Tracer, Track,
    TransportCounters,
};
use crate::rworker::{AttendBackend, PendingAttend, PoolStep, SeqTask};

use super::codec::{
    attend_request_overhead_bytes, decode_response, encode_request,
    outputs_response_overhead_bytes, vec_payload_bytes, NetRequest,
    NetResponse, NodeConfig, NodeStatsReport, WireMode, MAX_FRAME_BYTES,
};
use super::rnode;
use super::transport::{loopback_pair, Tcp, Transport};

/// Per-node wire accounting: attend ops, errors, and the
/// modeled-vs-measured payload drift detector (see `obs::counters`).
#[derive(Clone, Copy, Debug, Default)]
struct NodeWire {
    attend_ops: u64,
    errors: u64,
    modeled_sent: u64,
    measured_sent: u64,
    modeled_recv: u64,
    measured_recv: u64,
    drift_events: u64,
    /// Transport counters snapshotted at death; live nodes read their
    /// transport directly.
    final_transport: TransportCounters,
}

/// Clock-offset estimate for one node, from the RTT ping burst in the
/// `Configure` handshake. The node answered `Ping` with its
/// epoch-relative time `node_us`; at the client-side midpoint `mid` of
/// the minimum-RTT sample the node's clock read `node_us`, so
/// `offset_us = local_us(mid) − node_us` maps remote timestamps into
/// any local epoch with error bounded by ±`min_rtt_us / 2`.
#[derive(Clone, Copy, Debug)]
struct ClockSync {
    /// Client-side midpoint of the minimum-RTT ping round trip.
    mid: Instant,
    /// The node's epoch-relative microseconds in that ping's reply.
    node_us: f64,
    /// The minimum RTT observed across the burst (µs).
    min_rtt_us: f64,
}

/// Ping samples per node at Configure time: enough that one of them
/// usually avoids scheduler noise, cheap enough to not slow connect.
const CLOCK_SYNC_PINGS: usize = 8;

struct Node {
    /// `None` once the node is dead (with the cause in `fate`).
    transport: Option<Box<dyn Transport>>,
    label: String,
    /// Root cause of death, kept so later touches of the node still
    /// name the original failure.
    fate: Option<String>,
    wire_stats: NodeWire,
    /// Clock-offset estimate from the Configure-time ping burst.
    clock: Option<ClockSync>,
    /// Live measured performance profile (EWMA throughput, service-time
    /// percentiles, queue depth), fed by every submit/gather.
    profile: NodeProfile,
}

pub struct RemotePool {
    nodes: Vec<Node>,
    wire: WireMode,
    /// BTreeMap, not HashMap: rollback on partial registration failure
    /// and any future whole-map scatter walk this in key order, keeping
    /// wire traffic deterministic across runs (bit-identity pins).
    placement: BTreeMap<u64, usize>,
    next_node: usize,
    name: &'static str,
    /// Loopback server threads, joined on drop.
    servers: Vec<std::thread::JoinHandle<()>>,
    /// One trace track per node ("r-node{i}"), empty until a tracer is
    /// installed.
    tracks: Vec<Track>,
    /// The installed tracer itself — the merge target for fetched
    /// remote spans. Disabled until `install_tracer`.
    tracer: Tracer,
    /// Token-row width (heads × head_dim) of one q/k/v row, for row
    /// counts in the per-node profiles.
    width: usize,
    /// Per-node (rows, payload bytes) of the attend currently in
    /// flight, observed into the profile at gather time.
    pending_load: Vec<(usize, u64)>,
}

impl RemotePool {
    /// Configure one already-connected transport per node: sends
    /// `Configure` and awaits the `Ack`.
    pub fn from_transports(
        transports: Vec<Box<dyn Transport>>,
        cfg: NodeConfig,
        name: &'static str,
    ) -> Result<RemotePool> {
        if transports.is_empty() {
            bail!("remote pool needs at least one node");
        }
        let mut nodes = Vec::with_capacity(transports.len());
        for (i, mut t) in transports.into_iter().enumerate() {
            let label = format!("node {i} ({})", t.peer());
            t.send(&encode_request(&NetRequest::Configure(cfg), cfg.wire))
                .with_context(|| format!("configuring {label}"))?;
            let frame = t
                .recv()
                .with_context(|| format!("awaiting Configure ack from {label}"))?;
            match decode_response(&frame, cfg.wire)? {
                NetResponse::Ack => {}
                NetResponse::Err(msg) => {
                    bail!("{label} refused configuration: {msg}")
                }
                other => bail!(
                    "{label} answered Configure with {other:?} instead of Ack"
                ),
            }
            // RTT ping burst: the node answers each Ping with its
            // epoch-relative time; the minimum-RTT sample's midpoint
            // gives the clock offset with error ≤ RTT/2 — what
            // `merge_remote_traces` uses to align the node's spans.
            let sync_epoch = Instant::now();
            let us = |at: Instant| {
                at.duration_since(sync_epoch).as_secs_f64() * 1e6
            };
            let mut samples = Vec::with_capacity(CLOCK_SYNC_PINGS);
            for _ in 0..CLOCK_SYNC_PINGS {
                let t0 = Instant::now();
                t.send(&encode_request(&NetRequest::Ping, cfg.wire))
                    .with_context(|| format!("pinging {label}"))?;
                let frame = t
                    .recv()
                    .with_context(|| format!("awaiting Pong from {label}"))?;
                let t1 = Instant::now();
                let node_us = match decode_response(&frame, cfg.wire)? {
                    NetResponse::Pong { node_us } => node_us,
                    other => bail!(
                        "{label} answered Ping with {other:?} instead of Pong"
                    ),
                };
                samples.push((us(t0), node_us, us(t1)));
            }
            let clock = pick_clock_sync(&samples).map(
                |(mid_us, node_us, min_rtt_us)| ClockSync {
                    mid: sync_epoch
                        + Duration::from_secs_f64(mid_us / 1e6),
                    node_us,
                    min_rtt_us,
                },
            );
            nodes.push(Node {
                transport: Some(t),
                label,
                fate: None,
                wire_stats: NodeWire::default(),
                clock,
                profile: NodeProfile::default(),
            });
        }
        let n = nodes.len();
        Ok(RemotePool {
            nodes,
            wire: cfg.wire,
            placement: BTreeMap::new(),
            next_node: 0,
            name,
            servers: Vec::new(),
            tracks: Vec::new(),
            tracer: Tracer::disabled(),
            width: cfg.n_heads * cfg.head_dim,
            pending_load: vec![(0, 0); n],
        })
    }

    /// An all-in-process pool: `n` rnode serving loops on background
    /// threads, one loopback transport each. Every message round-trips
    /// through the codec byte-for-byte as TCP would ship it.
    pub fn loopback(cfg: NodeConfig, n: usize) -> Result<RemotePool> {
        let mut transports: Vec<Box<dyn Transport>> = Vec::with_capacity(n);
        let mut servers = Vec::with_capacity(n);
        for i in 0..n {
            let (server, client) = loopback_pair(&format!("rnode{i}"));
            let h = std::thread::Builder::new()
                .name(format!("rnode-loopback-{i}"))
                .spawn(move || {
                    if let Err(e) = rnode::serve_connection(server) {
                        crate::obs::log!(Warn, "loopback rnode {i}: {e:#}");
                    }
                })
                .context("spawning loopback rnode")?;
            servers.push(h);
            transports.push(Box::new(client));
        }
        let mut pool =
            RemotePool::from_transports(transports, cfg, "net-loopback")?;
        pool.servers = servers;
        Ok(pool)
    }

    /// Connect to already-running rnode listeners (`host:port` each) —
    /// one R-socket per address; several addresses may share one rnode
    /// process (it serves each connection independently).
    pub fn connect_tcp(addrs: &[String], cfg: NodeConfig) -> Result<RemotePool> {
        let mut transports: Vec<Box<dyn Transport>> =
            Vec::with_capacity(addrs.len());
        for a in addrs {
            transports.push(Box::new(Tcp::connect(a.as_str())?));
        }
        RemotePool::from_transports(transports, cfg, "net-tcp")
    }

    /// Live (non-dead) node count.
    pub fn live_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.transport.is_some()).count()
    }

    fn mark_dead(&mut self, i: usize, cause: &anyhow::Error) {
        let node = &mut self.nodes[i];
        if let Some(t) = node.transport.take() {
            // last chance to read the connection's counters
            node.wire_stats.final_transport = t.counters();
            node.fate = Some(format!("{cause:#}"));
            let m = Metrics::global();
            if m.is_enabled() {
                m.inc("rpool_node_deaths", &[("node", &node.label)], 1);
            }
        }
    }

    fn dead_error(&self, i: usize) -> anyhow::Error {
        anyhow!(
            "{} is dead: {}",
            self.nodes[i].label,
            self.nodes[i]
                .fate
                .as_deref()
                .unwrap_or("unknown cause")
        )
    }

    fn send_to(&mut self, i: usize, req: &NetRequest) -> Result<()> {
        let frame = encode_request(req, self.wire);
        if frame.len() > MAX_FRAME_BYTES {
            // local validation failure: nothing touched the stream, the
            // node is alive and in sync — a routed error, NOT a death
            bail!(
                "frame of {} bytes to {} exceeds the {} byte wire limit \
                 (split the batch)",
                frame.len(),
                self.nodes[i].label,
                MAX_FRAME_BYTES
            );
        }
        // Drift detector, sent leg: the LinkModel-modeled QKV payload
        // bytes vs. what the codec actually framed (frame minus the
        // deterministic framing overhead). Mismatch = the codec and the
        // perf model disagree about message shape.
        let attend_payload = match req {
            NetRequest::Attend { tasks, .. } => {
                let modeled: usize = tasks
                    .iter()
                    .map(|t| {
                        vec_payload_bytes(t.q.len(), self.wire)
                            + vec_payload_bytes(t.k_new.len(), self.wire)
                            + vec_payload_bytes(t.v_new.len(), self.wire)
                    })
                    .sum();
                let measured = frame
                    .len()
                    .saturating_sub(attend_request_overhead_bytes(tasks.len()));
                Some((modeled as u64, measured as u64))
            }
            _ => None,
        };
        let res = match self.nodes[i].transport.as_mut() {
            None => return Err(self.dead_error(i)),
            Some(t) => t.send(&frame),
        };
        if let Err(e) = res {
            self.nodes[i].wire_stats.errors += 1;
            self.mark_dead(i, &e);
            return Err(e.context(format!("sending to {}", self.nodes[i].label)));
        }
        if let Some((modeled, measured)) = attend_payload {
            let w = &mut self.nodes[i].wire_stats;
            w.attend_ops += 1;
            w.modeled_sent += modeled;
            w.measured_sent += measured;
            let drift = modeled != measured;
            if drift {
                w.drift_events += 1;
            }
            if drift {
                crate::obs::log!(
                    Warn,
                    "payload drift sending to {}: modeled {modeled} B, \
                     measured {measured} B",
                    self.nodes[i].label
                );
            }
        }
        Ok(())
    }

    /// Receive and decode one response from node `i`. Transport and
    /// decode failures kill the node (the stream can no longer be
    /// trusted); a `NetResponse::Err` does NOT — the node answered in
    /// protocol and stays usable.
    fn recv_from(&mut self, i: usize) -> Result<NetResponse> {
        let res = match self.nodes[i].transport.as_mut() {
            None => return Err(self.dead_error(i)),
            Some(t) => t.recv(),
        };
        let frame = match res {
            Ok(f) => f,
            Err(e) => {
                self.nodes[i].wire_stats.errors += 1;
                self.mark_dead(i, &e);
                return Err(
                    e.context(format!("receiving from {}", self.nodes[i].label))
                );
            }
        };
        match decode_response(&frame, self.wire) {
            Ok(resp) => {
                // Drift detector, received leg: modeled O payload vs.
                // measured (frame minus framing overhead).
                if let NetResponse::Outputs { outs, .. } = &resp {
                    let modeled: usize = outs
                        .iter()
                        .map(|(_, o)| vec_payload_bytes(o.len(), self.wire))
                        .sum();
                    let measured = frame.len().saturating_sub(
                        outputs_response_overhead_bytes(outs.len()),
                    );
                    let drift = modeled != measured;
                    let w = &mut self.nodes[i].wire_stats;
                    w.modeled_recv += modeled as u64;
                    w.measured_recv += measured as u64;
                    if drift {
                        w.drift_events += 1;
                        crate::obs::log!(
                            Warn,
                            "payload drift receiving from {}: modeled \
                             {modeled} B, measured {measured} B",
                            self.nodes[i].label
                        );
                    }
                }
                Ok(resp)
            }
            Err(e) => {
                self.nodes[i].wire_stats.errors += 1;
                self.mark_dead(i, &e);
                Err(e.context(format!(
                    "malformed frame from {}",
                    self.nodes[i].label
                )))
            }
        }
    }

    /// One request → one reply, expecting `Ack`.
    fn rpc_ack(&mut self, i: usize, req: &NetRequest) -> Result<()> {
        self.send_to(i, req)?;
        match self.recv_from(i)? {
            NetResponse::Ack => Ok(()),
            NetResponse::Err(msg) => {
                bail!("{} refused: {msg}", self.nodes[i].label)
            }
            other => bail!(
                "{} answered with {other:?} instead of Ack",
                self.nodes[i].label
            ),
        }
    }
}

impl AttendBackend for RemotePool {
    fn name(&self) -> &'static str {
        self.name
    }

    fn sockets(&self) -> usize {
        self.nodes.len()
    }

    fn socket_of(&self, seq_id: u64) -> Option<usize> {
        self.placement.get(&seq_id).copied()
    }

    /// Round-robin placement over LIVE nodes only — after a node
    /// death, new sequences keep landing on the survivors.
    /// All-or-nothing: the placement map commits only after EVERY node
    /// acked its group; a mid-loop failure rolls the acked nodes back
    /// (best effort), so no sequence is ever locally "placed" on a
    /// node that never registered it, and the pool stays usable.
    fn add_seqs(&mut self, seq_ids: &[u64]) -> Result<()> {
        if self.live_nodes() == 0 {
            bail!("no live nodes left in the remote pool");
        }
        // fdlint: allow(deterministic-iteration): membership-only duplicate check, never iterated
        let mut seen = HashSet::with_capacity(seq_ids.len());
        let mut per_node: Vec<Vec<u64>> = vec![vec![]; self.nodes.len()];
        for &id in seq_ids {
            if self.placement.contains_key(&id) || !seen.insert(id) {
                // caller bug, but panicking here would strand the pool:
                // route it and leave every node untouched
                bail!("sequence {id} already placed");
            }
            // advance past dead nodes (live_nodes > 0 ⇒ terminates)
            while self.nodes[self.next_node].transport.is_none() {
                self.next_node = (self.next_node + 1) % self.nodes.len();
            }
            let n = self.next_node;
            self.next_node = (self.next_node + 1) % self.nodes.len();
            per_node[n].push(id);
        }
        let mut acked: Vec<usize> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for (n, ids) in per_node.iter().enumerate() {
            if ids.is_empty() {
                continue;
            }
            match self.rpc_ack(n, &NetRequest::AddSeqs(ids.clone())) {
                Ok(()) => acked.push(n),
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            for n in acked {
                // roll back so the registration is all-or-nothing
                let _ = self
                    .rpc_ack(n, &NetRequest::DropSeqs(per_node[n].clone()));
            }
            return Err(e.context("registering sequences"));
        }
        for (n, ids) in per_node.into_iter().enumerate() {
            for id in ids {
                self.placement.insert(id, n);
            }
        }
        Ok(())
    }

    fn drop_seqs(&mut self, seq_ids: &[u64]) -> Result<()> {
        let mut per_node: Vec<Vec<u64>> = vec![vec![]; self.nodes.len()];
        for &id in seq_ids {
            if let Some(n) = self.placement.remove(&id) {
                per_node[n].push(id);
            }
        }
        for (n, ids) in per_node.into_iter().enumerate() {
            if ids.is_empty() || self.nodes[n].transport.is_none() {
                // dead node: its cache died with it — unplacing locally
                // IS the drop
                continue;
            }
            self.rpc_ack(n, &NetRequest::DropSeqs(ids))
                .context("dropping sequences")?;
        }
        Ok(())
    }

    /// COW-fork on the node holding the parent; the child inherits the
    /// parent's placement (shared blocks are node-local). A refusal
    /// (unknown parent on the node, child collision) is a routed error
    /// and does NOT place the child.
    fn fork_seq(
        &mut self,
        parent: u64,
        child: u64,
        upto: usize,
    ) -> Result<()> {
        let n = match self.placement.get(&parent) {
            Some(&n) => n,
            None => bail!("sequence {parent} not placed"),
        };
        if self.placement.contains_key(&child) {
            bail!("sequence {child} already placed");
        }
        self.rpc_ack(n, &NetRequest::ForkSeq { parent, child, upto })
            .context("forking sequence on remote node")?;
        self.placement.insert(child, n);
        Ok(())
    }

    fn submit_attend(
        &mut self,
        layer: usize,
        tasks: Vec<SeqTask>,
    ) -> Result<PendingAttend> {
        let n_tasks = tasks.len();
        let mut per_node: Vec<Vec<SeqTask>> =
            (0..self.nodes.len()).map(|_| Vec::new()).collect();
        for task in tasks {
            match self.placement.get(&task.seq_id) {
                Some(&n) => per_node[n].push(task),
                None => bail!("sequence {} not placed", task.seq_id),
            }
        }
        let mut active = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for (n, tasks) in per_node.into_iter().enumerate() {
            if tasks.is_empty() {
                continue;
            }
            // profile bookkeeping: rows and payload bytes of this
            // node's share, observed into its EWMA at gather time
            let rows: usize = tasks
                .iter()
                .map(|t| t.q.len() / self.width.max(1))
                .sum();
            let bytes: usize = tasks
                .iter()
                .map(|t| {
                    vec_payload_bytes(t.q.len(), self.wire)
                        + vec_payload_bytes(t.k_new.len(), self.wire)
                        + vec_payload_bytes(t.v_new.len(), self.wire)
                })
                .sum();
            self.pending_load[n] = (rows, bytes as u64);
            match self.send_to(n, &NetRequest::Attend { layer, tasks }) {
                Ok(()) => {
                    self.nodes[n].profile.on_submit();
                    active.push(n);
                }
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            // drain what was already scattered so no reply crosses into
            // the next attend
            for n in active {
                let _ = self.recv_from(n);
                self.nodes[n].profile.on_gather();
            }
            return Err(e.context("scattering attend to remote nodes"));
        }
        Ok(PendingAttend {
            active,
            layer,
            n: n_tasks,
            submitted: Instant::now(),
        })
    }

    fn wait_attend(&mut self, pending: PendingAttend) -> Result<PoolStep> {
        let mut outputs = BTreeMap::new();
        let mut max_busy = Duration::ZERO;
        let mut total_busy = Duration::ZERO;
        let mut socket_busy: Vec<(usize, Duration)> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for n in pending.active {
            let reply = self.recv_from(n);
            // the reply (or the failure) consumed this node's in-flight
            // slot either way
            self.nodes[n].profile.on_gather();
            match reply {
                Ok(NetResponse::Outputs { layer, outs, busy }) => {
                    if layer != pending.layer {
                        // a crossed reply means this connection is desynced
                        // from the request stream — the node's replies can
                        // no longer be trusted, so it dies and the error is
                        // routed (panicking here would strand every other
                        // node's in-flight reply)
                        let e = anyhow!(
                            "{} replied for layer {layer}, handle is for \
                             layer {}: attends gathered out of submission \
                             order",
                            self.nodes[n].label,
                            pending.layer
                        );
                        self.nodes[n].wire_stats.errors += 1;
                        self.mark_dead(n, &e);
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                        continue;
                    }
                    max_busy = max_busy.max(busy);
                    total_busy += busy;
                    socket_busy.push((n, busy));
                    let (rows, bytes) = self.pending_load[n];
                    self.nodes[n].profile.observe(
                        rows,
                        bytes,
                        Instant::now().duration_since(pending.submitted),
                    );
                    let m = Metrics::global();
                    if m.is_enabled() {
                        let node = self.nodes[n].label.clone();
                        let labels = [("node", node.as_str())];
                        let p = &self.nodes[n].profile;
                        m.inc("rpool_attend_ops", &labels, 1);
                        m.set_gauge(
                            "rpool_tokens_per_s",
                            &labels,
                            p.tokens_per_s,
                        );
                        m.set_gauge(
                            "rpool_bytes_per_s",
                            &labels,
                            p.bytes_per_s,
                        );
                        m.set_gauge(
                            "rpool_in_flight",
                            &labels,
                            p.queue_depth as f64,
                        );
                        m.observe_secs(
                            "rpool_service",
                            &labels,
                            busy.as_secs_f64(),
                        );
                    }
                    if let Some(track) = self.tracks.get(n) {
                        track.record(
                            "attend",
                            pending.submitted,
                            Instant::now(),
                            &[
                                ("node", n as f64),
                                ("layer", pending.layer as f64),
                                ("busy_us", busy.as_secs_f64() * 1e6),
                            ],
                        );
                    }
                    for (id, o) in outs {
                        outputs.insert(id, o);
                    }
                }
                Ok(NetResponse::Err(msg)) => {
                    self.nodes[n].wire_stats.errors += 1;
                    let m = Metrics::global();
                    if m.is_enabled() {
                        let node = self.nodes[n].label.clone();
                        m.inc("rpool_errors", &[("node", &node)], 1);
                    }
                    if first_err.is_none() {
                        first_err = Some(anyhow!(
                            "{} refused attend: {msg}",
                            self.nodes[n].label
                        ));
                    }
                }
                Ok(other) => {
                    self.nodes[n].wire_stats.errors += 1;
                    let m = Metrics::global();
                    if m.is_enabled() {
                        let node = self.nodes[n].label.clone();
                        m.inc("rpool_errors", &[("node", &node)], 1);
                    }
                    if first_err.is_none() {
                        first_err = Some(anyhow!(
                            "{} answered attend with {other:?}",
                            self.nodes[n].label
                        ));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e.context("gathering attend from remote nodes"));
        }
        if outputs.len() != pending.n {
            bail!(
                "attend returned {} outputs for {} tasks",
                outputs.len(),
                pending.n
            );
        }
        Ok(PoolStep {
            outputs,
            max_busy,
            total_busy,
            socket_busy,
        })
    }

    /// Stats of LIVE nodes (dead nodes hold no cache anymore).
    /// Scattered to every node before gathering any reply, so the
    /// latency is one round trip, not one per node — this sits on the
    /// serving hot path (`measured_kv_load` runs every step).
    fn stats(&mut self) -> Result<Vec<CacheStats>> {
        let live: Vec<usize> = (0..self.nodes.len())
            .filter(|&i| self.nodes[i].transport.is_some())
            .collect();
        let mut sent: Vec<usize> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for &i in &live {
            match self.send_to(i, &NetRequest::Stats) {
                Ok(()) => sent.push(i),
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        let mut all = Vec::new();
        for &i in &sent {
            match self.recv_from(i) {
                Ok(NetResponse::Stats(st)) => all.push(st),
                Ok(NetResponse::Err(msg)) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!(
                            "{} refused stats: {msg}",
                            self.nodes[i].label
                        ));
                    }
                }
                Ok(other) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!(
                            "{} answered stats with {other:?}",
                            self.nodes[i].label
                        ));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e.context("gathering stats from remote nodes"));
        }
        Ok(all)
    }

    /// Self-reported [`NodeStatsReport`] of every LIVE node, labeled by
    /// the node's display label. Same scatter-all-then-gather shape as
    /// [`Self::stats`] — one round trip for the whole cluster. Meant
    /// for dashboards/CI (`fdtop`), not the per-step hot path.
    fn node_reports(&mut self) -> Result<Vec<(String, NodeStatsReport)>> {
        let live: Vec<usize> = (0..self.nodes.len())
            .filter(|&i| self.nodes[i].transport.is_some())
            .collect();
        let mut sent: Vec<usize> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for &i in &live {
            match self.send_to(i, &NetRequest::NodeStats) {
                Ok(()) => sent.push(i),
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        let mut all = Vec::new();
        for &i in &sent {
            match self.recv_from(i) {
                Ok(NetResponse::NodeStats(report)) => {
                    all.push((self.nodes[i].label.clone(), report));
                }
                Ok(NetResponse::Err(msg)) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!(
                            "{} refused node stats: {msg}",
                            self.nodes[i].label
                        ));
                    }
                }
                Ok(other) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow!(
                            "{} answered node stats with {other:?}",
                            self.nodes[i].label
                        ));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e.context("gathering node stats from remote nodes"));
        }
        Ok(all)
    }

    /// One trace track per node; subsequent attends record submit→reply
    /// spans on the owning node's track. The tracer is kept as the
    /// merge target for spans fetched by [`Self::merge_remote_traces`].
    fn install_tracer(&mut self, tracer: Tracer) {
        self.tracks = (0..self.nodes.len())
            .map(|i| tracer.track(&format!("r-node{i}")))
            .collect();
        self.tracer = tracer;
    }

    /// Fetch each live node's server-side spans and fold them into the
    /// installed tracer, shifted by the node's clock-offset estimate
    /// (`offset_us = local_us(mid) − node_us` from the Configure-time
    /// ping burst). EVERY live node is drained before the first failure
    /// is reported, so survivors' partial traces still merge when a
    /// node dies mid-fetch — the error names the dead node.
    fn merge_remote_traces(&mut self) -> Result<usize> {
        let live: Vec<usize> = (0..self.nodes.len())
            .filter(|&i| self.nodes[i].transport.is_some())
            .collect();
        let mut sent: Vec<usize> = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for &i in &live {
            match self.send_to(i, &NetRequest::FetchTrace) {
                Ok(()) => sent.push(i),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        let mut merged = 0usize;
        for &i in &sent {
            match self.recv_from(i) {
                Ok(NetResponse::Trace(spans)) => {
                    let offset_us = match self.nodes[i].clock {
                        Some(c) => {
                            self.tracer.us_since_epoch(c.mid) - c.node_us
                        }
                        None => 0.0,
                    };
                    merged += self.tracer.merge_remote(
                        &format!("rnode{i}"),
                        spans,
                        offset_us,
                    );
                }
                Ok(NetResponse::Err(msg)) => {
                    self.nodes[i].wire_stats.errors += 1;
                    if first_err.is_none() {
                        first_err = Some(anyhow!(
                            "{} refused trace fetch: {msg}",
                            self.nodes[i].label
                        ));
                    }
                }
                Ok(other) => {
                    self.nodes[i].wire_stats.errors += 1;
                    if first_err.is_none() {
                        first_err = Some(anyhow!(
                            "{} answered FetchTrace with {other:?}",
                            self.nodes[i].label
                        ));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e.context("fetching remote traces")),
            None => Ok(merged),
        }
    }

    /// Wire accounting for EVERY node, dead ones included (their
    /// counters are snapshotted at death).
    fn net_stats(&self) -> Vec<NetStats> {
        self.nodes
            .iter()
            .enumerate()
            .map(|(i, node)| {
                let transport = match &node.transport {
                    Some(t) => t.counters(),
                    None => node.wire_stats.final_transport,
                };
                NetStats {
                    node: i,
                    label: node.label.clone(),
                    transport,
                    attend_ops: node.wire_stats.attend_ops,
                    errors: node.wire_stats.errors,
                    modeled_payload_sent: node.wire_stats.modeled_sent,
                    measured_payload_sent: node.wire_stats.measured_sent,
                    modeled_payload_recv: node.wire_stats.modeled_recv,
                    measured_payload_recv: node.wire_stats.measured_recv,
                    drift_events: node.wire_stats.drift_events,
                    profile: node.profile.clone(),
                }
            })
            .collect()
    }
}

impl Drop for RemotePool {
    fn drop(&mut self) {
        for i in 0..self.nodes.len() {
            let _ = self.send_to(i, &NetRequest::Shutdown);
        }
        // loopback servers exit on Shutdown (or their peer dropping)
        for h in self.servers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Precision, TINY};
    use crate::util::Rng;

    fn cfg(wire: WireMode) -> NodeConfig {
        NodeConfig::from_spec(&TINY, 8, 4, Precision::F32, wire)
    }

    fn mk_task(rng: &mut Rng, id: u64, n: usize) -> SeqTask {
        SeqTask {
            seq_id: id,
            q: rng.normal_vec(n, 1.0),
            k_new: rng.normal_vec(n, 1.0),
            v_new: rng.normal_vec(n, 1.0),
        }
    }

    /// Loopback pool (f32 wire) computes exactly what the in-process
    /// thread pool computes, node count = socket count.
    #[test]
    fn loopback_matches_thread_pool_bitwise() {
        use crate::rworker::{RPool, RPoolConfig};
        let n = TINY.hidden;
        let ids: Vec<u64> = (0..5).collect();
        let run_remote = || {
            let mut pool = RemotePool::loopback(cfg(WireMode::F32), 3).unwrap();
            pool.add_seqs(&ids).unwrap();
            let mut rng = Rng::new(42);
            let mut last = BTreeMap::new();
            for _ in 0..3 {
                let tasks: Vec<SeqTask> =
                    ids.iter().map(|&i| mk_task(&mut rng, i, n)).collect();
                last = pool.attend(0, tasks).unwrap().outputs;
            }
            last
        };
        let run_threads = || {
            let mut pool = RPool::spawn(
                &TINY,
                RPoolConfig {
                    sockets: 3,
                    capacity_per_seq: 8,
                    precision: Precision::F32,
                    ..Default::default()
                },
            );
            pool.add_seqs(&ids).unwrap();
            let mut rng = Rng::new(42);
            let mut last = BTreeMap::new();
            for _ in 0..3 {
                let tasks: Vec<SeqTask> =
                    ids.iter().map(|&i| mk_task(&mut rng, i, n)).collect();
                last = pool.attend(0, tasks).unwrap().outputs;
            }
            last
        };
        let remote = run_remote();
        let threads = run_threads();
        assert_eq!(remote.len(), threads.len());
        for (id, o) in &threads {
            assert_eq!(&remote[id], o, "seq {id} diverged over the wire");
        }
    }

    /// ForkSeq over the wire: the child lands on the parent's node and
    /// shares its prefix blocks (logical tokens > physical tokens in
    /// the gathered stats); a fork off an unknown parent is a routed
    /// error that does not place the child.
    #[test]
    fn fork_over_loopback_shares_blocks_and_routes_refusals() {
        let mut pool = RemotePool::loopback(cfg(WireMode::F32), 2).unwrap();
        // 1 → node 0, 2 → node 1
        pool.add_seqs(&[1, 2]).unwrap();
        let mut rng = Rng::new(5);
        for _ in 0..4 {
            // feed BOTH layers so every layer reaches the fork point
            for layer in 0..TINY.n_layers {
                let tasks = vec![
                    mk_task(&mut rng, 1, TINY.hidden),
                    mk_task(&mut rng, 2, TINY.hidden),
                ];
                pool.attend(layer, tasks).unwrap();
            }
        }
        pool.fork_seq(1, 7, 4).unwrap();
        assert_eq!(pool.socket_of(7), pool.socket_of(1));
        let stats = pool.stats().unwrap();
        let logical: usize = stats.iter().map(|s| s.total_tokens).sum();
        let physical: usize = stats.iter().map(|s| s.physical_tokens).sum();
        // 4 tokens × 2 layers × (seq 1 + seq 2 + forked 7)
        assert_eq!(logical, 24, "{stats:?}");
        assert_eq!(physical, 16, "{stats:?}"); // prefix stored once
        // the child keeps attending through shared blocks
        let step = pool
            .attend(0, vec![mk_task(&mut rng, 7, TINY.hidden)])
            .unwrap();
        assert_eq!(step.outputs.len(), 1);
        // refusal path: parent unknown ON THE NODE (placement forged)
        pool.placement.insert(99, 0);
        let err = pool.fork_seq(99, 100, 1).unwrap_err();
        assert!(format!("{err:#}").contains("unknown sequence"), "{err:#}");
        assert_eq!(pool.socket_of(100), None, "refused fork placed child");
        assert_eq!(pool.live_nodes(), 2, "a refusal must not kill the node");
    }

    /// `node_reports` gathers each node's listener-wide self-report
    /// (the `fdtop` surface): per-node attend counters, service
    /// percentiles, zero payload drift, cache occupancy — and a dead
    /// node drops out of the report the way it drops out of `stats`.
    #[test]
    fn node_reports_cover_live_nodes_and_skip_dead_ones() {
        let mut pool = RemotePool::loopback(cfg(WireMode::F32), 2).unwrap();
        // 1 → node 0, 2 → node 1
        pool.add_seqs(&[1, 2]).unwrap();
        let mut rng = Rng::new(11);
        for _ in 0..3 {
            pool.attend(
                0,
                vec![
                    mk_task(&mut rng, 1, TINY.hidden),
                    mk_task(&mut rng, 2, TINY.hidden),
                ],
            )
            .unwrap();
        }
        let reports = pool.node_reports().unwrap();
        assert_eq!(reports.len(), 2);
        for (label, r) in &reports {
            assert!(!label.is_empty());
            assert_eq!(r.attend_ops, 3, "{label}: {r:?}");
            assert_eq!(r.attend_rows, 3, "{label}: {r:?}");
            assert_eq!(r.attend_errors, 0, "{label}: {r:?}");
            assert_eq!(r.cache.sequences, 1, "{label}: {r:?}");
            assert_eq!(r.cache.total_tokens, 3, "{label}: {r:?}");
            assert!(r.uptime_us > 0, "{label}: uptime not ticking");
            assert!(r.blocks_used >= 1, "{label}: {r:?}");
            assert!(r.modeled_payload_bytes > 0, "{label}: {r:?}");
            assert_eq!(
                r.measured_payload_bytes, r.modeled_payload_bytes,
                "{label}: payload drift on the live wire"
            );
            assert!(
                r.service_p99_us >= r.service_p50_us,
                "{label}: {r:?}"
            );
        }
        // kill node 0: reports shrink to the survivor, no error
        pool.send_to(0, &NetRequest::Shutdown).unwrap();
        pool.attend(0, vec![mk_task(&mut rng, 1, TINY.hidden)])
            .unwrap_err();
        let reports = pool.node_reports().unwrap();
        assert_eq!(reports.len(), 1, "{reports:?}");
    }

    /// A node that refuses a request reports a routed error and stays
    /// alive (not marked dead).
    #[test]
    fn refused_request_keeps_node_alive() {
        let mut pool = RemotePool::loopback(cfg(WireMode::F16), 2).unwrap();
        pool.add_seqs(&[1, 2]).unwrap();
        let mut rng = Rng::new(3);
        // seq 3 is unknown on the node: bypass placement to force the
        // remote-side refusal
        pool.placement.insert(3, 0);
        let err = pool
            .attend(0, vec![mk_task(&mut rng, 3, TINY.hidden)])
            .unwrap_err();
        assert!(format!("{err:#}").contains("not placed"), "{err:#}");
        assert_eq!(pool.live_nodes(), 2, "a refusal must not kill the node");
        pool.placement.remove(&3);
        // and the pool keeps attending
        let step = pool
            .attend(
                0,
                vec![
                    mk_task(&mut rng, 1, TINY.hidden),
                    mk_task(&mut rng, 2, TINY.hidden),
                ],
            )
            .unwrap();
        assert_eq!(step.outputs.len(), 2);
    }

    /// Killed loopback node: routed error with the disconnect as root
    /// cause; survivors keep serving; new sequences place on live
    /// nodes only.
    #[test]
    fn killed_loopback_node_routes_error_and_pool_survives() {
        let mut pool = RemotePool::loopback(cfg(WireMode::F32), 2).unwrap();
        // 1,3 → node 0; 2,4 → node 1
        pool.add_seqs(&[1, 2, 3, 4]).unwrap();
        let mut rng = Rng::new(9);
        // kill node 0's server loop
        pool.send_to(0, &NetRequest::Shutdown).unwrap();
        let err = pool
            .attend(
                0,
                (1..=4).map(|i| mk_task(&mut rng, i, TINY.hidden)).collect(),
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("disconnected"), "{err:#}");
        assert_eq!(pool.live_nodes(), 1);
        // retiring the dead node's sequences succeeds locally
        pool.drop_seqs(&[1, 3]).unwrap();
        // new sequences go to the survivor, and attends work
        pool.add_seqs(&[10]).unwrap();
        assert_eq!(pool.socket_of(10), Some(1));
        let step = pool
            .attend(
                0,
                vec![
                    mk_task(&mut rng, 2, TINY.hidden),
                    mk_task(&mut rng, 4, TINY.hidden),
                    mk_task(&mut rng, 10, TINY.hidden),
                ],
            )
            .unwrap();
        assert_eq!(step.outputs.len(), 3);
        // dead-node touches keep naming the original cause
        let err2 = pool.rpc_ack(0, &NetRequest::Stats).unwrap_err();
        assert!(format!("{err2:#}").contains("dead"), "{err2:#}");
        // the dead node's counters survive as a snapshot
        let stats = pool.net_stats();
        assert_eq!(stats.len(), 2);
        assert!(stats[0].transport.frames_sent > 0, "{:?}", stats[0]);
        assert_eq!(stats[0].errors, 1, "{:?}", stats[0]);
    }

    /// Live wire traffic measures exactly what the LinkModel models:
    /// the runtime drift detector stays at zero across attends on both
    /// wire modes, and the counters actually count.
    #[test]
    fn net_stats_count_wire_traffic_without_drift() {
        for wire in [WireMode::F32, WireMode::F16] {
            let mut pool = RemotePool::loopback(cfg(wire), 2).unwrap();
            pool.install_tracer(Tracer::enabled());
            pool.add_seqs(&[1, 2, 3]).unwrap();
            let mut rng = Rng::new(7);
            for _ in 0..2 {
                let tasks: Vec<SeqTask> = [1u64, 2, 3]
                    .iter()
                    .map(|&i| mk_task(&mut rng, i, TINY.hidden))
                    .collect();
                pool.attend(0, tasks).unwrap();
            }
            let stats = pool.net_stats();
            assert_eq!(stats.len(), 2);
            for s in &stats {
                assert!(s.drift_free(), "{wire:?} node {}: {s:?}", s.node);
                assert_eq!(s.attend_ops, 2, "{s:?}");
                assert_eq!(s.errors, 0, "{s:?}");
                assert!(s.modeled_payload_sent > 0, "{s:?}");
                assert!(s.modeled_payload_recv > 0, "{s:?}");
                assert!(s.transport.frames_sent >= 3, "{s:?}"); // cfg + 2 attends
                assert!(s.transport.bytes_sent > s.modeled_payload_sent, "{s:?}");
                assert!(s.transport.frames_recv >= 3, "{s:?}");
            }
        }
    }

    /// Traced loopback nodes ship their server-side spans back through
    /// `FetchTrace`; the pool clock-aligns and merges them onto one
    /// track per node in the installed tracer, and the per-node
    /// profiles carry measured throughput with a drained queue.
    #[test]
    fn remote_traces_merge_and_profiles_measure() {
        use crate::util::json::Json;
        let tracer = Tracer::enabled();
        let mut pool =
            RemotePool::loopback(cfg(WireMode::F32).with_trace(true), 2)
                .unwrap();
        pool.install_tracer(tracer.clone());
        pool.add_seqs(&[1, 2]).unwrap();
        let mut rng = Rng::new(21);
        for _ in 0..3 {
            let tasks = vec![
                mk_task(&mut rng, 1, TINY.hidden),
                mk_task(&mut rng, 2, TINY.hidden),
            ];
            pool.attend(0, tasks).unwrap();
        }
        let merged = pool.merge_remote_traces().unwrap();
        assert!(merged > 0, "expected server-side spans to merge");
        let parsed = Json::parse(&tracer.chrome_trace().render()).unwrap();
        let events =
            parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        for label in ["rnode0", "rnode1"] {
            assert!(
                events.iter().any(|e| {
                    e.get("name").and_then(Json::as_str)
                        == Some("thread_name")
                        && e.get("args")
                            .and_then(|a| a.get("name"))
                            .and_then(Json::as_str)
                            == Some(label)
                }),
                "missing per-node track {label}"
            );
        }
        // every merged span lands inside the local timeline
        for e in events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        {
            let ts = e.get("ts").and_then(Json::as_f64).unwrap();
            let dur = e.get("dur").and_then(Json::as_f64).unwrap();
            assert!(ts >= 0.0 && dur >= 0.0, "span escaped the window");
        }
        let stats = pool.net_stats();
        for s in &stats {
            assert_eq!(s.profile.samples(), 3, "{s:?}");
            assert!(s.profile.tokens_per_s > 0.0, "{s:?}");
            assert!(s.profile.bytes_per_s > 0.0, "{s:?}");
            assert_eq!(s.profile.queue_depth, 0, "{s:?}");
            assert!(s.profile.peak_queue_depth >= 1, "{s:?}");
        }
    }

    /// Placement iterates in ascending sequence-id order (BTreeMap):
    /// whole-map walks (rollback, future migration scatters) see a
    /// deterministic order, and gathered outputs come back keyed the
    /// same way run to run — the deterministic-iteration discipline,
    /// pinned.
    #[test]
    fn placement_and_outputs_iterate_in_seq_id_order() {
        let mut pool = RemotePool::loopback(cfg(WireMode::F32), 2).unwrap();
        // insertion order deliberately shuffled
        pool.add_seqs(&[9, 2, 7, 1, 4]).unwrap();
        let ids: Vec<u64> = pool.placement.keys().copied().collect();
        assert_eq!(ids, vec![1, 2, 4, 7, 9], "placement walk not sorted");
        // ...while round-robin still follows INSERTION order: 9,2 → 0,1
        assert_eq!(pool.socket_of(9), Some(0));
        assert_eq!(pool.socket_of(2), Some(1));
        let mut rng = Rng::new(11);
        let tasks: Vec<SeqTask> = [9u64, 2, 7, 1, 4]
            .iter()
            .map(|&i| mk_task(&mut rng, i, TINY.hidden))
            .collect();
        let step = pool.attend(0, tasks).unwrap();
        let out_ids: Vec<u64> = step.outputs.keys().copied().collect();
        assert_eq!(out_ids, vec![1, 2, 4, 7, 9], "outputs walk not sorted");
    }

    /// Double placement is a routed error (not a panic, PR 3/5
    /// discipline) and leaves the pool fully usable.
    #[test]
    fn duplicate_placement_is_a_routed_error() {
        let mut pool = RemotePool::loopback(cfg(WireMode::F32), 2).unwrap();
        pool.add_seqs(&[1]).unwrap();
        let err = pool.add_seqs(&[2, 1]).unwrap_err();
        assert!(format!("{err:#}").contains("already placed"), "{err:#}");
        assert_eq!(pool.socket_of(2), None, "failed batch must not place");
        // an in-batch duplicate routes the same way
        let err2 = pool.add_seqs(&[5, 5]).unwrap_err();
        assert!(format!("{err2:#}").contains("already placed"), "{err2:#}");
        assert_eq!(pool.live_nodes(), 2, "a local refusal kills no node");
        // and the pool keeps placing and attending
        pool.add_seqs(&[2]).unwrap();
        let mut rng = Rng::new(1);
        let step = pool
            .attend(
                0,
                vec![
                    mk_task(&mut rng, 1, TINY.hidden),
                    mk_task(&mut rng, 2, TINY.hidden),
                ],
            )
            .unwrap();
        assert_eq!(step.outputs.len(), 2);
    }
}
