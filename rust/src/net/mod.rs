//! REAL wire transport for multi-node R-workers (paper abstract, §4:
//! "the aggregated memory capacity and compute of CPUs across multiple
//! nodes" absorb the KV-bound R-Part).
//!
//! This module is the counterpart of `crate::transport`, and the two
//! deliberately split one concern:
//!
//! * [`crate::transport`] **models** the wire — `LinkModel` prices
//!   latency+bandwidth for the byte counts a deployment WOULD ship, so
//!   offline benches reproduce Table 3 / Fig 15 without a cluster.
//!   Nothing crosses a socket there.
//! * `net` (this module) **is** the wire — activation vectors are
//!   length-prefix framed by a hand-rolled binary codec
//!   ([`codec`]), cross a [`Transport`] (in-process [`Loopback`] or
//!   real localhost [`Tcp`]), and are served by `rnode` hosts
//!   ([`rnode`], plus the `rnode` binary target) that own the remote
//!   `SocketCache`s. [`RemotePool`] is the client side: it shards
//!   sequences round-robin across nodes and implements
//!   [`crate::rworker::AttendBackend`], so `ThreadedPipeline`,
//!   `FastDecode` and `serve::ServeEngine` run unchanged over
//!   in-process threads, loopback, or TCP nodes.
//!
//! The codec's [`WireMode::F16`] packs the q/k/v/o payloads as IEEE
//! binary16 via `util::f16` — the paper's fp16 intermediate vectors
//! (Table 3), and exactly the byte counts `transport::
//! qkv_message_bytes` / `o_message_bytes` charge (pinned by test).
//! [`WireMode::F32`] ships raw bits and is pinned bit-identical to the
//! in-process thread backend.
//!
//! Fault handling extends PR 3's `SResp::Err` discipline to the R
//! side: a node death, a refused request or a malformed frame comes
//! back as a routed error with the root cause — never a hang, never a
//! bare thread death — and the surviving nodes stay usable.
//!
//! # Cross-process observability: trace → align → merge
//!
//! The wire also carries the distributed-tracing flow (`obs`):
//!
//! 1. **Trace** — the `Configure` handshake's `trace` flag turns on a
//!    server-side `Tracer` in each rnode, pinned to the connection's
//!    own monotonic epoch: queue-wait, frame-decode, per-layer
//!    kv-append + attend (row/task counts in args), and output-encode
//!    spans. `NetRequest::FetchTrace` → `NetResponse::Trace` ships
//!    them back as serialized span batches.
//! 2. **Align** — two processes' monotonic clocks share no epoch, so
//!    [`RemotePool`] follows the `Configure` ack with an RTT ping burst
//!    (`NetRequest::Ping` → `NetResponse::Pong` carrying the node's
//!    epoch-relative time). The minimum-RTT sample's midpoint
//!    (`obs::pick_clock_sync`) estimates the per-node clock offset with
//!    error bounded by ±RTT/2.
//! 3. **Merge** — `merge_remote_traces` (on the `AttendBackend` trait)
//!    fetches every live node's spans, shifts each by that node's
//!    offset, and folds them into the client's tracer as one track per
//!    node — one chrome://tracing timeline where each node's internals
//!    nest inside the client-side submit→reply spans that caused them.
//!    Every live node is drained before the first failure is reported,
//!    so a node dying mid-fetch still leaves the survivors' traces in
//!    the export.
//!
//! The same submit→reply timing feeds each node's live
//! `obs::NodeProfile` (EWMA tokens/s, bytes/s, service-time
//! percentiles, queue depth), surfaced through `net_stats` — the
//! measured per-node throughput that
//! `perfmodel::Planner::from_measured_profiles` consumes in place of
//! assumed-equal device models.
//!
//! # Live self-reporting: `NodeStats` and the monitor connection
//!
//! Two wire ops extend the protocol for live observability (see
//! `obs`'s two-surface overview):
//!
//! * [`NetRequest::NodeStats`] → [`NetResponse::NodeStats`] carrying a
//!   [`codec::NodeStatsReport`] — the LISTENER-wide cumulative
//!   counters every connection of an rnode shares
//!   ([`rnode::NodeShared`]): uptime, open connections, attend
//!   ops/rows/errors, queue-wait and busy time, service p50/p99,
//!   modeled-vs-measured payload bytes, and cache occupancy merged
//!   across live connections.
//! * `NodeStats` (or `Ping`) as a connection's FIRST frame enters
//!   **monitor mode** instead of being refused like other
//!   pre-`Configure` traffic: the connection serves only
//!   `NodeStats`/`Ping`/`Shutdown`, so a dashboard can poll a node
//!   that is busy serving attends without a `Configure` handshake and
//!   without touching the serving connections. [`monitor`] is that
//!   client (one fresh connection per poll; dead nodes become DEAD
//!   rows, not errors), and the `fdtop` binary is its CLI.

pub mod codec;
pub mod monitor;
pub mod remote;
pub mod rnode;
pub mod transport;

pub use codec::{
    decode_request, decode_response, encode_request, encode_response,
    vec_payload_bytes, NetRequest, NetResponse, NodeConfig,
    NodeStatsReport, WireMode, MAX_FRAME_BYTES,
};
pub use monitor::{
    cluster_json, poll_cluster, poll_node, validate_cluster,
    validate_cluster_file, NodeRow, CLUSTER_SCHEMA_VERSION,
};
pub use remote::RemotePool;
pub use rnode::{
    run_rnode, serve_connection, serve_connection_shared, serve_listener,
    spawn_local_listener, spawn_rnode_process, LocalRnode, NodeShared,
    RnodeProcess,
};
pub use transport::{loopback_pair, Loopback, Tcp, Transport};
