//! Cluster monitoring client — the library behind the `fdtop` binary.
//!
//! A monitor opens a FRESH TCP connection to each rnode per poll and
//! sends `NetRequest::NodeStats` as the FIRST frame, which the node
//! serves unconfigured (`rnode::serve_monitor`): polling never touches
//! the serving connections and never requires a `Configure` handshake.
//! One poll of a cluster is one connect+request+reply per node.
//!
//! Failure discipline matches the rest of `net`: a node that refuses
//! the connection, hangs up, or answers garbage becomes a DEAD
//! [`NodeRow`] carrying the root cause — the poll of the other nodes
//! proceeds, and the rendered table/JSON still has one row per asked
//! address. A dashboard that aborts because one node died is useless
//! precisely when it is needed.
//!
//! The JSON document ([`cluster_json`], schema below) is the
//! scripting/CI surface; [`validate_cluster`] is the gate CI runs over
//! `fdtop --once --json` output.
//!
//! # Cluster JSON (schema version 1)
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "nodes": [
//!     {
//!       "addr": "127.0.0.1:41234",
//!       "alive": true,
//!       "uptime_us": 1234567,
//!       "connections": 2,
//!       "attend_ops": 100, "attend_rows": 800, "attend_errors": 0,
//!       "attend_tok_per_s": 650.0,        // rows / uptime (cumulative)
//!       "bytes_per_s": 3.1e6,             // measured payload / uptime
//!       "service_p50_us": 900, "service_p99_us": 2100,
//!       "queue_wait_us": 40000, "busy_us": 90000,
//!       "payload_drift": 0.0,             // measured/modeled − 1
//!       "kv_utilization": 0.93,
//!       "kv_sequences": 8, "kv_total_tokens": 4096,
//!       "kv_physical_tokens": 4096,
//!       "blocks_used": 256, "blocks_free": 0
//!     },
//!     { "addr": "127.0.0.1:41235", "alive": false,
//!       "error": "connection refused" }
//!   ]
//! }
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::codec::{
    decode_response, encode_request, NetRequest, NetResponse,
    NodeStatsReport, WireMode,
};
use super::transport::{Tcp, Transport};

/// Bump when the cluster JSON layout changes incompatibly.
pub const CLUSTER_SCHEMA_VERSION: u64 = 1;

/// One polled node: either a live self-report or the reason the poll
/// failed. Exactly one of `report`/`error` is `Some`.
#[derive(Clone, Debug)]
pub struct NodeRow {
    /// The address that was asked (the row's display name).
    pub addr: String,
    pub report: Option<NodeStatsReport>,
    pub error: Option<String>,
}

impl NodeRow {
    pub fn alive(&self) -> bool {
        self.report.is_some()
    }
}

/// Fetch one node's [`NodeStatsReport`] over a fresh monitor
/// connection (`NodeStats` as the first frame — no `Configure`).
pub fn poll_node(addr: &str) -> Result<NodeStatsReport> {
    let mut t = Tcp::connect(addr).with_context(|| format!("connecting monitor to {addr}"))?;
    t.send(&encode_request(&NetRequest::NodeStats, WireMode::F32))
        .with_context(|| format!("sending NodeStats to {addr}"))?;
    let reply = t
        .recv()
        .with_context(|| format!("awaiting NodeStats from {addr}"))?;
    match decode_response(&reply, WireMode::F32)
        .with_context(|| format!("decoding NodeStats reply from {addr}"))?
    {
        NetResponse::NodeStats(report) => Ok(report),
        NetResponse::Err(msg) => {
            bail!("{addr} refused NodeStats: {msg}")
        }
        other => bail!("{addr} answered NodeStats with {other:?}"),
    }
}

/// Poll every address; a failed node yields a dead row with the root
/// cause instead of failing the poll.
pub fn poll_cluster(addrs: &[String]) -> Vec<NodeRow> {
    addrs
        .iter()
        .map(|addr| match poll_node(addr) {
            Ok(report) => NodeRow {
                addr: addr.clone(),
                report: Some(report),
                error: None,
            },
            Err(e) => NodeRow {
                addr: addr.clone(),
                report: None,
                error: Some(format!("{e:#}")),
            },
        })
        .collect()
}

/// Attend-rows-per-second between two polls of the SAME node (delta
/// rows over delta uptime). `None` when the node restarted between
/// polls (uptime went backwards) or no time passed — the caller should
/// fall back to the cumulative [`NodeStatsReport::rows_per_uptime_s`].
pub fn rate_between(prev: &NodeStatsReport, cur: &NodeStatsReport) -> Option<f64> {
    if cur.uptime_us <= prev.uptime_us || cur.attend_rows < prev.attend_rows {
        return None;
    }
    let dt_s = (cur.uptime_us - prev.uptime_us) as f64 / 1e6;
    Some((cur.attend_rows - prev.attend_rows) as f64 / dt_s)
}

fn node_json(row: &NodeRow) -> Json {
    let base = Json::obj().set("addr", row.addr.as_str()).set("alive", row.alive());
    match &row.report {
        Some(r) => {
            let uptime_s = r.uptime_us as f64 / 1e6;
            let bytes_per_s = if uptime_s > 0.0 {
                r.measured_payload_bytes as f64 / uptime_s
            } else {
                0.0
            };
            base.set("uptime_us", r.uptime_us)
                .set("connections", r.connections)
                .set("attend_ops", r.attend_ops)
                .set("attend_rows", r.attend_rows)
                .set("attend_errors", r.attend_errors)
                .set("attend_tok_per_s", r.rows_per_uptime_s())
                .set("bytes_per_s", bytes_per_s)
                .set("service_p50_us", r.service_p50_us)
                .set("service_p99_us", r.service_p99_us)
                .set("queue_wait_us", r.queue_wait_us)
                .set("busy_us", r.busy_us)
                .set("payload_drift", r.payload_drift())
                .set("kv_utilization", r.kv_utilization())
                .set("kv_sequences", r.cache.sequences)
                .set("kv_total_tokens", r.cache.total_tokens)
                .set("kv_physical_tokens", r.cache.physical_tokens)
                .set("blocks_used", r.blocks_used)
                .set("blocks_free", r.blocks_free)
        }
        None => {
            let cause = row.error.clone().unwrap_or_else(|| "unknown".to_string());
            base.set("error", cause)
        }
    }
}

/// The `fdtop --json` document: one entry per asked address, dead
/// nodes included (`alive: false` + `error`).
pub fn cluster_json(rows: &[NodeRow]) -> Json {
    Json::obj()
        .set("schema_version", CLUSTER_SCHEMA_VERSION)
        .set("nodes", Json::Arr(rows.iter().map(node_json).collect()))
}

fn req_num(j: &Json, ctx: &str, key: &str) -> Result<f64> {
    let v = j
        .get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("{ctx}: missing numeric field '{key}'"))?;
    if !v.is_finite() || v < 0.0 {
        bail!("{ctx}: field '{key}' is {v}, want finite and >= 0");
    }
    Ok(v)
}

/// CI gate over a parsed `fdtop --once --json` document: schema
/// version, one well-formed row per node, live rows carry every
/// numeric field (finite, non-negative, p99 >= p50), dead rows carry
/// the error cause.
pub fn validate_cluster(doc: &Json) -> Result<()> {
    let version = doc
        .get("schema_version")
        .and_then(Json::as_f64)
        .context("cluster: missing numeric field 'schema_version'")?;
    if version != CLUSTER_SCHEMA_VERSION as f64 {
        bail!(
            "unsupported cluster schema_version {version} (want \
             {CLUSTER_SCHEMA_VERSION})"
        );
    }
    let nodes = doc
        .get("nodes")
        .and_then(Json::as_arr)
        .context("cluster: missing array field 'nodes'")?;
    if nodes.is_empty() {
        bail!("cluster: empty 'nodes' — nothing was polled");
    }
    for (i, node) in nodes.iter().enumerate() {
        let ctx = format!("nodes[{i}]");
        let addr = node
            .get("addr")
            .and_then(Json::as_str)
            .with_context(|| format!("{ctx}: missing string 'addr'"))?;
        if addr.is_empty() {
            bail!("{ctx}: empty addr");
        }
        let alive = node
            .get("alive")
            .and_then(Json::as_bool)
            .with_context(|| format!("{ctx}: missing bool 'alive'"))?;
        if !alive {
            let err = node
                .get("error")
                .and_then(Json::as_str)
                .with_context(|| {
                    format!("{ctx} ({addr}): dead row without 'error'")
                })?;
            if err.is_empty() {
                bail!("{ctx} ({addr}): dead row with empty 'error'");
            }
            continue;
        }
        for key in [
            "uptime_us",
            "connections",
            "attend_ops",
            "attend_rows",
            "attend_errors",
            "attend_tok_per_s",
            "bytes_per_s",
            "service_p50_us",
            "service_p99_us",
            "queue_wait_us",
            "busy_us",
            "kv_utilization",
            "kv_sequences",
            "kv_total_tokens",
            "kv_physical_tokens",
            "blocks_used",
            "blocks_free",
        ] {
            req_num(node, &format!("{ctx} ({addr})"), key)?;
        }
        // drift is signed: measured below modeled is legal, so only
        // finiteness is required
        let drift = node
            .get("payload_drift")
            .and_then(Json::as_f64)
            .with_context(|| {
                format!("{ctx} ({addr}): missing 'payload_drift'")
            })?;
        if !drift.is_finite() {
            bail!("{ctx} ({addr}): payload_drift is {drift}");
        }
        let p50 = req_num(node, &ctx, "service_p50_us")?;
        let p99 = req_num(node, &ctx, "service_p99_us")?;
        if p99 < p50 {
            bail!("{ctx} ({addr}): p99 {p99} < p50 {p50}");
        }
    }
    Ok(())
}

/// Read, parse and [`validate_cluster`] an `fdtop --once --json` file.
pub fn validate_cluster_file(path: &Path) -> Result<()> {
    let body = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let doc = Json::parse(&body)
        .with_context(|| format!("parsing {}", path.display()))?;
    validate_cluster(&doc)
        .with_context(|| format!("validating {}", path.display()))
}

fn fmt_rate(v: f64) -> String {
    if v >= 1e6 {
        format!("{:.1}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}k", v / 1e3)
    } else {
        format!("{v:.1}")
    }
}

/// Render one poll as the fixed-width table the interactive `fdtop`
/// view shows. `rates` overrides the tok/s column with interval deltas
/// (same indices as `rows`; `None` falls back to cumulative).
pub fn render_table(rows: &[NodeRow], rates: &[Option<f64>]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<22} {:>6} {:>9} {:>9} {:>8} {:>8} {:>5} {:>5} {:>6} {:>7} \
         {:>7}\n",
        "NODE",
        "STATE",
        "TOK/S",
        "BYTES/S",
        "P50ms",
        "P99ms",
        "CONN",
        "SEQS",
        "KV%",
        "BLOCKS",
        "DRIFT%",
    ));
    for (i, row) in rows.iter().enumerate() {
        match &row.report {
            Some(r) => {
                let tok = rates
                    .get(i)
                    .copied()
                    .flatten()
                    .unwrap_or_else(|| r.rows_per_uptime_s());
                let uptime_s = r.uptime_us as f64 / 1e6;
                let bps = if uptime_s > 0.0 {
                    r.measured_payload_bytes as f64 / uptime_s
                } else {
                    0.0
                };
                out.push_str(&format!(
                    "{:<22} {:>6} {:>9} {:>9} {:>8.2} {:>8.2} {:>5} \
                     {:>5} {:>6.1} {:>7} {:>7.2}\n",
                    row.addr,
                    "up",
                    fmt_rate(tok),
                    fmt_rate(bps),
                    r.service_p50_us as f64 / 1e3,
                    r.service_p99_us as f64 / 1e3,
                    r.connections,
                    r.cache.sequences,
                    r.kv_utilization() * 100.0,
                    format!("{}/{}", r.blocks_used, r.blocks_free),
                    r.payload_drift() * 100.0,
                ));
            }
            None => {
                out.push_str(&format!(
                    "{:<22} {:>6}  {}\n",
                    row.addr,
                    "DEAD",
                    row.error.as_deref().unwrap_or("unknown"),
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::CacheStats;

    fn sample_report() -> NodeStatsReport {
        NodeStatsReport {
            uptime_us: 2_000_000,
            connections: 2,
            attend_ops: 10,
            attend_rows: 100,
            attend_errors: 1,
            queue_wait_us: 5_000,
            busy_us: 9_000,
            service_p50_us: 800,
            service_p99_us: 2_000,
            modeled_payload_bytes: 1_000,
            measured_payload_bytes: 1_000,
            blocks_used: 4,
            blocks_free: 1,
            cache: CacheStats {
                sequences: 3,
                total_tokens: 48,
                physical_tokens: 48,
                allocated_bytes: 4096,
                logical_bytes: 3072,
            },
        }
    }

    fn rows() -> Vec<NodeRow> {
        vec![
            NodeRow {
                addr: "127.0.0.1:1000".into(),
                report: Some(sample_report()),
                error: None,
            },
            NodeRow {
                addr: "127.0.0.1:1001".into(),
                report: None,
                error: Some("connection refused".into()),
            },
        ]
    }

    #[test]
    fn cluster_json_roundtrips_and_validates() {
        let doc = cluster_json(&rows());
        let parsed = Json::parse(&doc.render()).unwrap();
        validate_cluster(&parsed).unwrap();
        let nodes = parsed.get("nodes").and_then(Json::as_arr).unwrap();
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[0].get("alive").and_then(Json::as_bool), Some(true));
        assert_eq!(
            nodes[0].get("attend_tok_per_s").and_then(Json::as_f64),
            Some(50.0)
        );
        assert_eq!(nodes[1].get("alive").and_then(Json::as_bool), Some(false));
        assert_eq!(
            nodes[1].get("error").and_then(Json::as_str),
            Some("connection refused")
        );
    }

    #[test]
    fn validate_rejects_malformed_documents() {
        // wrong version
        let bad = Json::obj()
            .set("schema_version", 99u64)
            .set("nodes", Vec::<f64>::new());
        assert!(validate_cluster(&bad).is_err());
        // empty cluster
        let empty = Json::obj()
            .set("schema_version", CLUSTER_SCHEMA_VERSION)
            .set("nodes", Vec::<f64>::new());
        assert!(validate_cluster(&empty).is_err());
        // live row missing fields
        let live_partial = Json::obj().set("addr", "x:1").set("alive", true);
        let partial = Json::obj()
            .set("schema_version", CLUSTER_SCHEMA_VERSION)
            .set("nodes", Json::Arr(vec![live_partial]));
        assert!(validate_cluster(&partial).is_err());
        // dead row without a cause
        let dead_causeless = Json::obj().set("addr", "x:1").set("alive", false);
        let causeless = Json::obj()
            .set("schema_version", CLUSTER_SCHEMA_VERSION)
            .set("nodes", Json::Arr(vec![dead_causeless]));
        assert!(validate_cluster(&causeless).is_err());
        // p99 < p50 on a live row
        let mut doc = cluster_json(&rows());
        if let Json::Obj(fields) = &mut doc {
            if let Some((_, Json::Arr(nodes))) =
                fields.iter_mut().find(|(k, _)| k.as_str() == "nodes")
            {
                if let Json::Obj(node) = &mut nodes[0] {
                    for (k, v) in node.iter_mut() {
                        if k.as_str() == "service_p99_us" {
                            *v = Json::Num(1.0);
                        }
                    }
                }
            }
        }
        assert!(validate_cluster(&doc).is_err());
    }

    #[test]
    fn rate_between_uses_deltas_and_detects_restart() {
        let a = sample_report();
        let mut b = a;
        b.uptime_us += 1_000_000;
        b.attend_rows += 250;
        assert_eq!(rate_between(&a, &b), Some(250.0));
        // restarted node: uptime went backwards
        let mut fresh = a;
        fresh.uptime_us = 10;
        fresh.attend_rows = 0;
        assert_eq!(rate_between(&a, &fresh), None);
        // no time passed
        assert_eq!(rate_between(&a, &a), None);
    }

    #[test]
    fn table_renders_dead_and_live_rows() {
        let rows = rows();
        let table = render_table(&rows, &[None, None]);
        assert!(table.contains("NODE"), "header missing:\n{table}");
        assert!(table.contains("127.0.0.1:1000"));
        assert!(table.contains("DEAD"), "dead row missing:\n{table}");
        assert!(table.contains("connection refused"));
        // interval rate overrides the cumulative column
        let fast = render_table(&rows, &[Some(123456.0), None]);
        assert!(fast.contains("123.5k"), "rate override missing:\n{fast}");
    }

    #[test]
    fn poll_node_fetches_a_live_report_over_tcp() {
        let node = crate::net::rnode::spawn_local_listener().unwrap();
        let addr = node.addr.to_string();
        let report = poll_node(&addr).unwrap();
        assert!(report.uptime_us > 0, "uptime not ticking");
        // the monitor connection itself is counted
        assert!(report.connections >= 1, "report: {report:?}");
        // an address nobody listens on becomes an error, not a panic
        assert!(poll_node("127.0.0.1:1").is_err());
    }
}
