//! Scheduling: the token-level two-stage pipeline (§4.1, Fig 5), the
//! sequence-level load-stabilizing schedule (SLS, §4.2, Fig 7, eqs. 5–6)
//! and the generalized load-control Algorithm 1.

mod loadctl;
mod pipeline;
mod sls;

pub use loadctl::{LoadControl, MicroBatch};
pub use pipeline::{pipeline_step_latency, PipelineSim};
pub use sls::SlsSchedule;
