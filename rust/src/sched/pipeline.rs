//! The token-level two-stage pipeline (§4.1, Fig 5).
//!
//! The S-worker and the R-workers process two mini-batches in turns:
//! while the S-worker runs S-Part of mini-batch B, the R-workers run
//! R-Part of mini-batch A. With per-stage latencies `s` and `r`, one
//! pipelined step of one mini-batch costs `max(s, r)` in steady state
//! (plus exposed start/drain overhead); without pipelining it costs
//! `s + r` (Fig 5a vs 5b).

use crate::metrics::{StepRecord, StepTrace};

/// Effective latency of one step of one mini-batch.
///
/// `sync_comm=false` overlaps activation transfer with compute (the
/// production mode); `true` exposes it (the Fig 15 profiling mode).
/// `overlap_eff` ∈ [0,1] models how much of the faster stage actually
/// hides under the slower one: 1.0 is a perfect pipeline; the paper's
/// Fig 15 trace (S-worker busy <50 %, workers waiting on stragglers)
/// calibrates the default to 0.7.
pub fn pipeline_step_latency(
    s_time: f64,
    r_time: f64,
    comm_time: f64,
    pipelined: bool,
    sync_comm: bool,
    overlap_eff: f64,
) -> f64 {
    let comm = if sync_comm { comm_time } else { 0.0 };
    if pipelined {
        // two mini-batches in flight: the slower stage paces the system,
        // plus the un-overlapped remainder of the faster one
        let (hi, lo) = if s_time >= r_time + comm {
            (s_time, r_time + comm)
        } else {
            (r_time + comm, s_time)
        };
        hi + (1.0 - overlap_eff.clamp(0.0, 1.0)) * lo
    } else {
        s_time + r_time + comm_time
    }
}

/// A virtual-clock simulator of a whole generation run: per step it takes
/// the caller-supplied stage latencies and produces the per-step trace
/// (the engine behind Figs 8, 11, 12 and the baseline curves).
pub struct PipelineSim {
    pub pipelined: bool,
    pub sync_comm: bool,
    /// Fixed per-step scheduling overhead (batch (re)assembly etc.).
    pub overhead_s: f64,
    /// Fraction of the faster stage hidden under the slower (see
    /// [`pipeline_step_latency`]).
    pub overlap_eff: f64,
}

impl Default for PipelineSim {
    fn default() -> Self {
        PipelineSim {
            pipelined: true,
            sync_comm: false,
            overhead_s: 100e-6,
            overlap_eff: 0.7,
        }
    }
}

impl PipelineSim {
    /// Run `steps` steps; `stage(step)` returns
    /// (s_time, r_time, comm_time, tokens, total_ctx) for that step.
    pub fn run<F>(&self, steps: usize, mut stage: F) -> StepTrace
    where
        F: FnMut(usize) -> (f64, f64, f64, usize, usize),
    {
        let mut trace = StepTrace::default();
        for step in 0..steps {
            let (s, r, c, tokens, ctx) = stage(step);
            if tokens == 0 {
                continue;
            }
            let lat = pipeline_step_latency(
                s,
                r,
                c,
                self.pipelined,
                self.sync_comm,
                self.overlap_eff,
            ) + self.overhead_s;
            trace.push(StepRecord {
                step,
                latency_s: lat,
                s_time: s,
                r_time: r,
                comm_time: c,
                tokens,
                total_ctx: ctx,
                // modeled steps have no measured wait/skew breakdown
                ..Default::default()
            });
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_pipeline_is_max_of_stages() {
        assert_eq!(pipeline_step_latency(3.0, 5.0, 0.0, true, false, 1.0), 5.0);
        assert_eq!(pipeline_step_latency(5.0, 3.0, 0.0, true, false, 1.0), 5.0);
    }

    #[test]
    fn imperfect_overlap_exposes_remainder() {
        let l = pipeline_step_latency(4.0, 6.0, 0.0, true, false, 0.7);
        assert!((l - (6.0 + 0.3 * 4.0)).abs() < 1e-12);
    }

    #[test]
    fn unpipelined_is_sum() {
        assert_eq!(pipeline_step_latency(3.0, 5.0, 1.0, false, false, 1.0), 9.0);
    }

    #[test]
    fn sync_comm_extends_r_stage() {
        let a = pipeline_step_latency(6.0, 5.0, 2.0, true, true, 1.0);
        assert_eq!(a, 7.0); // r + comm exceeds s
        let b = pipeline_step_latency(6.0, 5.0, 2.0, true, false, 1.0);
        assert_eq!(b, 6.0); // overlapped
    }

    /// Fig 6's area argument: pipelining saves (s+r−max)/step; with
    /// balanced stages and perfect overlap the saving is ~50 % of serial
    /// time.
    #[test]
    fn balanced_pipeline_halves_serial_time() {
        let sim_p = PipelineSim {
            overhead_s: 0.0,
            overlap_eff: 1.0,
            ..Default::default()
        };
        let sim_s = PipelineSim {
            pipelined: false,
            overhead_s: 0.0,
            overlap_eff: 1.0,
            ..Default::default()
        };
        let stage = |_: usize| (1.0, 1.0, 0.0, 1, 0);
        let tp = sim_p.run(10, stage).total_time();
        let ts = sim_s.run(10, stage).total_time();
        assert!((tp / ts - 0.5).abs() < 1e-9);
    }

    #[test]
    fn zero_token_steps_are_skipped() {
        let sim = PipelineSim::default();
        let trace = sim.run(5, |s| {
            if s % 2 == 0 {
                (1.0, 1.0, 0.0, 1, 1)
            } else {
                (0.0, 0.0, 0.0, 0, 0)
            }
        });
        assert_eq!(trace.len(), 3);
    }
}
