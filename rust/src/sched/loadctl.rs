//! Algorithm 1: the load-control generalization of SLS (§4.2).
//!
//! Tracks, for every live micro-batch i, the aggregate workload W[i] at
//! its *final* step (where each micro-batch's contribution peaks).
//! `earliest_start` answers: given a load limit W_lim, what is the
//! earliest step a new micro-batch of size m may start without pushing
//! any of those peaks past the limit?
//!
//! The `*_init` variants generalize the paper's listing to requests
//! that begin life with KV already cached: a batched prefill appends
//! the whole prompt in the request's first step, so its per-sequence
//! context is `init + age` rather than `age`. `init = 0` recovers
//! Algorithm 1 exactly. The safety argument is unchanged by `init`:
//! every batch's contribution is nondecreasing while it is alive, so
//! the aggregate load at any step is bounded by some live batch's
//! end-step peak, and bounding the peaks bounds every step.

/// One live micro-batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MicroBatch {
    /// Number of sequences m.
    pub size: usize,
    /// Step at which it started.
    pub start: usize,
    /// Final step index (start + grow_len - 1 inclusive).
    pub end: usize,
    /// Context tokens per sequence already cached when the batch starts
    /// (a batched prefill's bulk append); 0 for plain decode arrivals.
    pub init: usize,
    /// Aggregate workload at step `end` counting all earlier-started
    /// batches plus later admissions (maintained by `add`).
    pub peak_load: usize,
}

/// The Algorithm 1 state machine.
#[derive(Clone, Debug, Default)]
pub struct LoadControl {
    live: Vec<MicroBatch>,
}

impl LoadControl {
    pub fn new() -> LoadControl {
        LoadControl::default()
    }

    pub fn live(&self) -> &[MicroBatch] {
        &self.live
    }

    /// Retire micro-batches that finish before `step` (contiguous
    /// serving; not in the paper's listing but required for an unbounded
    /// run).
    pub fn retire_before(&mut self, step: usize) {
        self.live.retain(|mb| mb.end >= step);
    }

    /// AddMicroBatch: admit `m` sequences of length `seq_len` starting at
    /// `start`. Updates every live batch's peak-step workload with the
    /// newcomer's contribution (the paper's `W[i] += (E[i] - t) * m`,
    /// with 1-based lengths: age at step E[i] is E[i] - t + 1).
    pub fn add(&mut self, start: usize, m: usize, seq_len: usize) {
        self.add_init(start, m, 0, seq_len);
    }

    /// AddMicroBatch generalized to a bulk-prefilled batch: each of the
    /// `m` sequences starts with `init` KV tokens already cached and
    /// stays live for `grow_len` steps, appending one token per step —
    /// its contribution at age a (1-based) is `m · (init + a)` and its
    /// peak `m · (init + grow_len)`.
    pub fn add_init(&mut self, start: usize, m: usize, init: usize, grow_len: usize) {
        assert!(m > 0 && grow_len > 0);
        let end = start + grow_len - 1;
        // the newcomer's own peak: its full context × m, plus what every
        // other batch still contributes at `end`
        let mut own_peak = m * (init + grow_len);
        for mb in &self.live {
            own_peak += Self::contribution(mb, end);
        }
        for mb in self.live.iter_mut() {
            // the newcomer is alive during [start, end]; outside that
            // window (including after it retires) it contributes nothing
            if mb.end >= start && mb.end <= end {
                let age_at_end = mb.end - start + 1;
                mb.peak_load += (init + age_at_end) * m;
            }
        }
        self.live.push(MicroBatch {
            size: m,
            start,
            end,
            init,
            peak_load: own_peak,
        });
    }

    /// Load contributed by `mb` at step `t` (0 outside its lifetime).
    fn contribution(mb: &MicroBatch, t: usize) -> usize {
        if t < mb.start || t > mb.end {
            0
        } else {
            (mb.init + t - mb.start + 1) * mb.size
        }
    }

    /// Total aggregate context at step `t` (for traces and invariants).
    pub fn load_at(&self, t: usize) -> usize {
        self.live.iter().map(|mb| Self::contribution(mb, t)).sum()
    }

    /// GetEarliestStep: the earliest start step ≥ `now` for a new
    /// micro-batch of `m` sequences of length `seq_len` such that no
    /// live batch's peak-step load exceeds `w_lim`, nor the newcomer's
    /// own peak.
    ///
    /// Option contract: `None` if and only if `m·seq_len > w_lim` (the
    /// newcomer alone can never fit). For any feasible request a start
    /// step always exists — once every live batch has ended the
    /// newcomer runs alone — so the forward scan below provably
    /// terminates at `horizon + 1` at the latest and every other path
    /// returns `Some`.
    pub fn earliest_start(
        &self,
        now: usize,
        m: usize,
        seq_len: usize,
        w_lim: usize,
    ) -> Option<usize> {
        self.earliest_start_init(now, m, 0, seq_len, w_lim)
    }

    /// GetEarliestStep generalized to a bulk-prefilled batch (see
    /// [`LoadControl::add_init`]): the newcomer's contribution at age a
    /// is `m · (init + a)`, peaking at `m · (init + grow_len)`.
    ///
    /// Option contract: `None` if and only if
    /// `m · (init + grow_len) > w_lim` (the newcomer alone can never
    /// fit); every feasible request gets a finite start.
    pub fn earliest_start_init(
        &self,
        now: usize,
        m: usize,
        init: usize,
        grow_len: usize,
        w_lim: usize,
    ) -> Option<usize> {
        if m * (init + grow_len) > w_lim {
            return None;
        }
        let mut r = now;
        for mb in &self.live {
            if mb.peak_load >= w_lim {
                // no headroom at this batch's peak: the newcomer must
                // start after that peak step entirely
                r = r.max(mb.end + 1);
                continue;
            }
            // max (init + age) the newcomer may carry at mb.end
            let x = (w_lim - mb.peak_load) / m;
            if x <= init {
                // even age 1 overflows once the prefill bulk is counted
                r = r.max(mb.end + 1);
                continue;
            }
            let max_age = x - init;
            if max_age >= grow_len {
                continue; // even a full-length overlap fits
            }
            // age at mb.end = mb.end - start + 1 ≤ max_age
            //   ⇒ start ≥ mb.end - max_age + 1
            r = r.max(mb.end + 1 - max_age.min(mb.end + 1));
        }
        // The newcomer's own peak must also fit: at its end step, the
        // sum of older batches' contributions + m·(init + grow_len) ≤
        // w_lim. Scan forward (bounded: past every live batch's end all
        // are gone).
        let horizon = self
            .live
            .iter()
            .map(|mb| mb.end + 1)
            .max()
            .unwrap_or(now);
        let mut start = r;
        loop {
            let end = start + grow_len - 1;
            let others: usize = self
                .live
                .iter()
                .map(|mb| Self::contribution(mb, end))
                .sum();
            if others + m * (init + grow_len) <= w_lim {
                // no intermediate violation is possible: every live
                // batch's peak was bounded above via the per-batch
                // constraint, and the newcomer's own end load fits
                return Some(start);
            }
            start += 1;
            if start > horizon {
                // every live batch has ended before `start`, so the
                // newcomer runs alone and m·(init+grow_len) ≤ w_lim
                // suffices
                return Some(start);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn single_batch_peak_is_full_length() {
        let mut lc = LoadControl::new();
        lc.add(0, 4, 10);
        assert_eq!(lc.live()[0].peak_load, 40);
        assert_eq!(lc.load_at(0), 4);
        assert_eq!(lc.load_at(9), 40);
        assert_eq!(lc.load_at(10), 0);
    }

    #[test]
    fn add_updates_existing_peaks() {
        let mut lc = LoadControl::new();
        lc.add(0, 2, 10); // ends at 9, own peak 20
        lc.add(5, 3, 10); // at step 9 it has age 5 → adds 15
        assert_eq!(lc.live()[0].peak_load, 20 + 15);
        // newcomer's peak at step 14: own 30, first batch gone
        assert_eq!(lc.live()[1].peak_load, 30);
        assert_eq!(lc.load_at(9), 20 + 15);
    }

    #[test]
    fn earliest_start_respects_limit() {
        let mut lc = LoadControl::new();
        lc.add(0, 2, 10); // peak 20 at step 9
        // a new m=2, S=10 batch would add age·2 at step 9; limit 30
        // allows age ≤ 5 at step 9 ⇒ start ≥ 5
        let r = lc.earliest_start(0, 2, 10, 30).unwrap();
        assert_eq!(r, 5);
        // verify: admit at r and check the old peak
        lc.add(r, 2, 10);
        assert!(lc.live()[0].peak_load <= 30);
    }

    #[test]
    fn infeasible_returns_none() {
        let lc = LoadControl::new();
        assert_eq!(lc.earliest_start(0, 10, 10, 50), None);
    }

    #[test]
    fn zero_headroom_defers_past_end() {
        let mut lc = LoadControl::new();
        lc.add(0, 3, 10); // peak 30
        let r = lc.earliest_start(0, 3, 10, 30).unwrap();
        assert_eq!(r, 10); // only after the first batch finishes
    }

    #[test]
    fn retire_drops_finished() {
        let mut lc = LoadControl::new();
        lc.add(0, 2, 5);
        lc.add(3, 2, 5);
        lc.retire_before(5); // first ends at 4
        assert_eq!(lc.live().len(), 1);
        assert_eq!(lc.live()[0].start, 3);
    }

    /// The core safety property: admitting at `earliest_start` never
    /// violates w_lim at ANY step, for any sequence of admissions with
    /// PER-ADMISSION random lengths (heterogeneous interleavings are
    /// exactly what SLS admission over the live pipeline produces) and
    /// `retire_before` interleaved with the admissions. A shadow
    /// controller that never retires checks the full history, so
    /// retirement cannot mask a past violation.
    #[test]
    fn prop_admission_never_violates_limit() {
        prop::check("loadctl-safe", 80, |g| {
            let w_lim = g.usize_in(8, 241);
            let mut lc = LoadControl::new(); // admission view (retires)
            let mut shadow = LoadControl::new(); // full history
            let mut now = 0usize;
            for _ in 0..10 {
                let m = g.usize_in(1, 7);
                let seq_len = g.usize_in(1, 41);
                if m * seq_len > w_lim {
                    // honest None contract: infeasible alone ⇒ rejected
                    assert_eq!(lc.earliest_start(now, m, seq_len, w_lim), None);
                    continue;
                }
                if g.usize_in(0, 4) == 0 {
                    lc.retire_before(now);
                }
                let start = lc
                    .earliest_start(now, m, seq_len, w_lim)
                    .expect("feasible request must admit");
                lc.add(start, m, seq_len);
                shadow.add(start, m, seq_len);
                now = start;
            }
            let horizon = shadow
                .live()
                .iter()
                .map(|b| b.end)
                .max()
                .unwrap_or(0);
            for t in 0..=horizon {
                let l = shadow.load_at(t);
                assert!(l <= w_lim, "load {l} > limit {w_lim} at step {t}");
            }
        });
    }

    /// peak_load bookkeeping must equal the true load at each end step.
    #[test]
    fn prop_peak_bookkeeping_consistent() {
        prop::check("loadctl-peaks", 60, |g| {
            let mut lc = LoadControl::new();
            let mut start = 0usize;
            for _ in 0..6 {
                start += g.usize_in(0, 7);
                lc.add(start, g.usize_in(1, 5), g.usize_in(3, 20));
            }
            for mb in lc.live() {
                assert_eq!(
                    mb.peak_load,
                    lc.load_at(mb.end),
                    "peak mismatch for batch starting {}",
                    mb.start
                );
            }
        });
    }

    /// A bulk-prefilled batch contributes `m·(init + age)` from its very
    /// first step and peaks at `m·(init + grow_len)`.
    #[test]
    fn init_offset_shifts_contribution() {
        let mut lc = LoadControl::new();
        lc.add_init(0, 2, 5, 4); // prefill of 5, then 4 decode steps
        assert_eq!(lc.load_at(0), 2 * 6); // init + age 1
        assert_eq!(lc.load_at(3), 2 * 9); // init + age 4 (peak)
        assert_eq!(lc.load_at(4), 0); // retired
        assert_eq!(lc.live()[0].peak_load, 18);
    }

    /// `earliest_start_init` honest Option contract: None iff the
    /// newcomer's own peak m·(init+grow) exceeds the limit.
    #[test]
    fn init_infeasible_returns_none() {
        let lc = LoadControl::new();
        assert_eq!(lc.earliest_start_init(0, 2, 10, 6, 31), None); // 32 > 31
        assert_eq!(lc.earliest_start_init(0, 2, 10, 6, 32), Some(0));
    }

    /// The prefill bulk counts against existing peaks: a newcomer whose
    /// init alone fills the elder's remaining headroom must wait for the
    /// elder to end, even though its age-based growth would have fit.
    #[test]
    fn init_defers_admission_past_elder_peak() {
        let mut lc = LoadControl::new();
        lc.add(0, 2, 10); // peak 20 at step 9
        // headroom 10 at the elder's peak; an (init=5, m=2) newcomer
        // carries 2·(5+age) ≥ 12 at any overlap ⇒ must start at 10
        let r = lc.earliest_start_init(0, 2, 5, 10, 30).unwrap();
        assert_eq!(r, 10);
        // with init 0 the same shape may overlap the elder's tail
        let r0 = lc.earliest_start_init(0, 2, 0, 10, 30).unwrap();
        assert_eq!(r0, 5);
    }

    /// Safety with heterogeneous init offsets: admitting at
    /// `earliest_start_init` never violates w_lim at ANY step, checked
    /// against a never-retiring shadow controller over the full history.
    #[test]
    fn prop_init_admission_never_violates_limit() {
        prop::check("loadctl-init-safe", 80, |g| {
            let w_lim = g.usize_in(12, 301);
            let mut lc = LoadControl::new();
            let mut shadow = LoadControl::new();
            let mut now = 0usize;
            for _ in 0..10 {
                let m = g.usize_in(1, 5);
                let init = g.usize_in(0, 12);
                let grow = g.usize_in(1, 25);
                if m * (init + grow) > w_lim {
                    assert_eq!(
                        lc.earliest_start_init(now, m, init, grow, w_lim),
                        None
                    );
                    continue;
                }
                if g.usize_in(0, 4) == 0 {
                    lc.retire_before(now);
                }
                let start = lc
                    .earliest_start_init(now, m, init, grow, w_lim)
                    .expect("feasible request must admit");
                lc.add_init(start, m, init, grow);
                shadow.add_init(start, m, init, grow);
                now = start;
            }
            let horizon =
                shadow.live().iter().map(|b| b.end).max().unwrap_or(0);
            for t in 0..=horizon {
                let l = shadow.load_at(t);
                assert!(l <= w_lim, "load {l} > limit {w_lim} at step {t}");
            }
            // peak bookkeeping stays exact under init offsets
            for mb in shadow.live() {
                assert_eq!(mb.peak_load, shadow.load_at(mb.end));
            }
        });
    }
}
