//! The sequence-level load-stabilizing schedule (§4.2).
//!
//! Instead of starting ℬ sequences of target length 𝒮 together (peak
//! R-Part load W_max = ℬ·𝒮 at the last step), start micro-batches of
//! M = ℬ·F/𝒮 sequences every F steps (eq. 5). In steady state sequences
//! of every age coexist and the aggregate context length stays near
//! W'_max = ℬ·(𝒮+F)/2 ≈ W_max/2 (eq. 6).

/// Static parameters of one SLS configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlsSchedule {
    /// Total concurrent batch size ℬ.
    pub batch: usize,
    /// Target generated length 𝒮 (steps per sequence).
    pub seq_len: usize,
    /// Micro-batch start interval F (steps).
    pub interval: usize,
}

impl SlsSchedule {
    pub fn new(batch: usize, seq_len: usize, interval: usize) -> SlsSchedule {
        assert!(batch > 0 && seq_len > 0 && interval > 0);
        assert!(
            interval <= seq_len,
            "interval F={interval} must not exceed S={seq_len}"
        );
        SlsSchedule {
            batch,
            seq_len,
            interval,
        }
    }

    /// eq. 5: micro-batch size M = ℬ·F/𝒮, clamped to ≥ 1. Without the
    /// clamp, ℬ·F < 𝒮/2 rounded to 0 — no sequences ever started, so
    /// `sls_load_at` reported zero load forever.
    pub fn micro_batch_size(&self) -> usize {
        (((self.batch * self.interval) as f64 / self.seq_len as f64).round()
            as usize)
            .max(1)
    }

    /// Number of micro-batches concurrently alive in steady state.
    pub fn concurrent_micro_batches(&self) -> usize {
        self.seq_len.div_ceil(self.interval)
    }

    /// Peak aggregate context if all ℬ start together (no SLS).
    pub fn w_max_naive(&self) -> usize {
        self.batch * self.seq_len
    }

    /// eq. 6: steady-state peak aggregate context under SLS,
    /// W'_max = Σ_{k=1..S/F} M·k·F = ℬ(𝒮+F)/2.
    pub fn w_max_sls(&self) -> usize {
        self.batch * (self.seq_len + self.interval) / 2
    }

    /// Aggregate context processed at `step` when all ℬ sequences start
    /// together at step 0 (each token attends to its full prefix,
    /// 1-based).
    pub fn naive_load_at(&self, step: usize) -> usize {
        if step < self.seq_len {
            self.batch * (step + 1)
        } else {
            0 // generation finished
        }
    }

    /// Aggregate context at `step` under SLS (cold start included):
    /// sum over alive micro-batches of M · age.
    pub fn sls_load_at(&self, step: usize) -> usize {
        let m = self.micro_batch_size();
        let mut total = 0;
        // micro-batch j starts at step j·F and lives S steps
        let mut j = 0usize;
        loop {
            let start = j * self.interval;
            if start > step {
                break;
            }
            let age = step - start + 1;
            if age <= self.seq_len {
                total += m * age;
            }
            j += 1;
        }
        total
    }

    /// Worst-case queueing delay for an incoming request (paper: S steps
    /// without SLS, F steps with).
    pub fn max_admission_delay(&self, sls: bool) -> usize {
        if sls {
            self.interval
        } else {
            self.seq_len
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn eq5_micro_batch_size() {
        // Fig 7's example: B=6, S=12?, F such that M=2... and the paper's
        // real cases: B=1024, S=1024, F=32 → M=32.
        let s = SlsSchedule::new(1024, 1024, 32);
        assert_eq!(s.micro_batch_size(), 32);
        assert_eq!(s.concurrent_micro_batches(), 32);
    }

    #[test]
    fn eq6_half_peak() {
        let s = SlsSchedule::new(1024, 1024, 32);
        let naive = s.w_max_naive();
        let sls = s.w_max_sls();
        let ratio = sls as f64 / naive as f64;
        // (S+F)/2S = 0.516 for S=1024, F=32
        assert!((ratio - 0.516).abs() < 0.01, "ratio {ratio}");
    }

    /// Fig 7's worked example: micro size 2, interval... B=6, S=6?, the
    /// paper: "size of the micro-batch is 2 ... total load 24 vs 36,
    /// 1/3 reduction" with S=3F.
    #[test]
    fn fig7_worked_example() {
        // S = 3F: F=2, S=6, B=6 → M = 2
        let s = SlsSchedule::new(6, 6, 2);
        assert_eq!(s.micro_batch_size(), 2);
        assert_eq!(s.w_max_naive(), 36);
        // W'max = B(S+F)/2 = 6·8/2 = 24 → 2/3 of naive
        assert_eq!(s.w_max_sls(), 24);
    }

    #[test]
    fn steady_state_load_matches_eq6() {
        let s = SlsSchedule::new(240, 120, 10);
        // after cold start (step ≥ S), load oscillates around W'max
        let w = s.w_max_sls();
        for step in 120..240 {
            let l = s.sls_load_at(step);
            assert!(
                (l as f64 - w as f64).abs() / w as f64 <= 0.15,
                "step {step}: load {l} vs W'max {w}"
            );
        }
    }

    #[test]
    fn naive_load_grows_linearly() {
        let s = SlsSchedule::new(8, 100, 10);
        assert_eq!(s.naive_load_at(0), 8);
        assert_eq!(s.naive_load_at(49), 8 * 50);
        assert_eq!(s.naive_load_at(99), 800);
    }

    #[test]
    fn sls_peak_never_exceeds_model_bound() {
        prop::check("sls-peak-bound", 100, |g| {
            let seq = g.usize_in(16, 512);
            let interval = g.usize_in(1, seq / 4 + 1);
            let batch = g.usize_in(interval.max(4), 2048);
            let s = SlsSchedule::new(batch, seq, interval);
            if 2 * batch * interval < seq {
                // degenerate regime: eq. 5 rounds to 0 and the clamp to
                // M=1 deliberately over-admits relative to eq. 6's bound
                return;
            }
            let m = s.micro_batch_size();
            // true peak over a long horizon
            let mut peak = 0;
            for step in 0..3 * seq {
                peak = peak.max(s.sls_load_at(step));
            }
            // peak ≈ M·F·(1+2+..+S/F) — within rounding of eq. 6's bound
            let bound = (s.w_max_sls() as f64 * 1.25 + (m * seq) as f64) as usize;
            assert!(peak <= bound, "peak {peak} > bound {bound} (M={m})");
        });
    }

    #[test]
    fn admission_delay_claim() {
        let s = SlsSchedule::new(1024, 1024, 32);
        assert_eq!(s.max_admission_delay(false), 1024);
        assert_eq!(s.max_admission_delay(true), 32);
    }

    #[test]
    #[should_panic(expected = "must not exceed")]
    fn interval_longer_than_seq_panics() {
        SlsSchedule::new(8, 10, 20);
    }

    /// Regression: ℬ·F/𝒮 = 16/64 = 0.25 used to round to a micro-batch
    /// of ZERO, so no sequence ever started and the reported load stayed
    /// zero at every step.
    #[test]
    fn micro_batch_size_clamps_to_one() {
        let s = SlsSchedule::new(4, 64, 4);
        assert_eq!(s.micro_batch_size(), 1);
        // with M ≥ 1 the schedule actually admits work
        assert!(s.sls_load_at(0) > 0);
        assert!(s.sls_load_at(64) > 0);
        let peak: usize = (0..128).map(|t| s.sls_load_at(t)).max().unwrap();
        assert!(peak > 0);
    }
}
