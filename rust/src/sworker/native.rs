//! Native S-worker: the in-process S-Part executor.
//!
//! Executes embed / s_pre / s_post / logits in pure Rust (fp32), with
//! the exact math of the exported HLO graphs (`python/compile/model.py`)
//! — so it slots in wherever the PJRT executor did, with no artifacts
//! and no native XLA library. Row counts are inferred from the inputs,
//! which lets the token-level pipeline drive it with mini-batches.

use anyhow::{bail, Result};

use crate::model::ModelSpec;
use crate::runtime::Tensor;

use super::ops;
use super::weights::ModelWeights;

pub struct NativeSWorker {
    pub weights: ModelWeights,
}

impl NativeSWorker {
    pub fn new(weights: ModelWeights) -> NativeSWorker {
        NativeSWorker { weights }
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.weights.spec
    }

    pub fn layers(&self) -> usize {
        self.weights.layers()
    }

    /// tokens `[n]` → embeddings `[n, h]`.
    pub fn embed(&self, tokens: &[i32]) -> Result<Tensor> {
        let spec = self.weights.spec;
        for &t in tokens {
            if t < 0 || t as usize >= spec.vocab {
                bail!("token id {t} outside vocab {}", spec.vocab);
            }
        }
        let rows = ops::embed_rows(
            tokens,
            self.weights.w_emb.as_f32()?,
            spec.vocab,
            spec.hidden,
        );
        Ok(Tensor::f32(&[tokens.len(), spec.hidden], rows))
    }

    /// S-Part before attention on `layer`: x `[n, h]` → qkv `[n, 3h]`.
    pub fn s_pre(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let h = self.weights.spec.hidden;
        let b = self.block(layer)?;
        let xs = x.as_f32()?;
        let n = xs.len() / h;
        let xn = ops::rmsnorm(xs, b.ln1.as_f32()?, h);
        let qkv = ops::matmul(&xn, b.wqkv.as_f32()?, n, h, 3 * h);
        Ok(Tensor::f32(&[n, 3 * h], qkv))
    }

    /// S-Part after attention on `layer`: (x, o) `[n, h]` → y `[n, h]`.
    pub fn s_post(&self, layer: usize, x: &Tensor, o: &Tensor) -> Result<Tensor> {
        let spec = self.weights.spec;
        let h = spec.hidden;
        let b = self.block(layer)?;
        let xs = x.as_f32()?;
        let os = o.as_f32()?;
        if xs.len() != os.len() {
            bail!("x/o row mismatch: {} vs {}", xs.len(), os.len());
        }
        let n = xs.len() / h;
        let attn = ops::matmul(os, b.wo.as_f32()?, n, h, h);
        let x1: Vec<f32> = xs.iter().zip(&attn).map(|(a, c)| a + c).collect();
        let xn2 = ops::rmsnorm(&x1, b.ln2.as_f32()?, h);
        let m = ops::gated_mlp(
            &xn2,
            b.w_gate.as_f32()?,
            b.w_up.as_f32()?,
            b.w_down.as_f32()?,
            h,
            spec.ffn,
        );
        let y: Vec<f32> = x1.iter().zip(&m).map(|(a, c)| a + c).collect();
        Ok(Tensor::f32(&[n, h], y))
    }

    /// Final norm + tied-embedding head: x `[n, h]` → logits `[n, vocab]`.
    pub fn logits(&self, x: &Tensor) -> Result<Tensor> {
        let spec = self.weights.spec;
        let h = spec.hidden;
        let xs = x.as_f32()?;
        let n = xs.len() / h;
        let xn = ops::rmsnorm(xs, self.weights.ln_f.as_f32()?, h);
        let logits =
            ops::tied_logits(&xn, self.weights.w_emb.as_f32()?, h, spec.vocab);
        Ok(Tensor::f32(&[n, spec.vocab], logits))
    }

    /// Greedy sampling over logits `[n, vocab]`.
    pub fn argmax(&self, logits: &Tensor) -> Result<Vec<i32>> {
        Ok(ops::argmax_rows(logits.as_f32()?, self.weights.spec.vocab))
    }

    fn block(&self, layer: usize) -> Result<&super::BlockWeights> {
        match self.weights.blocks.get(layer) {
            Some(b) => Ok(b),
            None => bail!(
                "layer {layer} out of range ({} instantiated)",
                self.weights.layers()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::SeqKv;
    use crate::model::{Precision, TINY};
    use crate::rworker::{attend_one, AttnScratch};
    use crate::util::Rng;

    /// The paper's load-bearing identity, in-process: s_pre → R-worker
    /// attention → s_post over several steps equals the fused
    /// single-device block with the same weights.
    #[test]
    fn decomposition_matches_fused_block() {
        let spec = TINY;
        let (b, h) = (4usize, spec.hidden);
        let (nh, d) = (spec.n_heads, spec.head_dim());
        let smax = 16usize;
        let w = ModelWeights::random(spec, 1, 77);
        let sw = NativeSWorker::new(w.clone());
        let blk = &w.blocks[0];

        // decomposed side: one SeqKv per sequence (f32, exact)
        let mut kvs: Vec<SeqKv> =
            (0..b).map(|_| SeqKv::new(nh, d, smax, Precision::F32)).collect();
        let mut scratch = AttnScratch::new(d);

        // fused side: padded caches
        let mut kc = vec![0.0f32; b * nh * smax * d];
        let mut vc = vec![0.0f32; b * nh * smax * d];
        let mut lengths = vec![0i32; b];

        let mut rng = Rng::new(5);
        for step in 0..6 {
            let x_data = rng.normal_vec(b * h, 0.5);
            let x = Tensor::f32(&[b, h], x_data.clone());

            // decomposed path
            let qkv = sw.s_pre(0, &x).unwrap();
            let qkv_f = qkv.as_f32().unwrap();
            let mut o = vec![0.0f32; b * h];
            for i in 0..b {
                let row = &qkv_f[i * 3 * h..(i + 1) * 3 * h];
                kvs[i].append(&row[h..2 * h], &row[2 * h..]);
                attend_one(
                    &kvs[i],
                    &row[..h],
                    &mut o[i * h..(i + 1) * h],
                    &mut scratch,
                );
            }
            let y = sw
                .s_post(0, &x, &Tensor::f32(&[b, h], o))
                .unwrap()
                .into_f32()
                .unwrap();

            // fused path
            let dims = ops::FusedDims {
                batch: b,
                hidden: h,
                n_heads: nh,
                smax,
                ffn: spec.ffn,
            };
            let (yf, k_new, v_new) = ops::fused_block_step(
                &x_data,
                &kc,
                &vc,
                &lengths,
                blk.ln1.as_f32().unwrap(),
                blk.wqkv.as_f32().unwrap(),
                blk.wo.as_f32().unwrap(),
                blk.ln2.as_f32().unwrap(),
                blk.w_gate.as_f32().unwrap(),
                blk.w_up.as_f32().unwrap(),
                blk.w_down.as_f32().unwrap(),
                dims,
            );
            // append K/V into the padded caches
            for i in 0..b {
                let pos = lengths[i] as usize;
                for head in 0..nh {
                    let dst = ((i * nh + head) * smax + pos) * d;
                    let src = i * h + head * d;
                    kc[dst..dst + d].copy_from_slice(&k_new[src..src + d]);
                    vc[dst..dst + d].copy_from_slice(&v_new[src..src + d]);
                }
                lengths[i] += 1;
            }

            for (a, c) in y.iter().zip(&yf) {
                assert!(
                    (a - c).abs() < 1e-4,
                    "step {step}: decomposed {a} vs fused {c}"
                );
            }
        }
    }

    #[test]
    fn embed_rejects_out_of_vocab() {
        let sw = NativeSWorker::new(ModelWeights::random(TINY, 1, 1));
        assert!(sw.embed(&[0, 1, 2]).is_ok());
        assert!(sw.embed(&[TINY.vocab as i32]).is_err());
        assert!(sw.embed(&[-1]).is_err());
    }

    #[test]
    fn shapes_flow_through() {
        let sw = NativeSWorker::new(ModelWeights::random(TINY, 2, 3));
        let x = sw.embed(&[1, 2, 3]).unwrap();
        assert_eq!(x.shape(), &[3, TINY.hidden]);
        let qkv = sw.s_pre(1, &x).unwrap();
        assert_eq!(qkv.shape(), &[3, 3 * TINY.hidden]);
        let y = sw.s_post(1, &x, &x).unwrap();
        assert_eq!(y.shape(), &[3, TINY.hidden]);
        let l = sw.logits(&y).unwrap();
        assert_eq!(l.shape(), &[3, TINY.vocab]);
        assert_eq!(sw.argmax(&l).unwrap().len(), 3);
        assert!(sw.s_pre(2, &x).is_err());
    }
}
