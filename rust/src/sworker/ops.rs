//! Pure-Rust S-Part math, mirroring `python/compile/kernels/ref.py` and
//! `python/compile/model.py` (fp32 accumulation everywhere).
//!
//! These primitives back the native S-worker (the offline replacement
//! for the PJRT/HLO bridge, which needs the unavailable `xla_extension`
//! native library) and the fused single-device reference block used by
//! the decomposition-equivalence tests: s_pre → attention → s_post must
//! be THE SAME FUNCTION as [`fused_block_step`].

/// RMSNorm epsilon, matching `ref.rmsnorm_ref`.
pub const RMS_EPS: f32 = 1e-5;

/// Row-major matmul: `a [m, k] × b [k, n] → [m, n]`, fp32 accumulate.
/// i-k-j loop order keeps the inner loop stride-1 over both `b` and the
/// output row, which LLVM auto-vectorizes.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "lhs shape mismatch");
    assert_eq!(b.len(), k * n, "rhs shape mismatch");
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in a[i * k..(i + 1) * k].iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// RMSNorm over the last axis for `x` of row width `h` (any row count).
pub fn rmsnorm(x: &[f32], w: &[f32], h: usize) -> Vec<f32> {
    assert_eq!(w.len(), h);
    assert_eq!(x.len() % h, 0);
    let mut out = vec![0.0f32; x.len()];
    for (row, orow) in x.chunks_exact(h).zip(out.chunks_exact_mut(h)) {
        let ms = row.iter().map(|v| v * v).sum::<f32>() / h as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for ((o, &v), &wv) in orow.iter_mut().zip(row).zip(w) {
            *o = v * inv * wv;
        }
    }
    out
}

#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Llama-style gated MLP: `(silu(xn Wg) * (xn Wu)) Wd` for rows of
/// width `h`, intermediate width `f`.
pub fn gated_mlp(
    xn: &[f32],
    w_gate: &[f32],
    w_up: &[f32],
    w_down: &[f32],
    h: usize,
    f: usize,
) -> Vec<f32> {
    let m = xn.len() / h;
    let mut g = matmul(xn, w_gate, m, h, f);
    let u = matmul(xn, w_up, m, h, f);
    for (gv, uv) in g.iter_mut().zip(&u) {
        *gv = silu(*gv) * uv;
    }
    matmul(&g, w_down, m, f, h)
}

/// Token embedding lookup: `tokens [n] → rows [n, h]` from `w_emb
/// [vocab, h]`. Token ids must be in `[0, vocab)`.
pub fn embed_rows(
    tokens: &[i32],
    w_emb: &[f32],
    vocab: usize,
    h: usize,
) -> Vec<f32> {
    assert_eq!(w_emb.len(), vocab * h);
    let mut out = Vec::with_capacity(tokens.len() * h);
    for &t in tokens {
        let t = t as usize;
        assert!(t < vocab, "token id {t} out of vocab {vocab}");
        out.extend_from_slice(&w_emb[t * h..(t + 1) * h]);
    }
    out
}

/// Tied-embedding head: `xn [m, h] × w_emb [vocab, h]ᵀ → [m, vocab]`.
pub fn tied_logits(
    xn: &[f32],
    w_emb: &[f32],
    h: usize,
    vocab: usize,
) -> Vec<f32> {
    assert_eq!(w_emb.len(), vocab * h);
    let m = xn.len() / h;
    let mut out = vec![0.0f32; m * vocab];
    for i in 0..m {
        let row = &xn[i * h..(i + 1) * h];
        let orow = &mut out[i * vocab..(i + 1) * vocab];
        for (o, wrow) in orow.iter_mut().zip(w_emb.chunks_exact(h)) {
            *o = row.iter().zip(wrow).map(|(a, b)| a * b).sum();
        }
    }
    out
}

/// Greedy sampling over `logits [m, vocab]`. Ties resolve to the LAST
/// maximum (the historical behavior of the serving path — both sides of
/// every equivalence test must use this same function).
pub fn argmax_rows(logits: &[f32], vocab: usize) -> Vec<i32> {
    logits
        .chunks_exact(vocab)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap()
        })
        .collect()
}

/// Dimensions of one fused block step.
#[derive(Clone, Copy, Debug)]
pub struct FusedDims {
    pub batch: usize,
    pub hidden: usize,
    pub n_heads: usize,
    /// Padded cache capacity S of `k_cache`/`v_cache` `[B, H, S, D]`.
    pub smax: usize,
    pub ffn: usize,
}

/// One whole transformer-block decode step on one device — the fused
/// single-device oracle (`model.fused_decode_step` in Python).
///
/// `k_cache`/`v_cache` are `[B, H, S, D]` WITHOUT this token's K/V;
/// `lengths` counts preceding tokens per sequence. Attention covers the
/// cached tokens plus the freshly projected K/V (two-pass softmax, fp32).
/// Returns `(y [B, h], k_new [B, h], v_new [B, h])`; the caller appends
/// K/V to its cache, exactly like the exported HLO contract.
#[allow(clippy::too_many_arguments)]
pub fn fused_block_step(
    x: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    lengths: &[i32],
    ln1: &[f32],
    wqkv: &[f32],
    wo: &[f32],
    ln2: &[f32],
    w_gate: &[f32],
    w_up: &[f32],
    w_down: &[f32],
    dims: FusedDims,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let FusedDims {
        batch: b,
        hidden: h,
        n_heads: nh,
        smax,
        ffn,
    } = dims;
    let d = h / nh;
    assert_eq!(x.len(), b * h);
    assert_eq!(k_cache.len(), b * nh * smax * d);
    assert_eq!(v_cache.len(), b * nh * smax * d);
    assert_eq!(lengths.len(), b);

    // s_pre: RMSNorm + fused QKV projection.
    let xn = rmsnorm(x, ln1, h);
    let qkv = matmul(&xn, wqkv, b, h, 3 * h);
    let mut q = vec![0.0f32; b * h];
    let mut k_new = vec![0.0f32; b * h];
    let mut v_new = vec![0.0f32; b * h];
    for i in 0..b {
        let row = &qkv[i * 3 * h..(i + 1) * 3 * h];
        q[i * h..(i + 1) * h].copy_from_slice(&row[..h]);
        k_new[i * h..(i + 1) * h].copy_from_slice(&row[h..2 * h]);
        v_new[i * h..(i + 1) * h].copy_from_slice(&row[2 * h..]);
    }

    // R-Part: per-(sequence, head) softmax attention over cache + new
    // token. Naive two-pass on purpose — a bug in the R-worker's online
    // softmax cannot hide in a shared trick.
    let scale = 1.0 / (d as f32).sqrt();
    let dot = |a: &[f32], c: &[f32]| -> f32 {
        a.iter().zip(c).map(|(x, y)| x * y).sum()
    };
    let mut o = vec![0.0f32; b * h];
    for i in 0..b {
        let len = lengths[i] as usize;
        assert!(len < smax, "sequence {i} overflows the padded cache");
        for head in 0..nh {
            let qh = &q[i * h + head * d..i * h + (head + 1) * d];
            let knh = &k_new[i * h + head * d..i * h + (head + 1) * d];
            let vnh = &v_new[i * h + head * d..i * h + (head + 1) * d];
            let base = (i * nh + head) * smax * d;
            let mut scores = Vec::with_capacity(len + 1);
            for t in 0..len {
                let krow = &k_cache[base + t * d..base + (t + 1) * d];
                scores.push(dot(qh, krow) * scale);
            }
            scores.push(dot(qh, knh) * scale);
            let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut l = 0.0f32;
            for s in scores.iter_mut() {
                *s = (*s - m).exp();
                l += *s;
            }
            let oh = &mut o[i * h + head * d..i * h + (head + 1) * d];
            for (t, p) in scores.iter().enumerate().take(len) {
                let vrow = &v_cache[base + t * d..base + (t + 1) * d];
                for (ov, &vv) in oh.iter_mut().zip(vrow) {
                    *ov += p / l * vv;
                }
            }
            let p_new = scores[len] / l;
            for (ov, &vv) in oh.iter_mut().zip(vnh) {
                *ov += p_new * vv;
            }
        }
    }

    // s_post: O-projection + residual + RMSNorm + gated MLP + residual.
    let attn = matmul(&o, wo, b, h, h);
    let x1: Vec<f32> = x.iter().zip(&attn).map(|(a, c)| a + c).collect();
    let xn2 = rmsnorm(&x1, ln2, h);
    let mlp = gated_mlp(&xn2, w_gate, w_up, w_down, h, ffn);
    let y: Vec<f32> = x1.iter().zip(&mlp).map(|(a, c)| a + c).collect();
    (y, k_new, v_new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0]; // [2, 2]
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 2, 2), a);
    }

    #[test]
    fn matmul_known_product() {
        // [1, 3] × [3, 2]
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0];
        assert_eq!(matmul(&a, &b, 1, 3, 2), vec![14.0, 32.0]);
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let h = 4;
        let x = vec![2.0; h];
        let w = vec![1.0; h];
        let y = rmsnorm(&x, &w, h);
        // mean square = 4 → inv ≈ 0.5
        for v in y {
            assert!((v - 1.0).abs() < 1e-4, "{v}");
        }
    }

    #[test]
    fn silu_matches_definition() {
        for x in [-3.0f32, -0.5, 0.0, 0.5, 3.0] {
            let want = x * (1.0 / (1.0 + (-x).exp()));
            assert!((silu(x) - want).abs() < 1e-7);
        }
    }

    #[test]
    fn tied_logits_matches_matmul_transpose() {
        let (h, vocab) = (3, 5);
        let mut rng = Rng::new(2);
        let xn = rng.normal_vec(2 * h, 1.0);
        let w = rng.normal_vec(vocab * h, 1.0);
        let got = tied_logits(&xn, &w, h, vocab);
        for i in 0..2 {
            for v in 0..vocab {
                let want: f32 = (0..h)
                    .map(|j| xn[i * h + j] * w[v * h + j])
                    .sum();
                assert!((got[i * vocab + v] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn argmax_picks_last_max_on_tie() {
        assert_eq!(argmax_rows(&[1.0, 3.0, 3.0, 0.0], 4), vec![2]);
    }

    #[test]
    fn embed_looks_up_rows() {
        let w = vec![0.0, 0.1, 1.0, 1.1, 2.0, 2.1]; // vocab 3, h 2
        assert_eq!(embed_rows(&[2, 0], &w, 3, 2), vec![2.0, 2.1, 0.0, 0.1]);
    }

    /// First decode step with an empty cache attends only the new token,
    /// so o == v_new and the block reduces to plain residual MLP flow.
    #[test]
    fn fused_first_token_attends_itself() {
        let (b, h, nh, smax, ffn) = (2, 8, 2, 4, 12);
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(b * h, 0.5);
        let kc = vec![0.0; b * nh * smax * (h / nh)];
        let vc = kc.clone();
        let ln = vec![1.0; h];
        let wqkv = rng.normal_vec(h * 3 * h, 0.2);
        let wo = rng.normal_vec(h * h, 0.2);
        let w_gate = rng.normal_vec(h * ffn, 0.2);
        let w_up = rng.normal_vec(h * ffn, 0.2);
        let w_down = rng.normal_vec(ffn * h, 0.2);
        let dims = FusedDims {
            batch: b,
            hidden: h,
            n_heads: nh,
            smax,
            ffn,
        };
        let (y, k_new, v_new) = fused_block_step(
            &x, &kc, &vc, &[0, 0], &ln, &wqkv, &wo, &ln, &w_gate, &w_up,
            &w_down, dims,
        );
        assert_eq!(y.len(), b * h);
        assert_eq!(k_new.len(), b * h);
        // with len=0 the softmax has one entry: o == v_new exactly, so
        // recomputing s_post from v_new must reproduce y
        let attn = matmul(&v_new, &wo, b, h, h);
        let x1: Vec<f32> = x.iter().zip(&attn).map(|(a, c)| a + c).collect();
        let xn2 = rmsnorm(&x1, &ln, h);
        let m = gated_mlp(&xn2, &w_gate, &w_up, &w_down, h, ffn);
        for ((yv, x1v), mv) in y.iter().zip(&x1).zip(&m) {
            assert!((yv - (x1v + mv)).abs() < 1e-5);
        }
    }
}
