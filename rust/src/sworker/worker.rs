//! PJRT-backed S-worker: the real-numerics S-Part executor.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::{Engine, Executable, Tensor};

use super::weights::ModelWeights;

/// Executes the four exported S-Part graphs for a fixed (model, batch).
///
/// Artifact names follow aot.py: `<model>_b<B>_{embed,s_pre,s_post,logits}`.
/// Weights are runtime inputs, so ONE compiled graph serves every layer.
pub struct PjrtSWorker {
    engine: Arc<Engine>,
    pub weights: ModelWeights,
    pub batch: usize,
    embed: Arc<Executable>,
    s_pre: Arc<Executable>,
    s_post: Arc<Executable>,
    logits: Arc<Executable>,
}

impl PjrtSWorker {
    pub fn new(
        engine: Arc<Engine>,
        weights: ModelWeights,
        batch: usize,
    ) -> Result<PjrtSWorker> {
        let prefix = format!("{}_b{}", weights.spec.name, batch);
        let get = |suffix: &str| {
            engine
                .executable(&format!("{prefix}_{suffix}"))
                .with_context(|| format!("loading {prefix}_{suffix}"))
        };
        Ok(PjrtSWorker {
            embed: get("embed")?,
            s_pre: get("s_pre")?,
            s_post: get("s_post")?,
            logits: get("logits")?,
            engine,
            weights,
            batch,
        })
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// tokens `[B]` → embeddings `[B, h]`.
    pub fn embed(&self, tokens: &[i32]) -> Result<Tensor> {
        assert_eq!(tokens.len(), self.batch);
        let t = Tensor::i32(&[self.batch], tokens.to_vec());
        let mut out = self
            .embed
            .run(&[t, self.weights.w_emb.clone()])?;
        Ok(out.remove(0))
    }

    /// S-Part before attention on `layer`: x `[B, h]` → qkv `[B, 3h]`.
    pub fn s_pre(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let b = &self.weights.blocks[layer];
        let mut out = self
            .s_pre
            .run(&[x.clone(), b.ln1.clone(), b.wqkv.clone()])?;
        Ok(out.remove(0))
    }

    /// S-Part after attention on `layer`: (x, o) `[B, h]` → y `[B, h]`.
    pub fn s_post(&self, layer: usize, x: &Tensor, o: &Tensor) -> Result<Tensor> {
        let b = &self.weights.blocks[layer];
        let mut out = self.s_post.run(&[
            x.clone(),
            o.clone(),
            b.wo.clone(),
            b.ln2.clone(),
            b.w_gate.clone(),
            b.w_up.clone(),
            b.w_down.clone(),
        ])?;
        Ok(out.remove(0))
    }

    /// Final norm + tied-embedding head: x `[B, h]` → logits `[B, vocab]`.
    pub fn logits(&self, x: &Tensor) -> Result<Tensor> {
        let mut out = self.logits.run(&[
            x.clone(),
            self.weights.ln_f.clone(),
            self.weights.w_emb.clone(),
        ])?;
        Ok(out.remove(0))
    }

    /// Greedy sampling over logits `[B, vocab]`.
    pub fn argmax(&self, logits: &Tensor) -> Result<Vec<i32>> {
        let data = logits.as_f32()?;
        let vocab = self.weights.spec.vocab;
        Ok(data
            .chunks_exact(vocab)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap()
            })
            .collect())
    }
}
