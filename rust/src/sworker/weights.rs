//! Synthetic model weights, generated in Rust (DESIGN.md §2: random
//! weights at the true dims stand in for proprietary checkpoints; the
//! golden-file tests pin numerics against the Python-generated weights
//! instead).

use crate::model::ModelSpec;
use crate::runtime::Tensor;
use crate::util::Rng;

/// One transformer block's weights, shaped for the exported HLO graphs.
#[derive(Clone)]
pub struct BlockWeights {
    pub ln1: Tensor,    // [h]
    pub wqkv: Tensor,   // [h, 3h]
    pub wo: Tensor,     // [h, h]
    pub ln2: Tensor,    // [h]
    pub w_gate: Tensor, // [h, f]
    pub w_up: Tensor,   // [h, f]
    pub w_down: Tensor, // [f, h]
}

impl BlockWeights {
    pub fn random(spec: &ModelSpec, rng: &mut Rng) -> BlockWeights {
        let h = spec.hidden;
        let f = spec.ffn;
        let s = 1.0 / (h as f32).sqrt();
        let sf = 1.0 / (f as f32).sqrt();
        BlockWeights {
            ln1: Tensor::f32(&[h], vec![1.0; h]),
            wqkv: Tensor::f32(&[h, 3 * h], rng.normal_vec(h * 3 * h, s)),
            wo: Tensor::f32(&[h, h], rng.normal_vec(h * h, s)),
            ln2: Tensor::f32(&[h], vec![1.0; h]),
            w_gate: Tensor::f32(&[h, f], rng.normal_vec(h * f, s)),
            w_up: Tensor::f32(&[h, f], rng.normal_vec(h * f, s)),
            w_down: Tensor::f32(&[f, h], rng.normal_vec(f * h, sf)),
        }
    }
}

/// Full-model weights: `layers` blocks plus embedding and final norm.
#[derive(Clone)]
pub struct ModelWeights {
    pub spec: ModelSpec,
    pub blocks: Vec<BlockWeights>,
    pub w_emb: Tensor, // [vocab, h]
    pub ln_f: Tensor,  // [h]
}

impl ModelWeights {
    /// Deterministic synthetic weights with `layers` instantiated blocks.
    pub fn random(spec: ModelSpec, layers: usize, seed: u64) -> ModelWeights {
        let mut rng = Rng::new(seed);
        let h = spec.hidden;
        let blocks = (0..layers)
            .map(|_| BlockWeights::random(&spec, &mut rng))
            .collect();
        let w_emb = Tensor::f32(
            &[spec.vocab, h],
            rng.normal_vec(spec.vocab * h, 1.0 / (h as f32).sqrt()),
        );
        ModelWeights {
            spec,
            blocks,
            w_emb,
            ln_f: Tensor::f32(&[h], vec![1.0; h]),
        }
    }

    pub fn layers(&self) -> usize {
        self.blocks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TINY;

    #[test]
    fn shapes_match_spec() {
        let w = ModelWeights::random(TINY, 2, 7);
        assert_eq!(w.layers(), 2);
        assert_eq!(w.blocks[0].wqkv.shape(), &[64, 192]);
        assert_eq!(w.blocks[0].w_down.shape(), &[176, 64]);
        assert_eq!(w.w_emb.shape(), &[256, 64]);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = ModelWeights::random(TINY, 1, 3);
        let b = ModelWeights::random(TINY, 1, 3);
        assert_eq!(
            a.blocks[0].wqkv.as_f32().unwrap()[..8],
            b.blocks[0].wqkv.as_f32().unwrap()[..8]
        );
        let c = ModelWeights::random(TINY, 1, 4);
        assert_ne!(
            a.blocks[0].wqkv.as_f32().unwrap()[..8],
            c.blocks[0].wqkv.as_f32().unwrap()[..8]
        );
    }

    #[test]
    fn layers_differ_from_each_other() {
        let w = ModelWeights::random(TINY, 2, 7);
        assert_ne!(
            w.blocks[0].wqkv.as_f32().unwrap()[..8],
            w.blocks[1].wqkv.as_f32().unwrap()[..8]
        );
    }
}
