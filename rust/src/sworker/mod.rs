//! The S-worker: executes S-Part (shared-parameter matmuls) of every
//! layer (paper §4.1).
//!
//! * [`NativeSWorker`] — real numerics in pure Rust (fp32), the same
//!   math as the exported HLO graphs (`python/compile/model.py`). Runs
//!   on its own thread inside the token-level pipeline
//!   (`runtime::pipeline`). The previous PJRT executor was removed: the
//!   `xla_extension` native library is unavailable in the offline build;
//!   the artifact/golden format (`runtime::manifest`) is kept so the AOT
//!   bridge can return as an optional backend.
//! * [`ops`] — the underlying primitives plus the fused single-device
//!   reference block used by the decomposition-equivalence tests.
//! * Modeled S-workers live in `perfmodel::GpuModel` and are consumed by
//!   the virtual-clock simulator (`coordinator::sim`) for figure-scale
//!   batch sizes.

mod native;
pub mod ops;
mod weights;

pub use native::NativeSWorker;
pub use weights::{BlockWeights, ModelWeights};
