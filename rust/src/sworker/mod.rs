//! The S-worker: executes S-Part (shared-parameter matmuls) of every
//! layer (paper §4.1). Two implementations:
//!
//! * [`PjrtSWorker`] — real numerics: runs the AOT-compiled HLO graphs
//!   (embed, s_pre, s_post, logits) on the PJRT CPU client. Used by the
//!   end-to-end example and cross-language tests.
//! * Modeled S-workers live in `perfmodel::GpuModel` and are consumed by
//!   the virtual-clock simulator (`coordinator::sim`) for figure-scale
//!   batch sizes.

mod weights;
mod worker;

pub use weights::{BlockWeights, ModelWeights};
pub use worker::PjrtSWorker;
