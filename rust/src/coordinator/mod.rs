//! The FastDecode coordinator (leader): request admission, micro-batch
//! assembly, the pipelined step loop, and token emission.
//!
//! * [`real`] — the real-numerics engine: PJRT S-worker + threaded
//!   R-worker pool, used by examples and integration tests (tiny model).
//! * [`sim`] — the virtual-clock engine: same control flow priced by the
//!   calibrated device/link models, used to regenerate the paper's
//!   figures at A10/Epyc scale (DESIGN.md §2, timing modes).

pub mod real;
pub mod sim;

pub use real::FastDecode;
pub use sim::{simulate, SimConfig};
