//! The FastDecode coordinator (leader): request admission, micro-batch
//! assembly, the pipelined step loop, and token emission.
//!
//! Two engines sit behind the [`Coordinator`] trait:
//!
//! * [`real`] — the live engine: native S-worker thread + threaded
//!   R-worker pool joined by the depth-D token-level pipeline
//!   (`runtime::pipeline`), tracing real wall-clock stage times, with
//!   an optional SLS-admission mode (`FastDecode::drive_arrivals`)
//!   that gates queued micro-batch arrivals through
//!   `LoadControl::earliest_start`. Used by the examples, the
//!   integration tests and the pipeline smoke/depth tests.
//! * [`sim`] — the virtual-clock engine: same control flow priced by the
//!   calibrated device/link models, used to regenerate the paper's
//!   figures at A10/Epyc scale (DESIGN.md §2, timing modes).

pub mod real;
pub mod sim;

use anyhow::Result;

use crate::metrics::StepTrace;

pub use real::FastDecode;
pub use sim::{simulate, SimConfig};

/// A decode engine that can drive `steps` generation steps and report a
/// per-step trace. `real::FastDecode` produces measured wall-clock
/// records; [`SimCoordinator`] produces virtual-clock records — the
/// benches and experiments consume either through this one interface.
pub trait Coordinator {
    /// Human-readable backend id (for reports and tables).
    fn backend(&self) -> &'static str;
    /// Drive `steps` decode steps, returning the per-step trace.
    fn run_steps(&mut self, steps: usize) -> Result<StepTrace>;
}

/// The virtual-clock simulator behind the [`Coordinator`] interface.
pub struct SimCoordinator {
    pub cfg: SimConfig,
}

impl SimCoordinator {
    pub fn new(cfg: SimConfig) -> SimCoordinator {
        SimCoordinator { cfg }
    }
}

impl Coordinator for SimCoordinator {
    fn backend(&self) -> &'static str {
        "virtual-clock-sim"
    }

    fn run_steps(&mut self, steps: usize) -> Result<StepTrace> {
        let mut cfg = self.cfg;
        cfg.steps = steps;
        Ok(simulate(&cfg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LLAMA_7B;
    use crate::perfmodel::{CpuModel, GpuModel, A10, EPYC_7452};

    #[test]
    fn sim_backend_runs_behind_the_trait() {
        let cfg = SimConfig::new(
            LLAMA_7B,
            GpuModel::new(A10),
            CpuModel::from_device(EPYC_7452),
            4,
            256,
            128,
        );
        let mut c: Box<dyn Coordinator> = Box::new(SimCoordinator::new(cfg));
        assert_eq!(c.backend(), "virtual-clock-sim");
        let trace = c.run_steps(64).unwrap();
        assert_eq!(trace.len(), 64);
        assert!(trace.throughput() > 0.0);
    }
}
