//! Real-numerics FastDecode engine: native S-Part on its own thread +
//! threaded R-Part socket pool, joined by the token-level pipeline.
//!
//! Data flow per generated token (paper Fig 4):
//!   embed → for each layer: s_pre → scatter QKV to R-workers →
//!   append+attend near the cache → gather O → s_post → logits →
//!   greedy sample.
//! The KV-cache never exists on the S-worker; only activation vectors
//! cross the S↔R boundary. The batch is split into two mini-batches that
//! the S thread and the R sockets process in alternation
//! (`runtime::pipeline`, Fig 5b), so each step's wall time approaches
//! max(s, r) instead of s + r.

use anyhow::{bail, Result};

use crate::metrics::{Histogram, StepRecord, StepTrace};
use crate::model::{ModelSpec, Precision};
use crate::runtime::{PipelineConfig, ThreadedPipeline};
use crate::rworker::{RPool, RPoolConfig};
use crate::sworker::{ModelWeights, NativeSWorker};

use super::Coordinator;

#[derive(Clone, Copy, Debug)]
pub struct FastDecodeConfig {
    pub batch: usize,
    pub sockets: usize,
    pub precision: Precision,
    pub capacity_per_seq: usize,
    pub weight_seed: u64,
    /// Number of instantiated layers (≤ spec.n_layers, like the paper's
    /// reduced-layer evaluation).
    pub layers: usize,
    /// Overlap the two mini-batches (Fig 5b); false = serial (Fig 5a).
    pub pipelined: bool,
    /// Artificial stage dilation for pipeline calibration/smoke tests
    /// (see `PipelineConfig::s_pad` / `RPoolConfig::attend_pad`).
    pub s_pad: std::time::Duration,
    pub r_pad: std::time::Duration,
}

impl Default for FastDecodeConfig {
    fn default() -> Self {
        FastDecodeConfig {
            batch: 8,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 256,
            weight_seed: 0xfa57,
            layers: 2,
            pipelined: true,
            s_pad: std::time::Duration::ZERO,
            r_pad: std::time::Duration::ZERO,
        }
    }
}

/// Output of a generation run.
pub struct GenerationResult {
    /// Generated token ids per sequence (excluding the prompt).
    pub tokens: Vec<Vec<i32>>,
    pub step_latency: Histogram,
    pub trace: StepTrace,
}

pub struct FastDecode {
    pub spec: ModelSpec,
    pub cfg: FastDecodeConfig,
    pipeline: ThreadedPipeline,
    seq_ids: Vec<u64>,
    /// Current context length per sequence (tokens in the cache).
    ctx_len: Vec<usize>,
    /// Current tokens after `prime` (consumed by `Coordinator::run_steps`).
    current: Option<Vec<i32>>,
}

impl FastDecode {
    pub fn new(spec: ModelSpec, cfg: FastDecodeConfig) -> Result<FastDecode> {
        if cfg.batch == 0 {
            bail!("batch must be > 0");
        }
        if cfg.sockets == 0 {
            bail!("sockets must be > 0");
        }
        if cfg.layers == 0 || cfg.layers > spec.n_layers {
            bail!(
                "layers {} outside 1..={} for {}",
                cfg.layers,
                spec.n_layers,
                spec.name
            );
        }
        // The R-pool sizes its per-sequence cache to the run's needs.
        let mut spec_l = spec;
        spec_l.n_layers = cfg.layers; // R-pool allocates per layer
        let rpool = RPool::spawn(
            &spec_l,
            RPoolConfig {
                sockets: cfg.sockets,
                capacity_per_seq: cfg.capacity_per_seq,
                precision: cfg.precision,
                attend_pad: cfg.r_pad,
            },
        );
        let weights = ModelWeights::random(spec, cfg.layers, cfg.weight_seed);
        let sworker = NativeSWorker::new(weights);
        let pipeline = ThreadedPipeline::new(
            sworker,
            rpool,
            PipelineConfig {
                pipelined: cfg.pipelined,
                s_pad: cfg.s_pad,
                ..Default::default()
            },
        );
        Ok(FastDecode {
            spec,
            cfg,
            pipeline,
            seq_ids: Vec::new(),
            ctx_len: Vec::new(),
            current: None,
        })
    }

    /// Register a fresh batch of sequences (drops any previous batch).
    pub fn start_batch(&mut self, first_id: u64) {
        if !self.seq_ids.is_empty() {
            let old = self.seq_ids.clone();
            self.pipeline.rpool_mut().drop_seqs(&old);
        }
        self.seq_ids = (0..self.cfg.batch as u64).map(|i| first_id + i).collect();
        self.ctx_len = vec![0; self.cfg.batch];
        let ids = self.seq_ids.clone();
        self.pipeline.rpool_mut().add_seqs(&ids);
        self.current = None;
    }

    /// One decode step: current tokens `[B]` in → next tokens `[B]` out.
    pub fn decode_step(&mut self, tokens: &[i32]) -> Result<Vec<i32>> {
        let (next, _) = self.decode_step_traced(tokens)?;
        Ok(next)
    }

    /// Decode step with stage timing measured from real wall-clock
    /// timestamps inside the threaded pipeline.
    pub fn decode_step_traced(
        &mut self,
        tokens: &[i32],
    ) -> Result<(Vec<i32>, StepRecord)> {
        let b = self.cfg.batch;
        assert_eq!(tokens.len(), b);
        // Every step appends one token's K/V per sequence; refuse the
        // step that would overflow the per-sequence cache instead of
        // asserting inside an R-worker thread.
        if self.ctx_len.first().is_some_and(|&l| l >= self.cfg.capacity_per_seq)
        {
            bail!(
                "KV capacity exhausted: {} tokens per sequence already \
                 cached (capacity_per_seq = {})",
                self.ctx_len[0],
                self.cfg.capacity_per_seq
            );
        }
        let (next, t) = self.pipeline.step(tokens, &self.seq_ids)?;
        for l in self.ctx_len.iter_mut() {
            *l += 1;
        }
        let rec = StepRecord {
            step: 0,
            latency_s: t.latency_s,
            s_time: t.s_time,
            r_time: t.r_time,
            comm_time: t.comm_time,
            tokens: b,
            total_ctx: self.ctx_len.iter().sum(),
        };
        Ok((next, rec))
    }

    /// Start a batch and run the prompt prefill, leaving the engine one
    /// decode step away from its first generated token. All prompts must
    /// have equal length.
    pub fn prime(&mut self, prompts: &[Vec<i32>], first_id: u64) -> Result<()> {
        let b = self.cfg.batch;
        if prompts.len() != b {
            bail!("need exactly batch={b} prompts, got {}", prompts.len());
        }
        let plen = prompts[0].len();
        if plen == 0 || prompts.iter().any(|p| p.len() != plen) {
            bail!("prompts must be equal non-zero length");
        }
        if plen > self.cfg.capacity_per_seq {
            bail!("prompt length {plen} exceeds KV capacity");
        }
        self.start_batch(first_id);
        // Prefill one position at a time (token-batched across sequences,
        // same code path as decode — correct but not prefill-optimized).
        let mut current: Vec<i32> = prompts.iter().map(|p| p[0]).collect();
        for pos in 1..plen {
            self.decode_step(&current)?;
            current = prompts.iter().map(|p| p[pos]).collect();
        }
        self.current = Some(current);
        Ok(())
    }

    /// Prefill + generate: feed each prompt token, then decode `steps`
    /// new tokens greedily.
    pub fn generate(
        &mut self,
        prompts: &[Vec<i32>],
        steps: usize,
    ) -> Result<GenerationResult> {
        let b = self.cfg.batch;
        let plen = prompts.first().map(Vec::len).unwrap_or(0);
        if plen + steps > self.cfg.capacity_per_seq {
            bail!("prompt+steps exceeds KV capacity");
        }
        self.prime(prompts, 1)?;
        let mut current = self.current.take().expect("primed");

        let mut out: Vec<Vec<i32>> = vec![Vec::with_capacity(steps); b];
        let mut hist = Histogram::new();
        let mut trace = StepTrace::default();
        for step in 0..steps {
            let (next, mut rec) = self.decode_step_traced(&current)?;
            rec.step = step;
            hist.record_secs(rec.latency_s);
            trace.push(rec);
            for (o, &t) in out.iter_mut().zip(&next) {
                o.push(t);
            }
            current = next;
        }
        self.current = Some(current);
        Ok(GenerationResult {
            tokens: out,
            step_latency: hist,
            trace,
        })
    }

    /// Aggregate KV tokens currently held across sockets.
    pub fn cache_tokens(&self) -> usize {
        self.pipeline
            .rpool()
            .stats()
            .iter()
            .map(|s| s.total_tokens)
            .sum()
    }
}

impl Coordinator for FastDecode {
    fn backend(&self) -> &'static str {
        // the pipeline silently degrades to the serial schedule when the
        // batch cannot be split into two mini-batches — report the mode
        // that actually ran, not the requested one
        if self.cfg.pipelined && self.cfg.batch >= 2 {
            "real-threaded-pipelined"
        } else {
            "real-threaded-serial"
        }
    }

    /// Decode `steps` tokens from the primed state (see
    /// [`FastDecode::prime`]), tracing every step with measured
    /// wall-clock stage times.
    fn run_steps(&mut self, steps: usize) -> Result<StepTrace> {
        let mut current = match self.current.take() {
            Some(c) => c,
            None => bail!("run_steps needs prime() first"),
        };
        let mut trace = StepTrace::default();
        for step in 0..steps {
            let (next, mut rec) = self.decode_step_traced(&current)?;
            rec.step = step;
            trace.push(rec);
            current = next;
        }
        self.current = Some(current);
        Ok(trace)
    }
}
