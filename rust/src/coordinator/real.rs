//! Real-numerics FastDecode engine: native S-Part on its own thread +
//! threaded R-Part socket pool, joined by the token-level pipeline.
//!
//! Data flow per generated token (paper Fig 4):
//!   embed → for each layer: s_pre → scatter QKV to R-workers →
//!   append+attend near the cache → gather O → s_post → logits →
//!   greedy sample.
//! The KV-cache never exists on the S-worker; only activation vectors
//! cross the S↔R boundary. The batch is split into depth-D mini-batches
//! that the S thread and the R sockets process as a rotating in-flight
//! set (`runtime::pipeline`, Fig 5b generalized), so each step's wall
//! time approaches max(s, r) instead of s + r.
//!
//! Two driving modes sit behind [`Coordinator::run_steps`]:
//!
//! * **primed fixed batch** ([`FastDecode::prime`]) — the paper's §6
//!   throughput benchmark: all ℬ sequences start together, prompts
//!   prefilled in one batched multi-row pass (ragged lengths allowed).
//! * **SLS admission** ([`FastDecode::drive_arrivals`], or
//!   [`FastDecode::drive_arrivals_with`] for a non-FIFO
//!   [`AdmissionPolicy`]) — queued micro-batch arrivals admitted per
//!   step by [`LoadControl::earliest_start`] under an aggregate-KV
//!   limit W_lim (§4.2, Algorithm 1), so SLS steady-state behavior is
//!   observable on wall-clock traces and not just in the virtual-clock
//!   sim.
//!
//! Request-level serving (continuous batching, per-request latencies)
//! does not add a third mode: `serve::ServeEngine` drives the raw
//! sequence-lifecycle API (`reset` / `alloc_seq_ids` / `register_seqs`
//! / `forward_rows` / `retire_seqs`) directly.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::metrics::{Histogram, StepRecord, StepTrace};
use crate::model::{ModelSpec, Precision};
use crate::obs::{NetStats, Tracer};
use crate::runtime::{PipelineConfig, StepTiming, ThreadedPipeline};
use crate::rworker::{AttendBackend, RPool, RPoolConfig};
use crate::sched::LoadControl;
use crate::serve::{admit_one, AdmissionPolicy, Fifo, QueuedJob};
use crate::sworker::{ModelWeights, NativeSWorker};

use super::Coordinator;

#[derive(Clone, Copy, Debug)]
pub struct FastDecodeConfig {
    pub batch: usize,
    pub sockets: usize,
    pub precision: Precision,
    pub capacity_per_seq: usize,
    /// Tokens per KV block in the paged allocator
    /// (`kvcache::BlockPool`); also the COW prefix-sharing granularity.
    pub kv_block_size: usize,
    pub weight_seed: u64,
    /// Number of instantiated layers (≤ spec.n_layers, like the paper's
    /// reduced-layer evaluation).
    pub layers: usize,
    /// Overlap the in-flight mini-batches (Fig 5b); false = serial
    /// (Fig 5a with the same mini-batch decomposition).
    pub pipelined: bool,
    /// Number of in-flight mini-batches D (`PipelineConfig::depth`).
    /// 2 is the paper's double buffer; deeper pipelines shrink the
    /// fill/drain bubbles (§7.3).
    pub depth: usize,
    /// Artificial stage dilation for pipeline calibration/smoke tests
    /// (see `PipelineConfig::s_pad` / `RPoolConfig::attend_pad`).
    pub s_pad: std::time::Duration,
    pub r_pad: std::time::Duration,
}

impl Default for FastDecodeConfig {
    fn default() -> Self {
        FastDecodeConfig {
            batch: 8,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 256,
            kv_block_size: 16,
            weight_seed: 0xfa57,
            layers: 2,
            pipelined: true,
            depth: 2,
            s_pad: std::time::Duration::ZERO,
            r_pad: std::time::Duration::ZERO,
        }
    }
}

/// One queued request for the SLS-admitted live engine: a micro-batch
/// of `m` sequences, each decoding `seq_len` tokens greedily from
/// `first_token`.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    /// Micro-batch size m (sequences admitted together).
    pub m: usize,
    /// Tokens each sequence generates (its KV footprint peaks at
    /// `m · seq_len` on its final step).
    pub seq_len: usize,
    /// Initial token each sequence decodes from.
    pub first_token: i32,
}

/// A live SLS-admitted sequence.
struct LiveSeq {
    id: u64,
    token: i32,
    remaining: usize,
}

/// State of the SLS-admission driving mode.
struct SlsState {
    /// Aggregate KV-token limit W_lim enforced by admission.
    w_lim: usize,
    /// Waiting arrivals, each paired with its admission-queue view.
    /// Ordering is the policy's business: [`crate::serve::Fifo`]
    /// reproduces the original head-of-line semantics, non-FIFO
    /// policies may let later arrivals slip past a deferred head.
    queue: VecDeque<(QueuedJob, Arrival)>,
    policy: Box<dyn AdmissionPolicy>,
    live: Vec<LiveSeq>,
    lc: LoadControl,
    /// Global step counter across `run_steps` calls.
    step: usize,
    next_id: u64,
}

/// Output of a generation run.
pub struct GenerationResult {
    /// Generated token ids per sequence (excluding the prompt).
    pub tokens: Vec<Vec<i32>>,
    pub step_latency: Histogram,
    pub trace: StepTrace,
}

pub struct FastDecode {
    pub spec: ModelSpec,
    pub cfg: FastDecodeConfig,
    pipeline: ThreadedPipeline,
    seq_ids: Vec<u64>,
    /// Current context length per sequence (tokens in the cache).
    ctx_len: Vec<usize>,
    /// Current tokens after `prime` (consumed by `Coordinator::run_steps`).
    current: Option<Vec<i32>>,
    /// Some(_) once `drive_arrivals` switched the engine into SLS
    /// admission mode.
    sls: Option<SlsState>,
    /// Next sequence id for SLS admissions, monotone across waves so a
    /// second `drive_arrivals` can never collide with ids still placed
    /// in the pool.
    next_seq_id: u64,
}

impl FastDecode {
    pub fn new(spec: ModelSpec, cfg: FastDecodeConfig) -> Result<FastDecode> {
        if cfg.sockets == 0 {
            bail!("sockets must be > 0");
        }
        if cfg.layers == 0 || cfg.layers > spec.n_layers {
            bail!(
                "layers {} outside 1..={} for {}",
                cfg.layers,
                spec.n_layers,
                spec.name
            );
        }
        // The R-pool sizes its per-sequence cache to the run's needs.
        let mut spec_l = spec;
        spec_l.n_layers = cfg.layers; // R-pool allocates per layer
        let rpool = RPool::spawn(
            &spec_l,
            RPoolConfig {
                sockets: cfg.sockets,
                capacity_per_seq: cfg.capacity_per_seq,
                block_size: cfg.kv_block_size,
                precision: cfg.precision,
                attend_pad: cfg.r_pad,
            },
        );
        FastDecode::with_backend(spec, cfg, Box::new(rpool))
    }

    /// Build the engine over ANY R-Part backend — in-process socket
    /// threads, wire loopback, or TCP connections to remote `rnode`
    /// processes (`crate::net::RemotePool`). The backend must already
    /// be provisioned for `cfg.layers` layers and
    /// `cfg.capacity_per_seq` KV slots per sequence;
    /// `cfg.sockets` is overwritten with the backend's socket count.
    pub fn with_backend(
        spec: ModelSpec,
        cfg: FastDecodeConfig,
        pool: Box<dyn AttendBackend>,
    ) -> Result<FastDecode> {
        FastDecode::with_backend_traced(spec, cfg, pool, Tracer::from_env())
    }

    /// [`FastDecode::with_backend`] with an explicit tracer — tests and
    /// benches inject [`Tracer::enabled`] to capture a Chrome trace of
    /// a live run regardless of `FASTDECODE_TRACE`.
    pub fn with_backend_traced(
        spec: ModelSpec,
        mut cfg: FastDecodeConfig,
        pool: Box<dyn AttendBackend>,
        tracer: Tracer,
    ) -> Result<FastDecode> {
        if cfg.batch == 0 {
            bail!("batch must be > 0");
        }
        if pool.sockets() == 0 {
            bail!("backend must expose at least one socket");
        }
        if cfg.layers == 0 || cfg.layers > spec.n_layers {
            bail!(
                "layers {} outside 1..={} for {}",
                cfg.layers,
                spec.n_layers,
                spec.name
            );
        }
        if cfg.depth == 0 {
            bail!("pipeline depth must be ≥ 1");
        }
        cfg.sockets = pool.sockets();
        let weights = ModelWeights::random(spec, cfg.layers, cfg.weight_seed);
        let sworker = NativeSWorker::new(weights);
        let pipeline = ThreadedPipeline::with_backend_traced(
            sworker,
            pool,
            PipelineConfig {
                pipelined: cfg.pipelined,
                depth: cfg.depth,
                s_pad: cfg.s_pad,
                ..Default::default()
            },
            tracer,
        );
        Ok(FastDecode {
            spec,
            cfg,
            pipeline,
            seq_ids: Vec::new(),
            ctx_len: Vec::new(),
            current: None,
            sls: None,
            next_seq_id: 1,
        })
    }

    /// Drop every sequence the engine currently holds — the primed
    /// fixed batch and/or the SLS live set — and clear both driving
    /// modes, so either mode can be (re)entered without colliding with
    /// ids still placed in the pool.
    fn release_all_sequences(&mut self) {
        // best-effort on the reset path: a dead socket must not block
        // leaving a driving mode (the backend unplaces dead-socket
        // sequences locally either way)
        if !self.seq_ids.is_empty() {
            let old = self.seq_ids.clone();
            let _ = self.pipeline.pool_mut().drop_seqs(&old);
            self.seq_ids.clear();
            self.ctx_len.clear();
        }
        if let Some(st) = self.sls.take() {
            let live: Vec<u64> = st.live.iter().map(|s| s.id).collect();
            if !live.is_empty() {
                let _ = self.pipeline.pool_mut().drop_seqs(&live);
            }
            self.next_seq_id = self.next_seq_id.max(st.next_id);
        }
        self.current = None;
    }

    /// Register a fresh batch of sequences (drops any previous batch
    /// and leaves SLS mode if it was active).
    pub fn start_batch(&mut self, first_id: u64) -> Result<()> {
        self.release_all_sequences();
        self.seq_ids = (0..self.cfg.batch as u64).map(|i| first_id + i).collect();
        self.ctx_len = vec![0; self.cfg.batch];
        let ids = self.seq_ids.clone();
        self.pipeline.pool_mut().add_seqs(&ids)?;
        self.current = None;
        Ok(())
    }

    /// One decode step: current tokens `[B]` in → next tokens `[B]` out.
    pub fn decode_step(&mut self, tokens: &[i32]) -> Result<Vec<i32>> {
        let (next, _) = self.decode_step_traced(tokens)?;
        Ok(next)
    }

    /// Decode step with stage timing measured from real wall-clock
    /// timestamps inside the threaded pipeline.
    pub fn decode_step_traced(
        &mut self,
        tokens: &[i32],
    ) -> Result<(Vec<i32>, StepRecord)> {
        let b = self.cfg.batch;
        assert_eq!(tokens.len(), b);
        // Every step appends one token's K/V per sequence; refuse the
        // step that would overflow any sequence's cache instead of
        // asserting inside an R-worker thread.
        if let Some(&l) = self
            .ctx_len
            .iter()
            .find(|&&l| l >= self.cfg.capacity_per_seq)
        {
            bail!(
                "KV capacity exhausted: {l} tokens already cached for a \
                 sequence (capacity_per_seq = {})",
                self.cfg.capacity_per_seq
            );
        }
        let (next, t) = self.pipeline.step(tokens, &self.seq_ids)?;
        for l in self.ctx_len.iter_mut() {
            *l += 1;
        }
        let rec = StepRecord {
            step: 0,
            latency_s: t.latency_s,
            s_time: t.s_time,
            r_time: t.r_time,
            comm_time: t.comm_time,
            queue_wait_s: t.queue_wait_s,
            gather_wait_s: t.gather_wait_s,
            dispatch_s: t.dispatch_s,
            skew_s: t.skew_s,
            socket_busy: t.socket_busy,
            tokens: b,
            total_ctx: self.ctx_len.iter().sum(),
        };
        Ok((next, rec))
    }

    /// Start a batch and run the prompt prefill, leaving the engine one
    /// decode step away from its first generated token. Prompts may be
    /// RAGGED (any non-zero lengths): positions `0..len−1` of every
    /// prompt cross the pipeline in ONE batched multi-row causal pass
    /// (`ThreadedPipeline::forward`), and each prompt's last token is
    /// left as the current token — the same contract, and bit-identical
    /// cache state, as the old token-at-a-time prefill, at one round
    /// trip per layer instead of one per prompt position.
    pub fn prime(&mut self, prompts: &[Vec<i32>], first_id: u64) -> Result<()> {
        let b = self.cfg.batch;
        if prompts.len() != b {
            bail!("need exactly batch={b} prompts, got {}", prompts.len());
        }
        if prompts.iter().any(|p| p.is_empty()) {
            bail!("prompts must be non-empty");
        }
        let max_len = prompts.iter().map(Vec::len).max().unwrap_or(0);
        if max_len > self.cfg.capacity_per_seq {
            bail!("prompt length {max_len} exceeds KV capacity");
        }
        self.start_batch(first_id)?;
        let mut tokens: Vec<i32> = Vec::new();
        let mut rows: Vec<u64> = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            for &t in &p[..p.len() - 1] {
                tokens.push(t);
                rows.push(self.seq_ids[i]);
            }
        }
        if !tokens.is_empty() {
            // prefill samples are discarded — only the cache state and
            // the pending last tokens matter
            self.pipeline.forward(&tokens, &rows)?;
        }
        for (l, p) in self.ctx_len.iter_mut().zip(prompts) {
            *l = p.len() - 1;
        }
        self.current =
            Some(prompts.iter().map(|p| *p.last().expect("non-empty")).collect());
        Ok(())
    }

    /// Prefill + generate: feed each prompt token, then decode `steps`
    /// new tokens greedily.
    pub fn generate(
        &mut self,
        prompts: &[Vec<i32>],
        steps: usize,
    ) -> Result<GenerationResult> {
        let b = self.cfg.batch;
        let plen = prompts.iter().map(Vec::len).max().unwrap_or(0);
        if plen + steps > self.cfg.capacity_per_seq {
            bail!("prompt+steps exceeds KV capacity");
        }
        self.prime(prompts, 1)?;
        let mut current = self.current.take().expect("primed");

        let mut out: Vec<Vec<i32>> = vec![Vec::with_capacity(steps); b];
        let mut hist = Histogram::new();
        let mut trace = StepTrace::default();
        for step in 0..steps {
            let (next, mut rec) = self.decode_step_traced(&current)?;
            rec.step = step;
            hist.record_secs(rec.latency_s);
            trace.push(rec);
            for (o, &t) in out.iter_mut().zip(&next) {
                o.push(t);
            }
            current = next;
        }
        self.current = Some(current);
        Ok(GenerationResult {
            tokens: out,
            step_latency: hist,
            trace,
        })
    }

    /// Aggregate LOGICAL KV tokens currently held across sockets —
    /// what sequences believe they cache, shared prefix blocks counted
    /// once per sequence (remote backends answer over the wire, hence
    /// fallible and `&mut`).
    pub fn cache_tokens(&mut self) -> Result<usize> {
        Ok(self.cache_stats()?.total_tokens)
    }

    /// Merged cache statistics across every socket: logical AND
    /// physical token/byte counts (one stats round trip).
    pub fn cache_stats(&mut self) -> Result<crate::kvcache::CacheStats> {
        let mut merged = crate::kvcache::CacheStats::default();
        for st in self.pipeline.pool_mut().stats()? {
            merged.merge(&st);
        }
        Ok(merged)
    }

    /// Instantiated layer count (`cfg.layers`) — the divisor that turns
    /// per-layer cache totals into Algorithm 1's per-sequence W.
    pub fn layers(&self) -> usize {
        self.cfg.layers
    }

    /// Measured per-layer aggregate context across sockets — the live
    /// counterpart of Algorithm 1's W (each sequence counts its cached
    /// tokens once, not once per layer). PHYSICAL: blocks shared by a
    /// COW fork are counted once, so admission sees the real headroom
    /// paging buys.
    pub fn measured_kv_load(&mut self) -> Result<usize> {
        Ok(self.cache_stats()?.physical_tokens / self.cfg.layers)
    }

    /// COW-fork `child` off the first `upto` tokens of `parent` on the
    /// parent's socket (all layers). The child is registered by the
    /// fork — do not `register_seqs` it.
    pub fn fork_seq(
        &mut self,
        parent: u64,
        child: u64,
        upto: usize,
    ) -> Result<()> {
        self.pipeline.pool_mut().fork_seq(parent, child, upto)
    }

    /// The attend backend this engine is running over (for traces and
    /// bench tables).
    pub fn pool_name(&self) -> &'static str {
        self.pipeline.pool().name()
    }

    /// The tracer this engine runs under — flush it with
    /// `tracer().write_chrome_trace(..)` after a traced run.
    pub fn tracer(&self) -> &Tracer {
        self.pipeline.tracer()
    }

    /// Wire-level counters of the attend backend, one entry per remote
    /// node (empty for in-process backends). Includes the
    /// modeled-vs-measured payload drift detector and the live per-node
    /// performance profile.
    pub fn net_stats(&self) -> Vec<NetStats> {
        self.pipeline.pool().net_stats()
    }

    /// Fetch every remote node's server-side trace spans and merge
    /// them, clock-aligned, into this engine's tracer — one track per
    /// node in the same Chrome trace as the S-thread and socket spans.
    /// Returns the number of spans merged (0 for in-process backends).
    /// Call before `tracer().write_chrome_trace(..)`.
    pub fn merge_remote_traces(&mut self) -> Result<usize> {
        self.pipeline.pool_mut().merge_remote_traces()
    }

    // ── raw sequence-lifecycle API (used by `serve::ServeEngine`) ──
    //
    // The serving subsystem manages request lifecycles itself: it
    // resets the engine's own driving modes once, then registers,
    // decodes and retires sequences per request. Capacity accounting is
    // the caller's job here — the R-workers still reject an overflowing
    // append loudly.

    /// Drop every held sequence and leave both driving modes (primed
    /// fixed batch and SLS admission), so a caller can take manual
    /// control of the sequence lifecycle.
    pub fn reset(&mut self) {
        self.release_all_sequences();
    }

    /// Allocate `n` fresh sequence ids — monotone across resets, waves
    /// and serving runs, so a new lifetime can never collide with ids
    /// still placed in the pool.
    pub fn alloc_seq_ids(&mut self, n: usize) -> Vec<u64> {
        let ids: Vec<u64> =
            (self.next_seq_id..self.next_seq_id + n as u64).collect();
        self.next_seq_id += n as u64;
        ids
    }

    /// Register sequences with the socket pool (round-robin placement).
    pub fn register_seqs(&mut self, ids: &[u64]) -> Result<()> {
        self.pipeline.pool_mut().add_seqs(ids)
    }

    /// Drop finished sequences, freeing their KV across the pool.
    pub fn retire_seqs(&mut self, ids: &[u64]) -> Result<()> {
        self.pipeline.pool_mut().drop_seqs(ids)
    }

    /// One raw ragged forward pass (`ThreadedPipeline::forward`):
    /// `row_seqs[i]` owns row `i`, a sequence may own several
    /// consecutive rows (batched prefill), and decode rows of other
    /// sequences may share the pass — continuous batching. Returns the
    /// sampled next token of every row plus the measured stage timing.
    pub fn forward_rows(
        &mut self,
        tokens: &[i32],
        row_seqs: &[u64],
    ) -> Result<(Vec<i32>, StepTiming)> {
        self.pipeline.forward(tokens, row_seqs)
    }

    /// Switch the engine into SLS admission mode with FIFO ordering
    /// (head-of-line: a deferred head is never bypassed) — see
    /// [`FastDecode::drive_arrivals_with`] for pluggable policies.
    pub fn drive_arrivals(
        &mut self,
        arrivals: &[Arrival],
        w_lim: usize,
    ) -> Result<()> {
        self.drive_arrivals_with(arrivals, w_lim, Box::new(Fifo))
    }

    /// Switch the engine into SLS admission mode: `arrivals` queue up
    /// and `Coordinator::run_steps` then admits them per step — the
    /// given [`AdmissionPolicy`] picks WHICH waiting arrival starts,
    /// [`LoadControl::earliest_start`] under `w_lim` (aggregate KV
    /// tokens) decides WHETHER it may start now — decoding every live
    /// sequence each step. Any primed fixed batch is dropped. Arrivals
    /// whose lone footprint `m · seq_len` exceeds `w_lim` are rejected
    /// here — by `earliest_start`'s Option contract they could never be
    /// admitted.
    pub fn drive_arrivals_with(
        &mut self,
        arrivals: &[Arrival],
        w_lim: usize,
        policy: Box<dyn AdmissionPolicy>,
    ) -> Result<()> {
        for a in arrivals {
            if a.m == 0 || a.seq_len == 0 {
                bail!("arrival must have m ≥ 1 and seq_len ≥ 1");
            }
            if a.m * a.seq_len > w_lim {
                bail!(
                    "arrival footprint m·S = {} alone exceeds W_lim = {w_lim}",
                    a.m * a.seq_len
                );
            }
            if a.seq_len > self.cfg.capacity_per_seq {
                bail!(
                    "arrival seq_len {} exceeds KV capacity {}",
                    a.seq_len,
                    self.cfg.capacity_per_seq
                );
            }
            if a.first_token < 0 || a.first_token as usize >= self.spec.vocab {
                bail!(
                    "arrival first_token {} outside vocab {}",
                    a.first_token,
                    self.spec.vocab
                );
            }
        }
        self.release_all_sequences();
        self.sls = Some(SlsState {
            w_lim,
            queue: arrivals
                .iter()
                .enumerate()
                .map(|(i, &a)| {
                    (
                        QueuedJob {
                            id: i as u64,
                            m: a.m,
                            init_len: 0,
                            grow_len: a.seq_len,
                            arrive_step: 0,
                        },
                        a,
                    )
                })
                .collect(),
            policy,
            live: Vec::new(),
            lc: LoadControl::new(),
            step: 0,
            next_id: self.next_seq_id,
        });
        Ok(())
    }

    /// Arrivals not yet admitted (SLS mode only).
    pub fn pending_arrivals(&self) -> usize {
        self.sls.as_ref().map_or(0, |st| st.queue.len())
    }

    /// Sequences currently decoding (SLS mode only).
    pub fn live_sequences(&self) -> usize {
        self.sls.as_ref().map_or(0, |st| st.live.len())
    }

    /// One SLS-admitted step: retire finished micro-batches from the
    /// controller, admit every arrival whose earliest feasible start is
    /// now, decode all live sequences, and release finished caches.
    fn sls_step(&mut self) -> Result<StepRecord> {
        let mut st = self.sls.take().expect("sls state");
        let res = self.sls_step_inner(&mut st);
        self.sls = Some(st);
        res
    }

    fn sls_step_inner(&mut self, st: &mut SlsState) -> Result<StepRecord> {
        let t = st.step;
        st.step += 1;
        st.lc.retire_before(t);
        loop {
            if st.queue.is_empty() {
                break;
            }
            let jobs: Vec<QueuedJob> =
                st.queue.iter().map(|&(j, _)| j).collect();
            // `admit_one` enforces the policy contract (bounds + the
            // selected job must start exactly now) and charges the
            // controller — the same machinery `serve::ServeEngine` uses
            let Some(idx) =
                admit_one(st.policy.as_ref(), t, &jobs, &mut st.lc, st.w_lim)?
            else {
                break; // nothing startable now under this policy
            };
            let (_, a) =
                st.queue.remove(idx).expect("admit_one bounds-checked");
            // admission decisions land as instants on the coordinator
            // track, between the surrounding steps' scatter/gather spans
            self.pipeline.track().instant(
                "admit",
                &[
                    ("step", t as f64),
                    ("m", a.m as f64),
                    ("seq_len", a.seq_len as f64),
                ],
            );
            let ids: Vec<u64> = (st.next_id..st.next_id + a.m as u64).collect();
            st.next_id += a.m as u64;
            self.pipeline.pool_mut().add_seqs(&ids)?;
            for &id in &ids {
                st.live.push(LiveSeq {
                    id,
                    token: a.first_token,
                    remaining: a.seq_len,
                });
            }
        }
        if st.live.is_empty() {
            // an idle step: either the queue has drained, or every
            // waiting arrival is deferred (with an empty live set the
            // controller is empty after retirement, so any feasible
            // arrival is startable — a sane policy admits one)
            return Ok(StepRecord {
                step: t,
                ..Default::default()
            });
        }
        let tokens: Vec<i32> = st.live.iter().map(|s| s.token).collect();
        let ids: Vec<u64> = st.live.iter().map(|s| s.id).collect();
        let (next, timing) = self.pipeline.step(&tokens, &ids)?;
        let served = st.live.len();
        for (seq, &tok) in st.live.iter_mut().zip(&next) {
            seq.token = tok;
            seq.remaining -= 1;
        }
        // Measure the aggregate KV load this step actually processed,
        // BEFORE finished sequences release their cache — this is what
        // the admission limit W_lim must bound.
        let kv_load = self.measured_kv_load()?;
        let finished: Vec<u64> = st
            .live
            .iter()
            .filter(|s| s.remaining == 0)
            .map(|s| s.id)
            .collect();
        if !finished.is_empty() {
            self.pipeline.pool_mut().drop_seqs(&finished)?;
            st.live.retain(|s| s.remaining > 0);
        }
        Ok(StepRecord {
            step: t,
            latency_s: timing.latency_s,
            s_time: timing.s_time,
            r_time: timing.r_time,
            comm_time: timing.comm_time,
            queue_wait_s: timing.queue_wait_s,
            gather_wait_s: timing.gather_wait_s,
            dispatch_s: timing.dispatch_s,
            skew_s: timing.skew_s,
            socket_busy: timing.socket_busy,
            tokens: served,
            total_ctx: kv_load,
        })
    }
}

impl Coordinator for FastDecode {
    fn backend(&self) -> &'static str {
        // the pipeline silently degrades to the serial schedule when the
        // batch cannot be split into at least two mini-batches — report
        // the mode that actually ran, not the requested one
        if self.sls.is_some() {
            "real-threaded-sls"
        } else if self.cfg.pipelined && self.cfg.batch >= 2 && self.cfg.depth >= 2
        {
            "real-threaded-pipelined"
        } else {
            "real-threaded-serial"
        }
    }

    /// Decode `steps` tokens, tracing every step with measured
    /// wall-clock stage times. In SLS mode (see
    /// [`FastDecode::drive_arrivals`]) each step first runs admission;
    /// otherwise the primed fixed batch decodes (see
    /// [`FastDecode::prime`]).
    fn run_steps(&mut self, steps: usize) -> Result<StepTrace> {
        if self.sls.is_some() {
            let mut trace = StepTrace::default();
            for _ in 0..steps {
                trace.push(self.sls_step()?);
            }
            return Ok(trace);
        }
        let mut current = match self.current.take() {
            Some(c) => c,
            None => bail!("run_steps needs prime() first"),
        };
        let mut trace = StepTrace::default();
        for step in 0..steps {
            let (next, mut rec) = self.decode_step_traced(&current)?;
            rec.step = step;
            trace.push(rec);
            current = next;
        }
        self.current = Some(current);
        Ok(trace)
    }
}
