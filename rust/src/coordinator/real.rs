//! Real-numerics FastDecode engine: native S-Part on its own thread +
//! threaded R-Part socket pool, joined by the token-level pipeline.
//!
//! Data flow per generated token (paper Fig 4):
//!   embed → for each layer: s_pre → scatter QKV to R-workers →
//!   append+attend near the cache → gather O → s_post → logits →
//!   greedy sample.
//! The KV-cache never exists on the S-worker; only activation vectors
//! cross the S↔R boundary. The batch is split into depth-D mini-batches
//! that the S thread and the R sockets process as a rotating in-flight
//! set (`runtime::pipeline`, Fig 5b generalized), so each step's wall
//! time approaches max(s, r) instead of s + r.
//!
//! Two driving modes sit behind [`Coordinator::run_steps`]:
//!
//! * **primed fixed batch** ([`FastDecode::prime`]) — the paper's §6
//!   throughput benchmark: all ℬ sequences start together.
//! * **SLS admission** ([`FastDecode::drive_arrivals`]) — queued
//!   micro-batch arrivals admitted per step by
//!   [`LoadControl::earliest_start`] under an aggregate-KV limit W_lim
//!   (§4.2, Algorithm 1), so SLS steady-state behavior is observable on
//!   wall-clock traces and not just in the virtual-clock sim.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::metrics::{Histogram, StepRecord, StepTrace};
use crate::model::{ModelSpec, Precision};
use crate::runtime::{PipelineConfig, ThreadedPipeline};
use crate::rworker::{RPool, RPoolConfig};
use crate::sched::LoadControl;
use crate::sworker::{ModelWeights, NativeSWorker};

use super::Coordinator;

#[derive(Clone, Copy, Debug)]
pub struct FastDecodeConfig {
    pub batch: usize,
    pub sockets: usize,
    pub precision: Precision,
    pub capacity_per_seq: usize,
    pub weight_seed: u64,
    /// Number of instantiated layers (≤ spec.n_layers, like the paper's
    /// reduced-layer evaluation).
    pub layers: usize,
    /// Overlap the in-flight mini-batches (Fig 5b); false = serial
    /// (Fig 5a with the same mini-batch decomposition).
    pub pipelined: bool,
    /// Number of in-flight mini-batches D (`PipelineConfig::depth`).
    /// 2 is the paper's double buffer; deeper pipelines shrink the
    /// fill/drain bubbles (§7.3).
    pub depth: usize,
    /// Artificial stage dilation for pipeline calibration/smoke tests
    /// (see `PipelineConfig::s_pad` / `RPoolConfig::attend_pad`).
    pub s_pad: std::time::Duration,
    pub r_pad: std::time::Duration,
}

impl Default for FastDecodeConfig {
    fn default() -> Self {
        FastDecodeConfig {
            batch: 8,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 256,
            weight_seed: 0xfa57,
            layers: 2,
            pipelined: true,
            depth: 2,
            s_pad: std::time::Duration::ZERO,
            r_pad: std::time::Duration::ZERO,
        }
    }
}

/// One queued request for the SLS-admitted live engine: a micro-batch
/// of `m` sequences, each decoding `seq_len` tokens greedily from
/// `first_token`.
#[derive(Clone, Copy, Debug)]
pub struct Arrival {
    /// Micro-batch size m (sequences admitted together).
    pub m: usize,
    /// Tokens each sequence generates (its KV footprint peaks at
    /// `m · seq_len` on its final step).
    pub seq_len: usize,
    /// Initial token each sequence decodes from.
    pub first_token: i32,
}

/// A live SLS-admitted sequence.
struct LiveSeq {
    id: u64,
    token: i32,
    remaining: usize,
}

/// State of the SLS-admission driving mode.
struct SlsState {
    /// Aggregate KV-token limit W_lim enforced by admission.
    w_lim: usize,
    /// FIFO arrival queue (head-of-line: a deferred head is never
    /// bypassed by a smaller later arrival).
    queue: VecDeque<Arrival>,
    live: Vec<LiveSeq>,
    lc: LoadControl,
    /// Global step counter across `run_steps` calls.
    step: usize,
    next_id: u64,
}

/// Output of a generation run.
pub struct GenerationResult {
    /// Generated token ids per sequence (excluding the prompt).
    pub tokens: Vec<Vec<i32>>,
    pub step_latency: Histogram,
    pub trace: StepTrace,
}

pub struct FastDecode {
    pub spec: ModelSpec,
    pub cfg: FastDecodeConfig,
    pipeline: ThreadedPipeline,
    seq_ids: Vec<u64>,
    /// Current context length per sequence (tokens in the cache).
    ctx_len: Vec<usize>,
    /// Current tokens after `prime` (consumed by `Coordinator::run_steps`).
    current: Option<Vec<i32>>,
    /// Some(_) once `drive_arrivals` switched the engine into SLS
    /// admission mode.
    sls: Option<SlsState>,
    /// Next sequence id for SLS admissions, monotone across waves so a
    /// second `drive_arrivals` can never collide with ids still placed
    /// in the pool.
    next_seq_id: u64,
}

impl FastDecode {
    pub fn new(spec: ModelSpec, cfg: FastDecodeConfig) -> Result<FastDecode> {
        if cfg.batch == 0 {
            bail!("batch must be > 0");
        }
        if cfg.sockets == 0 {
            bail!("sockets must be > 0");
        }
        if cfg.layers == 0 || cfg.layers > spec.n_layers {
            bail!(
                "layers {} outside 1..={} for {}",
                cfg.layers,
                spec.n_layers,
                spec.name
            );
        }
        if cfg.depth == 0 {
            bail!("pipeline depth must be ≥ 1");
        }
        // The R-pool sizes its per-sequence cache to the run's needs.
        let mut spec_l = spec;
        spec_l.n_layers = cfg.layers; // R-pool allocates per layer
        let rpool = RPool::spawn(
            &spec_l,
            RPoolConfig {
                sockets: cfg.sockets,
                capacity_per_seq: cfg.capacity_per_seq,
                precision: cfg.precision,
                attend_pad: cfg.r_pad,
            },
        );
        let weights = ModelWeights::random(spec, cfg.layers, cfg.weight_seed);
        let sworker = NativeSWorker::new(weights);
        let pipeline = ThreadedPipeline::new(
            sworker,
            rpool,
            PipelineConfig {
                pipelined: cfg.pipelined,
                depth: cfg.depth,
                s_pad: cfg.s_pad,
                ..Default::default()
            },
        );
        Ok(FastDecode {
            spec,
            cfg,
            pipeline,
            seq_ids: Vec::new(),
            ctx_len: Vec::new(),
            current: None,
            sls: None,
            next_seq_id: 1,
        })
    }

    /// Drop every sequence the engine currently holds — the primed
    /// fixed batch and/or the SLS live set — and clear both driving
    /// modes, so either mode can be (re)entered without colliding with
    /// ids still placed in the pool.
    fn release_all_sequences(&mut self) {
        if !self.seq_ids.is_empty() {
            let old = self.seq_ids.clone();
            self.pipeline.rpool_mut().drop_seqs(&old);
            self.seq_ids.clear();
            self.ctx_len.clear();
        }
        if let Some(st) = self.sls.take() {
            let live: Vec<u64> = st.live.iter().map(|s| s.id).collect();
            if !live.is_empty() {
                self.pipeline.rpool_mut().drop_seqs(&live);
            }
            self.next_seq_id = self.next_seq_id.max(st.next_id);
        }
        self.current = None;
    }

    /// Register a fresh batch of sequences (drops any previous batch
    /// and leaves SLS mode if it was active).
    pub fn start_batch(&mut self, first_id: u64) {
        self.release_all_sequences();
        self.seq_ids = (0..self.cfg.batch as u64).map(|i| first_id + i).collect();
        self.ctx_len = vec![0; self.cfg.batch];
        let ids = self.seq_ids.clone();
        self.pipeline.rpool_mut().add_seqs(&ids);
        self.current = None;
    }

    /// One decode step: current tokens `[B]` in → next tokens `[B]` out.
    pub fn decode_step(&mut self, tokens: &[i32]) -> Result<Vec<i32>> {
        let (next, _) = self.decode_step_traced(tokens)?;
        Ok(next)
    }

    /// Decode step with stage timing measured from real wall-clock
    /// timestamps inside the threaded pipeline.
    pub fn decode_step_traced(
        &mut self,
        tokens: &[i32],
    ) -> Result<(Vec<i32>, StepRecord)> {
        let b = self.cfg.batch;
        assert_eq!(tokens.len(), b);
        // Every step appends one token's K/V per sequence; refuse the
        // step that would overflow the per-sequence cache instead of
        // asserting inside an R-worker thread.
        if self.ctx_len.first().is_some_and(|&l| l >= self.cfg.capacity_per_seq)
        {
            bail!(
                "KV capacity exhausted: {} tokens per sequence already \
                 cached (capacity_per_seq = {})",
                self.ctx_len[0],
                self.cfg.capacity_per_seq
            );
        }
        let (next, t) = self.pipeline.step(tokens, &self.seq_ids)?;
        for l in self.ctx_len.iter_mut() {
            *l += 1;
        }
        let rec = StepRecord {
            step: 0,
            latency_s: t.latency_s,
            s_time: t.s_time,
            r_time: t.r_time,
            comm_time: t.comm_time,
            tokens: b,
            total_ctx: self.ctx_len.iter().sum(),
        };
        Ok((next, rec))
    }

    /// Start a batch and run the prompt prefill, leaving the engine one
    /// decode step away from its first generated token. All prompts must
    /// have equal length.
    pub fn prime(&mut self, prompts: &[Vec<i32>], first_id: u64) -> Result<()> {
        let b = self.cfg.batch;
        if prompts.len() != b {
            bail!("need exactly batch={b} prompts, got {}", prompts.len());
        }
        let plen = prompts[0].len();
        if plen == 0 || prompts.iter().any(|p| p.len() != plen) {
            bail!("prompts must be equal non-zero length");
        }
        if plen > self.cfg.capacity_per_seq {
            bail!("prompt length {plen} exceeds KV capacity");
        }
        self.start_batch(first_id);
        // Prefill one position at a time (token-batched across sequences,
        // same code path as decode — correct but not prefill-optimized).
        let mut current: Vec<i32> = prompts.iter().map(|p| p[0]).collect();
        for pos in 1..plen {
            self.decode_step(&current)?;
            current = prompts.iter().map(|p| p[pos]).collect();
        }
        self.current = Some(current);
        Ok(())
    }

    /// Prefill + generate: feed each prompt token, then decode `steps`
    /// new tokens greedily.
    pub fn generate(
        &mut self,
        prompts: &[Vec<i32>],
        steps: usize,
    ) -> Result<GenerationResult> {
        let b = self.cfg.batch;
        let plen = prompts.first().map(Vec::len).unwrap_or(0);
        if plen + steps > self.cfg.capacity_per_seq {
            bail!("prompt+steps exceeds KV capacity");
        }
        self.prime(prompts, 1)?;
        let mut current = self.current.take().expect("primed");

        let mut out: Vec<Vec<i32>> = vec![Vec::with_capacity(steps); b];
        let mut hist = Histogram::new();
        let mut trace = StepTrace::default();
        for step in 0..steps {
            let (next, mut rec) = self.decode_step_traced(&current)?;
            rec.step = step;
            hist.record_secs(rec.latency_s);
            trace.push(rec);
            for (o, &t) in out.iter_mut().zip(&next) {
                o.push(t);
            }
            current = next;
        }
        self.current = Some(current);
        Ok(GenerationResult {
            tokens: out,
            step_latency: hist,
            trace,
        })
    }

    /// Aggregate KV tokens currently held across sockets.
    pub fn cache_tokens(&self) -> usize {
        self.pipeline
            .rpool()
            .stats()
            .iter()
            .map(|s| s.total_tokens)
            .sum()
    }

    /// Measured per-layer aggregate context across sockets — the live
    /// counterpart of Algorithm 1's W (each sequence counts its cached
    /// tokens once, not once per layer).
    pub fn measured_kv_load(&self) -> usize {
        self.cache_tokens() / self.cfg.layers
    }

    /// Switch the engine into SLS admission mode: `arrivals` queue FIFO
    /// and `Coordinator::run_steps` then admits them per step via
    /// [`LoadControl::earliest_start`] under `w_lim` (aggregate KV
    /// tokens), decoding every live sequence each step. Any primed
    /// fixed batch is dropped. Arrivals whose lone footprint
    /// `m · seq_len` exceeds `w_lim` are rejected here — by
    /// `earliest_start`'s Option contract they could never be admitted.
    pub fn drive_arrivals(
        &mut self,
        arrivals: &[Arrival],
        w_lim: usize,
    ) -> Result<()> {
        for a in arrivals {
            if a.m == 0 || a.seq_len == 0 {
                bail!("arrival must have m ≥ 1 and seq_len ≥ 1");
            }
            if a.m * a.seq_len > w_lim {
                bail!(
                    "arrival footprint m·S = {} alone exceeds W_lim = {w_lim}",
                    a.m * a.seq_len
                );
            }
            if a.seq_len > self.cfg.capacity_per_seq {
                bail!(
                    "arrival seq_len {} exceeds KV capacity {}",
                    a.seq_len,
                    self.cfg.capacity_per_seq
                );
            }
            if a.first_token < 0 || a.first_token as usize >= self.spec.vocab {
                bail!(
                    "arrival first_token {} outside vocab {}",
                    a.first_token,
                    self.spec.vocab
                );
            }
        }
        self.release_all_sequences();
        self.sls = Some(SlsState {
            w_lim,
            queue: arrivals.iter().copied().collect(),
            live: Vec::new(),
            lc: LoadControl::new(),
            step: 0,
            next_id: self.next_seq_id,
        });
        Ok(())
    }

    /// Arrivals not yet admitted (SLS mode only).
    pub fn pending_arrivals(&self) -> usize {
        self.sls.as_ref().map_or(0, |st| st.queue.len())
    }

    /// Sequences currently decoding (SLS mode only).
    pub fn live_sequences(&self) -> usize {
        self.sls.as_ref().map_or(0, |st| st.live.len())
    }

    /// One SLS-admitted step: retire finished micro-batches from the
    /// controller, admit every arrival whose earliest feasible start is
    /// now, decode all live sequences, and release finished caches.
    fn sls_step(&mut self) -> Result<StepRecord> {
        let mut st = self.sls.take().expect("sls state");
        let res = self.sls_step_inner(&mut st);
        self.sls = Some(st);
        res
    }

    fn sls_step_inner(&mut self, st: &mut SlsState) -> Result<StepRecord> {
        let t = st.step;
        st.step += 1;
        st.lc.retire_before(t);
        while let Some(a) = st.queue.front().copied() {
            let s = st
                .lc
                .earliest_start(t, a.m, a.seq_len, st.w_lim)
                .expect("validated at enqueue: m·seq_len ≤ w_lim");
            if s > t {
                break; // head deferred; FIFO admission never skips it
            }
            st.queue.pop_front();
            st.lc.add(t, a.m, a.seq_len);
            let ids: Vec<u64> = (st.next_id..st.next_id + a.m as u64).collect();
            st.next_id += a.m as u64;
            self.pipeline.rpool_mut().add_seqs(&ids);
            for &id in &ids {
                st.live.push(LiveSeq {
                    id,
                    token: a.first_token,
                    remaining: a.seq_len,
                });
            }
        }
        if st.live.is_empty() {
            // only reachable once the queue has drained (an empty live
            // set leaves the controller empty, so any queued head would
            // have been admitted above): an idle step
            return Ok(StepRecord {
                step: t,
                ..Default::default()
            });
        }
        let tokens: Vec<i32> = st.live.iter().map(|s| s.token).collect();
        let ids: Vec<u64> = st.live.iter().map(|s| s.id).collect();
        let (next, timing) = self.pipeline.step(&tokens, &ids)?;
        let served = st.live.len();
        for (seq, &tok) in st.live.iter_mut().zip(&next) {
            seq.token = tok;
            seq.remaining -= 1;
        }
        // Measure the aggregate KV load this step actually processed,
        // BEFORE finished sequences release their cache — this is what
        // the admission limit W_lim must bound.
        let kv_load = self.measured_kv_load();
        let finished: Vec<u64> = st
            .live
            .iter()
            .filter(|s| s.remaining == 0)
            .map(|s| s.id)
            .collect();
        if !finished.is_empty() {
            self.pipeline.rpool_mut().drop_seqs(&finished);
            st.live.retain(|s| s.remaining > 0);
        }
        Ok(StepRecord {
            step: t,
            latency_s: timing.latency_s,
            s_time: timing.s_time,
            r_time: timing.r_time,
            comm_time: timing.comm_time,
            tokens: served,
            total_ctx: kv_load,
        })
    }
}

impl Coordinator for FastDecode {
    fn backend(&self) -> &'static str {
        // the pipeline silently degrades to the serial schedule when the
        // batch cannot be split into at least two mini-batches — report
        // the mode that actually ran, not the requested one
        if self.sls.is_some() {
            "real-threaded-sls"
        } else if self.cfg.pipelined && self.cfg.batch >= 2 && self.cfg.depth >= 2
        {
            "real-threaded-pipelined"
        } else {
            "real-threaded-serial"
        }
    }

    /// Decode `steps` tokens, tracing every step with measured
    /// wall-clock stage times. In SLS mode (see
    /// [`FastDecode::drive_arrivals`]) each step first runs admission;
    /// otherwise the primed fixed batch decodes (see
    /// [`FastDecode::prime`]).
    fn run_steps(&mut self, steps: usize) -> Result<StepTrace> {
        if self.sls.is_some() {
            let mut trace = StepTrace::default();
            for _ in 0..steps {
                trace.push(self.sls_step()?);
            }
            return Ok(trace);
        }
        let mut current = match self.current.take() {
            Some(c) => c,
            None => bail!("run_steps needs prime() first"),
        };
        let mut trace = StepTrace::default();
        for step in 0..steps {
            let (next, mut rec) = self.decode_step_traced(&current)?;
            rec.step = step;
            trace.push(rec);
            current = next;
        }
        self.current = Some(current);
        Ok(trace)
    }
}
