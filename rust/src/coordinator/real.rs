//! Real-numerics FastDecode engine: PJRT S-Part + Rust R-Part.
//!
//! Data flow per generated token (paper Fig 4):
//!   embed → for each layer: s_pre (HLO) → scatter QKV to R-workers →
//!   append+attend near the cache → gather O → s_post (HLO) → logits →
//!   greedy sample.
//! The KV-cache never exists on the S-worker; only activation vectors
//! cross the S↔R boundary.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::metrics::{Histogram, StepRecord, StepTrace};
use crate::model::{ModelSpec, Precision};
use crate::runtime::{Engine, Tensor};
use crate::rworker::{RPool, RPoolConfig, SeqTask};
use crate::sworker::{ModelWeights, PjrtSWorker};

#[derive(Clone, Copy, Debug)]
pub struct FastDecodeConfig {
    pub batch: usize,
    pub sockets: usize,
    pub precision: Precision,
    pub capacity_per_seq: usize,
    pub weight_seed: u64,
    /// Number of instantiated layers (≤ spec.n_layers, like the paper's
    /// reduced-layer evaluation).
    pub layers: usize,
}

impl Default for FastDecodeConfig {
    fn default() -> Self {
        FastDecodeConfig {
            batch: 8,
            sockets: 2,
            precision: Precision::F16,
            capacity_per_seq: 256,
            weight_seed: 0xfa57,
            layers: 2,
        }
    }
}

/// Output of a generation run.
pub struct GenerationResult {
    /// Generated token ids per sequence (excluding the prompt).
    pub tokens: Vec<Vec<i32>>,
    pub step_latency: Histogram,
    pub trace: StepTrace,
}

pub struct FastDecode {
    pub spec: ModelSpec,
    pub cfg: FastDecodeConfig,
    sworker: PjrtSWorker,
    rpool: RPool,
    seq_ids: Vec<u64>,
    /// Current context length per sequence (tokens in the cache).
    ctx_len: Vec<usize>,
}

impl FastDecode {
    pub fn new(
        engine: Arc<Engine>,
        spec: ModelSpec,
        cfg: FastDecodeConfig,
    ) -> Result<FastDecode> {
        // The R-pool sizes its per-sequence cache to the run's needs.
        let mut spec_l = spec;
        spec_l.n_layers = cfg.layers; // R-pool allocates per layer
        let rpool = RPool::spawn(
            &spec_l,
            RPoolConfig {
                sockets: cfg.sockets,
                capacity_per_seq: cfg.capacity_per_seq,
                precision: cfg.precision,
            },
        );
        let weights = ModelWeights::random(spec, cfg.layers, cfg.weight_seed);
        let sworker = PjrtSWorker::new(engine, weights, cfg.batch)?;
        Ok(FastDecode {
            spec,
            cfg,
            sworker,
            rpool,
            seq_ids: Vec::new(),
            ctx_len: Vec::new(),
        })
    }

    /// Register a fresh batch of sequences (drops any previous batch).
    pub fn start_batch(&mut self, first_id: u64) {
        if !self.seq_ids.is_empty() {
            let old = self.seq_ids.clone();
            self.rpool.drop_seqs(&old);
        }
        self.seq_ids = (0..self.cfg.batch as u64).map(|i| first_id + i).collect();
        self.ctx_len = vec![0; self.cfg.batch];
        self.rpool.add_seqs(&self.seq_ids.clone());
    }

    /// One decode step: current tokens `[B]` in → next tokens `[B]` out.
    pub fn decode_step(&mut self, tokens: &[i32]) -> Result<Vec<i32>> {
        let (next, _) = self.decode_step_traced(tokens)?;
        Ok(next)
    }

    /// Decode step with stage timing (s_time / r_time measured).
    pub fn decode_step_traced(
        &mut self,
        tokens: &[i32],
    ) -> Result<(Vec<i32>, StepRecord)> {
        let b = self.cfg.batch;
        let h = self.spec.hidden;
        assert_eq!(tokens.len(), b);
        let mut s_time = 0.0;
        let mut r_time = 0.0;

        let t0 = Instant::now();
        let mut x = self.sworker.embed(tokens)?;
        s_time += t0.elapsed().as_secs_f64();

        for layer in 0..self.cfg.layers {
            let t = Instant::now();
            let qkv = self.sworker.s_pre(layer, &x)?;
            s_time += t.elapsed().as_secs_f64();

            // Scatter: per-sequence Q/K/V slices (head-major [H*D]).
            let qkv_data = qkv.as_f32()?;
            let tasks: Vec<SeqTask> = (0..b)
                .map(|i| {
                    let row = &qkv_data[i * 3 * h..(i + 1) * 3 * h];
                    SeqTask {
                        seq_id: self.seq_ids[i],
                        q: row[0..h].to_vec(),
                        k_new: row[h..2 * h].to_vec(),
                        v_new: row[2 * h..3 * h].to_vec(),
                    }
                })
                .collect();
            let t = Instant::now();
            let step = self.rpool.attend(layer, tasks);
            r_time += t.elapsed().as_secs_f64();

            // Gather O in sequence order.
            let mut o_data = Vec::with_capacity(b * h);
            for &id in &self.seq_ids {
                o_data.extend_from_slice(&step.outputs[&id]);
            }
            let o = Tensor::f32(&[b, h], o_data);

            let t = Instant::now();
            x = self.sworker.s_post(layer, &x, &o)?;
            s_time += t.elapsed().as_secs_f64();
        }

        for l in self.ctx_len.iter_mut() {
            *l += 1;
        }
        let t = Instant::now();
        let logits = self.sworker.logits(&x)?;
        let next = self.sworker.argmax(&logits)?;
        s_time += t.elapsed().as_secs_f64();

        let rec = StepRecord {
            step: 0,
            latency_s: t0.elapsed().as_secs_f64(),
            s_time,
            r_time,
            comm_time: 0.0,
            tokens: b,
            total_ctx: self.ctx_len.iter().sum(),
        };
        Ok((next, rec))
    }

    /// Prefill + generate: feed each prompt token, then decode `steps`
    /// new tokens greedily. All prompts must have equal length (the
    /// paper's throughput benchmark uses a short fixed prompt).
    pub fn generate(
        &mut self,
        prompts: &[Vec<i32>],
        steps: usize,
    ) -> Result<GenerationResult> {
        let b = self.cfg.batch;
        assert_eq!(prompts.len(), b, "need exactly batch={b} prompts");
        let plen = prompts[0].len();
        assert!(plen > 0);
        assert!(
            prompts.iter().all(|p| p.len() == plen),
            "prompts must be equal length"
        );
        assert!(
            plen + steps <= self.cfg.capacity_per_seq,
            "prompt+steps exceeds KV capacity"
        );
        self.start_batch(1);

        // Prefill one position at a time (token-batched across sequences,
        // same code path as decode — correct but not prefill-optimized).
        let mut current: Vec<i32> = prompts.iter().map(|p| p[0]).collect();
        for pos in 1..plen {
            self.decode_step(&current)?;
            current = prompts.iter().map(|p| p[pos]).collect();
        }

        let mut out: Vec<Vec<i32>> = vec![Vec::with_capacity(steps); b];
        let mut hist = Histogram::new();
        let mut trace = StepTrace::default();
        for step in 0..steps {
            let (next, mut rec) = self.decode_step_traced(&current)?;
            rec.step = step;
            hist.record_secs(rec.latency_s);
            trace.push(rec);
            for (o, &t) in out.iter_mut().zip(&next) {
                o.push(t);
            }
            current = next;
        }
        Ok(GenerationResult {
            tokens: out,
            step_latency: hist,
            trace,
        })
    }

    /// Aggregate KV tokens currently held across sockets.
    pub fn cache_tokens(&self) -> usize {
        self.rpool.stats().iter().map(|s| s.total_tokens).sum()
    }
}
