//! Virtual-clock FastDecode simulator — regenerates the paper's figures
//! at A10/Epyc scale on a laptop (DESIGN.md §2, timing modes).
//!
//! The control flow mirrors the real coordinator (SLS admission, token
//! pipeline, per-layer S/R/comm stages); stage costs come from the
//! calibrated models: GpuModel (S-Part roofline), CpuModel (R-Part KV
//! streaming — optionally calibrated from a *measured* probe of this
//! machine) and LinkModel (Table 3 wires).

use crate::metrics::StepTrace;
use crate::model::{ModelSpec, Precision};
use crate::perfmodel::{CpuModel, GpuModel};
use crate::sched::{PipelineSim, SlsSchedule};
use crate::transport::{activation_roundtrip_time, LinkModel, PCIE4_X16, ROCE_100G};

#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub spec: ModelSpec,
    pub gpu: GpuModel,
    pub cpu: CpuModel,
    /// Number of R-worker sockets 𝒫.
    pub sockets: usize,
    /// Total concurrent batch ℬ.
    pub batch: usize,
    /// Generated length 𝒮 per sequence.
    pub seq_len: usize,
    /// Some(F) → SLS with interval F; None → all sequences start at once.
    pub sls_interval: Option<usize>,
    /// Steps to simulate. For SLS runs use ≥ 2·seq_len to cover cold
    /// start + steady state; for naive runs seq_len is natural.
    pub steps: usize,
    pub pipelined: bool,
    /// Expose activation transfer in the step time (Fig 15 mode).
    pub sync_comm: bool,
    pub precision: Precision,
    pub pcie: LinkModel,
    pub net: LinkModel,
    /// Layer count override (0 → spec.n_layers).
    pub layers: usize,
}

impl SimConfig {
    pub fn new(
        spec: ModelSpec,
        gpu: GpuModel,
        cpu: CpuModel,
        sockets: usize,
        batch: usize,
        seq_len: usize,
    ) -> SimConfig {
        SimConfig {
            spec,
            gpu,
            cpu,
            sockets,
            batch,
            seq_len,
            sls_interval: None,
            steps: seq_len,
            pipelined: true,
            sync_comm: false,
            precision: Precision::F16,
            pcie: PCIE4_X16,
            net: ROCE_100G,
            layers: 0,
        }
    }

    pub fn layers(&self) -> usize {
        if self.layers == 0 {
            self.spec.n_layers
        } else {
            self.layers
        }
    }

    /// Active sequences and aggregate context at `step`.
    pub fn load_at(&self, step: usize) -> (usize, usize) {
        match self.sls_interval {
            None => {
                if step < self.seq_len {
                    (self.batch, self.batch * (step + 1))
                } else {
                    (0, 0)
                }
            }
            Some(f) => {
                let sls = SlsSchedule::new(self.batch, self.seq_len, f);
                let m = sls.micro_batch_size(); // ≥ 1 by contract
                // count alive micro-batches at `step`
                let mut active = 0usize;
                let mut j = 0usize;
                loop {
                    let start = j * f;
                    if start > step {
                        break;
                    }
                    if step - start < self.seq_len {
                        active += m;
                    }
                    j += 1;
                }
                (active.min(self.batch), sls.load_at_capped(step, self.batch))
            }
        }
    }
}

// Extension used only by the simulator: SLS load with the micro-batch
// count capped so aggregate active sequences never exceed ℬ.
impl SlsSchedule {
    pub fn load_at_capped(&self, step: usize, batch_cap: usize) -> usize {
        let m = self.micro_batch_size(); // ≥ 1 by contract
        let mut total = 0usize;
        let mut active = 0usize;
        // youngest first so the cap drops the OLDEST batches (they finish)
        let mut starts: Vec<usize> = Vec::new();
        let mut j = 0usize;
        loop {
            let start = j * self.interval;
            if start > step {
                break;
            }
            if step - start < self.seq_len {
                starts.push(start);
            }
            j += 1;
        }
        for &start in starts.iter().rev() {
            if active + m > batch_cap {
                break;
            }
            active += m;
            total += m * (step - start + 1);
        }
        total
    }
}

/// Run the virtual-clock simulation.
pub fn simulate(cfg: &SimConfig) -> StepTrace {
    let layers = cfg.layers() as f64;
    let sim = PipelineSim {
        pipelined: cfg.pipelined,
        sync_comm: cfg.sync_comm,
        ..Default::default()
    };
    sim.run(cfg.steps, |step| {
        let (active, ctx) = cfg.load_at(step);
        if active == 0 {
            return (0.0, 0.0, 0.0, 0, 0);
        }
        let s = layers * cfg.gpu.s_part_latency(&cfg.spec, active);
        // per-socket share of the aggregate context (balanced placement)
        let per_socket = ctx.div_ceil(cfg.sockets);
        let r = layers
            * cfg
                .cpu
                .r_part_latency(&cfg.spec, per_socket, cfg.precision);
        let c = layers
            * activation_roundtrip_time(
                cfg.spec.hidden,
                active,
                cfg.pcie,
                cfg.net,
                cfg.sockets,
            );
        (s, r, c, active, ctx)
    })
}

/// Steady-state throughput of an SLS run (skips the cold start).
pub fn steady_throughput(trace: &StepTrace, skip: usize) -> f64 {
    let tail: Vec<_> = trace.records.iter().skip(skip).collect();
    if tail.is_empty() {
        return 0.0;
    }
    let tokens: usize = tail.iter().map(|r| r.tokens).sum();
    let time: f64 = tail.iter().map(|r| r.latency_s).sum();
    tokens as f64 / time
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LLAMA_13B, LLAMA_7B};
    use crate::perfmodel::{A10, EPYC_7452};

    fn base(spec: ModelSpec, sockets: usize, b: usize, s: usize) -> SimConfig {
        SimConfig::new(
            spec,
            GpuModel::new(A10),
            CpuModel::from_device(EPYC_7452),
            sockets,
            b,
            s,
        )
    }

    /// Fig 11 shape, naive schedule: latency grows with step (R-Part
    /// dominates late), early steps pipeline-flat (S-Part dominates).
    #[test]
    fn fig11_latency_grows_without_sls() {
        let cfg = base(LLAMA_7B, 8, 1024, 1024);
        let trace = simulate(&cfg);
        assert_eq!(trace.len(), 1024);
        let early = trace.records[10].latency_s;
        let late = trace.records[1000].latency_s;
        assert!(late > 1.5 * early, "late {late} early {early}");
        // early steps are S-bound → flat
        let e5 = trace.records[5].latency_s;
        let e50 = trace.records[50].latency_s;
        assert!((e50 / e5) < 1.3, "early region not flat: {e5} vs {e50}");
    }

    /// Fig 11 with SLS: steady-state latency ≈ 2/3 of the naive peak and
    /// sustainable throughput improves.
    #[test]
    fn fig11_sls_stabilizes() {
        let naive = simulate(&base(LLAMA_7B, 8, 1024, 1024));
        let mut cfg = base(LLAMA_7B, 8, 1024, 1024);
        cfg.sls_interval = Some(32);
        cfg.steps = 2048;
        let sls = simulate(&cfg);
        let peak_naive = naive.max_latency();
        let steady = sls.steady_latency(1024);
        let ratio = steady / peak_naive;
        assert!(
            (0.45..=0.85).contains(&ratio),
            "steady/peak = {ratio} (paper: 0.66–0.70)"
        );
        // steady-state load stays near W'max
        let w: Vec<usize> = sls.records[1200..1800]
            .iter()
            .map(|r| r.total_ctx)
            .collect();
        let (lo, hi) = (
            *w.iter().min().unwrap() as f64,
            *w.iter().max().unwrap() as f64,
        );
        assert!(hi / lo < 1.25, "steady load not stable: {lo}..{hi}");
    }

    /// Throughput gain of SLS lands in the paper's 8–20 % window
    /// (§7.1 reports 8–11 % measured, 20 % ideal).
    #[test]
    fn sls_throughput_gain_in_paper_range() {
        let spec = LLAMA_13B;
        let naive = simulate(&base(spec, 8, 1024, 1024));
        let tp_naive = naive.throughput();
        let mut cfg = base(spec, 8, 1024, 1024);
        cfg.sls_interval = Some(32);
        cfg.steps = 3072;
        let sls = simulate(&cfg);
        let tp_sls = steady_throughput(&sls, 1024);
        let gain = tp_sls / tp_naive - 1.0;
        assert!(
            (0.02..=0.35).contains(&gain),
            "SLS gain {gain} outside plausible window"
        );
    }

    /// More sockets shrink R time until the S-worker floor (Fig 13).
    #[test]
    fn socket_scaling_saturates() {
        let tp = |sockets| {
            let mut cfg = base(LLAMA_7B, sockets, 1024, 1024);
            cfg.sls_interval = Some(32);
            cfg.steps = 2048;
            steady_throughput(&simulate(&cfg), 1024)
        };
        let t1 = tp(1);
        let t4 = tp(4);
        let t8 = tp(8);
        assert!(t4 > 2.0 * t1, "t4/t1 = {}", t4 / t1);
        assert!(t8 >= t4);
        // efficiency at 8 sockets in the paper's 60–90 % band
        let eff = t8 / (8.0 * t1);
        assert!((0.4..=1.0).contains(&eff), "eff {eff}");
    }

    #[test]
    fn active_never_exceeds_batch() {
        let mut cfg = base(LLAMA_7B, 4, 512, 256);
        cfg.sls_interval = Some(16);
        cfg.steps = 1024;
        for step in 0..cfg.steps {
            let (active, ctx) = cfg.load_at(step);
            assert!(active <= cfg.batch);
            assert!(ctx <= cfg.batch * cfg.seq_len);
        }
    }
}
