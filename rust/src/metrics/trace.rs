//! Per-step latency traces — the data behind Figs 8, 11, 12 and the
//! per-op breakdown of Fig 15.

use crate::util::json::Json;

/// One generation step of the whole system.
///
/// Two families of fields coexist:
///
/// * the *attributed* model times `s_time`/`r_time`/`comm_time` (S-Part
///   compute, R-Part busy max over sockets, modeled activation
///   transfer) — these can overlap in a pipelined step, so they do NOT
///   sum to `latency_s`;
/// * the *measured* coordinator-thread segments `queue_wait_s`
///   (blocked on the S-thread channel), `gather_wait_s` (O-gather
///   incast: `wait_attend` + output reassembly) and `dispatch_s` (QKV
///   split + scatter submit) — these are disjoint wall-clock intervals
///   on the coordinator, so [`accounted_s`](StepRecord::accounted_s)
///   tiles `latency_s` up to a small [`residual_s`](StepRecord::residual_s)
///   (validation, range bookkeeping, channel sends). That identity is
///   asserted per-step by `tests/obs_trace.rs`.
///
/// `socket_busy` / `skew_s` decompose `r_time` per socket/node so
/// stragglers are visible in the trace, not just the max.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepRecord {
    pub step: usize,
    /// Wall (or virtual) time of the step, seconds.
    pub latency_s: f64,
    /// Time attributable to S-Part compute.
    pub s_time: f64,
    /// Time attributable to R-Part compute (max over sockets).
    pub r_time: f64,
    /// Time attributable to activation transfer.
    pub comm_time: f64,
    /// Measured coordinator wait on S-thread responses (queue-wait).
    pub queue_wait_s: f64,
    /// Measured O-gather incast wait (attend gather + reassembly).
    pub gather_wait_s: f64,
    /// Measured QKV split + scatter-submit time on the coordinator.
    pub dispatch_s: f64,
    /// Straggler skew: Σ over gathers of (max − min) socket busy time.
    pub skew_s: f64,
    /// Per-socket (or per-node) R-Part busy seconds, indexed by socket.
    pub socket_busy: Vec<f64>,
    /// Tokens generated in this step.
    pub tokens: usize,
    /// Aggregate context length processed this step (R-Part load W).
    pub total_ctx: usize,
}

impl StepRecord {
    /// Total measured wait (queue-wait + incast gather wait).
    pub fn wait_s(&self) -> f64 {
        self.queue_wait_s + self.gather_wait_s
    }

    /// Sum of the disjoint measured coordinator segments; tiles
    /// `latency_s` (`accounted_s() ≲ latency_s`, small residual).
    pub fn accounted_s(&self) -> f64 {
        self.queue_wait_s + self.gather_wait_s + self.dispatch_s
    }

    /// Wall time not captured by any measured segment.
    pub fn residual_s(&self) -> f64 {
        self.latency_s - self.accounted_s()
    }
}

/// An append-only trace of steps.
#[derive(Clone, Debug, Default)]
pub struct StepTrace {
    pub records: Vec<StepRecord>,
}

impl StepTrace {
    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn total_time(&self) -> f64 {
        self.records.iter().map(|r| r.latency_s).sum()
    }

    pub fn total_tokens(&self) -> usize {
        self.records.iter().map(|r| r.tokens).sum()
    }

    pub fn throughput(&self) -> f64 {
        let t = self.total_time();
        if t == 0.0 {
            0.0
        } else {
            self.total_tokens() as f64 / t
        }
    }

    pub fn max_latency(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.latency_s)
            .fold(0.0, f64::max)
    }

    /// Mean latency over the steady-state window (skip cold start).
    pub fn steady_latency(&self, skip: usize) -> f64 {
        let tail = &self.records[skip.min(self.records.len())..];
        if tail.is_empty() {
            return 0.0;
        }
        tail.iter().map(|r| r.latency_s).sum::<f64>() / tail.len() as f64
    }

    /// Downsample to at most `n` points for plotting (keeps endpoints).
    pub fn downsample(&self, n: usize) -> Vec<StepRecord> {
        if self.records.len() <= n || n < 2 {
            return self.records.clone();
        }
        let stride = (self.records.len() - 1) as f64 / (n - 1) as f64;
        (0..n)
            .map(|i| self.records[(i as f64 * stride).round() as usize].clone())
            .collect()
    }

    /// Serialize the full per-step series for plotting: latency plus
    /// the complete breakdown (attributed s/r/comm and measured
    /// queue-wait/gather-wait/dispatch/skew), all column-aligned with
    /// `step`.
    pub fn to_json(&self, name: &str) -> Json {
        fn col(records: &[StepRecord], f: impl Fn(&StepRecord) -> f64) -> Json {
            Json::Arr(records.iter().map(|r| Json::Num(f(r))).collect())
        }
        let r = &self.records;
        Json::obj()
            .set("name", name)
            .set("step", col(r, |x| x.step as f64))
            .set("latency_ms", col(r, |x| x.latency_s * 1e3))
            .set("s_ms", col(r, |x| x.s_time * 1e3))
            .set("r_ms", col(r, |x| x.r_time * 1e3))
            .set("comm_ms", col(r, |x| x.comm_time * 1e3))
            .set("queue_wait_ms", col(r, |x| x.queue_wait_s * 1e3))
            .set("gather_wait_ms", col(r, |x| x.gather_wait_s * 1e3))
            .set("dispatch_ms", col(r, |x| x.dispatch_s * 1e3))
            .set("skew_ms", col(r, |x| x.skew_s * 1e3))
            .set("total_ctx", col(r, |x| x.total_ctx as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, lat: f64, tokens: usize) -> StepRecord {
        StepRecord {
            step,
            latency_s: lat,
            tokens,
            ..Default::default()
        }
    }

    #[test]
    fn throughput_and_max() {
        let mut t = StepTrace::default();
        t.push(rec(0, 0.1, 10));
        t.push(rec(1, 0.3, 10));
        assert!((t.throughput() - 50.0).abs() < 1e-9);
        assert_eq!(t.max_latency(), 0.3);
        assert_eq!(t.total_tokens(), 20);
    }

    #[test]
    fn steady_skips_cold_start() {
        let mut t = StepTrace::default();
        t.push(rec(0, 1.0, 1));
        t.push(rec(1, 0.2, 1));
        t.push(rec(2, 0.2, 1));
        assert!((t.steady_latency(1) - 0.2).abs() < 1e-12);
        assert_eq!(t.steady_latency(10), 0.0);
    }

    #[test]
    fn downsample_keeps_endpoints() {
        let mut t = StepTrace::default();
        for i in 0..100 {
            t.push(rec(i, i as f64, 1));
        }
        let d = t.downsample(10);
        assert_eq!(d.len(), 10);
        assert_eq!(d[0].step, 0);
        assert_eq!(d[9].step, 99);
    }

    #[test]
    fn json_renders() {
        let mut t = StepTrace::default();
        t.push(rec(0, 0.001, 1));
        let s = t.to_json("fig11").render();
        assert!(s.contains("\"fig11\""));
        assert!(s.contains("latency_ms"));
    }

    #[test]
    fn json_emits_full_breakdown_series() {
        let mut t = StepTrace::default();
        t.push(StepRecord {
            step: 0,
            latency_s: 0.004,
            s_time: 0.001,
            r_time: 0.002,
            comm_time: 0.0005,
            queue_wait_s: 0.0011,
            gather_wait_s: 0.0021,
            dispatch_s: 0.0003,
            skew_s: 0.0002,
            tokens: 4,
            ..Default::default()
        });
        let j = t.to_json("bd");
        for key in [
            "s_ms",
            "r_ms",
            "comm_ms",
            "queue_wait_ms",
            "gather_wait_ms",
            "dispatch_ms",
            "skew_ms",
        ] {
            let col = j.get(key).and_then(Json::as_arr).unwrap_or_else(|| {
                panic!("missing breakdown column {key}")
            });
            assert_eq!(col.len(), 1, "{key} misaligned");
        }
        assert_eq!(
            j.get("r_ms").and_then(Json::as_arr).unwrap()[0].as_f64(),
            Some(2.0)
        );
    }

    #[test]
    fn downsample_keeps_endpoints_and_breakdown_alignment() {
        let mut t = StepTrace::default();
        for i in 0..97 {
            // encode the step index into every breakdown field so any
            // row shuffle or column slip is detectable after sampling
            t.push(StepRecord {
                step: i,
                latency_s: i as f64,
                s_time: i as f64 * 2.0,
                r_time: i as f64 * 3.0,
                comm_time: i as f64 * 4.0,
                queue_wait_s: i as f64 * 5.0,
                gather_wait_s: i as f64 * 6.0,
                dispatch_s: i as f64 * 7.0,
                skew_s: i as f64 * 8.0,
                socket_busy: vec![i as f64; 2],
                tokens: 1,
                total_ctx: i,
            });
        }
        let d = t.downsample(10);
        assert_eq!(d.len(), 10);
        assert_eq!(d[0].step, 0, "first endpoint dropped");
        assert_eq!(d[9].step, 96, "last endpoint dropped");
        for r in &d {
            let i = r.step as f64;
            assert_eq!(r.latency_s, i);
            assert_eq!(r.s_time, i * 2.0);
            assert_eq!(r.r_time, i * 3.0);
            assert_eq!(r.comm_time, i * 4.0);
            assert_eq!(r.queue_wait_s, i * 5.0);
            assert_eq!(r.gather_wait_s, i * 6.0);
            assert_eq!(r.dispatch_s, i * 7.0);
            assert_eq!(r.skew_s, i * 8.0);
            assert_eq!(r.socket_busy, vec![i; 2]);
            assert_eq!(r.total_ctx, r.step);
        }
    }

    #[test]
    fn breakdown_identity_helpers() {
        let r = StepRecord {
            latency_s: 0.010,
            queue_wait_s: 0.004,
            gather_wait_s: 0.003,
            dispatch_s: 0.002,
            ..Default::default()
        };
        assert!((r.wait_s() - 0.007).abs() < 1e-12);
        assert!((r.accounted_s() - 0.009).abs() < 1e-12);
        assert!((r.residual_s() - 0.001).abs() < 1e-12);
    }
}
