//! Per-step latency traces — the data behind Figs 8, 11, 12 and the
//! per-op breakdown of Fig 15.

use crate::util::json::Json;

/// One generation step of the whole system.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StepRecord {
    pub step: usize,
    /// Wall (or virtual) time of the step, seconds.
    pub latency_s: f64,
    /// Time attributable to S-Part compute.
    pub s_time: f64,
    /// Time attributable to R-Part compute (max over sockets).
    pub r_time: f64,
    /// Time attributable to activation transfer.
    pub comm_time: f64,
    /// Tokens generated in this step.
    pub tokens: usize,
    /// Aggregate context length processed this step (R-Part load W).
    pub total_ctx: usize,
}

/// An append-only trace of steps.
#[derive(Clone, Debug, Default)]
pub struct StepTrace {
    pub records: Vec<StepRecord>,
}

impl StepTrace {
    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn total_time(&self) -> f64 {
        self.records.iter().map(|r| r.latency_s).sum()
    }

    pub fn total_tokens(&self) -> usize {
        self.records.iter().map(|r| r.tokens).sum()
    }

    pub fn throughput(&self) -> f64 {
        let t = self.total_time();
        if t == 0.0 {
            0.0
        } else {
            self.total_tokens() as f64 / t
        }
    }

    pub fn max_latency(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.latency_s)
            .fold(0.0, f64::max)
    }

    /// Mean latency over the steady-state window (skip cold start).
    pub fn steady_latency(&self, skip: usize) -> f64 {
        let tail = &self.records[skip.min(self.records.len())..];
        if tail.is_empty() {
            return 0.0;
        }
        tail.iter().map(|r| r.latency_s).sum::<f64>() / tail.len() as f64
    }

    /// Downsample to at most `n` points for plotting (keeps endpoints).
    pub fn downsample(&self, n: usize) -> Vec<StepRecord> {
        if self.records.len() <= n || n < 2 {
            return self.records.clone();
        }
        let stride = (self.records.len() - 1) as f64 / (n - 1) as f64;
        (0..n)
            .map(|i| self.records[(i as f64 * stride).round() as usize])
            .collect()
    }

    /// Serialize the latency series for plotting.
    pub fn to_json(&self, name: &str) -> Json {
        Json::obj()
            .set("name", name)
            .set(
                "step",
                self.records.iter().map(|r| r.step as f64).collect::<Vec<_>>(),
            )
            .set(
                "latency_ms",
                self.records
                    .iter()
                    .map(|r| r.latency_s * 1e3)
                    .collect::<Vec<_>>(),
            )
            .set(
                "total_ctx",
                self.records
                    .iter()
                    .map(|r| r.total_ctx as f64)
                    .collect::<Vec<_>>(),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, lat: f64, tokens: usize) -> StepRecord {
        StepRecord {
            step,
            latency_s: lat,
            tokens,
            ..Default::default()
        }
    }

    #[test]
    fn throughput_and_max() {
        let mut t = StepTrace::default();
        t.push(rec(0, 0.1, 10));
        t.push(rec(1, 0.3, 10));
        assert!((t.throughput() - 50.0).abs() < 1e-9);
        assert_eq!(t.max_latency(), 0.3);
        assert_eq!(t.total_tokens(), 20);
    }

    #[test]
    fn steady_skips_cold_start() {
        let mut t = StepTrace::default();
        t.push(rec(0, 1.0, 1));
        t.push(rec(1, 0.2, 1));
        t.push(rec(2, 0.2, 1));
        assert!((t.steady_latency(1) - 0.2).abs() < 1e-12);
        assert_eq!(t.steady_latency(10), 0.0);
    }

    #[test]
    fn downsample_keeps_endpoints() {
        let mut t = StepTrace::default();
        for i in 0..100 {
            t.push(rec(i, i as f64, 1));
        }
        let d = t.downsample(10);
        assert_eq!(d.len(), 10);
        assert_eq!(d[0].step, 0);
        assert_eq!(d[9].step, 99);
    }

    #[test]
    fn json_renders() {
        let mut t = StepTrace::default();
        t.push(rec(0, 0.001, 1));
        let s = t.to_json("fig11").render();
        assert!(s.contains("\"fig11\""));
        assert!(s.contains("latency_ms"));
    }
}
