//! Log-bucketed latency histogram (offline stand-in for hdrhistogram).
//!
//! Buckets span 1 µs .. ~1000 s with 32 sub-buckets per power of two:
//! ≤ ~2.2 % relative error on percentile queries, 4 KiB of counters.

const SUB: usize = 32; // sub-buckets per octave
const OCTAVES: usize = 30; // 2^30 µs ≈ 1073 s

#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum_us: f64,
    min_us: f64,
    max_us: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

// compact: summarizing moments, not 960 bucket counters
impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.total)
            .field("mean_us", &self.mean_us())
            .field("min_us", &self.min_us())
            .field("max_us", &self.max_us)
            .finish()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; SUB * OCTAVES],
            total: 0,
            sum_us: 0.0,
            min_us: f64::INFINITY,
            max_us: 0.0,
        }
    }

    fn bucket_of(us: f64) -> usize {
        if us < 1.0 {
            return 0;
        }
        let octave = us.log2().floor() as usize;
        let octave = octave.min(OCTAVES - 1);
        let lo = (1u64 << octave) as f64;
        let frac = ((us - lo) / lo * SUB as f64) as usize;
        octave * SUB + frac.min(SUB - 1)
    }

    fn bucket_value(idx: usize) -> f64 {
        let octave = idx / SUB;
        let frac = idx % SUB;
        let lo = (1u64 << octave) as f64;
        lo + lo * (frac as f64 + 0.5) / SUB as f64
    }

    pub fn record_secs(&mut self, secs: f64) {
        self.record_us(secs * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        let us = us.max(0.0);
        self.counts[Self::bucket_of(us)] += 1;
        self.total += 1;
        self.sum_us += us;
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    pub fn min_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min_us
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Percentile in microseconds; q in [0, 1].
    pub fn percentile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let rank = rank.max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_value(i).clamp(self.min_us, self.max_us);
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Percentile summary as JSON (milliseconds) — the per-metric
    /// block inside `BENCH_*.json` snapshots and serve reports.
    pub fn to_json_ms(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("count", self.total)
            .set("mean_ms", self.mean_us() / 1e3)
            .set("p50_ms", self.percentile_us(0.50) / 1e3)
            .set("p95_ms", self.percentile_us(0.95) / 1e3)
            .set("p99_ms", self.percentile_us(0.99) / 1e3)
            .set("max_ms", self.max_us() / 1e3)
    }

    /// One-line summary for reports: mean / p01 / p50 / p99 in ms.
    pub fn summary_ms(&self) -> String {
        format!(
            "mean {:.2} ms, p01 {:.2}, p50 {:.2}, p99 {:.2} (n={})",
            self.mean_us() / 1e3,
            self.percentile_us(0.01) / 1e3,
            self.percentile_us(0.50) / 1e3,
            self.percentile_us(0.99) / 1e3,
            self.total
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn single_value() {
        let mut h = Histogram::new();
        h.record_us(1000.0);
        assert_eq!(h.count(), 1);
        assert!((h.percentile_us(0.5) - 1000.0).abs() / 1000.0 < 0.05);
        assert_eq!(h.mean_us(), 1000.0);
    }

    #[test]
    fn percentiles_of_uniform() {
        let mut h = Histogram::new();
        let mut rng = Rng::new(1);
        for _ in 0..100_000 {
            h.record_us(rng.next_f64() * 10_000.0);
        }
        let p50 = h.percentile_us(0.5);
        let p99 = h.percentile_us(0.99);
        assert!((p50 - 5000.0).abs() / 5000.0 < 0.05, "p50 {p50}");
        assert!((p99 - 9900.0).abs() / 9900.0 < 0.05, "p99 {p99}");
        assert!(h.percentile_us(0.01) < p50 && p50 < p99);
    }

    #[test]
    fn relative_error_bounded() {
        for v in [3.7, 120.0, 4096.0, 1.5e6, 9.9e8] {
            let mut h = Histogram::new();
            for _ in 0..100 {
                h.record_us(v);
            }
            let p = h.percentile_us(0.5);
            assert!((p - v).abs() / v < 0.05, "{v} → {p}");
        }
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        let mut rng = Rng::new(2);
        for i in 0..1000 {
            let v = rng.next_f64() * 1e5;
            if i % 2 == 0 {
                a.record_us(v)
            } else {
                b.record_us(v)
            }
            all.record_us(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.percentile_us(0.9), all.percentile_us(0.9));
    }

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile_us(0.5), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    /// Merge must fold EVERY moment, not just the bucket counts: after
    /// `a.merge(&b)`, count, mean (exact — `sum_us` folds losslessly),
    /// min, max and all percentiles equal those of the concatenated
    /// stream. Disjoint value ranges make a count-only fold fail the
    /// min/max/mean assertions (the satellite audit this test pins).
    #[test]
    fn merge_equals_concatenation_all_moments() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut all = Histogram::new();
        let mut rng = Rng::new(7);
        for _ in 0..500 {
            let small = 1.0 + rng.next_f64() * 100.0; // [1, 101) µs
            let big = 1e6 + rng.next_f64() * 1e6; // [1s, 2s) in µs
            a.record_us(small);
            b.record_us(big);
            all.record_us(small);
            all.record_us(big);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        // summation order differs, so allow f64 rounding in the mean
        let (ma, mc) = (a.mean_us(), all.mean_us());
        assert!((ma - mc).abs() / mc < 1e-12, "sum_us not folded: {ma} vs {mc}");
        assert_eq!(a.min_us(), all.min_us(), "min_us not folded");
        assert_eq!(a.max_us(), all.max_us(), "max_us not folded");
        for q in [0.01, 0.5, 0.95, 0.99] {
            assert_eq!(a.percentile_us(q), all.percentile_us(q), "p{q}");
        }
    }

    /// Property: `percentile_us` agrees with the exact sorted-vector
    /// percentile (same rank definition, rank = ⌈q·n⌉ clamped to ≥ 1)
    /// within the log-bucketing's ~2.2 % relative error — across
    /// uniform, bimodal and single-element distributions.
    #[test]
    fn prop_percentile_matches_exact_sorted() {
        use crate::util::prop;
        prop::check("hist-percentile-exact", 60, |g| {
            let dist = g.usize_in(0, 3); // 0 uniform, 1 bimodal, 2 single
            let n = if dist == 2 { 1 } else { g.usize_in(1, 400) };
            let mut vals: Vec<f64> = Vec::with_capacity(n);
            let mut h = Histogram::new();
            for _ in 0..n {
                let v = match dist {
                    0 => 1.0 + g.f32_in(0.0, 10_000.0) as f64,
                    1 => {
                        if g.bool() {
                            1.0 + g.f32_in(0.0, 100.0) as f64
                        } else {
                            1e6 + g.f32_in(0.0, 1e6) as f64
                        }
                    }
                    _ => 1.0 + g.f32_in(0.0, 1e5) as f64,
                };
                vals.push(v);
                h.record_us(v);
            }
            vals.sort_by(f64::total_cmp);
            for q in [0.0, 0.01, 0.5, 0.9, 0.99, 1.0] {
                let rank = ((q * n as f64).ceil() as usize).max(1);
                let exact = vals[rank - 1];
                let got = h.percentile_us(q);
                assert!(
                    (got - exact).abs() <= exact * 0.05 + 1.0,
                    "q={q} n={n} dist={dist}: exact {exact}, hist {got}"
                );
            }
        });
    }

    /// Merging into (or from) an empty histogram is the identity.
    #[test]
    fn merge_with_empty_is_identity() {
        let mut a = Histogram::new();
        a.record_us(42.0);
        let before = (a.count(), a.mean_us(), a.min_us(), a.max_us());
        a.merge(&Histogram::new());
        assert_eq!(before, (a.count(), a.mean_us(), a.min_us(), a.max_us()));

        let mut e = Histogram::new();
        e.merge(&a);
        assert_eq!(e.count(), 1);
        assert_eq!(e.min_us(), 42.0);
        assert_eq!(e.max_us(), 42.0);
        assert_eq!(e.mean_us(), 42.0);
    }
}
