//! Serving metrics — the trace → breakdown → snapshot flow.
//!
//! Every run (live or modeled) produces a [`StepTrace`]: one
//! [`StepRecord`] per decode step carrying both the headline latency
//! and its measured breakdown — S-compute (`s_time`), R-attend
//! (`r_time`), activation transfer (`comm_time`), and the coordinator
//! wait terms added for observability (`queue_wait_s`,
//! `gather_wait_s`, `dispatch_s`) plus the cross-socket straggler skew
//! (`skew_s`, max−min socket busy time) and the raw per-socket busy
//! vector. `StepRecord::accounted_s` / `residual_s` let tests assert
//! the identity `s + r + comm + wait ≈ latency`.
//!
//! Downstream consumers:
//! * [`StepTrace::to_json`] emits the full breakdown as column arrays
//!   for plotting (Figs 8, 11, 12).
//! * [`Histogram`] (log-bucketed, no external deps) condenses any
//!   latency stream into p50/p95/p99 (Fig 10's P.01/.5/.99 bars).
//! * `bench::snapshot` folds a trace + config into the machine-readable
//!   `BENCH_<name>.json` artifacts that CI validates (see its module
//!   doc for the schema).
//!
//! Span-level timing (who was running *when*, on which thread/socket/
//! node) lives in [`crate::obs`]; this module is the per-step
//! aggregate view of the same events.

mod histogram;
mod trace;

pub use histogram::Histogram;
pub use trace::{StepRecord, StepTrace};

/// Simple throughput accumulator.
#[derive(Clone, Copy, Debug, Default)]
pub struct Throughput {
    pub tokens: u64,
    pub seconds: f64,
}

impl Throughput {
    pub fn add(&mut self, tokens: u64, seconds: f64) {
        self.tokens += tokens;
        self.seconds += seconds;
    }

    pub fn tokens_per_sec(&self) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.seconds
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_accumulates() {
        let mut t = Throughput::default();
        t.add(100, 2.0);
        t.add(300, 2.0);
        assert_eq!(t.tokens_per_sec(), 100.0);
        assert_eq!(Throughput::default().tokens_per_sec(), 0.0);
    }
}
