//! Serving metrics: latency histograms with percentiles (Fig 10's
//! P.01/.5/.99 bars), per-step latency traces (Figs 8, 11, 12), and
//! throughput counters. No external deps — log-bucketed histogram.

mod histogram;
mod trace;

pub use histogram::Histogram;
pub use trace::{StepRecord, StepTrace};

/// Simple throughput accumulator.
#[derive(Clone, Copy, Debug, Default)]
pub struct Throughput {
    pub tokens: u64,
    pub seconds: f64,
}

impl Throughput {
    pub fn add(&mut self, tokens: u64, seconds: f64) {
        self.tokens += tokens;
        self.seconds += seconds;
    }

    pub fn tokens_per_sec(&self) -> f64 {
        if self.seconds == 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.seconds
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_accumulates() {
        let mut t = Throughput::default();
        t.add(100, 2.0);
        t.add(300, 2.0);
        assert_eq!(t.tokens_per_sec(), 100.0);
        assert_eq!(Throughput::default().tokens_per_sec(), 0.0);
    }
}
