//! Symmetric per-vector quantization for KV entries (§5.2).
//!
//! One fp32 scale per (head, token) vector: `x ≈ scale * q` with q in
//! i8 ([-127,127]) or i4 ([-7,7], two values per byte). Chosen over
//! per-tensor scales because K/V magnitudes drift over a sequence, and
//! over asymmetric zero-points because attention dot-products then stay
//! a single fused multiply per element.

/// Quantize one vector to i8; returns the scale.
pub fn quant_i8(src: &[f32], dst: &mut [i8]) -> f32 {
    assert_eq!(src.len(), dst.len());
    let max = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if max == 0.0 {
        dst.fill(0);
        return 0.0;
    }
    let scale = max / 127.0;
    let inv = 127.0 / max;
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = (s * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// Dequantize i8 into an fp32 buffer.
pub fn dequant_i8(src: &[i8], scale: f32, dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s as f32 * scale;
    }
}

/// Quantize one vector to packed i4 (two values per byte, low nibble
/// first); `dst.len() == src.len().div_ceil(2)`. Returns the scale.
pub fn quant_i4(src: &[f32], dst: &mut [u8]) -> f32 {
    assert_eq!(dst.len(), src.len().div_ceil(2));
    let max = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    if max == 0.0 {
        dst.fill(0);
        return 0.0;
    }
    let scale = max / 7.0;
    let inv = 7.0 / max;
    for (i, pair) in dst.iter_mut().enumerate() {
        let lo = (src[2 * i] * inv).round().clamp(-7.0, 7.0) as i8;
        let hi = src
            .get(2 * i + 1)
            .map(|&x| (x * inv).round().clamp(-7.0, 7.0) as i8)
            .unwrap_or(0);
        *pair = ((lo as u8) & 0x0f) | ((hi as u8) << 4);
    }
    scale
}

/// Sign-extend a nibble (stored two's-complement in 4 bits).
#[inline(always)]
pub fn nibble_to_i32(n: u8) -> i32 {
    ((n as i32) << 28) >> 28
}

static NIBBLE_PAIR_LUT: std::sync::OnceLock<[[f32; 2]; 256]> =
    std::sync::OnceLock::new();

/// Byte → (low nibble, high nibble) as f32, via a 2 KiB L1-resident LUT
/// (one load replaces two shift/mask/sign-extend/convert chains in the
/// int4 attention hot loop — EXPERIMENTS.md §Perf). Callers hoist the
/// returned reference out of their inner loops.
pub fn nibble_pair_lut() -> &'static [[f32; 2]; 256] {
    NIBBLE_PAIR_LUT.get_or_init(|| {
        let mut t = [[0.0f32; 2]; 256];
        for (b, pair) in t.iter_mut().enumerate() {
            pair[0] = nibble_to_i32(b as u8 & 0x0f) as f32;
            pair[1] = nibble_to_i32(b as u8 >> 4) as f32;
        }
        t
    })
}

/// Dequantize packed i4 into fp32; `dst.len()` values are produced.
pub fn dequant_i4(src: &[u8], scale: f32, dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len().div_ceil(2));
    for (i, d) in dst.iter_mut().enumerate() {
        let byte = src[i / 2];
        let nib = if i % 2 == 0 { byte & 0x0f } else { byte >> 4 };
        *d = nibble_to_i32(nib) as f32 * scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn i8_roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let src = rng.normal_vec(64, 1.0);
            let mut q = vec![0i8; 64];
            let scale = quant_i8(&src, &mut q);
            let mut back = vec![0.0; 64];
            dequant_i8(&q, scale, &mut back);
            let max = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for (a, b) in src.iter().zip(&back) {
                assert!((a - b).abs() <= max / 127.0 * 0.51 + 1e-6);
            }
        }
    }

    #[test]
    fn i4_roundtrip_error_bounded() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let src = rng.normal_vec(63, 1.0); // odd length exercises tail
            let mut q = vec![0u8; 32];
            let scale = quant_i4(&src, &mut q);
            let mut back = vec![0.0; 63];
            dequant_i4(&q, scale, &mut back);
            let max = src.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for (a, b) in src.iter().zip(&back) {
                assert!((a - b).abs() <= max / 7.0 * 0.51 + 1e-6, "{a} {b}");
            }
        }
    }

    #[test]
    fn zero_vector_is_exact() {
        let src = vec![0.0f32; 16];
        let mut q8 = vec![0i8; 16];
        assert_eq!(quant_i8(&src, &mut q8), 0.0);
        let mut q4 = vec![0u8; 8];
        assert_eq!(quant_i4(&src, &mut q4), 0.0);
    }

    #[test]
    fn nibble_sign_extension() {
        assert_eq!(nibble_to_i32(0x0), 0);
        assert_eq!(nibble_to_i32(0x7), 7);
        assert_eq!(nibble_to_i32(0x9), -7);
        assert_eq!(nibble_to_i32(0xf), -1);
    }

    #[test]
    fn extremes_hit_limits() {
        let src = [1.0f32, -1.0, 0.5, -0.5];
        let mut q = vec![0i8; 4];
        let scale = quant_i8(&src, &mut q);
        assert_eq!(q[0], 127);
        assert_eq!(q[1], -127);
        assert!((scale * 127.0 - 1.0).abs() < 1e-6);
    }
}
