//! KV storage: the contiguous per-sequence store (`SeqKv`, kept as the
//! reference/shadow implementation and as the payload of one block) and
//! the paged per-socket cache (`BlockPool` + block tables + COW forks).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::model::Precision;
use crate::util::f16::{encode_slice, F16};

/// K and V of one sequence on one layer, laid out `[H][capacity][D]`
/// (per-head scans are contiguous — the attention hot loop walks `t`
/// within a head).
#[derive(Clone)]
pub struct SeqKv {
    pub n_heads: usize,
    pub head_dim: usize,
    pub capacity: usize,
    pub len: usize,
    prec: Precision,
    // exactly one representation is non-empty, selected by `prec`
    k16: Vec<F16>,
    v16: Vec<F16>,
    k32: Vec<f32>,
    v32: Vec<f32>,
    k8: Vec<i8>,
    v8: Vec<i8>,
    k4: Vec<u8>,
    v4: Vec<u8>,
    /// per-(head, token) scales for the quantized formats
    k_scale: Vec<f32>,
    v_scale: Vec<f32>,
}

impl SeqKv {
    pub fn new(
        n_heads: usize,
        head_dim: usize,
        capacity: usize,
        prec: Precision,
    ) -> SeqKv {
        let n = n_heads * capacity * head_dim;
        let mut s = SeqKv {
            n_heads,
            head_dim,
            capacity,
            len: 0,
            prec,
            k16: vec![],
            v16: vec![],
            k32: vec![],
            v32: vec![],
            k8: vec![],
            v8: vec![],
            k4: vec![],
            v4: vec![],
            k_scale: vec![],
            v_scale: vec![],
        };
        match prec {
            Precision::F16 => {
                s.k16 = vec![F16::ZERO; n];
                s.v16 = vec![F16::ZERO; n];
            }
            Precision::F32 => {
                s.k32 = vec![0.0; n];
                s.v32 = vec![0.0; n];
            }
            Precision::Int8 => {
                s.k8 = vec![0; n];
                s.v8 = vec![0; n];
                s.k_scale = vec![0.0; n_heads * capacity];
                s.v_scale = vec![0.0; n_heads * capacity];
            }
            Precision::Int4 => {
                assert_eq!(head_dim % 2, 0, "int4 needs even head_dim");
                s.k4 = vec![0; n / 2];
                s.v4 = vec![0; n / 2];
                s.k_scale = vec![0.0; n_heads * capacity];
                s.v_scale = vec![0.0; n_heads * capacity];
            }
        }
        s
    }

    pub fn precision(&self) -> Precision {
        self.prec
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.capacity
    }

    /// Tokens that can still be appended before the cache is full (used
    /// by the R-worker to reject a multi-row prefill that would overflow
    /// before any of its appends land).
    pub fn remaining(&self) -> usize {
        self.capacity.saturating_sub(self.len)
    }

    /// Append one token's K and V, each `[H * D]` f32 (head-major).
    /// Returns the token's position.
    pub fn append(&mut self, k: &[f32], v: &[f32]) -> usize {
        let (h, d) = (self.n_heads, self.head_dim);
        assert_eq!(k.len(), h * d);
        assert_eq!(v.len(), h * d);
        assert!(!self.is_full(), "KV-cache overflow (capacity {})", self.capacity);
        let t = self.len;
        for head in 0..h {
            let src_k = &k[head * d..(head + 1) * d];
            let src_v = &v[head * d..(head + 1) * d];
            let off = (head * self.capacity + t) * d;
            match self.prec {
                Precision::F16 => {
                    encode_slice(src_k, &mut self.k16[off..off + d]);
                    encode_slice(src_v, &mut self.v16[off..off + d]);
                }
                Precision::F32 => {
                    self.k32[off..off + d].copy_from_slice(src_k);
                    self.v32[off..off + d].copy_from_slice(src_v);
                }
                Precision::Int8 => {
                    let si = head * self.capacity + t;
                    self.k_scale[si] =
                        super::quant_i8(src_k, &mut self.k8[off..off + d]);
                    self.v_scale[si] =
                        super::quant_i8(src_v, &mut self.v8[off..off + d]);
                }
                Precision::Int4 => {
                    let si = head * self.capacity + t;
                    let po = off / 2;
                    self.k_scale[si] =
                        super::quant_i4(src_k, &mut self.k4[po..po + d / 2]);
                    self.v_scale[si] =
                        super::quant_i4(src_v, &mut self.v4[po..po + d / 2]);
                }
            }
        }
        self.len = t + 1;
        t
    }

    /// Raw per-head K row access for the attention hot loop (fp16 path).
    #[inline(always)]
    pub fn k16_head(&self, head: usize) -> &[F16] {
        let (c, d) = (self.capacity, self.head_dim);
        &self.k16[head * c * d..(head + 1) * c * d]
    }

    #[inline(always)]
    pub fn v16_head(&self, head: usize) -> &[F16] {
        let (c, d) = (self.capacity, self.head_dim);
        &self.v16[head * c * d..(head + 1) * c * d]
    }

    #[inline(always)]
    pub fn k32_head(&self, head: usize) -> &[f32] {
        let (c, d) = (self.capacity, self.head_dim);
        &self.k32[head * c * d..(head + 1) * c * d]
    }

    #[inline(always)]
    pub fn v32_head(&self, head: usize) -> &[f32] {
        let (c, d) = (self.capacity, self.head_dim);
        &self.v32[head * c * d..(head + 1) * c * d]
    }

    #[inline(always)]
    pub fn k8_head(&self, head: usize) -> (&[i8], &[f32]) {
        let (c, d) = (self.capacity, self.head_dim);
        (
            &self.k8[head * c * d..(head + 1) * c * d],
            &self.k_scale[head * c..head * c + c],
        )
    }

    #[inline(always)]
    pub fn v8_head(&self, head: usize) -> (&[i8], &[f32]) {
        let (c, d) = (self.capacity, self.head_dim);
        (
            &self.v8[head * c * d..(head + 1) * c * d],
            &self.v_scale[head * c..head * c + c],
        )
    }

    #[inline(always)]
    pub fn k4_head(&self, head: usize) -> (&[u8], &[f32]) {
        let (c, d) = (self.capacity, self.head_dim);
        (
            &self.k4[head * c * d / 2..(head + 1) * c * d / 2],
            &self.k_scale[head * c..head * c + c],
        )
    }

    #[inline(always)]
    pub fn v4_head(&self, head: usize) -> (&[u8], &[f32]) {
        let (c, d) = (self.capacity, self.head_dim);
        (
            &self.v4[head * c * d / 2..(head + 1) * c * d / 2],
            &self.v_scale[head * c..head * c + c],
        )
    }

    /// Decode token `t` of head `h` (K) into `out` — test/debug helper.
    pub fn decode_k(&self, head: usize, t: usize, out: &mut [f32]) {
        let d = self.head_dim;
        assert!(t < self.len);
        let off = (head * self.capacity + t) * d;
        match self.prec {
            Precision::F16 => {
                for (o, x) in out.iter_mut().zip(&self.k16[off..off + d]) {
                    *o = x.to_f32();
                }
            }
            Precision::F32 => out.copy_from_slice(&self.k32[off..off + d]),
            Precision::Int8 => super::dequant_i8(
                &self.k8[off..off + d],
                self.k_scale[head * self.capacity + t],
                out,
            ),
            Precision::Int4 => super::dequant_i4(
                &self.k4[off / 2..off / 2 + d / 2],
                self.k_scale[head * self.capacity + t],
                out,
            ),
        }
    }

    /// Bytes of KV payload actually stored (capacity allocation).
    pub fn allocated_bytes(&self) -> usize {
        self.k16.len() * 2
            + self.v16.len() * 2
            + (self.k32.len() + self.v32.len()) * 4
            + self.k8.len()
            + self.v8.len()
            + self.k4.len()
            + self.v4.len()
            + (self.k_scale.len() + self.v_scale.len()) * 4
    }
}

/// Bytes one token's K+V (data plus quantization scales) occupies at
/// `prec` — the per-token cost used for the logical footprint.
pub fn kv_token_bytes(
    n_heads: usize,
    head_dim: usize,
    prec: Precision,
) -> usize {
    let elems = 2 * n_heads * head_dim; // K and V
    match prec {
        Precision::F32 => elems * 4,
        Precision::F16 => elems * 2,
        Precision::Int8 => elems + 2 * n_heads * 4,
        Precision::Int4 => elems / 2 + 2 * n_heads * 4,
    }
}

/// Aggregate statistics of one socket's cache (capacity planning, eq. 9).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub sequences: usize,
    /// LOGICAL tokens: sum of live lengths across sequences × layers —
    /// what each sequence believes it holds, shared prefixes counted
    /// once PER SEQUENCE.
    pub total_tokens: usize,
    /// PHYSICAL tokens actually stored: block fills summed over unique
    /// live blocks — a block shared by N forked sequences counts ONCE.
    /// This is W in Algorithm 1's terms under paging.
    pub physical_tokens: usize,
    /// Bytes of block storage held (allocated blocks × bytes per block).
    pub allocated_bytes: usize,
    /// Bytes the logical tokens would occupy stored contiguously and
    /// unshared (`total_tokens × kv_token_bytes`).
    pub logical_bytes: usize,
}

impl CacheStats {
    /// Utilization ratio logical/allocated. Below 1.0 the gap is block
    /// padding (fragmentation); ABOVE 1.0 prefix sharing stores less
    /// than the logical footprint — the paging win made measurable.
    pub fn utilization(&self) -> f64 {
        if self.allocated_bytes == 0 {
            0.0
        } else {
            self.logical_bytes as f64 / self.allocated_bytes as f64
        }
    }

    /// Accumulate another socket's stats (scatter-gather aggregation).
    pub fn merge(&mut self, other: &CacheStats) {
        self.sequences += other.sequences;
        self.total_tokens += other.total_tokens;
        self.physical_tokens += other.physical_tokens;
        self.allocated_bytes += other.allocated_bytes;
        self.logical_bytes += other.logical_bytes;
    }
}

/// Fixed-size KV block arena for one socket. A block is a `SeqKv` with
/// `capacity == block_size` plus a refcount; copy-on-write forking lets
/// sequences share prefix blocks until one writes past the fork point.
pub struct BlockPool {
    n_heads: usize,
    head_dim: usize,
    block_size: usize,
    prec: Precision,
    slots: Vec<Option<Block>>,
    free: Vec<u32>,
}

struct Block {
    rc: u32,
    /// `kv.len` is the block's fill (tokens written).
    kv: SeqKv,
}

impl BlockPool {
    pub fn new(
        n_heads: usize,
        head_dim: usize,
        block_size: usize,
        prec: Precision,
    ) -> BlockPool {
        assert!(block_size >= 1, "block_size must be >= 1");
        BlockPool {
            n_heads,
            head_dim,
            block_size,
            prec,
            slots: Vec::new(),
            free: Vec::new(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Blocks currently live (allocated and referenced).
    pub fn live_blocks(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    /// Freed slots available for reuse without growing the pool.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    fn insert(&mut self, b: Block) -> u32 {
        match self.free.pop() {
            Some(i) => {
                self.slots[i as usize] = Some(b);
                i
            }
            None => {
                self.slots.push(Some(b));
                (self.slots.len() - 1) as u32
            }
        }
    }

    /// Allocate a fresh empty block with refcount 1.
    fn alloc(&mut self) -> u32 {
        let kv = SeqKv::new(
            self.n_heads,
            self.head_dim,
            self.block_size,
            self.prec,
        );
        self.insert(Block { rc: 1, kv })
    }

    fn slot(&self, idx: u32) -> &Block {
        self.slots[idx as usize].as_ref().expect("freed block")
    }

    fn slot_mut(&mut self, idx: u32) -> &mut Block {
        self.slots[idx as usize].as_mut().expect("freed block")
    }

    fn rc(&self, idx: u32) -> u32 {
        self.slot(idx).rc
    }

    fn retain(&mut self, idx: u32) {
        self.slot_mut(idx).rc += 1;
    }

    fn release(&mut self, idx: u32) {
        let b = self.slot_mut(idx);
        b.rc -= 1;
        if b.rc == 0 {
            self.slots[idx as usize] = None;
            self.free.push(idx);
        }
    }

    pub fn block(&self, idx: u32) -> &SeqKv {
        &self.slot(idx).kv
    }

    fn block_mut(&mut self, idx: u32) -> &mut SeqKv {
        &mut self.slot_mut(idx).kv
    }

    /// Copy-on-write: drop one reference to `idx` and return a fresh
    /// exclusive block (rc 1) holding its first `keep` tokens.
    fn cow_clone(&mut self, idx: u32, keep: usize) -> u32 {
        let mut kv = self.block(idx).clone();
        kv.len = keep;
        self.release(idx);
        self.insert(Block { rc: 1, kv })
    }

    fn stats_into(&self, st: &mut CacheStats) {
        for b in self.slots.iter().flatten() {
            st.physical_tokens += b.kv.len;
            st.allocated_bytes += b.kv.allocated_bytes();
        }
    }
}

/// Read view of one (sequence, layer): the block table resolved against
/// the pool. The attention hot loop walks blocks in order; per-head
/// token rows inside one block are contiguous exactly as in `SeqKv`.
pub struct PagedKv<'a> {
    pool: &'a BlockPool,
    table: &'a [u32],
    pub len: usize,
    pub capacity: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub block_size: usize,
    prec: Precision,
}

impl PagedKv<'_> {
    pub fn precision(&self) -> Precision {
        self.prec
    }

    pub fn n_blocks(&self) -> usize {
        self.table.len()
    }

    /// The block holding tokens `[i * block_size, ...)`.
    pub fn block(&self, i: usize) -> &SeqKv {
        self.pool.block(self.table[i])
    }

    /// Live tokens of THIS sequence inside block `i` (a shared tail
    /// block may physically hold more tokens than this sequence
    /// references, so this is derived from the sequence length, not
    /// from the block's fill).
    pub fn block_tokens(&self, i: usize) -> usize {
        (self.len - i * self.block_size).min(self.block_size)
    }

    pub fn remaining(&self) -> usize {
        self.capacity.saturating_sub(self.len)
    }

    /// Decode token `t` of head `h` (K) — test/debug helper mirroring
    /// `SeqKv::decode_k`.
    pub fn decode_k(&self, head: usize, t: usize, out: &mut [f32]) {
        assert!(t < self.len);
        self.block(t / self.block_size)
            .decode_k(head, t % self.block_size, out);
    }
}

/// One (sequence, layer)'s view into the pool: logical length plus the
/// ordered block table. Lengths are per-layer because a pass appends
/// layer by layer.
struct SeqLayer {
    len: usize,
    table: Vec<u32>,
}

/// All sequences assigned to one R-worker socket, stored paged:
/// (seq, layer) → block table → `BlockPool`.
pub struct SocketCache {
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_layers: usize,
    pub capacity_per_seq: usize,
    pub block_size: usize,
    pub prec: Precision,
    pool: BlockPool,
    /// BTreeMap so whole-cache walks (stats totals, future
    /// save/migrate serialization) run in ascending seq-id order.
    seqs: BTreeMap<u64, Vec<SeqLayer>>,
}

impl SocketCache {
    pub fn new(
        n_heads: usize,
        head_dim: usize,
        n_layers: usize,
        capacity_per_seq: usize,
        block_size: usize,
        prec: Precision,
    ) -> SocketCache {
        SocketCache {
            n_heads,
            head_dim,
            n_layers,
            capacity_per_seq,
            block_size,
            prec,
            pool: BlockPool::new(n_heads, head_dim, block_size, prec),
            seqs: BTreeMap::new(),
        }
    }

    /// Register a new sequence. No storage is reserved up front: blocks
    /// are allocated one at a time as tokens are appended (the point of
    /// paging — admission cost is actual occupancy, not worst case).
    pub fn add_seq(&mut self, seq_id: u64) {
        let layers = (0..self.n_layers)
            .map(|_| SeqLayer {
                len: 0,
                table: Vec::new(),
            })
            .collect();
        let prev = self.seqs.insert(seq_id, layers);
        assert!(prev.is_none(), "sequence {seq_id} already present");
    }

    /// Drop a finished sequence (§4.1: "drop KV-cache of a certain
    /// sequence upon its generation ends"). Its block references are
    /// released; blocks still shared with forked children survive.
    pub fn drop_seq(&mut self, seq_id: u64) -> bool {
        match self.seqs.remove(&seq_id) {
            Some(layers) => {
                for sl in &layers {
                    for &idx in &sl.table {
                        self.pool.release(idx);
                    }
                }
                true
            }
            None => false,
        }
    }

    pub fn contains(&self, seq_id: u64) -> bool {
        self.seqs.contains_key(&seq_id)
    }

    fn layer_of(&self, seq_id: u64, layer: usize) -> Result<&SeqLayer> {
        let layers = self
            .seqs
            .get(&seq_id)
            .ok_or_else(|| anyhow!("unknown sequence {seq_id}"))?;
        layers.get(layer).ok_or_else(|| {
            anyhow!("layer {layer} out of range ({} layers)", self.n_layers)
        })
    }

    /// Logical length of (seq, layer). `Err` on an unknown sequence —
    /// never a panic, so a stale id is routable as a protocol error.
    pub fn seq_len(&self, seq_id: u64, layer: usize) -> Result<usize> {
        Ok(self.layer_of(seq_id, layer)?.len)
    }

    /// Paged read view of (seq, layer) for the attention hot loop.
    /// `Err` on an unknown sequence — never a panic.
    pub fn get(&self, seq_id: u64, layer: usize) -> Result<PagedKv<'_>> {
        let sl = self.layer_of(seq_id, layer)?;
        Ok(PagedKv {
            pool: &self.pool,
            table: &sl.table,
            len: sl.len,
            capacity: self.capacity_per_seq,
            n_heads: self.n_heads,
            head_dim: self.head_dim,
            block_size: self.block_size,
            prec: self.prec,
        })
    }

    /// Append one token's K and V (each `[H * D]` f32, head-major) to
    /// (seq, layer). Allocates a block when the tail is full; a shared
    /// tail block is copied before the first divergent write (COW).
    pub fn append(
        &mut self,
        seq_id: u64,
        layer: usize,
        k: &[f32],
        v: &[f32],
    ) -> Result<usize> {
        let (bs, cap) = (self.block_size, self.capacity_per_seq);
        let layers = self
            .seqs
            .get_mut(&seq_id)
            .ok_or_else(|| anyhow!("unknown sequence {seq_id}"))?;
        let n_layers = layers.len();
        let sl = layers.get_mut(layer).ok_or_else(|| {
            anyhow!("layer {layer} out of range ({n_layers} layers)")
        })?;
        if sl.len >= cap {
            bail!("KV-cache overflow (capacity {cap})");
        }
        let pos = sl.len % bs;
        if pos == 0 {
            let idx = self.pool.alloc();
            sl.table.push(idx);
        } else {
            let tail = *sl.table.last().expect("non-empty table");
            if self.pool.rc(tail) > 1 {
                // first divergent write into a shared block: copy the
                // prefix we own, release the shared reference
                let idx = self.pool.cow_clone(tail, pos);
                *sl.table.last_mut().expect("non-empty table") = idx;
            } else if self.pool.block(tail).len != pos {
                // sole owner of a block once shared with a longer (now
                // dropped) relative: truncate the stale fill in place
                self.pool.block_mut(tail).len = pos;
            }
        }
        let tail = *sl.table.last().expect("non-empty table");
        let t = self.pool.block_mut(tail).append(k, v);
        debug_assert_eq!(t, pos);
        sl.len += 1;
        Ok(sl.len - 1)
    }

    /// Fork `child` from `parent`, sharing the first `upto` tokens on
    /// every layer. Shared blocks are refcounted, not copied; the first
    /// append past the fork point copies the tail block (COW). The
    /// child's logical length starts at `upto` on every layer.
    pub fn fork_seq(
        &mut self,
        parent: u64,
        child: u64,
        upto: usize,
    ) -> Result<()> {
        if self.seqs.contains_key(&child) {
            bail!("sequence {child} already present");
        }
        let parent_layers = self
            .seqs
            .get(&parent)
            .ok_or_else(|| anyhow!("unknown sequence {parent}"))?;
        for sl in parent_layers {
            if upto > sl.len {
                bail!(
                    "fork upto {upto} exceeds parent {parent} length {}",
                    sl.len
                );
            }
        }
        let shared = upto.div_ceil(self.block_size);
        let tables: Vec<Vec<u32>> = parent_layers
            .iter()
            .map(|sl| sl.table[..shared].to_vec())
            .collect();
        let mut child_layers = Vec::with_capacity(tables.len());
        for table in tables {
            for &idx in &table {
                self.pool.retain(idx);
            }
            child_layers.push(SeqLayer { len: upto, table });
        }
        self.seqs.insert(child, child_layers);
        Ok(())
    }

    /// Blocks currently live in the arena (shared blocks counted once).
    pub fn live_blocks(&self) -> usize {
        self.pool.live_blocks()
    }

    /// Freed arena slots available for reuse without growing the pool.
    pub fn free_blocks(&self) -> usize {
        self.pool.free_blocks()
    }

    pub fn stats(&self) -> CacheStats {
        let mut st = CacheStats {
            sequences: self.seqs.len(),
            ..CacheStats::default()
        };
        for layers in self.seqs.values() {
            for sl in layers {
                st.total_tokens += sl.len;
            }
        }
        st.logical_bytes = st.total_tokens
            * kv_token_bytes(self.n_heads, self.head_dim, self.prec);
        self.pool.stats_into(&mut st);
        let m = crate::obs::Metrics::global();
        if m.is_enabled() {
            m.set_gauge("kv_blocks_used", &[], self.live_blocks() as f64);
            m.set_gauge("kv_blocks_free", &[], self.free_blocks() as f64);
            m.set_gauge("kv_utilization", &[], st.utilization());
        }
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip(prec: Precision, tol: f32) {
        let (h, d, cap) = (3, 8, 16);
        let mut kv = SeqKv::new(h, d, cap, prec);
        let mut rng = Rng::new(5);
        let mut tokens = Vec::new();
        for _ in 0..10 {
            let k = rng.normal_vec(h * d, 1.0);
            let v = rng.normal_vec(h * d, 1.0);
            kv.append(&k, &v);
            tokens.push(k);
        }
        assert_eq!(kv.len, 10);
        let mut out = vec![0.0; d];
        for (t, k) in tokens.iter().enumerate() {
            for head in 0..h {
                kv.decode_k(head, t, &mut out);
                for (a, b) in out.iter().zip(&k[head * d..(head + 1) * d]) {
                    assert!((a - b).abs() <= tol, "{prec:?} t={t}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn f32_roundtrip_exact() {
        roundtrip(Precision::F32, 0.0);
    }

    #[test]
    fn f16_roundtrip_half_ulp() {
        roundtrip(Precision::F16, 3e-3);
    }

    #[test]
    fn int8_roundtrip_bounded() {
        roundtrip(Precision::Int8, 0.05);
    }

    #[test]
    fn int4_roundtrip_bounded() {
        roundtrip(Precision::Int4, 0.5);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut kv = SeqKv::new(1, 2, 2, Precision::F16);
        let (k, v) = ([0.0, 0.0], [0.0, 0.0]);
        kv.append(&k, &v);
        kv.append(&k, &v);
        kv.append(&k, &v);
    }

    /// `is_full` is ENFORCED: an append past capacity is rejected before
    /// any write, so the stored tokens survive untouched (no silent
    /// ring-buffer overwrite).
    #[test]
    fn append_past_capacity_rejected_without_overwrite() {
        let (h, d, cap) = (2, 4, 3);
        let mut kv = SeqKv::new(h, d, cap, Precision::F32);
        let mut rng = Rng::new(8);
        let tokens: Vec<(Vec<f32>, Vec<f32>)> = (0..cap)
            .map(|_| (rng.normal_vec(h * d, 1.0), rng.normal_vec(h * d, 1.0)))
            .collect();
        for (k, v) in &tokens {
            kv.append(k, v);
        }
        assert!(kv.is_full());
        let extra_k = rng.normal_vec(h * d, 1.0);
        let extra_v = rng.normal_vec(h * d, 1.0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || kv.append(&extra_k, &extra_v),
        ));
        assert!(result.is_err(), "overfull append must be rejected");
        // every original token decodes back exactly — nothing overwritten
        assert_eq!(kv.len, cap);
        let mut out = vec![0.0; d];
        for (t, (k, _)) in tokens.iter().enumerate() {
            for head in 0..h {
                kv.decode_k(head, t, &mut out);
                assert_eq!(out, &k[head * d..(head + 1) * d], "token {t}");
            }
        }
    }

    /// Int4 with an odd number of appended tokens: the per-token packing
    /// is independent of the token count, and every token (including the
    /// last, odd one) round-trips within the int4 quantization bound.
    #[test]
    fn int4_odd_token_count_roundtrips() {
        let (h, d, cap) = (2, 6, 16);
        let mut kv = SeqKv::new(h, d, cap, Precision::Int4);
        let mut rng = Rng::new(21);
        let mut kept = Vec::new();
        for _ in 0..7 {
            let k = rng.normal_vec(h * d, 1.0);
            let v = rng.normal_vec(h * d, 1.0);
            kv.append(&k, &v);
            kept.push(k);
        }
        assert_eq!(kv.len, 7);
        let mut out = vec![0.0; d];
        for (t, k) in kept.iter().enumerate() {
            for head in 0..h {
                let row = &k[head * d..(head + 1) * d];
                let max = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                kv.decode_k(head, t, &mut out);
                for (a, b) in out.iter().zip(row) {
                    assert!(
                        (a - b).abs() <= max / 7.0 * 0.51 + 1e-6,
                        "t={t} head={head}: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// Int8 per-(head, token) scales: each token's decode error is
    /// bounded by ITS OWN scale, even when magnitudes differ by 100×
    /// between tokens (a per-tensor scale would fail this).
    #[test]
    fn int8_scale_roundtrip_per_token() {
        let (h, d, cap) = (1, 8, 8);
        let mut kv = SeqKv::new(h, d, cap, Precision::Int8);
        let mut rng = Rng::new(33);
        let magnitudes = [0.01f32, 1.0, 100.0];
        let rows: Vec<Vec<f32>> = magnitudes
            .iter()
            .map(|&m| rng.normal_vec(d, m))
            .collect();
        for row in &rows {
            kv.append(row, row);
        }
        let mut out = vec![0.0; d];
        for (t, row) in rows.iter().enumerate() {
            let max = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            kv.decode_k(0, t, &mut out);
            for (a, b) in out.iter().zip(row) {
                // half-step of THIS token's scale, not the batch max
                assert!(
                    (a - b).abs() <= max / 127.0 * 0.51 + 1e-7,
                    "t={t}: {a} vs {b} (scale step {})",
                    max / 127.0
                );
            }
        }
    }

    #[test]
    fn quantization_shrinks_memory() {
        let mk = |p| SeqKv::new(8, 64, 128, p).allocated_bytes();
        let f16 = mk(Precision::F16);
        let i8b = mk(Precision::Int8);
        let i4b = mk(Precision::Int4);
        assert!(i8b < f16);
        assert!(i4b < i8b);
        // §5.2: int4 payload is a quarter of fp16 (scales add a little)
        assert!((i4b as f64) < 0.3 * f16 as f64);
    }

    #[test]
    fn socket_cache_lifecycle() {
        let mut sc = SocketCache::new(2, 4, 3, 8, 2, Precision::F16);
        sc.add_seq(7);
        sc.add_seq(9);
        let mut rng = Rng::new(1);
        let k = rng.normal_vec(8, 1.0);
        let v = rng.normal_vec(8, 1.0);
        for layer in 0..3 {
            sc.append(7, layer, &k, &v).unwrap();
        }
        sc.append(9, 0, &k, &v).unwrap();
        let st = sc.stats();
        assert_eq!(st.sequences, 2);
        assert_eq!(st.total_tokens, 4);
        assert_eq!(st.physical_tokens, 4);
        assert!(sc.drop_seq(7));
        assert!(!sc.drop_seq(7));
        assert_eq!(sc.stats().sequences, 1);
    }

    #[test]
    #[should_panic(expected = "already present")]
    fn duplicate_seq_panics() {
        let mut sc = SocketCache::new(1, 2, 1, 4, 2, Precision::F16);
        sc.add_seq(1);
        sc.add_seq(1);
    }

    /// Paged storage is exact (f32): appends spanning several blocks
    /// decode back bit-identically through the paged view.
    #[test]
    fn paged_append_roundtrips_across_blocks() {
        let (h, d, bs) = (2, 4, 3);
        let mut sc = SocketCache::new(h, d, 1, 16, bs, Precision::F32);
        sc.add_seq(1);
        let mut rng = Rng::new(9);
        let mut kept = Vec::new();
        for _ in 0..8 {
            let k = rng.normal_vec(h * d, 1.0);
            let v = rng.normal_vec(h * d, 1.0);
            sc.append(1, 0, &k, &v).unwrap();
            kept.push(k);
        }
        let view = sc.get(1, 0).unwrap();
        assert_eq!(view.len, 8);
        assert_eq!(view.n_blocks(), 3); // ceil(8 / 3)
        let mut out = vec![0.0; d];
        for (t, k) in kept.iter().enumerate() {
            for head in 0..h {
                view.decode_k(head, t, &mut out);
                assert_eq!(out, &k[head * d..(head + 1) * d], "t={t}");
            }
        }
    }

    /// Paging allocates lazily: an admitted-but-empty sequence holds no
    /// blocks, and storage grows one block at a time with occupancy —
    /// never the eager full-capacity reservation the contiguous store
    /// made.
    #[test]
    fn lazy_allocation_grows_blockwise() {
        let (h, d, bs) = (2, 4, 4);
        let mut sc = SocketCache::new(h, d, 1, 64, bs, Precision::F16);
        sc.add_seq(1);
        assert_eq!(sc.stats().allocated_bytes, 0, "eager allocation");
        assert_eq!(sc.live_blocks(), 0);
        let k = vec![0.5; h * d];
        sc.append(1, 0, &k, &k).unwrap();
        let one_block = sc.stats().allocated_bytes;
        assert!(one_block > 0);
        for _ in 1..bs {
            sc.append(1, 0, &k, &k).unwrap();
        }
        assert_eq!(sc.stats().allocated_bytes, one_block, "block not reused");
        sc.append(1, 0, &k, &k).unwrap(); // crosses into block 2
        assert_eq!(sc.stats().allocated_bytes, 2 * one_block);
        assert_eq!(sc.live_blocks(), 2);
    }

    /// Forking shares prefix blocks physically: logical tokens double-
    /// count the prefix, physical tokens count it once.
    #[test]
    fn fork_shares_blocks_physically() {
        let (h, d, bs) = (1, 4, 2);
        let mut sc = SocketCache::new(h, d, 1, 16, bs, Precision::F32);
        sc.add_seq(1);
        let mut rng = Rng::new(4);
        for _ in 0..6 {
            let k = rng.normal_vec(h * d, 1.0);
            sc.append(1, 0, &k, &k).unwrap();
        }
        sc.fork_seq(1, 2, 4).unwrap();
        let st = sc.stats();
        assert_eq!(st.sequences, 2);
        assert_eq!(st.total_tokens, 10, "logical: 6 + 4");
        assert_eq!(st.physical_tokens, 6, "physical: shared counted once");
        assert_eq!(sc.live_blocks(), 3);
        assert!(st.utilization() > 1.0, "sharing must beat 1.0 utilization");
        // child reads the parent's bits through the shared blocks
        let mut a = vec![0.0; d];
        let mut b = vec![0.0; d];
        for t in 0..4 {
            sc.get(1, 0).unwrap().decode_k(0, t, &mut a);
            sc.get(2, 0).unwrap().decode_k(0, t, &mut b);
            assert_eq!(a, b, "t={t}");
        }
    }

    /// COW: a fork mid-block diverges correctly — the child's first
    /// append past the fork point copies the tail block, and neither
    /// sequence sees the other's subsequent tokens.
    #[test]
    fn cow_fork_then_diverge() {
        let (h, d, bs) = (1, 4, 2);
        let mut sc = SocketCache::new(h, d, 1, 16, bs, Precision::F32);
        sc.add_seq(1);
        let mut rng = Rng::new(17);
        let mut parent_rows = Vec::new();
        for _ in 0..3 {
            let k = rng.normal_vec(h * d, 1.0);
            sc.append(1, 0, &k, &k).unwrap();
            parent_rows.push(k);
        }
        // fork at 3: mid-block (block 1 holds token 2 only, for child)
        sc.fork_seq(1, 2, 3).unwrap();
        let child_row = rng.normal_vec(h * d, 1.0);
        sc.append(2, 0, &child_row, &child_row).unwrap(); // COW copy
        let parent_row = rng.normal_vec(h * d, 1.0);
        sc.append(1, 0, &parent_row, &parent_row).unwrap();
        let mut out = vec![0.0; d];
        // shared prefix intact on both
        for t in 0..3 {
            for seq in [1, 2] {
                sc.get(seq, 0).unwrap().decode_k(0, t, &mut out);
                assert_eq!(out, parent_rows[t].as_slice(), "seq {seq} t={t}");
            }
        }
        // divergent token 3 differs per sequence
        sc.get(1, 0).unwrap().decode_k(0, 3, &mut out);
        assert_eq!(out, parent_row.as_slice());
        sc.get(2, 0).unwrap().decode_k(0, 3, &mut out);
        assert_eq!(out, child_row.as_slice());
        // token-3 block was copied: 2 shared-prefix blocks + 2 tails
        assert_eq!(sc.live_blocks(), 4);
    }

    /// Dropping the parent keeps the child's shared blocks alive
    /// (refcounts), and fully-released blocks return to the free list
    /// for reuse by later sequences.
    #[test]
    fn drop_parent_keeps_child_blocks_and_recycles() {
        let (h, d, bs) = (1, 4, 2);
        let mut sc = SocketCache::new(h, d, 1, 16, bs, Precision::F32);
        sc.add_seq(1);
        let mut rng = Rng::new(23);
        let mut rows = Vec::new();
        for _ in 0..6 {
            let k = rng.normal_vec(h * d, 1.0);
            sc.append(1, 0, &k, &k).unwrap();
            rows.push(k);
        }
        // fork MID-BLOCK: child references only the first token of the
        // second shared block
        sc.fork_seq(1, 2, 3).unwrap();
        assert_eq!(sc.live_blocks(), 3);
        assert!(sc.drop_seq(1));
        // parent's exclusive tail block freed; shared prefix survives
        assert_eq!(sc.live_blocks(), 2);
        let mut out = vec![0.0; d];
        for t in 0..3 {
            sc.get(2, 0).unwrap().decode_k(0, t, &mut out);
            assert_eq!(out, rows[t].as_slice(), "t={t}");
        }
        // a new sequence reuses the freed slot instead of growing
        let arena_before = sc.live_blocks();
        sc.add_seq(3);
        sc.append(3, 0, &rows[0], &rows[0]).unwrap();
        assert_eq!(sc.live_blocks(), arena_before + 1);
        // the child now solely owns a tail block with STALE fill (the
        // dropped parent wrote 2 tokens, the child references 1):
        // appending truncates in place and stays consistent
        let fresh = rng.normal_vec(h * d, 1.0);
        sc.append(2, 0, &fresh, &fresh).unwrap();
        assert_eq!(sc.seq_len(2, 0).unwrap(), 4);
        sc.get(2, 0).unwrap().decode_k(0, 3, &mut out);
        assert_eq!(out, fresh.as_slice());
    }

    /// The satellite bugfix: a stale sequence id is an `Err`, not a
    /// process-killing panic — the caller can route it as a protocol
    /// error and keep serving.
    #[test]
    fn unknown_sequence_is_an_error_not_a_panic() {
        let mut sc = SocketCache::new(1, 2, 1, 4, 2, Precision::F16);
        assert!(sc.get(42, 0).is_err());
        assert!(sc.seq_len(42, 0).is_err());
        assert!(sc.append(42, 0, &[0.0, 0.0], &[0.0, 0.0]).is_err());
        assert!(sc.fork_seq(42, 43, 0).is_err());
        let msg = format!("{:#}", sc.get(42, 0).unwrap_err());
        assert!(msg.contains("unknown sequence"), "{msg}");
        // and a layer out of range is equally routable
        sc.add_seq(1);
        assert!(sc.get(1, 9).is_err());
    }

    /// Logical overflow (per-sequence capacity) surfaces as an error
    /// through the paged API as well.
    #[test]
    fn paged_overflow_is_an_error() {
        let mut sc = SocketCache::new(1, 2, 1, 2, 4, Precision::F32);
        sc.add_seq(1);
        let r = [0.5, 0.5];
        sc.append(1, 0, &r, &r).unwrap();
        sc.append(1, 0, &r, &r).unwrap();
        let err = sc.append(1, 0, &r, &r).unwrap_err();
        assert!(format!("{err:#}").contains("overflow"));
        assert_eq!(sc.seq_len(1, 0).unwrap(), 2, "overflow must not write");
    }

    /// Fork validation: bad parents and over-long prefixes are errors.
    #[test]
    fn fork_validation_errors() {
        let mut sc = SocketCache::new(1, 2, 1, 8, 2, Precision::F32);
        sc.add_seq(1);
        let r = [0.1, 0.2];
        sc.append(1, 0, &r, &r).unwrap();
        assert!(sc.fork_seq(9, 2, 0).is_err(), "unknown parent");
        assert!(sc.fork_seq(1, 1, 1).is_err(), "child collides");
        assert!(sc.fork_seq(1, 2, 5).is_err(), "upto exceeds parent");
        // valid fork still works after the failures
        sc.fork_seq(1, 2, 1).unwrap();
        assert!(sc.contains(2));
    }

    /// logical_bytes tracks tokens × per-token cost; utilization is the
    /// fragmentation/sharing signal (< 1 padding, > 1 sharing).
    #[test]
    fn stats_logical_vs_allocated() {
        let (h, d, bs) = (2, 4, 4);
        let mut sc = SocketCache::new(h, d, 1, 16, bs, Precision::F16);
        sc.add_seq(1);
        let k = vec![0.25; h * d];
        sc.append(1, 0, &k, &k).unwrap();
        let st = sc.stats();
        assert_eq!(st.logical_bytes, kv_token_bytes(h, d, Precision::F16));
        // one token in a 4-token block: utilization = 1/4
        assert!((st.utilization() - 0.25).abs() < 1e-9, "{}", st.utilization());
        assert_eq!(st.allocated_bytes, 4 * st.logical_bytes);
    }
}
