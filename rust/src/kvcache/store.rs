//! Per-sequence KV storage and the per-socket cache map.

use std::collections::HashMap;

use crate::model::Precision;
use crate::util::f16::{encode_slice, F16};

/// K and V of one sequence on one layer, laid out `[H][capacity][D]`
/// (per-head scans are contiguous — the attention hot loop walks `t`
/// within a head).
pub struct SeqKv {
    pub n_heads: usize,
    pub head_dim: usize,
    pub capacity: usize,
    pub len: usize,
    prec: Precision,
    // exactly one representation is non-empty, selected by `prec`
    k16: Vec<F16>,
    v16: Vec<F16>,
    k32: Vec<f32>,
    v32: Vec<f32>,
    k8: Vec<i8>,
    v8: Vec<i8>,
    k4: Vec<u8>,
    v4: Vec<u8>,
    /// per-(head, token) scales for the quantized formats
    k_scale: Vec<f32>,
    v_scale: Vec<f32>,
}

impl SeqKv {
    pub fn new(
        n_heads: usize,
        head_dim: usize,
        capacity: usize,
        prec: Precision,
    ) -> SeqKv {
        let n = n_heads * capacity * head_dim;
        let mut s = SeqKv {
            n_heads,
            head_dim,
            capacity,
            len: 0,
            prec,
            k16: vec![],
            v16: vec![],
            k32: vec![],
            v32: vec![],
            k8: vec![],
            v8: vec![],
            k4: vec![],
            v4: vec![],
            k_scale: vec![],
            v_scale: vec![],
        };
        match prec {
            Precision::F16 => {
                s.k16 = vec![F16::ZERO; n];
                s.v16 = vec![F16::ZERO; n];
            }
            Precision::F32 => {
                s.k32 = vec![0.0; n];
                s.v32 = vec![0.0; n];
            }
            Precision::Int8 => {
                s.k8 = vec![0; n];
                s.v8 = vec![0; n];
                s.k_scale = vec![0.0; n_heads * capacity];
                s.v_scale = vec![0.0; n_heads * capacity];
            }
            Precision::Int4 => {
                assert_eq!(head_dim % 2, 0, "int4 needs even head_dim");
                s.k4 = vec![0; n / 2];
                s.v4 = vec![0; n / 2];
                s.k_scale = vec![0.0; n_heads * capacity];
                s.v_scale = vec![0.0; n_heads * capacity];
            }
        }
        s
    }

    pub fn precision(&self) -> Precision {
        self.prec
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.capacity
    }

    /// Tokens that can still be appended before the cache is full (used
    /// by the R-worker to reject a multi-row prefill that would overflow
    /// before any of its appends land).
    pub fn remaining(&self) -> usize {
        self.capacity.saturating_sub(self.len)
    }

    /// Append one token's K and V, each `[H * D]` f32 (head-major).
    /// Returns the token's position.
    pub fn append(&mut self, k: &[f32], v: &[f32]) -> usize {
        let (h, d) = (self.n_heads, self.head_dim);
        assert_eq!(k.len(), h * d);
        assert_eq!(v.len(), h * d);
        assert!(!self.is_full(), "KV-cache overflow (capacity {})", self.capacity);
        let t = self.len;
        for head in 0..h {
            let src_k = &k[head * d..(head + 1) * d];
            let src_v = &v[head * d..(head + 1) * d];
            let off = (head * self.capacity + t) * d;
            match self.prec {
                Precision::F16 => {
                    encode_slice(src_k, &mut self.k16[off..off + d]);
                    encode_slice(src_v, &mut self.v16[off..off + d]);
                }
                Precision::F32 => {
                    self.k32[off..off + d].copy_from_slice(src_k);
                    self.v32[off..off + d].copy_from_slice(src_v);
                }
                Precision::Int8 => {
                    let si = head * self.capacity + t;
                    self.k_scale[si] =
                        super::quant_i8(src_k, &mut self.k8[off..off + d]);
                    self.v_scale[si] =
                        super::quant_i8(src_v, &mut self.v8[off..off + d]);
                }
                Precision::Int4 => {
                    let si = head * self.capacity + t;
                    let po = off / 2;
                    self.k_scale[si] =
                        super::quant_i4(src_k, &mut self.k4[po..po + d / 2]);
                    self.v_scale[si] =
                        super::quant_i4(src_v, &mut self.v4[po..po + d / 2]);
                }
            }
        }
        self.len = t + 1;
        t
    }

    /// Raw per-head K row access for the attention hot loop (fp16 path).
    #[inline(always)]
    pub fn k16_head(&self, head: usize) -> &[F16] {
        let (c, d) = (self.capacity, self.head_dim);
        &self.k16[head * c * d..(head + 1) * c * d]
    }

    #[inline(always)]
    pub fn v16_head(&self, head: usize) -> &[F16] {
        let (c, d) = (self.capacity, self.head_dim);
        &self.v16[head * c * d..(head + 1) * c * d]
    }

    #[inline(always)]
    pub fn k32_head(&self, head: usize) -> &[f32] {
        let (c, d) = (self.capacity, self.head_dim);
        &self.k32[head * c * d..(head + 1) * c * d]
    }

    #[inline(always)]
    pub fn v32_head(&self, head: usize) -> &[f32] {
        let (c, d) = (self.capacity, self.head_dim);
        &self.v32[head * c * d..(head + 1) * c * d]
    }

    #[inline(always)]
    pub fn k8_head(&self, head: usize) -> (&[i8], &[f32]) {
        let (c, d) = (self.capacity, self.head_dim);
        (
            &self.k8[head * c * d..(head + 1) * c * d],
            &self.k_scale[head * c..head * c + c],
        )
    }

    #[inline(always)]
    pub fn v8_head(&self, head: usize) -> (&[i8], &[f32]) {
        let (c, d) = (self.capacity, self.head_dim);
        (
            &self.v8[head * c * d..(head + 1) * c * d],
            &self.v_scale[head * c..head * c + c],
        )
    }

    #[inline(always)]
    pub fn k4_head(&self, head: usize) -> (&[u8], &[f32]) {
        let (c, d) = (self.capacity, self.head_dim);
        (
            &self.k4[head * c * d / 2..(head + 1) * c * d / 2],
            &self.k_scale[head * c..head * c + c],
        )
    }

    #[inline(always)]
    pub fn v4_head(&self, head: usize) -> (&[u8], &[f32]) {
        let (c, d) = (self.capacity, self.head_dim);
        (
            &self.v4[head * c * d / 2..(head + 1) * c * d / 2],
            &self.v_scale[head * c..head * c + c],
        )
    }

    /// Decode token `t` of head `h` (K) into `out` — test/debug helper.
    pub fn decode_k(&self, head: usize, t: usize, out: &mut [f32]) {
        let d = self.head_dim;
        assert!(t < self.len);
        let off = (head * self.capacity + t) * d;
        match self.prec {
            Precision::F16 => {
                for (o, x) in out.iter_mut().zip(&self.k16[off..off + d]) {
                    *o = x.to_f32();
                }
            }
            Precision::F32 => out.copy_from_slice(&self.k32[off..off + d]),
            Precision::Int8 => super::dequant_i8(
                &self.k8[off..off + d],
                self.k_scale[head * self.capacity + t],
                out,
            ),
            Precision::Int4 => super::dequant_i4(
                &self.k4[off / 2..off / 2 + d / 2],
                self.k_scale[head * self.capacity + t],
                out,
            ),
        }
    }

    /// Bytes of KV payload actually stored (capacity allocation).
    pub fn allocated_bytes(&self) -> usize {
        self.k16.len() * 2
            + self.v16.len() * 2
            + (self.k32.len() + self.v32.len()) * 4
            + self.k8.len()
            + self.v8.len()
            + self.k4.len()
            + self.v4.len()
            + (self.k_scale.len() + self.v_scale.len()) * 4
    }
}

/// Aggregate statistics of one socket's cache (capacity planning, eq. 9).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub sequences: usize,
    /// Sum of live lengths across sequences × layers (the R-Part load,
    /// W in Algorithm 1's terms).
    pub total_tokens: usize,
    pub allocated_bytes: usize,
}

/// All sequences assigned to one R-worker socket: (seq, layer) → SeqKv.
pub struct SocketCache {
    pub n_heads: usize,
    pub head_dim: usize,
    pub n_layers: usize,
    pub capacity_per_seq: usize,
    pub prec: Precision,
    seqs: HashMap<u64, Vec<SeqKv>>,
}

impl SocketCache {
    pub fn new(
        n_heads: usize,
        head_dim: usize,
        n_layers: usize,
        capacity_per_seq: usize,
        prec: Precision,
    ) -> SocketCache {
        SocketCache {
            n_heads,
            head_dim,
            n_layers,
            capacity_per_seq,
            prec,
            seqs: HashMap::new(),
        }
    }

    /// Register a new sequence (all layers allocated lazily at insert).
    pub fn add_seq(&mut self, seq_id: u64) {
        let layers = (0..self.n_layers)
            .map(|_| {
                SeqKv::new(
                    self.n_heads,
                    self.head_dim,
                    self.capacity_per_seq,
                    self.prec,
                )
            })
            .collect();
        let prev = self.seqs.insert(seq_id, layers);
        assert!(prev.is_none(), "sequence {seq_id} already present");
    }

    /// Drop a finished sequence, freeing its memory (§4.1: "drop KV-cache
    /// of a certain sequence upon its generation ends").
    pub fn drop_seq(&mut self, seq_id: u64) -> bool {
        self.seqs.remove(&seq_id).is_some()
    }

    pub fn contains(&self, seq_id: u64) -> bool {
        self.seqs.contains_key(&seq_id)
    }

    pub fn get_mut(&mut self, seq_id: u64, layer: usize) -> &mut SeqKv {
        &mut self.seqs.get_mut(&seq_id).expect("unknown sequence")[layer]
    }

    pub fn get(&self, seq_id: u64, layer: usize) -> &SeqKv {
        &self.seqs.get(&seq_id).expect("unknown sequence")[layer]
    }

    pub fn stats(&self) -> CacheStats {
        let mut st = CacheStats::default();
        st.sequences = self.seqs.len();
        for layers in self.seqs.values() {
            for kv in layers {
                st.total_tokens += kv.len;
                st.allocated_bytes += kv.allocated_bytes();
            }
        }
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip(prec: Precision, tol: f32) {
        let (h, d, cap) = (3, 8, 16);
        let mut kv = SeqKv::new(h, d, cap, prec);
        let mut rng = Rng::new(5);
        let mut tokens = Vec::new();
        for _ in 0..10 {
            let k = rng.normal_vec(h * d, 1.0);
            let v = rng.normal_vec(h * d, 1.0);
            kv.append(&k, &v);
            tokens.push(k);
        }
        assert_eq!(kv.len, 10);
        let mut out = vec![0.0; d];
        for (t, k) in tokens.iter().enumerate() {
            for head in 0..h {
                kv.decode_k(head, t, &mut out);
                for (a, b) in out.iter().zip(&k[head * d..(head + 1) * d]) {
                    assert!((a - b).abs() <= tol, "{prec:?} t={t}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn f32_roundtrip_exact() {
        roundtrip(Precision::F32, 0.0);
    }

    #[test]
    fn f16_roundtrip_half_ulp() {
        roundtrip(Precision::F16, 3e-3);
    }

    #[test]
    fn int8_roundtrip_bounded() {
        roundtrip(Precision::Int8, 0.05);
    }

    #[test]
    fn int4_roundtrip_bounded() {
        roundtrip(Precision::Int4, 0.5);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut kv = SeqKv::new(1, 2, 2, Precision::F16);
        let (k, v) = ([0.0, 0.0], [0.0, 0.0]);
        kv.append(&k, &v);
        kv.append(&k, &v);
        kv.append(&k, &v);
    }

    /// `is_full` is ENFORCED: an append past capacity is rejected before
    /// any write, so the stored tokens survive untouched (no silent
    /// ring-buffer overwrite).
    #[test]
    fn append_past_capacity_rejected_without_overwrite() {
        let (h, d, cap) = (2, 4, 3);
        let mut kv = SeqKv::new(h, d, cap, Precision::F32);
        let mut rng = Rng::new(8);
        let tokens: Vec<(Vec<f32>, Vec<f32>)> = (0..cap)
            .map(|_| (rng.normal_vec(h * d, 1.0), rng.normal_vec(h * d, 1.0)))
            .collect();
        for (k, v) in &tokens {
            kv.append(k, v);
        }
        assert!(kv.is_full());
        let extra_k = rng.normal_vec(h * d, 1.0);
        let extra_v = rng.normal_vec(h * d, 1.0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || kv.append(&extra_k, &extra_v),
        ));
        assert!(result.is_err(), "overfull append must be rejected");
        // every original token decodes back exactly — nothing overwritten
        assert_eq!(kv.len, cap);
        let mut out = vec![0.0; d];
        for (t, (k, _)) in tokens.iter().enumerate() {
            for head in 0..h {
                kv.decode_k(head, t, &mut out);
                assert_eq!(out, &k[head * d..(head + 1) * d], "token {t}");
            }
        }
    }

    /// Int4 with an odd number of appended tokens: the per-token packing
    /// is independent of the token count, and every token (including the
    /// last, odd one) round-trips within the int4 quantization bound.
    #[test]
    fn int4_odd_token_count_roundtrips() {
        let (h, d, cap) = (2, 6, 16);
        let mut kv = SeqKv::new(h, d, cap, Precision::Int4);
        let mut rng = Rng::new(21);
        let mut kept = Vec::new();
        for _ in 0..7 {
            let k = rng.normal_vec(h * d, 1.0);
            let v = rng.normal_vec(h * d, 1.0);
            kv.append(&k, &v);
            kept.push(k);
        }
        assert_eq!(kv.len, 7);
        let mut out = vec![0.0; d];
        for (t, k) in kept.iter().enumerate() {
            for head in 0..h {
                let row = &k[head * d..(head + 1) * d];
                let max = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                kv.decode_k(head, t, &mut out);
                for (a, b) in out.iter().zip(row) {
                    assert!(
                        (a - b).abs() <= max / 7.0 * 0.51 + 1e-6,
                        "t={t} head={head}: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// Int8 per-(head, token) scales: each token's decode error is
    /// bounded by ITS OWN scale, even when magnitudes differ by 100×
    /// between tokens (a per-tensor scale would fail this).
    #[test]
    fn int8_scale_roundtrip_per_token() {
        let (h, d, cap) = (1, 8, 8);
        let mut kv = SeqKv::new(h, d, cap, Precision::Int8);
        let mut rng = Rng::new(33);
        let magnitudes = [0.01f32, 1.0, 100.0];
        let rows: Vec<Vec<f32>> = magnitudes
            .iter()
            .map(|&m| rng.normal_vec(d, m))
            .collect();
        for row in &rows {
            kv.append(row, row);
        }
        let mut out = vec![0.0; d];
        for (t, row) in rows.iter().enumerate() {
            let max = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            kv.decode_k(0, t, &mut out);
            for (a, b) in out.iter().zip(row) {
                // half-step of THIS token's scale, not the batch max
                assert!(
                    (a - b).abs() <= max / 127.0 * 0.51 + 1e-7,
                    "t={t}: {a} vs {b} (scale step {})",
                    max / 127.0
                );
            }
        }
    }

    #[test]
    fn quantization_shrinks_memory() {
        let mk = |p| SeqKv::new(8, 64, 128, p).allocated_bytes();
        let f16 = mk(Precision::F16);
        let i8b = mk(Precision::Int8);
        let i4b = mk(Precision::Int4);
        assert!(i8b < f16);
        assert!(i4b < i8b);
        // §5.2: int4 payload is a quarter of fp16 (scales add a little)
        assert!((i4b as f64) < 0.3 * f16 as f64);
    }

    #[test]
    fn socket_cache_lifecycle() {
        let mut sc = SocketCache::new(2, 4, 3, 8, Precision::F16);
        sc.add_seq(7);
        sc.add_seq(9);
        let mut rng = Rng::new(1);
        let k = rng.normal_vec(8, 1.0);
        let v = rng.normal_vec(8, 1.0);
        for layer in 0..3 {
            sc.get_mut(7, layer).append(&k, &v);
        }
        sc.get_mut(9, 0).append(&k, &v);
        let st = sc.stats();
        assert_eq!(st.sequences, 2);
        assert_eq!(st.total_tokens, 4);
        assert!(sc.drop_seq(7));
        assert!(!sc.drop_seq(7));
        assert_eq!(sc.stats().sequences, 1);
    }

    #[test]
    #[should_panic(expected = "already present")]
    fn duplicate_seq_panics() {
        let mut sc = SocketCache::new(1, 2, 1, 4, Precision::F16);
        sc.add_seq(1);
        sc.add_seq(1);
    }
}
