//! KV-cache storage near the CPU (paper §4.1, §5.1–5.2), PAGED.
//!
//! Storage is a per-socket block arena ([`BlockPool`]): fixed-size KV
//! blocks, each laid out `[heads][block_size][dim]` so the per-head
//! attention scan stays contiguous *within a block*. A sequence maps to
//! one **block table** per layer (ordered block indices); the attention
//! hot loop walks the table block by block, threading the online-softmax
//! state across block boundaries — bit-identical to a contiguous scan.
//!
//! Why paging: the contiguous store reserved full `capacity_per_seq`
//! per layer at admission, so the batch ceiling (the paper's central
//! fight) was set by worst-case length. Paged allocation charges actual
//! occupancy, one block at a time.
//!
//! **COW prefix sharing**: `fork_seq(parent, child, upto)` makes the
//! child reference the parent's first `ceil(upto / block_size)` blocks
//! (refcounted, not copied). N sequences sharing a system prompt pay
//! for its KV once. The first append past the fork point triggers
//! copy-on-write of the tail block; everything earlier stays shared for
//! both lifetimes — dropping the parent only releases its references.
//!
//! **Block-size tradeoff**: small blocks minimize padding waste (at
//! most `block_size − 1` slack tokens per (seq, layer)) and maximize
//! shareable prefix granularity, but grow the table and add a per-block
//! loop-restart cost in the attend kernel; large blocks amortize the
//! scan but waste tail space and round fork points down harder
//! (`shared = ceil(upto / block_size)` blocks, with a COW copy for a
//! mid-block fork on first divergence). Default 16 suits the tiny
//! models here; production sizes (cf. vLLM) sit at 16–32 tokens.
//!
//! [`CacheStats`] reports both views: `total_tokens`/`logical_bytes`
//! (what sequences believe they hold) and `physical_tokens`/
//! `allocated_bytes` (unique blocks actually resident — shared blocks
//! counted once). `utilization()` = logical/allocated: below 1.0 is
//! block padding, above 1.0 is the sharing win.
//!
//! Element formats (`model::Precision`): fp16 (lossless vs the fp16 GPU
//! baseline), int8 and int4 with one scale per (head, token) — §5.2's
//! quantization hooks. Scales live inside their block, so a block is
//! self-contained and COW copies carry them along.
//!
//! [`SeqKv`] — the original contiguous per-sequence store — remains as
//! the single-block payload and as the reference/shadow implementation
//! the property tests pin the paged store against.

mod quant;
mod store;

pub use quant::{
    dequant_i4, dequant_i8, nibble_pair_lut, nibble_to_i32, quant_i4,
    quant_i8,
};
pub use store::{
    kv_token_bytes, BlockPool, CacheStats, PagedKv, SeqKv, SocketCache,
};
