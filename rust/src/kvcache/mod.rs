//! KV-cache storage near the CPU (paper §4.1, §5.1–5.2).
//!
//! Each R-worker socket owns the KV-cache of its assigned sequences.
//! Storage is per-sequence, per-layer, laid out `[heads][capacity][dim]`
//! so the per-head attention scan is contiguous. Element formats
//! (`model::Precision`): fp16 (lossless vs the fp16 GPU baseline), int8
//! and int4 with one scale per (head, token) — §5.2's quantization hooks.

mod quant;
mod store;

pub use quant::{
    dequant_i4, dequant_i8, nibble_pair_lut, nibble_to_i32, quant_i4,
    quant_i8,
};
pub use store::{CacheStats, SeqKv, SocketCache};
