//! FastDecode — high-throughput GPU-efficient LLM serving using
//! heterogeneous pipelines (reproduction of He & Zhai, 2024).
//!
//! The transformer decode step is split at the paper's R/S boundary:
//! *S-Part* (shared-parameter matmuls) runs on the S-worker thread
//! (native Rust executor, `sworker::NativeSWorker`); *R-Part*
//! (per-sequence attention over the KV-cache) runs near the cache on CPU
//! R-worker socket threads. The coordinator pipelines the two at token
//! level — two mini-batches double-buffered over channels
//! (`runtime::pipeline`) — and stabilizes R-Part load at sequence level
//! (SLS + Algorithm 1). The `serve` subsystem layers request-level
//! continuous batching on top: open-loop arrivals, pluggable admission
//! policies under W_lim, batched prefill, and per-request latency
//! accounting. R-Part runs behind the pluggable
//! `rworker::AttendBackend` trait: in-process socket threads, or REAL
//! wire transport (`net`) to `rnode` host processes over loopback/TCP
//! with a length-prefixed fp16/fp32 activation codec. See DESIGN.md
//! for the system inventory and the per-experiment index.

pub mod analysis;
pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod perfmodel;
pub mod runtime;
pub mod rworker;
pub mod sched;
pub mod serve;
pub mod server;
pub mod sworker;
pub mod transport;
pub mod util;
pub mod workload;

/// Default artifacts directory, overridable with FASTDECODE_ARTIFACTS.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("FASTDECODE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            // Resolve relative to the crate root so tests/benches work
            // from any CWD.
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}
