//! FastDecode CLI: device tables, capacity planning, figure simulation,
//! and a real end-to-end demo on the tiny model.
//!
//! Offline environment: no clap — a small hand-rolled arg parser.

use anyhow::{Context, Result};

use fastdecode::bench::Table;
use fastdecode::coordinator::real::{FastDecode, FastDecodeConfig};
use fastdecode::coordinator::{simulate, SimConfig};
use fastdecode::model::ModelSpec;
use fastdecode::perfmodel::{
    CpuModel, GpuModel, PlanInput, Planner, A10, EPYC_7452, V100, XEON_5218,
};
use fastdecode::rworker::stream_bandwidth_probe;
use fastdecode::workload::fixed_batch;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let result = match cmd {
        "devices" => cmd_devices(),
        "plan" => cmd_plan(rest),
        "simulate" => cmd_simulate(rest),
        "probe" => cmd_probe(),
        "demo" => cmd_demo(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown command {other:?}"))
        }
    };
    if let Err(e) = result {
        // fdlint: allow(no-raw-eprintln): CLI error epilogue — the one place stderr IS the interface
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "fastdecode — heterogeneous-pipeline LLM serving (paper reproduction)

USAGE: fastdecode <command> [options]

COMMANDS:
  devices               print the Table 1 hardware comparison
  plan [--model M] [--seq S] [--latency SECONDS]
                        run the §4.3 planner: optimal (batch, sockets)
  simulate [--model M] [--batch B] [--seq S] [--sockets P] [--sls F]
                        virtual-clock run; prints per-step stats
  probe                 measure this machine's per-thread KV bandwidth
  demo [--batch B] [--steps N] [--sockets P] [--no-pipeline]
                        real end-to-end decode on the tiny model
                        (native S-worker + threaded R-pool)
"
    );
}

fn flag(rest: &[String], name: &str) -> Option<String> {
    rest.iter()
        .position(|a| a == name)
        .and_then(|i| rest.get(i + 1).cloned())
}

fn parse_model(rest: &[String]) -> Result<ModelSpec> {
    let name = flag(rest, "--model").unwrap_or_else(|| "llama7b".into());
    ModelSpec::by_name(&name).with_context(|| format!("unknown model {name}"))
}

fn cmd_devices() -> Result<()> {
    let mut t = Table::new(
        "Table 1: performance and power comparison",
        &["type", "model", "TDP", "TFLOPs", "W/TFLOP", "GB/s", "W/(GB/s)"],
    );
    for d in [XEON_5218, EPYC_7452, A10, V100] {
        t.row(&[
            d.kind.to_string(),
            d.name.to_string(),
            format!("{:.0} W", d.tdp_w),
            format!("{:.1}", d.flops / 1e12),
            format!("{:.2}", d.w_per_tflop()),
            format!("{:.0}", d.mem_bw / 1e9),
            format!("{:.2}", d.w_per_gbps()),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_plan(rest: &[String]) -> Result<()> {
    let spec = parse_model(rest)?;
    let seq: usize = flag(rest, "--seq")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1024);
    let latency: Option<f64> =
        flag(rest, "--latency").map(|s| s.parse()).transpose()?;
    let planner = Planner::new(GpuModel::new(A10), CpuModel::from_device(EPYC_7452));
    let r = planner.plan(
        &spec,
        PlanInput {
            seq_len: seq,
            latency_budget: latency,
            ..Default::default()
        },
    );
    println!("model {} (h={}, {} layers)", spec.name, spec.hidden, spec.n_layers);
    println!("  batch ℬ        = {}  (bound: {:?})", r.batch, r.batch_bound);
    println!("  sockets 𝒫      = {}", r.sockets);
    println!("  T(ℬ) per block = {:.3} ms", r.t_b * 1e3);
    println!("  step latency   = {:.1} ms", r.step_latency * 1e3);
    println!("  throughput     = {:.0} tok/s", r.throughput);
    Ok(())
}

fn cmd_simulate(rest: &[String]) -> Result<()> {
    let spec = parse_model(rest)?;
    let batch: usize = flag(rest, "--batch")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1024);
    let seq: usize = flag(rest, "--seq")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(1024);
    let sockets: usize = flag(rest, "--sockets")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(8);
    let sls: Option<usize> = flag(rest, "--sls").map(|s| s.parse()).transpose()?;

    let mut cfg = SimConfig::new(
        spec,
        GpuModel::new(A10),
        CpuModel::from_device(EPYC_7452),
        sockets,
        batch,
        seq,
    );
    cfg.sls_interval = sls;
    if sls.is_some() {
        cfg.steps = 2 * seq;
    }
    let trace = simulate(&cfg);
    println!(
        "{} B={batch} S={seq} P={sockets} sls={sls:?}: {} steps, \
         throughput {:.0} tok/s, max latency {:.1} ms, steady {:.1} ms",
        spec.name,
        trace.len(),
        trace.throughput(),
        trace.max_latency() * 1e3,
        trace.steady_latency(seq) * 1e3,
    );
    Ok(())
}

fn cmd_probe() -> Result<()> {
    let bw = stream_bandwidth_probe(64);
    println!(
        "per-thread KV streaming bandwidth: {:.2} GB/s (fp16 decode + online softmax)",
        bw / 1e9
    );
    println!("(calibrates CpuModel::from_measured for virtual-clock runs)");
    Ok(())
}

fn cmd_demo(rest: &[String]) -> Result<()> {
    let batch: usize = flag(rest, "--batch")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(8);
    let steps: usize = flag(rest, "--steps")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(32);
    let sockets: usize = flag(rest, "--sockets")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2);
    let pipelined = !rest.iter().any(|a| a == "--no-pipeline");
    let spec = fastdecode::model::TINY;
    let mut fd = FastDecode::new(
        spec,
        FastDecodeConfig {
            batch,
            sockets,
            pipelined,
            ..Default::default()
        },
    )?;
    println!(
        "backend: native S-worker thread + {sockets} R-socket threads \
         (pipelined: {pipelined})"
    );
    let prompts = fixed_batch(batch, 4, spec.vocab, 42);
    let start = std::time::Instant::now();
    let result = fd.generate(&prompts, steps)?;
    let dt = start.elapsed().as_secs_f64();
    println!(
        "generated {} tokens in {:.2} s — {:.1} tok/s; per-step {}",
        batch * steps,
        dt,
        (batch * steps) as f64 / dt,
        result.step_latency.summary_ms()
    );
    for (i, toks) in result.tokens.iter().take(3).enumerate() {
        println!("  seq {i}: {:?}...", &toks[..toks.len().min(12)]);
    }
    Ok(())
}
