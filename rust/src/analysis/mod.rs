//! fdlint — the project-invariant static analyzer.
//!
//! The correctness story of this repo rests on hand-maintained
//! disciplines that ordinary `rustc`/clippy cannot see: failures on
//! serving paths must be *routed* (`SResp::Err` / `NetResponse::Err` /
//! dead-node marking) rather than panicking, bit-identity-pinned
//! modules must iterate deterministically, the simulator must never
//! read the wall clock, and the wire codec's encoder, decoder, and
//! property-test corpus must cover every message variant in lockstep.
//! fdlint pins those invariants with a lightweight, fully offline
//! analyzer: a string/comment-aware lexer ([`lexer`]), per-line rules
//! plus one cross-file consistency check ([`rules`]), and a
//! suppress/baseline engine ([`engine`]) run as a CI gate by the
//! `fdlint` binary and by `tests/fdlint.rs`.
//!
//! # Rules
//!
//! - **`no-unwrap-in-routed`** — `.unwrap()` / `.expect(` are forbidden
//!   in `net/`, `rworker/`, `runtime/`, and `serve/`. These modules sit
//!   on the serving path where the routed-error discipline applies: a
//!   panic strands in-flight attends and poisons locks, whereas a
//!   routed error keeps survivors serving (PR 3/5 behavior, and the
//!   precondition for DéjàVu-style failover).
//! - **`no-panic-in-worker-loop`** — `panic!` / `unreachable!` /
//!   `todo!` are forbidden inside long-lived thread-loop bodies
//!   (`run_loop`, `s_worker_loop`, `serve_connection`,
//!   `serve_listener`). A panic there kills the thread, not the
//!   request: the failure must flow through the loop's error channel.
//! - **`no-raw-eprintln`** — `eprintln!` outside `obs/logging.rs` and
//!   `bin/` bypasses the leveled `obs::log!` sink added in PR 6 and
//!   corrupts benchmark stderr parsing.
//! - **`deterministic-iteration`** — `HashMap` / `HashSet` are flagged
//!   in the bit-identity-pinned modules `kvcache/`, `rworker/`, `net/`.
//!   Random iteration order reaching scatter order, stats output, or
//!   reduction order breaks the repo's bit-identity pins; use
//!   `BTreeMap` / sorted keys, or justify membership-only usage with
//!   an allow.
//! - **`wall-clock-in-sim`** — `Instant::now` / `SystemTime` are
//!   forbidden in `coordinator/sim.rs` and `perfmodel/`: the simulator
//!   and the §5 performance model are virtual-clock-pure and must stay
//!   reproducible.
//! - **`unsafe-needs-safety-comment`** — every `unsafe` must have a
//!   `// SAFETY:` comment within the five lines above it stating the
//!   invariant that makes it sound. Applies in test code too.
//! - **`codec-exhaustive`** — cross-file check that every
//!   `NetRequest` / `NetResponse` variant appears in
//!   `encode_request`/`encode_response`, in the decoder tag matches,
//!   and in the codec test corpus, and that the wire enums stay a
//!   mirror of the in-process `RRequest`/`RResponse` (minus the
//!   transport-only variants). This is the exact hazard PR 7's
//!   `ForkSeq` addition skated past by hand.
//! - **`malformed-suppression`** — a directive that matches the allow
//!   trigger but names an unknown rule or omits the reason is itself a
//!   violation. Suppressions fail open: a broken allow can never
//!   silently hide a finding.
//!
//! # Suppressing a finding
//!
//! Add a line comment on the offending line (or the line directly
//! above) naming the rule and a non-empty justification, e.g.:
//!
//! ```text
//! // fdlint: allow(deterministic-iteration): membership-only HashSet, order never observed
//! set.insert(id);
//! ```
//!
//! The rule name must be one of the rules above and the `: reason`
//! tail is mandatory — anything else is reported as
//! `malformed-suppression`.
//!
//! # The baseline ratchet
//!
//! `rust/fdlint.baseline` grandfathers pre-existing violations as
//! `rule path count` lines. The gate fails when a (rule, file) count
//! rises above its baseline **or** falls below it without the baseline
//! being updated — improvements must be locked in by ratcheting the
//! file down:
//!
//! ```text
//! cargo run --release --bin fdlint            # the CI gate
//! cargo run --release --bin fdlint -- --update-baseline
//! ```
//!
//! The analyzer runs over its own sources like any other module.

pub mod engine;
pub mod lexer;
pub mod rules;

pub use engine::{
    analyze, baseline_of, collect_sources, compare, format_baseline,
    parse_baseline, Analysis, Baseline,
};
pub use lexer::{lex, Line};
pub use rules::Violation;
