//! The fdlint engine: runs every rule over an in-memory source tree,
//! applies `fdlint: allow` suppressions, and checks the result against
//! the grandfathered-violation baseline (the CI ratchet).
//!
//! The core is filesystem-free — `analyze` takes a `BTreeMap` of
//! relative path → source text — so the ratchet semantics are unit- and
//! property-testable without touching disk. `collect_sources` is the
//! thin walker the `fdlint` binary and the integration gate use to
//! build that map from `rust/src`.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{bail, Context as _, Result};

use super::lexer::{lex, Line};
use super::rules::{self, Violation};

/// Grandfathered counts: `(rule, file)` → number of allowed violations.
pub type Baseline = BTreeMap<(String, String), usize>;

/// Result of one analyzer run over a source tree.
#[derive(Debug)]
pub struct Analysis {
    /// Unsuppressed violations, ordered by (file, line, rule).
    pub violations: Vec<Violation>,
    /// Violations silenced by a well-formed allow directive.
    pub allowed: usize,
    /// Files analyzed.
    pub files: usize,
}

/// The literal that opens a suppression directive in a comment. The
/// trigger is deliberately exact: a misspelled directive simply never
/// suppresses (the underlying violation still fails the build), while
/// anything matching the trigger must parse fully or it is reported as
/// a malformed-suppression violation — a suppression can fail open,
/// never silently.
const ALLOW_MARKER: &str = "fdlint: allow(";

/// Parse the directive body following [`ALLOW_MARKER`]: a known rule
/// name up to `)`, then `:` and a non-empty reason.
fn parse_allow_body(s: &str) -> Result<String, String> {
    let Some(close) = s.find(')') else {
        return Err("unclosed rule name in fdlint allow directive".to_string());
    };
    let rule = s[..close].trim();
    if !rules::RULES.iter().any(|r| *r == rule) {
        return Err(format!("fdlint allow names unknown rule `{rule}`"));
    }
    let rest = s[close + 1..].trim_start();
    let Some(reason) = rest.strip_prefix(':') else {
        return Err(format!(
            "fdlint allow for `{rule}` is missing a `: <reason>` tail"
        ));
    };
    if reason.trim().is_empty() {
        return Err(format!("fdlint allow for `{rule}` has an empty reason"));
    }
    Ok(rule.to_string())
}

/// Collect allow directives from the comment channel. A well-formed
/// allow covers its own line and the next line (so it works both as a
/// trailing comment and as a comment line directly above the site).
fn collect_allows(
    path: &str,
    lines: &[Line],
    allows: &mut BTreeSet<(String, String, usize)>,
    out: &mut Vec<Violation>,
) {
    for line in lines {
        let mut rest = line.comment.as_str();
        while let Some(pos) = rest.find(ALLOW_MARKER) {
            let after = &rest[pos + ALLOW_MARKER.len()..];
            match parse_allow_body(after) {
                Ok(rule) => {
                    allows.insert((path.to_string(), rule.clone(), line.number));
                    allows.insert((path.to_string(), rule, line.number + 1));
                }
                Err(message) => out.push(Violation {
                    rule: rules::MALFORMED_SUPPRESSION,
                    file: path.to_string(),
                    line: line.number,
                    message,
                }),
            }
            rest = after;
        }
    }
}

/// Run every rule over the tree and apply suppressions.
pub fn analyze(files: &BTreeMap<String, String>) -> Analysis {
    let mut raw = Vec::new();
    let mut allows: BTreeSet<(String, String, usize)> = BTreeSet::new();
    for (path, text) in files {
        let lines = lex(text);
        collect_allows(path, &lines, &mut allows, &mut raw);
        rules::check_file(path, &lines, &mut raw);
    }
    rules::check_codec(files, &mut raw);
    let mut violations = Vec::new();
    let mut allowed = 0usize;
    for v in raw {
        if allows.contains(&(v.file.clone(), v.rule.to_string(), v.line)) {
            allowed += 1;
        } else {
            violations.push(v);
        }
    }
    violations.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    Analysis {
        violations,
        allowed,
        files: files.len(),
    }
}

/// Aggregate violations into per-(rule, file) counts.
pub fn baseline_of(violations: &[Violation]) -> Baseline {
    let mut b = Baseline::new();
    for v in violations {
        *b.entry((v.rule.to_string(), v.file.clone())).or_insert(0) += 1;
    }
    b
}

/// Serialize a baseline in the checked-in `fdlint.baseline` format.
pub fn format_baseline(b: &Baseline) -> String {
    let mut s = String::from(
        "# fdlint baseline: grandfathered violations, one `rule path count`\n\
         # per line. New violations fail the build; fixing a grandfathered\n\
         # one requires ratcheting this file DOWN (the check also fails\n\
         # when a count is stale-high):\n\
         #     cargo run --bin fdlint -- --update-baseline\n",
    );
    for ((rule, file), count) in b {
        s.push_str(&format!("{rule} {file} {count}\n"));
    }
    s
}

/// Parse a checked-in baseline file.
pub fn parse_baseline(text: &str) -> Result<Baseline> {
    let mut b = Baseline::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        let (rule, file, count) = match fields.as_slice() {
            [rule, file, count] => (*rule, *file, *count),
            _ => bail!(
                "baseline line {}: expected `rule path count`, got {raw:?}",
                i + 1
            ),
        };
        if !rules::RULES.iter().any(|r| *r == rule) {
            bail!("baseline line {}: unknown rule {rule:?}", i + 1);
        }
        let count: usize = count
            .parse()
            .with_context(|| format!("baseline line {}: bad count", i + 1))?;
        if count == 0 {
            bail!(
                "baseline line {}: zero-count entry for {rule} {file} — \
                 delete the line instead",
                i + 1
            );
        }
        let prev = b.insert((rule.to_string(), file.to_string()), count);
        if prev.is_some() {
            bail!(
                "baseline line {}: duplicate entry for {rule} {file}",
                i + 1
            );
        }
    }
    Ok(b)
}

/// The ratchet: compare current per-(rule, file) counts against the
/// grandfathered baseline. Returns human-readable failures — empty
/// means the gate passes. A count above baseline is a regression; a
/// count below baseline is a stale baseline (the fix must ratchet the
/// file down so the improvement is locked in).
pub fn compare(
    current: &Baseline,
    grandfathered: &Baseline,
    violations: &[Violation],
) -> Vec<String> {
    let mut failures = Vec::new();
    for ((rule, file), &cur) in current {
        let base = grandfathered
            .get(&(rule.clone(), file.clone()))
            .copied()
            .unwrap_or(0);
        if cur > base {
            failures.push(format!(
                "{file}: {cur} violation(s) of `{rule}` (baseline allows \
                 {base})"
            ));
            for v in violations
                .iter()
                .filter(|v| v.rule == rule.as_str() && v.file == *file)
            {
                failures.push(format!("    {}:{}: {}", v.file, v.line, v.message));
            }
        }
    }
    for ((rule, file), &base) in grandfathered {
        let cur = current
            .get(&(rule.clone(), file.clone()))
            .copied()
            .unwrap_or(0);
        if cur < base {
            failures.push(format!(
                "stale baseline: `{rule}` in {file} grandfathers {base} but \
                 only {cur} remain — ratchet down with `cargo run --bin \
                 fdlint -- --update-baseline`"
            ));
        }
    }
    failures
}

/// Recursively collect `*.rs` files under `root` into relative-path →
/// source-text map (`/`-separated paths, sorted by the BTreeMap).
pub fn collect_sources(root: &Path) -> Result<BTreeMap<String, String>> {
    fn walk(
        dir: &Path,
        root: &Path,
        out: &mut BTreeMap<String, String>,
    ) -> Result<()> {
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("reading {}", dir.display()))?;
        for entry in entries {
            let path = entry
                .with_context(|| format!("walking {}", dir.display()))?
                .path();
            if path.is_dir() {
                walk(&path, root, out)?;
            } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
                let text = std::fs::read_to_string(&path)
                    .with_context(|| format!("reading {}", path.display()))?;
                let rel = path
                    .strip_prefix(root)
                    .unwrap_or(&path)
                    .to_string_lossy()
                    .replace('\\', "/");
                out.insert(rel, text);
            }
        }
        Ok(())
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn tree(path: &str, src: &str) -> BTreeMap<String, String> {
        let mut files = BTreeMap::new();
        files.insert(path.to_string(), src.to_string());
        files
    }

    /// The full gate as the binary and CI run it.
    fn gate(files: &BTreeMap<String, String>, baseline: &str) -> Vec<String> {
        let a = analyze(files);
        let gf = parse_baseline(baseline).expect("baseline parses");
        compare(&baseline_of(&a.violations), &gf, &a.violations)
    }

    #[test]
    fn clean_tree_passes_empty_baseline() {
        let files = tree("util/a.rs", "pub fn ok() -> u8 {\n    1\n}\n");
        assert!(gate(&files, "").is_empty());
    }

    #[test]
    fn new_violation_fails_empty_baseline() {
        let files = tree("net/a.rs", "fn f() {\n    x.unwrap();\n}\n");
        let failures = gate(&files, "");
        assert_eq!(failures.len(), 2, "{failures:?}");
        assert!(failures[0].contains("no-unwrap-in-routed"));
        assert!(failures[1].contains("net/a.rs:2"));
    }

    #[test]
    fn grandfathered_violation_passes_exact_baseline() {
        let files = tree("net/a.rs", "fn f() {\n    x.unwrap();\n}\n");
        assert!(gate(&files, "no-unwrap-in-routed net/a.rs 1\n").is_empty());
    }

    #[test]
    fn count_above_baseline_fails() {
        let files = tree(
            "net/a.rs",
            "fn f() {\n    x.unwrap();\n    y.unwrap();\n}\n",
        );
        let failures = gate(&files, "no-unwrap-in-routed net/a.rs 1\n");
        assert!(!failures.is_empty());
        assert!(failures[0].contains("baseline allows 1"), "{failures:?}");
    }

    #[test]
    fn stale_high_baseline_fails_until_ratcheted() {
        // the violation was fixed but the baseline still grandfathers 2:
        // the gate demands the ratchet move down
        let files = tree("net/a.rs", "fn f() {\n    x.unwrap();\n}\n");
        let failures = gate(&files, "no-unwrap-in-routed net/a.rs 2\n");
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("stale baseline"), "{failures:?}");
    }

    #[test]
    fn update_baseline_roundtrip_shrinks_and_passes() {
        let files = tree("net/a.rs", "fn f() {\n    x.unwrap();\n}\n");
        let a = analyze(&files);
        // what --update-baseline writes...
        let written = format_baseline(&baseline_of(&a.violations));
        // ...parses back to the exact current counts and gates clean
        let gf = parse_baseline(&written).unwrap();
        assert_eq!(gf.len(), 1);
        assert!(gate(&files, &written).is_empty());
    }

    #[test]
    fn baseline_rejects_garbage() {
        assert!(parse_baseline("not a baseline line\n").is_err());
        assert!(parse_baseline("made-up-rule net/a.rs 1\n").is_err());
        assert!(parse_baseline("no-unwrap-in-routed net/a.rs zero\n").is_err());
        assert!(parse_baseline("no-unwrap-in-routed net/a.rs 0\n").is_err());
        assert!(parse_baseline(
            "no-unwrap-in-routed net/a.rs 1\nno-unwrap-in-routed net/a.rs 2\n"
        )
        .is_err());
        assert!(parse_baseline("# comment\n\nno-raw-eprintln serve/e.rs 3\n")
            .is_ok());
    }

    // The allow-directive texts below live inside string literals, so
    // the self-scan of this file never parses them as real directives.

    #[test]
    fn trailing_allow_with_reason_suppresses() {
        let src = "fn f() {\n    x.unwrap(); // fdlint: \
                   allow(no-unwrap-in-routed): test fixture\n}\n";
        let a = analyze(&tree("net/a.rs", src));
        assert!(a.violations.is_empty(), "{:?}", a.violations);
        assert_eq!(a.allowed, 1);
    }

    #[test]
    fn allow_on_the_line_above_suppresses() {
        let src = "fn f() {\n    // fdlint: allow(no-unwrap-in-routed): \
                   test fixture\n    x.unwrap();\n}\n";
        let a = analyze(&tree("net/a.rs", src));
        assert!(a.violations.is_empty(), "{:?}", a.violations);
        assert_eq!(a.allowed, 1);
    }

    #[test]
    fn allow_does_not_reach_past_the_next_line() {
        let src = "fn f() {\n    // fdlint: allow(no-unwrap-in-routed): \
                   too far away\n    let ok = 1;\n    x.unwrap();\n}\n";
        let a = analyze(&tree("net/a.rs", src));
        assert_eq!(a.violations.len(), 1, "{:?}", a.violations);
        assert_eq!(a.allowed, 0);
    }

    #[test]
    fn allow_for_the_wrong_rule_does_not_suppress() {
        let src = "fn f() {\n    x.unwrap(); // fdlint: \
                   allow(no-raw-eprintln): wrong rule named\n}\n";
        let a = analyze(&tree("net/a.rs", src));
        assert_eq!(a.violations.len(), 1, "{:?}", a.violations);
    }

    #[test]
    fn allow_with_unknown_rule_is_malformed() {
        let src = "fn f() {\n    // fdlint: allow(no-such-rule): reason\n\
                       x();\n}\n";
        let a = analyze(&tree("util/a.rs", src));
        assert_eq!(a.violations.len(), 1, "{:?}", a.violations);
        assert_eq!(a.violations[0].rule, rules::MALFORMED_SUPPRESSION);
        assert!(a.violations[0].message.contains("unknown rule"));
    }

    #[test]
    fn allow_without_reason_is_malformed() {
        for src in [
            // missing the `: reason` tail entirely
            "fn f() {\n    // fdlint: allow(no-unwrap-in-routed)\n    \
             x.unwrap();\n}\n",
            // colon present but reason blank
            "fn f() {\n    // fdlint: allow(no-unwrap-in-routed):\n    \
             x.unwrap();\n}\n",
        ] {
            let a = analyze(&tree("net/a.rs", src));
            assert!(
                a.violations
                    .iter()
                    .any(|v| v.rule == rules::MALFORMED_SUPPRESSION),
                "{:?}",
                a.violations
            );
            // and the underlying violation still fires — a broken
            // suppression fails open
            assert!(
                a.violations
                    .iter()
                    .any(|v| v.rule == rules::NO_UNWRAP_IN_ROUTED),
                "{:?}",
                a.violations
            );
        }
    }

    #[test]
    fn directive_inside_a_string_is_inert() {
        // a directive-shaped string literal is neither a suppression
        // nor a malformed-suppression violation: only comment text is
        // parsed
        let src = "fn f() {\n    let s = \"fdlint: allow(bogus)\";\n}\n";
        let a = analyze(&tree("util/a.rs", src));
        assert!(a.violations.is_empty(), "{:?}", a.violations);
        assert_eq!(a.allowed, 0);
    }

    /// Property: rule patterns inside strings, raw strings and comments
    /// never fire; the violation count equals exactly the number of
    /// real code sites generated.
    #[test]
    fn prop_masked_channels_never_fire() {
        prop::check("fdlint-masking", 200, |g| {
            let mut src = String::from("pub fn f() {\n");
            let mut expected = 0usize;
            let n = g.usize_in(1, 13);
            for _ in 0..n {
                match g.usize_in(0, 5) {
                    0 => {
                        src.push_str("    x.unwrap();\n");
                        expected += 1;
                    }
                    1 => src.push_str(
                        "    let s = \".unwrap() HashMap eprintln!\";\n",
                    ),
                    2 => src.push_str(
                        "    // .unwrap() unsafe panic! in a comment\n",
                    ),
                    3 => src.push_str(
                        "    let r = r#\".expect( HashSet todo!\"#;\n",
                    ),
                    4 => src.push_str(
                        "    let c = '\\n'; let l: &'static str = \"x\";\n",
                    ),
                    _ => unreachable!("usize_in(0, 5) is half-open"),
                }
            }
            src.push_str("}\n");
            let a = analyze(&tree("net/gen.rs", src.as_str()));
            let unwraps = a
                .violations
                .iter()
                .filter(|v| v.rule == rules::NO_UNWRAP_IN_ROUTED)
                .count();
            assert_eq!(unwraps, expected, "source was:\n{src}");
            assert_eq!(
                a.violations.len(),
                expected,
                "unexpected extra rules fired for:\n{src}"
            );
        });
    }
}
