//! The fdlint rules: per-line pattern rules driven by the masked code
//! channel, plus the cross-file codec-exhaustive consistency check.
//! See `analysis` module docs for the catalogue with rationale.

use std::collections::BTreeMap;

use super::lexer::{mask_code, Line};

/// `.unwrap()` / `.expect(` are forbidden where the routed-error
/// discipline applies (`net/`, `rworker/`, `runtime/`, `serve/`).
pub const NO_UNWRAP_IN_ROUTED: &str = "no-unwrap-in-routed";
/// `panic!` / `unreachable!` / `todo!` forbidden inside thread loop
/// bodies (`run_loop`, `s_worker_loop`, `serve_connection`,
/// `serve_listener`).
pub const NO_PANIC_IN_WORKER_LOOP: &str = "no-panic-in-worker-loop";
/// Raw `eprintln!` outside `obs/logging.rs` and `bin/` — use
/// `obs::log!` so output is leveled and capturable.
pub const NO_RAW_EPRINTLN: &str = "no-raw-eprintln";
/// `HashMap` / `HashSet` in bit-identity-pinned modules (`kvcache/`,
/// `rworker/`, `net/`) — iteration order must be deterministic.
pub const DETERMINISTIC_ITERATION: &str = "deterministic-iteration";
/// `Instant::now` / `SystemTime` in the virtual-clock sim
/// (`coordinator/sim.rs`, `perfmodel/`).
pub const WALL_CLOCK_IN_SIM: &str = "wall-clock-in-sim";
/// Every `unsafe` needs a `// SAFETY:` comment on or just above it.
pub const UNSAFE_NEEDS_SAFETY_COMMENT: &str = "unsafe-needs-safety-comment";
/// Every `NetRequest`/`NetResponse` variant must appear in the encoder,
/// the decoder and the codec test corpus; `RRequest`/`RResponse` must
/// mirror them.
pub const CODEC_EXHAUSTIVE: &str = "codec-exhaustive";
/// An `fdlint: allow` directive that does not parse (unknown rule,
/// missing reason) is itself a violation — never a silent no-op.
pub const MALFORMED_SUPPRESSION: &str = "malformed-suppression";

/// Every active rule name (what allow directives and baselines may
/// reference).
pub const RULES: &[&str] = &[
    NO_UNWRAP_IN_ROUTED,
    NO_PANIC_IN_WORKER_LOOP,
    NO_RAW_EPRINTLN,
    DETERMINISTIC_ITERATION,
    WALL_CLOCK_IN_SIM,
    UNSAFE_NEEDS_SAFETY_COMMENT,
    CODEC_EXHAUSTIVE,
    MALFORMED_SUPPRESSION,
];

/// One finding, anchored at a line of a file (line 0 = file level).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

const ROUTED_DIRS: &[&str] = &["net/", "rworker/", "runtime/", "serve/"];
const PINNED_DIRS: &[&str] = &["kvcache/", "rworker/", "net/"];
const WORKER_LOOP_FNS: &[&str] =
    &["run_loop", "s_worker_loop", "serve_connection", "serve_listener"];
const PANIC_TOKENS: &[&str] = &["panic!", "unreachable!", "todo!"];

fn in_dirs(path: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| path.starts_with(d))
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Substring search with identifier-boundary checks at whichever ends
/// of `token` are identifier characters (so `unsafe` does not match
/// inside `UnwindSafe`, but `.unwrap()` matches after any receiver).
fn has_token(code: &str, token: &str) -> bool {
    token_pos(code, token).is_some()
}

/// Like [`has_token`] but returns the byte offset of the first match.
fn token_pos(code: &str, token: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let head_is_ident = token.starts_with(|c: char| c.is_alphanumeric() || c == '_');
    let tail_is_ident = token.ends_with(|c: char| c.is_alphanumeric() || c == '_');
    let mut start = 0;
    while let Some(pos) = code[start..].find(token) {
        let at = start + pos;
        let end = at + token.len();
        let before_ok = !head_is_ident || at == 0 || !is_ident_byte(bytes[at - 1]);
        let after_ok =
            !tail_is_ident || end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return Some(at);
        }
        start = at + 1;
    }
    None
}

/// 1-based line number of byte offset `pos` in `text`.
fn line_of(text: &str, pos: usize) -> usize {
    text.as_bytes()[..pos].iter().filter(|&&b| b == b'\n').count() + 1
}

/// Byte span of the brace block following `from`: `(open + 1, close)`,
/// i.e. the content between the braces.
fn block_after(code: &str, from: usize) -> Option<(usize, usize)> {
    let open = from + code[from..].find('{')?;
    let mut depth = 0usize;
    for (off, c) in code[open..].char_indices() {
        match c {
            '{' => depth += 1,
            '}' => {
                depth -= 1;
                if depth == 0 {
                    return Some((open + 1, open + off));
                }
            }
            _ => {}
        }
    }
    None
}

/// Byte span of the body of `fn <name>` in masked code.
pub(crate) fn fn_body_span(code: &str, name: &str) -> Option<(usize, usize)> {
    let pos = token_pos(code, &format!("fn {name}"))?;
    block_after(code, pos)
}

/// Line ranges (inclusive) of the worker-loop function bodies present
/// in this file.
fn worker_loop_ranges(code: &str) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    for name in WORKER_LOOP_FNS {
        if let Some((a, b)) = fn_body_span(code, name) {
            ranges.push((line_of(code, a), line_of(code, b)));
        }
    }
    ranges
}

/// True when any of lines `number-5 ..= number` carries a `SAFETY:`
/// marker in its comment channel.
fn has_safety_comment(lines: &[Line], number: usize) -> bool {
    let lo = number.saturating_sub(6); // 0-based index of number-5
    lines[lo..number]
        .iter()
        .any(|l| l.comment.contains("SAFETY:"))
}

/// Run every per-file rule over one lexed file.
pub fn check_file(path: &str, lines: &[Line], out: &mut Vec<Violation>) {
    let routed = in_dirs(path, ROUTED_DIRS);
    let pinned = in_dirs(path, PINNED_DIRS);
    let sim = path == "coordinator/sim.rs" || path.starts_with("perfmodel/");
    let eprintln_exempt =
        path.starts_with("bin/") || path == "obs/logging.rs";
    let joined: String = lines
        .iter()
        .map(|l| l.code.as_str())
        .collect::<Vec<_>>()
        .join("\n");
    let loop_ranges = worker_loop_ranges(&joined);
    let mut push = |rule: &'static str, line: usize, message: String| {
        out.push(Violation {
            rule,
            file: path.to_string(),
            line,
            message,
        });
    };
    for line in lines {
        // unsafe discipline applies everywhere, test code included
        if has_token(&line.code, "unsafe")
            && !has_safety_comment(lines, line.number)
        {
            push(
                UNSAFE_NEEDS_SAFETY_COMMENT,
                line.number,
                "`unsafe` without a `// SAFETY:` comment on or just above it"
                    .to_string(),
            );
        }
        if line.in_test {
            continue;
        }
        if routed
            && (has_token(&line.code, ".unwrap()")
                || has_token(&line.code, ".expect("))
        {
            push(
                NO_UNWRAP_IN_ROUTED,
                line.number,
                "unwrap/expect in a routed-error module — surface failures \
                 as Result (NetResponse::Err / dead-node paths) instead"
                    .to_string(),
            );
        }
        if pinned
            && (has_token(&line.code, "HashMap")
                || has_token(&line.code, "HashSet"))
        {
            push(
                DETERMINISTIC_ITERATION,
                line.number,
                "HashMap/HashSet in a bit-identity-pinned module — use \
                 BTreeMap/BTreeSet (or justify with an allow: never \
                 iterated, or iteration is order-independent)"
                    .to_string(),
            );
        }
        if sim
            && (has_token(&line.code, "Instant::now")
                || has_token(&line.code, "SystemTime"))
        {
            push(
                WALL_CLOCK_IN_SIM,
                line.number,
                "wall-clock read inside the virtual-clock sim — derive \
                 time from the simulated clock"
                    .to_string(),
            );
        }
        if !eprintln_exempt && has_token(&line.code, "eprintln!") {
            push(
                NO_RAW_EPRINTLN,
                line.number,
                "raw eprintln! — use obs::log! so output is leveled"
                    .to_string(),
            );
        }
        if loop_ranges
            .iter()
            .any(|&(a, b)| a <= line.number && line.number <= b)
        {
            for tok in PANIC_TOKENS {
                if has_token(&line.code, tok) {
                    push(
                        NO_PANIC_IN_WORKER_LOOP,
                        line.number,
                        format!(
                            "{tok} inside a worker loop body — a dead loop \
                             strands its channel peers; route the error"
                        ),
                    );
                }
            }
        }
    }
}

const CODEC_PATH: &str = "net/codec.rs";
const WORKER_PATH: &str = "rworker/worker.rs";

/// Variant names of `enum <name>` in masked code: blank every nested
/// `()`/`{}`/`[]` group inside the enum body, then the first identifier
/// of each comma piece is a variant.
fn enum_variants(code: &str, name: &str) -> Option<Vec<String>> {
    let pos = token_pos(code, &format!("enum {name}"))?;
    let (a, b) = block_after(code, pos)?;
    let mut top = String::new();
    let mut depth = 0usize;
    for c in code[a..b].chars() {
        match c {
            '{' | '(' | '[' => {
                depth += 1;
                top.push(' ');
            }
            '}' | ')' | ']' => {
                depth = depth.saturating_sub(1);
                top.push(' ');
            }
            _ if depth > 0 => top.push(' '),
            _ => top.push(c),
        }
    }
    let mut vars = Vec::new();
    for piece in top.split(',') {
        let ident: String = piece
            .chars()
            .skip_while(|c| !(c.is_alphanumeric() || *c == '_'))
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if !ident.is_empty() {
            vars.push(ident);
        }
    }
    Some(vars)
}

/// The cross-file codec-exhaustive check (see [`CODEC_EXHAUSTIVE`]).
/// Skipped silently when `net/codec.rs` is absent from the tree (unit
/// tests analyze synthetic trees); the integration gate always hands it
/// the real sources.
pub fn check_codec(files: &BTreeMap<String, String>, out: &mut Vec<Violation>) {
    let Some(codec_src) = files.get(CODEC_PATH) else {
        return;
    };
    let codec = mask_code(codec_src);
    let mut anchored = |line: usize, message: String| {
        out.push(Violation {
            rule: CODEC_EXHAUSTIVE,
            file: CODEC_PATH.to_string(),
            line,
            message,
        });
    };
    let tests_span =
        token_pos(&codec, "mod tests").and_then(|p| block_after(&codec, p));
    let mut wire_variants: BTreeMap<&str, Vec<String>> = BTreeMap::new();
    for (enum_name, enc_fn, dec_fn) in [
        ("NetRequest", "encode_request", "decode_request"),
        ("NetResponse", "encode_response", "decode_response"),
    ] {
        let Some(vars) = enum_variants(&codec, enum_name) else {
            anchored(0, format!("enum {enum_name} not found in {CODEC_PATH}"));
            continue;
        };
        let enum_line = token_pos(&codec, &format!("enum {enum_name}"))
            .map(|p| line_of(&codec, p))
            .unwrap_or(0);
        for (fn_name, span) in [
            (enc_fn, fn_body_span(&codec, enc_fn)),
            (dec_fn, fn_body_span(&codec, dec_fn)),
        ] {
            let Some((a, b)) = span else {
                anchored(0, format!("fn {fn_name} not found in {CODEC_PATH}"));
                continue;
            };
            for v in &vars {
                let qualified = format!("{enum_name}::{v}");
                if !has_token(&codec[a..b], &qualified) {
                    anchored(
                        enum_line,
                        format!(
                            "variant {qualified} is not handled in {fn_name} \
                             — encoder/decoder drifted from the enum"
                        ),
                    );
                }
            }
        }
        match tests_span {
            Some((a, b)) => {
                for v in &vars {
                    let qualified = format!("{enum_name}::{v}");
                    if !has_token(&codec[a..b], &qualified) {
                        anchored(
                            enum_line,
                            format!(
                                "variant {qualified} never appears in the \
                                 codec test corpus (mod tests) — round-trip \
                                 coverage drifted from the enum"
                            ),
                        );
                    }
                }
            }
            None => anchored(0, format!("mod tests not found in {CODEC_PATH}")),
        }
        wire_variants.insert(enum_name, vars);
    }
    // Mirror check: the in-process protocol (RRequest/RResponse) and
    // the wire protocol must stay in lockstep. Wire-only variants are
    // exempt: Configure/Ping/FetchTrace are connection setup and
    // observability of the process boundary itself (meaningless
    // in-process); Err/Pong/Trace are their replies (in-proc failures
    // are routed through the channel itself).
    let Some(worker_src) = files.get(WORKER_PATH) else {
        return;
    };
    let worker = mask_code(worker_src);
    for (local, wire, wire_only) in [
        (
            "RRequest",
            "NetRequest",
            &["Configure", "Ping", "FetchTrace", "NodeStats"][..],
        ),
        (
            "RResponse",
            "NetResponse",
            &["Err", "Pong", "Trace", "NodeStats"][..],
        ),
    ] {
        let Some(wire_vars) = wire_variants.get(wire) else {
            continue;
        };
        let Some(local_vars) = enum_variants(&worker, local) else {
            out.push(Violation {
                rule: CODEC_EXHAUSTIVE,
                file: WORKER_PATH.to_string(),
                line: 0,
                message: format!("enum {local} not found in {WORKER_PATH}"),
            });
            continue;
        };
        let local_line = token_pos(&worker, &format!("enum {local}"))
            .map(|p| line_of(&worker, p))
            .unwrap_or(0);
        for v in &local_vars {
            if !wire_vars.iter().any(|w| w == v) {
                out.push(Violation {
                    rule: CODEC_EXHAUSTIVE,
                    file: WORKER_PATH.to_string(),
                    line: local_line,
                    message: format!(
                        "{local}::{v} has no {wire} counterpart — the wire \
                         protocol cannot express it"
                    ),
                });
            }
        }
        for v in wire_vars {
            if !wire_only.contains(&v.as_str())
                && !local_vars.iter().any(|l| l == v)
            {
                out.push(Violation {
                    rule: CODEC_EXHAUSTIVE,
                    file: WORKER_PATH.to_string(),
                    line: local_line,
                    message: format!(
                        "{wire}::{v} has no {local} counterpart — rnode \
                         cannot serve it in-process"
                    ),
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn violations(path: &str, src: &str) -> Vec<Violation> {
        let mut out = Vec::new();
        check_file(path, &lex(src), &mut out);
        out
    }

    fn count(hits: &[Violation], rule: &str) -> usize {
        hits.iter().filter(|v| v.rule == rule).count()
    }

    #[test]
    fn unwrap_flagged_in_routed_dirs_only() {
        let src = "fn f() {\n    x.unwrap();\n    y.expect(\"z\");\n}\n";
        let hits = violations("net/a.rs", src);
        assert_eq!(count(&hits, NO_UNWRAP_IN_ROUTED), 2, "{hits:?}");
        assert_eq!(hits[0].line, 2);
        let outside = violations("util/a.rs", src);
        assert_eq!(count(&outside, NO_UNWRAP_IN_ROUTED), 0, "{outside:?}");
    }

    #[test]
    fn unwrap_in_test_mod_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() {\n        \
                   x.unwrap();\n    }\n}\n";
        assert!(violations("net/a.rs", src).is_empty());
    }

    #[test]
    fn unwrap_inside_string_never_fires() {
        let src = "fn f() {\n    let s = \"x.unwrap() y.expect(\";\n}\n";
        assert!(violations("net/a.rs", src).is_empty());
    }

    #[test]
    fn panic_only_flagged_in_worker_loop_bodies() {
        let src = "fn run_loop() {\n    panic!(\"boom\");\n}\n\
                   fn other() {\n    panic!(\"fine\");\n}\n";
        let hits = violations("rworker/a.rs", src);
        assert_eq!(count(&hits, NO_PANIC_IN_WORKER_LOOP), 1, "{hits:?}");
        let hit = hits
            .iter()
            .find(|v| v.rule == NO_PANIC_IN_WORKER_LOOP)
            .unwrap();
        assert_eq!(hit.line, 2);
    }

    #[test]
    fn unreachable_and_todo_flagged_in_loops() {
        let src =
            "fn serve_connection() {\n    unreachable!();\n    todo!();\n}\n";
        let hits = violations("net/r.rs", src);
        assert_eq!(count(&hits, NO_PANIC_IN_WORKER_LOOP), 2, "{hits:?}");
    }

    #[test]
    fn eprintln_exemptions() {
        let src = "fn f() {\n    eprintln!(\"x\");\n}\n";
        assert_eq!(count(&violations("serve/a.rs", src), NO_RAW_EPRINTLN), 1);
        assert_eq!(count(&violations("bin/tool.rs", src), NO_RAW_EPRINTLN), 0);
        assert_eq!(
            count(&violations("obs/logging.rs", src), NO_RAW_EPRINTLN),
            0
        );
    }

    #[test]
    fn hash_collections_flagged_in_pinned_dirs() {
        let src = "use std::collections::HashMap;\nfn f() {\n    \
                   let s: HashSet<u8> = HashSet::new();\n}\n";
        let hits = violations("kvcache/a.rs", src);
        assert_eq!(count(&hits, DETERMINISTIC_ITERATION), 2, "{hits:?}");
        let outside = violations("serve/a.rs", src);
        assert_eq!(count(&outside, DETERMINISTIC_ITERATION), 0, "{outside:?}");
    }

    #[test]
    fn wall_clock_flagged_in_sim_paths_only() {
        let src = "fn f() {\n    let t = Instant::now();\n    \
                   let s = SystemTime::now();\n}\n";
        assert_eq!(
            count(&violations("coordinator/sim.rs", src), WALL_CLOCK_IN_SIM),
            2
        );
        assert_eq!(
            count(&violations("perfmodel/planner.rs", src), WALL_CLOCK_IN_SIM),
            2
        );
        assert_eq!(
            count(&violations("coordinator/real.rs", src), WALL_CLOCK_IN_SIM),
            0
        );
    }

    #[test]
    fn unsafe_needs_a_safety_comment() {
        let bad = "fn f() {\n    unsafe { g() }\n}\n";
        assert_eq!(
            count(&violations("util/a.rs", bad), UNSAFE_NEEDS_SAFETY_COMMENT),
            1
        );
        let good = "fn f() {\n    // SAFETY: g has no preconditions\n    \
                    unsafe { g() }\n}\n";
        assert!(violations("util/a.rs", good).is_empty());
        // the word in a comment or a string is not unsafe code, and an
        // identifier merely containing it is not the keyword
        let masked = "fn f() {\n    // unsafe is discussed here\n    \
                      let s = \"unsafe\";\n    let unsafety = 1;\n}\n";
        assert!(violations("util/a.rs", masked).is_empty());
    }

    fn real_tree() -> BTreeMap<String, String> {
        let mut files = BTreeMap::new();
        files.insert(
            CODEC_PATH.to_string(),
            include_str!("../net/codec.rs").to_string(),
        );
        files.insert(
            WORKER_PATH.to_string(),
            include_str!("../rworker/worker.rs").to_string(),
        );
        files
    }

    #[test]
    fn real_codec_is_exhaustive() {
        let mut out = Vec::new();
        check_codec(&real_tree(), &mut out);
        assert!(out.is_empty(), "{out:?}");
    }

    /// The acceptance-criteria test: surgically remove one variant's
    /// decode arm from the real codec source (braces stay balanced;
    /// the encoder and the test corpus still mention the variant) and
    /// the codec-exhaustive rule must fail the build.
    #[test]
    fn removing_a_decode_arm_is_caught() {
        let mut files = real_tree();
        let src = files[CODEC_PATH].clone();
        let code = mask_code(&src);
        let (a, b) =
            fn_body_span(&code, "decode_request").expect("decode_request");
        let doctored = format!(
            "{}{}{}",
            &src[..a],
            src[a..b].replace("NetRequest::ForkSeq", "NetRequest::Stats"),
            &src[b..]
        );
        assert_ne!(doctored, src, "surgery must have changed the decoder");
        files.insert(CODEC_PATH.to_string(), doctored);
        let mut out = Vec::new();
        check_codec(&files, &mut out);
        assert!(
            out.iter().any(|v| v.rule == CODEC_EXHAUSTIVE
                && v.message.contains("NetRequest::ForkSeq")
                && v.message.contains("decode_request")),
            "{out:?}"
        );
    }

    #[test]
    fn dropping_a_variant_from_the_test_corpus_is_caught() {
        let mut files = real_tree();
        let src = files[CODEC_PATH].clone();
        let code = mask_code(&src);
        let (a, b) = token_pos(&code, "mod tests")
            .and_then(|p| block_after(&code, p))
            .expect("mod tests");
        let doctored = format!(
            "{}{}{}",
            &src[..a],
            src[a..b].replace("NetRequest::DropSeqs", "NetRequest::AddSeqs"),
            &src[b..]
        );
        assert_ne!(doctored, src, "surgery must have changed the corpus");
        files.insert(CODEC_PATH.to_string(), doctored);
        let mut out = Vec::new();
        check_codec(&files, &mut out);
        assert!(
            out.iter().any(|v| v.rule == CODEC_EXHAUSTIVE
                && v.message.contains("NetRequest::DropSeqs")
                && v.message.contains("test corpus")),
            "{out:?}"
        );
    }

    #[test]
    fn wire_and_inproc_enums_must_mirror() {
        let codec = "\
pub enum NetRequest { Ping, Pong }\n\
pub enum NetResponse { Ack, Err }\n\
fn encode_request() { NetRequest::Ping; NetRequest::Pong; }\n\
fn decode_request() { NetRequest::Ping; NetRequest::Pong; }\n\
fn encode_response() { NetResponse::Ack; NetResponse::Err; }\n\
fn decode_response() { NetResponse::Ack; NetResponse::Err; }\n\
mod tests { fn t() { NetRequest::Ping; NetRequest::Pong; \
NetResponse::Ack; NetResponse::Err; } }\n";
        let mut files = BTreeMap::new();
        files.insert(CODEC_PATH.to_string(), codec.to_string());
        files.insert(
            WORKER_PATH.to_string(),
            "pub enum RRequest { Ping, Pong }\npub enum RResponse { Ack }\n"
                .to_string(),
        );
        let mut out = Vec::new();
        check_codec(&files, &mut out);
        assert!(out.is_empty(), "{out:?}");
        // drop Pong from the in-proc protocol: the wire can say it but
        // the node cannot serve it — a mirror violation
        files.insert(
            WORKER_PATH.to_string(),
            "pub enum RRequest { Ping }\npub enum RResponse { Ack }\n"
                .to_string(),
        );
        let mut out = Vec::new();
        check_codec(&files, &mut out);
        assert!(
            out.iter().any(|v| v.message.contains("NetRequest::Pong")),
            "{out:?}"
        );
    }
}
