//! Comment/string/char-literal-aware lexer shared by every fdlint rule.
//!
//! `lex` splits a Rust source file into [`Line`]s carrying two aligned
//! channels: `code` (literals and comments blanked out, so a rule
//! pattern can never fire inside a string or a comment) and `comment`
//! (only comment text survives, which is where `SAFETY:` markers and
//! `fdlint` allow directives are read from). Both channels preserve the
//! byte length of the raw source — multi-byte characters are padded
//! with spaces — so a byte span found in one channel is valid in the
//! raw text too (the codec-exhaustive surgery test relies on this).
//!
//! The lexer understands: `//` line comments, nested `/* */` block
//! comments, `"..."` strings with escapes, `b"..."` byte strings,
//! `r"..."`/`r#"..."#`/`br#"..."#` raw strings with any number of
//! hashes, and char literals (`'x'`, `'\n'`, `b'x'`, `'\u{1F4A3}'`)
//! versus lifetimes (`'a`, `'static`), which stay in the code channel.
//!
//! It also tracks `#[cfg(test)]` regions by brace depth: the attribute
//! arms the tracker and the next `{` opens a test region until its
//! matching `}`. Most rules skip lines inside test regions (tests may
//! unwrap and panic freely).

/// One source line, split into aligned channels.
#[derive(Debug, Clone)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// Code channel: comment and literal bytes blanked with spaces.
    pub code: String,
    /// Comment channel: only comment text (sans the `//`/`/* */`
    /// markers) survives; everything else is blanked.
    pub comment: String,
    /// True when the line touches a `#[cfg(test)]` region.
    pub in_test: bool,
}

enum State {
    Code,
    LineComment,
    BlockComment { depth: usize },
    Str,
    RawStr { hashes: usize },
}

/// Push `c` to `out` as padding: newlines survive (they keep the line
/// split aligned across channels), everything else becomes one space
/// per byte.
fn pad(out: &mut String, c: char) {
    if c == '\n' {
        out.push('\n');
    } else {
        for _ in 0..c.len_utf8() {
            out.push(' ');
        }
    }
}

/// If position `i` (holding `r` or `b`) opens a raw/byte string
/// literal, return `(consumed_including_quote, hashes, is_raw)`.
fn literal_open(chars: &[char], i: usize) -> Option<(usize, usize, bool)> {
    let mut j = i + 1; // past the leading 'r' or 'b'
    let mut raw = chars[i] == 'r';
    if chars[i] == 'b' && chars.get(j) == Some(&'r') {
        raw = true;
        j += 1;
    }
    if raw {
        let mut hashes = 0;
        while chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        if chars.get(j) == Some(&'"') {
            return Some((j + 1 - i, hashes, true));
        }
        return None;
    }
    // b"..." byte string (escapes behave like a normal string)
    if chars.get(j) == Some(&'"') {
        return Some((2, 0, false));
    }
    None
}

/// If position `i` (holding `'`) starts a char literal, return its
/// total length in chars; `None` means it is a lifetime tick (or
/// malformed) and stays in the code channel.
fn char_literal_len(chars: &[char], i: usize) -> Option<usize> {
    match chars.get(i + 1) {
        Some('\\') => {
            // escaped form: skip the backslash and the escaped char,
            // then scan (bounded) for the closing quote — long enough
            // for '\u{10FFFF}', short enough to never swallow code
            let mut j = i + 3;
            while let Some(&c) = chars.get(j) {
                if c == '\'' {
                    return Some(j + 1 - i);
                }
                if c == '\n' || j > i + 12 {
                    return None;
                }
                j += 1;
            }
            None
        }
        Some(&c) if c != '\'' => {
            if chars.get(i + 2) == Some(&'\'') {
                Some(3)
            } else {
                None // lifetime: 'a, 'static, '_
            }
        }
        _ => None,
    }
}

/// True when the quote at `i` is followed by `hashes` `#` chars — the
/// closer of an `r#"..."#`-style literal.
fn closes_raw(chars: &[char], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Blank literals and comments out of `source`. Returns the code and
/// comment channels, each byte-length-equal to the input.
pub(crate) fn mask(source: &str) -> (String, String) {
    let chars: Vec<char> = source.chars().collect();
    let mut code = String::with_capacity(source.len());
    let mut comment = String::with_capacity(source.len());
    let mut state = State::Code;
    // whether the previous code char could end an identifier — tells
    // `r"raw"` apart from an identifier that happens to end in `r`
    let mut prev_ident = false;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        match state {
            State::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    pad(&mut code, '/');
                    pad(&mut comment, '/');
                    pad(&mut code, '/');
                    pad(&mut comment, '/');
                    i += 2;
                    state = State::LineComment;
                    prev_ident = false;
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    pad(&mut code, '/');
                    pad(&mut comment, '/');
                    pad(&mut code, '*');
                    pad(&mut comment, '*');
                    i += 2;
                    state = State::BlockComment { depth: 1 };
                    prev_ident = false;
                    continue;
                }
                if c == '"' {
                    pad(&mut code, c);
                    pad(&mut comment, c);
                    i += 1;
                    state = State::Str;
                    prev_ident = false;
                    continue;
                }
                if (c == 'r' || c == 'b') && !prev_ident {
                    if let Some((consumed, hashes, raw)) =
                        literal_open(&chars, i)
                    {
                        for k in 0..consumed {
                            pad(&mut code, chars[i + k]);
                            pad(&mut comment, chars[i + k]);
                        }
                        i += consumed;
                        state = if raw {
                            State::RawStr { hashes }
                        } else {
                            State::Str
                        };
                        prev_ident = false;
                        continue;
                    }
                }
                if c == '\'' {
                    if let Some(len) = char_literal_len(&chars, i) {
                        for k in 0..len {
                            pad(&mut code, chars[i + k]);
                            pad(&mut comment, chars[i + k]);
                        }
                        i += len;
                        prev_ident = false;
                        continue;
                    }
                }
                code.push(c);
                pad(&mut comment, c);
                prev_ident = c.is_alphanumeric() || c == '_';
                i += 1;
            }
            State::LineComment => {
                if c == '\n' {
                    code.push('\n');
                    comment.push('\n');
                    state = State::Code;
                } else {
                    pad(&mut code, c);
                    comment.push(c);
                }
                i += 1;
            }
            State::BlockComment { depth } => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    pad(&mut code, '*');
                    pad(&mut comment, '*');
                    pad(&mut code, '/');
                    pad(&mut comment, '/');
                    i += 2;
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment { depth: depth - 1 }
                    };
                    continue;
                }
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    pad(&mut code, '/');
                    pad(&mut comment, '/');
                    pad(&mut code, '*');
                    pad(&mut comment, '*');
                    i += 2;
                    state = State::BlockComment { depth: depth + 1 };
                    continue;
                }
                if c == '\n' {
                    code.push('\n');
                    comment.push('\n');
                } else {
                    pad(&mut code, c);
                    comment.push(c);
                }
                i += 1;
            }
            State::Str => {
                if c == '\\' && i + 1 < chars.len() {
                    pad(&mut code, c);
                    pad(&mut comment, c);
                    pad(&mut code, chars[i + 1]);
                    pad(&mut comment, chars[i + 1]);
                    i += 2;
                    continue;
                }
                if c == '"' {
                    pad(&mut code, c);
                    pad(&mut comment, c);
                    i += 1;
                    state = State::Code;
                    continue;
                }
                pad(&mut code, c);
                pad(&mut comment, c);
                i += 1;
            }
            State::RawStr { hashes } => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    for k in 0..=hashes {
                        pad(&mut code, chars[i + k]);
                        pad(&mut comment, chars[i + k]);
                    }
                    i += 1 + hashes;
                    state = State::Code;
                    continue;
                }
                pad(&mut code, c);
                pad(&mut comment, c);
                i += 1;
            }
        }
    }
    (code, comment)
}

/// Code channel only (comments and literals blanked; byte-length equal
/// to the input).
pub(crate) fn mask_code(source: &str) -> String {
    mask(source).0
}

/// Lex a source file into per-line channels plus `#[cfg(test)]` region
/// flags.
pub fn lex(source: &str) -> Vec<Line> {
    let (code, comment) = mask(source);
    let mut lines = Vec::new();
    let mut armed = false; // saw #[cfg(test)], waiting for its '{'
    let mut depth = 0usize;
    let mut test_depth: Option<usize> = None;
    for (idx, (code_l, comment_l)) in
        code.split('\n').zip(comment.split('\n')).enumerate()
    {
        let started_in_test = test_depth.is_some();
        if code_l.contains("#[cfg(test)]") {
            armed = true;
        }
        for ch in code_l.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if armed {
                        armed = false;
                        if test_depth.is_none() {
                            test_depth = Some(depth);
                        }
                    }
                }
                '}' => {
                    if test_depth == Some(depth) {
                        test_depth = None;
                    }
                    depth = depth.saturating_sub(1);
                }
                _ => {}
            }
        }
        lines.push(Line {
            number: idx + 1,
            code: code_l.to_string(),
            comment: comment_l.to_string(),
            in_test: started_in_test || test_depth.is_some(),
        });
    }
    lines
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> String {
        mask(src).0
    }

    fn comment_of(src: &str) -> String {
        mask(src).1
    }

    #[test]
    fn masks_string_literals() {
        let src = "let s = \".unwrap() HashMap panic!\"; s.len();";
        let code = code_of(src);
        assert!(!code.contains(".unwrap()"), "{code:?}");
        assert!(!code.contains("HashMap"), "{code:?}");
        assert!(code.contains("let s = "), "{code:?}");
        assert!(code.contains("s.len();"), "{code:?}");
        assert_eq!(code.len(), src.len());
    }

    #[test]
    fn masks_line_and_block_comments() {
        let src = "x(); // .unwrap() here\n/* HashMap\n * eprintln! */ y();";
        let code = code_of(src);
        assert!(!code.contains(".unwrap()"));
        assert!(!code.contains("HashMap"));
        assert!(!code.contains("eprintln!"));
        assert!(code.contains("x();"));
        assert!(code.contains("y();"));
        // ...while the comment channel keeps the text
        let comment = comment_of(src);
        assert!(comment.contains(".unwrap() here"));
        assert!(comment.contains("HashMap"));
        assert!(!comment.contains("x();"));
    }

    #[test]
    fn block_comments_nest() {
        let src = "/* outer /* inner */ still.unwrap() */ code()";
        let code = code_of(src);
        assert!(!code.contains("still.unwrap()"), "{code:?}");
        assert!(code.contains("code()"), "{code:?}");
    }

    #[test]
    fn masks_raw_strings_with_hashes() {
        let src = "let r = r#\"panic! \"quoted\" .expect(\"#; done();";
        let code = code_of(src);
        assert!(!code.contains("panic!"), "{code:?}");
        assert!(!code.contains(".expect("), "{code:?}");
        assert!(code.contains("done();"), "{code:?}");
    }

    #[test]
    fn masks_byte_and_raw_byte_strings() {
        let src = "let a = b\".unwrap()\"; let c = br#\"todo!\"#; ok();";
        let code = code_of(src);
        assert!(!code.contains(".unwrap()"));
        assert!(!code.contains("todo!"));
        assert!(code.contains("ok();"));
    }

    #[test]
    fn identifier_ending_in_r_is_not_a_raw_string() {
        let src = "let var = 1; let x = var\n    + 1;";
        let code = code_of(src);
        assert_eq!(code, src);
    }

    #[test]
    fn char_literals_masked_but_lifetimes_kept() {
        let src = "fn f<'a>(x: &'a str) { let c = '\"'; let d = '\\n'; }";
        let code = code_of(src);
        assert!(code.contains("<'a>"), "{code:?}");
        assert!(code.contains("&'a str"), "{code:?}");
        // the quote chars inside the literals must not open strings
        assert!(code.contains("let d = "), "{code:?}");
        assert!(!code.contains('"'), "{code:?}");
    }

    #[test]
    fn string_escapes_do_not_end_the_string() {
        let src = "let s = \"a\\\" .unwrap() b\"; tail();";
        let code = code_of(src);
        assert!(!code.contains(".unwrap()"), "{code:?}");
        assert!(code.contains("tail();"), "{code:?}");
    }

    #[test]
    fn multibyte_chars_pad_to_equal_byte_length() {
        let src = "// 𝒫 sockets → θ\nlet x = \"π\"; y();";
        let (code, comment) = mask(src);
        assert_eq!(code.len(), src.len());
        assert_eq!(comment.len(), src.len());
        assert!(code.contains("y();"));
    }

    #[test]
    fn cfg_test_region_tracking() {
        let src = "fn prod() {\n    x.unwrap();\n}\n\n#[cfg(test)]\n\
                   mod tests {\n    fn t() {\n        y.unwrap();\n    }\n}\n\
                   fn after() {}\n";
        let lines = lex(src);
        assert!(!lines[1].in_test, "prod body");
        assert!(!lines[4].in_test, "the attribute line itself");
        assert!(lines[5].in_test, "mod tests opener");
        assert!(lines[7].in_test, "test body");
        assert!(lines[9].in_test, "closing brace of the test mod");
        assert!(!lines[10].in_test, "code after the test mod");
    }

    #[test]
    fn braces_inside_strings_do_not_move_depth() {
        let src = "#[cfg(test)]\nmod tests {\n    let s = \"}}}}\";\n    \
                   z.unwrap();\n}\n";
        let lines = lex(src);
        assert!(lines[3].in_test, "stray braces in a string closed the mod");
    }

    #[test]
    fn line_numbers_are_one_based_and_aligned() {
        let src = "a\nb\nc";
        let lines = lex(src);
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].number, 1);
        assert_eq!(lines[2].number, 3);
        assert_eq!(lines[2].code, "c");
    }
}
