//! Performance modeling (paper §4.3): device specs (Table 1), the GPU
//! roofline T(ℬ) (Fig 1/3), the CPU R-Part cost model, and the
//! (ℬ, 𝒫) planner implementing equations 7, 9 and 11.

mod devices;
mod gpu;
mod planner;

pub use devices::{DeviceSpec, A10, EPYC_7452, V100, XEON_5218};
pub use gpu::{CpuModel, GpuModel};
pub use planner::{PlanInput, Planner, PlannerResult};
