//! The (ℬ, 𝒫) planner — paper §4.3, equations 7, 9 and 11.
//!
//! Given a model, a GPU model (providing 𝕋(ℬ)), a CPU model (providing
//! R), an expected sequence length 𝒮 and an optional per-sequence latency
//! budget L, pick:
//!   ℬ — the largest batch meeting 2·N·𝒮·𝕋(ℬ) ≤ L (eq. 7), or the knee
//!       of E(ℬ) = ℬ/𝕋(ℬ) when unconstrained (eq. 8);
//!   𝒫 — the fewest CPU sockets whose aggregate R-Part latency matches
//!       𝕋(ℬ) (eq. 10 → 11), subject to the memory constraint (eq. 9).

use crate::model::{ModelSpec, Precision};
use crate::obs::NodeProfile;

use super::gpu::{CpuModel, GpuModel};

#[derive(Clone, Copy, Debug)]
pub struct PlanInput {
    /// Expected (maximum) generated sequence length 𝒮.
    pub seq_len: usize,
    /// Optional end-to-end per-sequence latency budget L, seconds.
    pub latency_budget: Option<f64>,
    /// KV tokens one socket's memory can hold (C in eq. 9).
    pub tokens_per_socket: usize,
    /// KV storage precision.
    pub precision: Precision,
    /// Knee threshold: stop growing ℬ when doubling it improves E(ℬ)
    /// by less than this factor (paper: "increasing it brings marginal
    /// throughput improvement").
    pub knee_gain: f64,
}

impl Default for PlanInput {
    fn default() -> Self {
        PlanInput {
            seq_len: 1024,
            latency_budget: None,
            // 256 GB socket, 7b-scale KV (512 KiB/token) ≈ 500k tokens;
            // conservative default:
            tokens_per_socket: 400_000,
            precision: Precision::F16,
            knee_gain: 1.10,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlannerResult {
    /// Chosen batch size ℬ.
    pub batch: usize,
    /// Minimum CPU sockets 𝒫 (eq. 11, rounded up).
    pub sockets: usize,
    /// 𝕋(ℬ): per-block S-Part latency at ℬ, seconds.
    pub t_b: f64,
    /// Modeled per-token step latency (2·N·𝕋(ℬ)), seconds.
    pub step_latency: f64,
    /// Modeled aggregate throughput, tokens/second.
    pub throughput: f64,
    /// Which constraint bound ℬ.
    pub batch_bound: BatchBound,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BatchBound {
    /// eq. 7 latency budget.
    Latency,
    /// eq. 8 knee of E(ℬ).
    Knee,
    /// eq. 9 socket memory (with the planned 𝒫).
    Memory,
}

pub struct Planner {
    pub gpu: GpuModel,
    pub cpu: CpuModel,
}

impl Planner {
    pub fn new(gpu: GpuModel, cpu: CpuModel) -> Planner {
        Planner { gpu, cpu }
    }

    /// A planner whose CPU model is MEASURED, not assumed: ingest the
    /// live per-node [`NodeProfile`]s (as surfaced by
    /// `AttendBackend::net_stats`) and use their mean EWMA KV-streaming
    /// bandwidth as the per-socket R-Part rate — replacing the
    /// assumed-equal Table 1 device model with what the deployed,
    /// possibly heterogeneous nodes actually sustain. Profiles with no
    /// samples are ignored; with no sampled profile at all the
    /// `fallback` CPU model is used unchanged.
    pub fn from_measured_profiles(
        gpu: GpuModel,
        profiles: &[NodeProfile],
        fallback: CpuModel,
    ) -> Planner {
        let sampled: Vec<f64> = profiles
            .iter()
            .filter(|p| p.samples() > 0 && p.bytes_per_s > 0.0)
            .map(|p| p.bytes_per_s)
            .collect();
        let cpu = if sampled.is_empty() {
            fallback
        } else {
            CpuModel::from_measured(
                sampled.iter().sum::<f64>() / sampled.len() as f64,
            )
        };
        Planner { gpu, cpu }
    }

    /// eq. 7 left side: modeled latency to generate one full sequence.
    pub fn sequence_latency(&self, spec: &ModelSpec, b: usize, s: usize) -> f64 {
        2.0 * spec.n_layers as f64
            * s as f64
            * self.gpu.s_part_latency(spec, b)
    }

    /// eq. 11: 𝒫 ≈ ½·𝒮·R·E(ℬ), with R from the CPU model. The ½ comes
    /// from the SLS schedule holding aggregate context at ℬ𝒮/2.
    pub fn min_sockets(
        &self,
        spec: &ModelSpec,
        b: usize,
        s: usize,
        prec: Precision,
    ) -> usize {
        let r = self.cpu.r_coeff(spec, prec);
        let e = self.gpu.efficiency(spec, b);
        let p = 0.5 * s as f64 * r * e;
        p.ceil().max(1.0) as usize
    }

    pub fn plan(&self, spec: &ModelSpec, input: PlanInput) -> PlannerResult {
        // Sweep ℬ over powers of two (the paper evaluates the same grid).
        let mut chosen = 1usize;
        let mut bound = BatchBound::Knee;
        let mut b = 1usize;
        loop {
            let next = b * 2;
            // eq. 7: latency budget on the *next* candidate
            if let Some(l) = input.latency_budget {
                if self.sequence_latency(spec, next, input.seq_len) > l {
                    bound = BatchBound::Latency;
                    break;
                }
            }
            // eq. 8: knee detection
            let gain = self.gpu.efficiency(spec, next)
                / self.gpu.efficiency(spec, b);
            if gain < input.knee_gain {
                bound = BatchBound::Knee;
                break;
            }
            b = next;
            if b >= 1 << 20 {
                break; // safety rail
            }
        }
        chosen = chosen.max(b);

        let mut sockets =
            self.min_sockets(spec, chosen, input.seq_len, input.precision);

        // eq. 9: ½·ℬ·𝒮 ≤ C·𝒫 — shrink ℬ or add sockets. The paper notes
        // this "is barely the actual limitation"; we add sockets first
        // (cheap), and only shrink ℬ if even a huge pool cannot hold it.
        let need_tokens = |b: usize| b * input.seq_len / 2;
        while need_tokens(chosen) > input.tokens_per_socket * sockets {
            if sockets < 1024 {
                sockets += 1;
            } else {
                chosen /= 2;
                bound = BatchBound::Memory;
                sockets =
                    self.min_sockets(spec, chosen, input.seq_len, input.precision);
            }
        }

        let t_b = self.gpu.s_part_latency(spec, chosen);
        let step_latency = 2.0 * spec.n_layers as f64 * t_b;
        PlannerResult {
            batch: chosen,
            sockets,
            t_b,
            step_latency,
            throughput: chosen as f64 / step_latency,
            batch_bound: bound,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LLAMA_13B, LLAMA_7B, OPT_175B};
    use crate::perfmodel::devices::{A10, EPYC_7452};

    fn planner() -> Planner {
        Planner::new(GpuModel::new(A10), CpuModel::from_device(EPYC_7452))
    }

    #[test]
    fn unconstrained_plan_lands_past_the_knee() {
        let p = planner();
        let r = p.plan(&LLAMA_7B, PlanInput::default());
        // paper operates at ℬ ∈ [128, 1024+]
        assert!(r.batch >= 128, "batch {}", r.batch);
        assert!(r.sockets >= 1);
        assert!(r.throughput > 100.0);
    }

    #[test]
    fn latency_budget_caps_batch() {
        let p = planner();
        let loose = p.plan(&LLAMA_7B, PlanInput::default());
        let tight = p.plan(
            &LLAMA_7B,
            PlanInput {
                latency_budget: Some(60.0), // 60 s for a 1024-token sequence
                ..Default::default()
            },
        );
        assert!(tight.batch <= loose.batch);
        assert_eq!(tight.batch_bound, BatchBound::Latency);
    }

    /// §4.3's closing claim: 𝒫 ∝ 1/h — larger models need FEWER sockets
    /// per GPU (motivates Fig 14 using opt-175b with 2 sockets).
    #[test]
    fn bigger_models_need_fewer_sockets() {
        let p = planner();
        let b = 512;
        let s7 = p.min_sockets(&LLAMA_7B, b, 1024, Precision::F16);
        let s13 = p.min_sockets(&LLAMA_13B, b, 1024, Precision::F16);
        let s175 = p.min_sockets(&OPT_175B, b, 1024, Precision::F16);
        assert!(s13 <= s7, "{s13} > {s7}");
        assert!(s175 < s7, "{s175} >= {s7}");
    }

    /// Longer sequences require proportionally more sockets (eq. 11).
    #[test]
    fn sockets_scale_with_seq_len() {
        let p = planner();
        let short = p.min_sockets(&LLAMA_7B, 512, 128, Precision::F16);
        let long = p.min_sockets(&LLAMA_7B, 512, 1024, Precision::F16);
        assert!(long > short);
        let ratio = long as f64 / short as f64;
        assert!((4.0..=12.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn memory_constraint_adds_sockets() {
        let p = planner();
        let tiny_mem = p.plan(
            &LLAMA_7B,
            PlanInput {
                tokens_per_socket: 10_000,
                ..Default::default()
            },
        );
        let big_mem = p.plan(&LLAMA_7B, PlanInput::default());
        assert!(tiny_mem.sockets >= big_mem.sockets);
        // eq. 9 must hold in the result
        assert!(
            tiny_mem.batch * 1024 / 2
                <= 10_000 * tiny_mem.sockets
        );
    }

    /// Feed a NodeProfile through observe() so it carries a measured
    /// EWMA bandwidth: `bytes` streamed in `us` microseconds.
    fn measured(bytes: u64, us: u64) -> NodeProfile {
        let mut p = NodeProfile::default();
        p.observe(1, bytes, std::time::Duration::from_micros(us));
        p
    }

    #[test]
    fn measured_profiles_replace_the_assumed_cpu_model() {
        let gpu = || GpuModel::new(A10);
        let fallback = CpuModel::from_device(EPYC_7452);
        // 100 GB/s vs 25 GB/s measured KV-streaming bandwidth.
        let fast = Planner::from_measured_profiles(
            gpu(),
            &[measured(100_000, 1), measured(100_000, 1)],
            fallback,
        );
        let slow = Planner::from_measured_profiles(
            gpu(),
            &[measured(25_000, 1), measured(25_000, 1)],
            fallback,
        );
        let pf = fast.min_sockets(&LLAMA_7B, 512, 1024, Precision::F16);
        let ps = slow.min_sockets(&LLAMA_7B, 512, 1024, Precision::F16);
        assert!(pf < ps, "fast nodes need fewer sockets: {pf} !< {ps}");
        // Unsampled profiles are ignored; mixing one in changes nothing.
        let mixed = Planner::from_measured_profiles(
            gpu(),
            &[measured(100_000, 1), NodeProfile::default()],
            fallback,
        );
        assert_eq!(
            mixed.min_sockets(&LLAMA_7B, 512, 1024, Precision::F16),
            Planner::from_measured_profiles(
                gpu(),
                &[measured(100_000, 1)],
                fallback
            )
            .min_sockets(&LLAMA_7B, 512, 1024, Precision::F16),
        );
    }

    #[test]
    fn no_sampled_profiles_fall_back_to_the_device_model() {
        let fallback = CpuModel::from_device(EPYC_7452);
        let p = Planner::from_measured_profiles(
            GpuModel::new(A10),
            &vec![NodeProfile::default(); 3],
            fallback,
        );
        let want = planner().plan(&LLAMA_7B, PlanInput::default());
        assert_eq!(p.plan(&LLAMA_7B, PlanInput::default()), want);
    }

    #[test]
    fn quantized_kv_needs_fewer_sockets() {
        let p = planner();
        let f16 = p.min_sockets(&LLAMA_7B, 512, 1024, Precision::F16);
        let i4 = p.min_sockets(&LLAMA_7B, 512, 1024, Precision::Int4);
        assert!(i4 < f16, "int4 {i4} !< f16 {f16}"); // §5.2 "save 4× CPUs"
    }
}
