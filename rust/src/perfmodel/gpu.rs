//! Analytic device cost models.
//!
//! `GpuModel` is a two-term roofline: a batch-B S-Part step of one
//! transformer block costs
//!   max(flops_time(B), weight_traffic_time) + launch overhead
//! which reproduces Fig 1's shape — latency flat while memory-bound
//! (weights dominate), then linear in B once compute-bound; throughput
//! B/T(B) rises steeply and saturates.
//!
//! `CpuModel` prices R-Part by streamed KV bytes over socket bandwidth —
//! the paper's "aggregated memory bandwidth is the key metric" (§4.3);
//! the per-socket bandwidth can come from Table 1 or from a *measured*
//! probe of this machine.

use crate::model::{ModelSpec, Precision};

use super::devices::DeviceSpec;

/// Cost model of the S-worker GPU for one transformer block.
#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    pub device: DeviceSpec,
    /// Asymptotic fraction of peak FLOPs for huge GEMMs.
    pub flops_eff: f64,
    /// Achievable fraction of peak bandwidth.
    pub bw_eff: f64,
    /// Per-block fixed overhead (kernel launches etc.), seconds.
    pub launch_s: f64,
    /// Batch at which GEMM efficiency reaches half its asymptote: thin
    /// matrices underutilize the tensor cores, so achieved FLOPs scale as
    /// eff·B/(B+b_half). This is what makes Fig 1's throughput keep
    /// climbing past B=128 (paper: 128→1024 still gives ~2×).
    pub b_half: f64,
}

impl GpuModel {
    pub fn new(device: DeviceSpec) -> GpuModel {
        GpuModel {
            device,
            // Calibrated so Table 2's measured A10 values are reproduced
            // (see tests below).
            flops_eff: 0.70,
            bw_eff: 0.85,
            launch_s: 25e-6,
            b_half: 256.0,
        }
    }

    /// Achieved-FLOPs time for the batched matmuls at batch `b`.
    fn compute_time(&self, spec: &ModelSpec, b: usize) -> f64 {
        let flops = (spec.s_part_flops_per_token_layer() * b) as f64;
        let eff = self.flops_eff * b as f64 / (b as f64 + self.b_half);
        flops / (self.device.flops * eff)
    }

    /// T(ℬ): latency of S-Part of ONE block at batch `b`, seconds.
    pub fn s_part_latency(&self, spec: &ModelSpec, b: usize) -> f64 {
        let compute = self.compute_time(spec, b);
        // weights are re-read per step (batch-independent), activations
        // are negligible next to them until B is huge
        let bytes = spec.block_weight_bytes() as f64
            + (b * spec.activation_bytes_per_token_layer()) as f64;
        let memory = bytes / (self.device.mem_bw * self.bw_eff);
        compute.max(memory) + self.launch_s
    }

    /// GPU utilization at batch `b`: achieved FLOP/s over peak.
    pub fn utilization(&self, spec: &ModelSpec, b: usize) -> f64 {
        let flops = (spec.s_part_flops_per_token_layer() * b) as f64;
        flops / self.s_part_latency(spec, b) / self.device.flops
    }

    /// E(ℬ) = ℬ / T(ℬ) (eq. 8): per-block token throughput.
    pub fn efficiency(&self, spec: &ModelSpec, b: usize) -> f64 {
        b as f64 / self.s_part_latency(spec, b)
    }

    /// R-Part latency if it ran ON the GPU (Table 2's comparison row):
    /// streaming the whole KV working set at batch `b`, context `ctx`.
    pub fn r_part_latency(
        &self,
        spec: &ModelSpec,
        b: usize,
        ctx: usize,
    ) -> f64 {
        let bytes =
            (spec.r_part_bytes_per_token_layer(ctx, Precision::F16) * b) as f64;
        bytes / (self.device.mem_bw * self.bw_eff) + self.launch_s
    }

    /// S-Part latency if it ran on a CPU socket (Table 2, "S-Part CPU").
    pub fn s_part_latency_on(
        device: DeviceSpec,
        spec: &ModelSpec,
        b: usize,
    ) -> f64 {
        let flops = (spec.s_part_flops_per_token_layer() * b) as f64;
        // CPUs saturate their (scalar-ish) FLOP pipes at modest B.
        let compute = flops / (device.flops * 0.75);
        let bytes = spec.block_weight_bytes() as f64;
        let memory = bytes / (device.mem_bw * 0.68);
        compute.max(memory)
    }
}

/// Cost model of one R-worker CPU socket.
#[derive(Clone, Copy, Debug)]
pub struct CpuModel {
    /// Effective KV streaming bandwidth of one socket, bytes/s.
    pub socket_bw: f64,
    /// Fixed per-batch-message handling cost, seconds.
    pub dispatch_s: f64,
}

impl CpuModel {
    /// From a Table 1 device at the paper's achieved fraction (68 %,
    /// §2.3 "a dual-socket AMD Epyc server can achieve 68 % of its
    /// memory bandwidth").
    pub fn from_device(device: DeviceSpec) -> CpuModel {
        CpuModel {
            socket_bw: device.mem_bw * 0.68,
            dispatch_s: 20e-6,
        }
    }

    /// From a measured probe of this machine (bytes/s per thread).
    pub fn from_measured(bytes_per_s: f64) -> CpuModel {
        CpuModel {
            socket_bw: bytes_per_s,
            dispatch_s: 20e-6,
        }
    }

    /// R: per-token per-unit-context cost coefficient (seconds), i.e.
    /// the paper's "latency that one CPU processes one token for R-Part"
    /// divided by the context length, per layer.
    pub fn r_coeff(&self, spec: &ModelSpec, prec: Precision) -> f64 {
        spec.r_part_bytes_per_token_layer(1, prec) as f64 / self.socket_bw
    }

    /// Latency for ONE socket to process `total_ctx_tokens` of aggregate
    /// context (Σ over its sequences of their lengths) on one layer.
    pub fn r_part_latency(
        &self,
        spec: &ModelSpec,
        total_ctx_tokens: usize,
        prec: Precision,
    ) -> f64 {
        self.r_coeff(spec, prec) * total_ctx_tokens as f64 + self.dispatch_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LLAMA_7B, TINY};
    use crate::perfmodel::devices::{A10, EPYC_7452};

    /// Table 2 pins (7b model, A10, two Epyc sockets). We require the
    /// model to land within ~40 % of the paper's measured numbers — the
    /// point is the *ratios* that drive the design decisions.
    #[test]
    fn table2_magnitudes() {
        let gpu = GpuModel::new(A10);
        let cpu = CpuModel::from_device(EPYC_7452);

        // S-Part GPU: 1.46 ms @ B=1 (weight-bound), 7.08 ms @ B=1024.
        let t1 = gpu.s_part_latency(&LLAMA_7B, 1) * 1e3;
        let t1024 = gpu.s_part_latency(&LLAMA_7B, 1024) * 1e3;
        assert!((0.6..=2.2).contains(&t1), "T(1) = {t1} ms");
        assert!((4.0..=10.0).contains(&t1024), "T(1024) = {t1024} ms");

        // R-Part on GPU @ B=1024, ctx=512 (mid-generation): paper 8.32 ms
        // at their working set; we check the B=1024/ctx=512 point lands
        // in single-digit ms.
        let r_gpu = gpu.r_part_latency(&LLAMA_7B, 1024, 512) * 1e3;
        assert!((2.0..=20.0).contains(&r_gpu), "R on GPU = {r_gpu} ms");

        // R-Part on 2 CPU sockets ≈ R-Part on GPU (the paper's key
        // near-parity claim): total_ctx = 1024 seqs × 512 ctx / 2 sockets.
        let r_cpu = cpu.r_part_latency(&LLAMA_7B, 1024 * 512 / 2, Precision::F16) * 1e3;
        assert!(
            (0.33..=3.0).contains(&(r_cpu / r_gpu)),
            "CPU/GPU R-part ratio = {}",
            r_cpu / r_gpu
        );

        // S-Part on CPU is catastrophically slower (paper: 611 ms vs
        // 7 ms at B=1024) — the reason S-Part stays on the GPU.
        let s_cpu = GpuModel::s_part_latency_on(EPYC_7452, &LLAMA_7B, 1024);
        assert!(s_cpu / (t1024 / 1e3) > 30.0, "only {}×", s_cpu / (t1024 / 1e3));
    }

    /// Fig 1/3 shape: throughput rises steeply then saturates; the knee
    /// sits where compute time overtakes weight streaming.
    #[test]
    fn fig1_throughput_knee() {
        let gpu = GpuModel::new(A10);
        let e32 = gpu.efficiency(&LLAMA_7B, 32);
        let e256 = gpu.efficiency(&LLAMA_7B, 256);
        let e1024 = gpu.efficiency(&LLAMA_7B, 1024);
        let e4096 = gpu.efficiency(&LLAMA_7B, 4096);
        assert!(e256 > 4.0 * e32 / 8.0); // still climbing fast below knee
        assert!(e1024 / e256 > 1.2); // paper: 128→1024 gives ~2×
        assert!(e4096 / e1024 < 1.6); // saturating
    }

    #[test]
    fn utilization_monotone_in_batch() {
        let gpu = GpuModel::new(A10);
        let mut prev = 0.0;
        for b in [1, 8, 64, 512, 4096] {
            let u = gpu.utilization(&TINY, b);
            assert!(u >= prev - 1e-9, "utilization dipped at B={b}");
            assert!(u <= 1.0 + 1e-9);
            prev = u;
        }
    }

    #[test]
    fn quantization_quarters_r_cost() {
        let cpu = CpuModel::from_device(EPYC_7452);
        let f16 = cpu.r_coeff(&LLAMA_7B, Precision::F16);
        let i4 = cpu.r_coeff(&LLAMA_7B, Precision::Int4);
        assert!((f16 / i4 - 4.0).abs() < 1e-9); // §5.2's 4× claim
    }
}
