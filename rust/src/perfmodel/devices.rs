//! Hardware specifications — the paper's Table 1 / Figure 2, verbatim.
//!
//! These numbers parameterize the analytic device models used by the
//! virtual-clock experiments (DESIGN.md §2: the A10/Epyc testbed is
//! simulated; the R-Part cost can instead be calibrated from a *measured*
//! probe of this machine, see rworker::stream_bandwidth_probe).

/// Static spec of one device type.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub kind: &'static str, // "cpu" | "gpu"
    /// Thermal design power, watts.
    pub tdp_w: f64,
    /// Peak dense fp16 compute, FLOP/s.
    pub flops: f64,
    /// Peak memory bandwidth, bytes/s.
    pub mem_bw: f64,
}

impl DeviceSpec {
    /// Watts per TFLOP (Table 1 "W. per." compute column).
    pub fn w_per_tflop(&self) -> f64 {
        self.tdp_w / (self.flops / 1e12)
    }

    /// Watts per GB/s (Table 1 "W. per." memory column).
    pub fn w_per_gbps(&self) -> f64 {
        self.tdp_w / (self.mem_bw / 1e9)
    }
}

/// Intel Xeon Gold 5218 (one socket).
pub const XEON_5218: DeviceSpec = DeviceSpec {
    name: "Xeon Gold 5218",
    kind: "cpu",
    tdp_w: 125.0,
    flops: 1.3e12,
    mem_bw: 128.0e9,
};

/// AMD Epyc 7452 (one socket) — the paper's R-worker hardware.
pub const EPYC_7452: DeviceSpec = DeviceSpec {
    name: "Epyc 7452",
    kind: "cpu",
    tdp_w: 155.0,
    flops: 1.2e12,
    mem_bw: 205.0e9,
};

/// NVIDIA A10 — the paper's S-worker GPU.
pub const A10: DeviceSpec = DeviceSpec {
    name: "A10",
    kind: "gpu",
    tdp_w: 150.0,
    flops: 125.0e12,
    mem_bw: 600.0e9,
};

/// NVIDIA V100.
pub const V100: DeviceSpec = DeviceSpec {
    name: "V100",
    kind: "gpu",
    tdp_w: 250.0,
    flops: 112.0e12,
    mem_bw: 900.0e9,
};

#[cfg(test)]
mod tests {
    use super::*;

    /// Pin Table 1's derived efficiency columns (the paper's argument
    /// that the bandwidth-per-watt gap is ~4×, not ~100×).
    #[test]
    fn table1_efficiency_columns() {
        assert!((XEON_5218.w_per_tflop() - 96.15).abs() < 0.1);
        assert!((EPYC_7452.w_per_tflop() - 129.2).abs() < 0.1);
        assert!((A10.w_per_tflop() - 1.2).abs() < 0.01);
        assert!((V100.w_per_tflop() - 2.2).abs() < 0.05);
        assert!((XEON_5218.w_per_gbps() - 0.97).abs() < 0.01);
        assert!((EPYC_7452.w_per_gbps() - 0.76).abs() < 0.01);
        assert!((A10.w_per_gbps() - 0.25).abs() < 0.01);
        assert!((V100.w_per_gbps() - 0.27).abs() < 0.01);
    }

    /// Fig 2's qualitative claim: compute gap ≈100×, bandwidth gap <10×.
    #[test]
    fn fig2_gap_shapes() {
        let compute_gap = A10.flops / EPYC_7452.flops;
        let bw_gap = A10.mem_bw / EPYC_7452.mem_bw;
        assert!(compute_gap > 80.0);
        assert!(bw_gap < 10.0);
    }
}
