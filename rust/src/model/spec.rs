//! Model geometry and derived workload numbers.
//!
//! Every per-token byte/FLOP count used by the performance model
//! (perfmodel/), the capacity planner (eq. 9) and Table 3 is derived
//! here, in one place, from the model dimensions.

/// KV-cache element precision (§5.1–5.2): lossless fp16 or quantized.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// 32-bit float (exact cross-check against the f32 HLO path).
    F32,
    /// fp16 storage, fp32 compute — the paper's lossless default.
    F16,
    /// int8 per-(head, token) scale quantization.
    Int8,
    /// int4 per-(head, token) scale quantization (2 values/byte).
    Int4,
}

impl Precision {
    /// Stored bits per KV element.
    pub fn bits(self) -> usize {
        match self {
            Precision::F32 => 32,
            Precision::F16 => 16,
            Precision::Int8 => 8,
            Precision::Int4 => 4,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
        }
    }
}

/// Static geometry of one transformer decoder model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: &'static str,
    /// Feature dimension h.
    pub hidden: usize,
    pub n_heads: usize,
    /// Full-model layer count (experiments run fewer and extrapolate,
    /// like the paper's Fig 8).
    pub n_layers: usize,
    /// MLP intermediate dimension f.
    pub ffn: usize,
    pub vocab: usize,
}

pub const TINY: ModelSpec = ModelSpec {
    name: "tiny",
    hidden: 64,
    n_heads: 4,
    n_layers: 2,
    ffn: 176,
    vocab: 256,
};

pub const LLAMA_7B: ModelSpec = ModelSpec {
    name: "llama7b",
    hidden: 4096,
    n_heads: 32,
    n_layers: 32,
    ffn: 11008,
    vocab: 32000,
};

pub const LLAMA_13B: ModelSpec = ModelSpec {
    name: "llama13b",
    hidden: 5120,
    n_heads: 40,
    n_layers: 40,
    ffn: 13824,
    vocab: 32000,
};

pub const OPT_175B: ModelSpec = ModelSpec {
    name: "opt175b",
    hidden: 12288,
    n_heads: 96,
    n_layers: 96,
    ffn: 49152,
    vocab: 50272,
};

impl ModelSpec {
    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name {
            "tiny" => Some(TINY),
            "llama7b" => Some(LLAMA_7B),
            "llama13b" => Some(LLAMA_13B),
            "opt175b" => Some(OPT_175B),
            _ => None,
        }
    }

    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.hidden % self.n_heads, 0);
        self.hidden / self.n_heads
    }

    // ---- memory ----------------------------------------------------------

    /// Bytes of K+V appended per token per layer at `prec`.
    pub fn kv_bytes_per_token_layer(&self, prec: Precision) -> usize {
        2 * self.hidden * prec.bits() / 8
    }

    /// Bytes of K+V per token across all layers (Fig 1's footprint slope).
    pub fn kv_bytes_per_token(&self, prec: Precision) -> usize {
        self.kv_bytes_per_token_layer(prec) * self.n_layers
    }

    /// Total KV footprint for `batch` sequences of length `seq`.
    pub fn kv_bytes_total(
        &self,
        batch: usize,
        seq: usize,
        prec: Precision,
    ) -> usize {
        self.kv_bytes_per_token(prec) * batch * seq
    }

    /// fp16 weight bytes of ONE transformer block (Table 3 "Model Weight").
    pub fn block_weight_bytes(&self) -> usize {
        let h = self.hidden;
        let f = self.ffn;
        // qkv (h×3h) + o (h×h) + gate/up (2 h×f) + down (f×h), fp16
        (3 * h * h + h * h + 2 * h * f + f * h) * 2
    }

    /// fp16 bytes of the per-token activation vectors that FastDecode
    /// ships per block: Q,K,V (S→R) and O (R→S) (Table 3 "Intermediate
    /// Vectors").
    pub fn activation_bytes_per_token_layer(&self) -> usize {
        4 * self.hidden * 2
    }

    // ---- compute ---------------------------------------------------------

    /// FLOPs of S-Part per token per layer (the batched matmuls).
    pub fn s_part_flops_per_token_layer(&self) -> usize {
        let h = self.hidden;
        let f = self.ffn;
        // 2·h·3h (qkv) + 2·h·h (o) + 3·2·h·f (gate,up,down)
        2 * h * 3 * h + 2 * h * h + 3 * 2 * h * f
    }

    /// FLOPs of R-Part per token per layer for context length `ctx`:
    /// q·Kᵀ and p·V, each 2·ctx·h.
    pub fn r_part_flops_per_token_layer(&self, ctx: usize) -> usize {
        2 * 2 * ctx * self.hidden
    }

    /// Bytes R-Part must stream from memory per token per layer at `prec`
    /// (the whole K and V of the sequence — the memory-bound core).
    pub fn r_part_bytes_per_token_layer(
        &self,
        ctx: usize,
        prec: Precision,
    ) -> usize {
        2 * ctx * self.hidden * prec.bits() / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_dims_divide() {
        for m in [TINY, LLAMA_7B, LLAMA_13B, OPT_175B] {
            assert_eq!(m.hidden % m.n_heads, 0, "{}", m.name);
        }
    }

    /// Table 3 cross-check: 7b model, one block.
    /// KV-cache of one token ≈ 4.19 MB/1024 tokens... per the paper the
    /// per-block numbers are: weights 402 MB (all blocks? no: paper says
    /// "within a transformer block") — we pin our derived values instead
    /// and verify the ratios the argument needs.
    #[test]
    fn table3_magnitudes_7b() {
        let m = LLAMA_7B;
        // Per-token per-layer KV fp16: 2·4096·2 B = 16 KiB; × 32 layers
        // = 512 KiB/token. Paper's "KV-Cache, batch 1" row is one block
        // at S=1024 ctx: 2·4096·2·1024 / 2^20 = 16 MiB... the paper's
        // 4.19 MB = 2·4096·2·256? We pin OUR definition and check the
        // orders of magnitude that drive the design:
        let act = m.activation_bytes_per_token_layer(); // 32 KiB
        assert_eq!(act, 4 * 4096 * 2);
        let kv_tok_layer = m.kv_bytes_per_token_layer(Precision::F16);
        assert_eq!(kv_tok_layer, 2 * 4096 * 2);
        // activations per token are ~2× one token's per-layer KV, but the
        // R-part STREAMS ctx× that per step — the orders-of-magnitude gap
        // the paper's Table 3 demonstrates:
        let streamed = m.r_part_bytes_per_token_layer(1024, Precision::F16);
        assert!(streamed > 100 * act);
    }

    #[test]
    fn weight_bytes_7b_close_to_paper() {
        // Paper Table 3: one block of the 7b model = 402 MB?? No — 402 MB
        // is for fp16 ALL weights of one block × ... our formula gives:
        // (3·h² + h² + 3·h·f)·2 with h=4096, f=11008 → ~403 MB? compute:
        // 4·4096² = 67.1e6; 3·4096·11008 = 135.3e6; sum 202.4e6 els ×2B
        // = 404.8 MB — matches the paper's 402 MB within rounding. ✓
        let mb = LLAMA_7B.block_weight_bytes() as f64 / 1e6;
        assert!((mb - 402.0).abs() < 5.0, "got {mb} MB");
    }

    #[test]
    fn quantization_quarters_kv() {
        let m = LLAMA_7B;
        let f16 = m.kv_bytes_per_token(Precision::F16);
        let i4 = m.kv_bytes_per_token(Precision::Int4);
        assert_eq!(f16, 4 * i4); // §5.2's 4× saving
    }

    #[test]
    fn by_name_roundtrip() {
        for m in [TINY, LLAMA_7B, LLAMA_13B, OPT_175B] {
            assert_eq!(ModelSpec::by_name(m.name), Some(m));
        }
        assert_eq!(ModelSpec::by_name("nope"), None);
    }
}
