//! Model specifications — the Rust mirror of `python/compile/configs.py`.

mod spec;

pub use spec::{ModelSpec, Precision, LLAMA_13B, LLAMA_7B, OPT_175B, TINY};
