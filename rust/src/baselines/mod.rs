//! Baseline serving systems (paper §6.1), as calibrated simulators
//! sharing the same GpuModel/LinkModel substrate as the FastDecode sim,
//! so Figs 9–11 compare like against like (DESIGN.md §2).
//!
//! * `vanilla`  — the reference PyTorch implementation: whole model on
//!   the GPU, KV in GPU memory, batch capped by what fits at full length.
//! * `tensorrt` — same structure with a faster-kernel GpuModel (the
//!   paper: best per-token latency, small static batch).
//! * `fastllm`  — C++ serving stack, kernels between vanilla and TRT.
//! * `vllm`     — paged KV + host swapping: starts at a huge batch while
//!   sequences are short, loses batch as KV grows, pays PCIe swap stalls
//!   (the paper's "few steps that swap are significantly slow").

use crate::metrics::{StepRecord, StepTrace};
use crate::model::{ModelSpec, Precision};
use crate::perfmodel::{DeviceSpec, GpuModel};
use crate::transport::{LinkModel, PCIE4_X16};

/// Common testbed parameters for all GPU-only baselines.
#[derive(Clone, Copy, Debug)]
pub struct BaselineConfig {
    pub spec: ModelSpec,
    pub device: DeviceSpec,
    /// GPU memory, bytes (A10: 24 GB).
    pub gpu_mem: usize,
    /// Host memory for vLLM swap space, bytes.
    pub host_mem: usize,
    /// Requested batch (systems cap it by memory).
    pub batch: usize,
    pub seq_len: usize,
    pub pcie: LinkModel,
}

impl BaselineConfig {
    pub fn a10(spec: ModelSpec, batch: usize, seq_len: usize) -> BaselineConfig {
        BaselineConfig {
            spec,
            device: crate::perfmodel::A10,
            gpu_mem: 24 << 30,
            host_mem: 256 << 30,
            batch,
            seq_len,
            pcie: PCIE4_X16,
        }
    }

    /// fp16 bytes of ALL model weights (blocks + embedding).
    pub fn total_weight_bytes(&self) -> usize {
        self.spec.block_weight_bytes() * self.spec.n_layers
            + self.spec.vocab * self.spec.hidden * 2
    }

    /// GPU memory left for KV after weights + activation scratch.
    ///
    /// Models whose full fp16 weights don't fit the GPU (13b/175b on an
    /// A10) are evaluated the way the paper does it (§6.1): with a
    /// reduced layer count and linear extrapolation — which leaves such
    /// runs the same *fraction* of GPU memory for KV as a fitting model.
    /// We grant non-fitting models a floor of 40 % of GPU memory (the
    /// fraction the 7b model leaves on a 24 GB A10), matching the
    /// reduced-layer evaluation's memory conditions.
    pub fn kv_budget(&self) -> usize {
        let scratch = 1 << 30; // 1 GB activations/workspace
        let fit = self
            .gpu_mem
            .saturating_sub(self.total_weight_bytes())
            .saturating_sub(scratch);
        fit.max(self.gpu_mem * 40 / 100)
    }

    /// Max batch whose KV fits on-GPU at context `ctx`.
    pub fn gpu_batch_cap(&self, ctx: usize) -> usize {
        let per_seq = self.spec.kv_bytes_per_token(Precision::F16) * ctx.max(1);
        (self.kv_budget() / per_seq).max(1)
    }
}

/// Kernel-quality tiers for the GPU-only systems.
fn tuned_gpu(device: DeviceSpec, tier: &str) -> GpuModel {
    let mut g = GpuModel::new(device);
    match tier {
        // TensorRT-LLM: best kernels, lowest launch overhead
        "tensorrt" => {
            g.flops_eff = 0.85;
            g.bw_eff = 0.92;
            g.launch_s = 8e-6;
        }
        // vanilla PyTorch: eager-mode kernels + launch gaps
        "vanilla" => {
            g.flops_eff = 0.45;
            g.bw_eff = 0.60;
            g.launch_s = 60e-6;
        }
        // fastllm: hand-written C++/CUDA, between the two
        "fastllm" => {
            g.flops_eff = 0.55;
            g.bw_eff = 0.70;
            g.launch_s = 30e-6;
        }
        // vLLM: paged-attention kernels near TRT quality
        "vllm" => {
            g.flops_eff = 0.75;
            g.bw_eff = 0.85;
            g.launch_s = 15e-6;
        }
        _ => panic!("unknown tier {tier}"),
    }
    g
}

/// A GPU-only static-batch run (vanilla / tensorrt / fastllm): batch is
/// capped so the FULL-length KV fits; every step runs S+R on the GPU.
pub fn gpu_only(cfg: &BaselineConfig, tier: &str) -> StepTrace {
    let gpu = tuned_gpu(cfg.device, tier);
    let b = cfg.batch.min(cfg.gpu_batch_cap(cfg.seq_len));
    let layers = cfg.spec.n_layers as f64;
    let mut trace = StepTrace::default();
    for step in 0..cfg.seq_len {
        let ctx = step + 1;
        let s = layers * gpu.s_part_latency(&cfg.spec, b);
        let r = layers * gpu.r_part_latency(&cfg.spec, b, ctx);
        trace.push(StepRecord {
            step,
            latency_s: s + r,
            s_time: s,
            r_time: r,
            comm_time: 0.0,
            tokens: b,
            total_ctx: b * ctx,
            // modeled steps have no measured wait/skew breakdown
            ..Default::default()
        });
    }
    trace
}

pub fn vanilla(cfg: &BaselineConfig) -> StepTrace {
    gpu_only(cfg, "vanilla")
}

pub fn tensorrt(cfg: &BaselineConfig) -> StepTrace {
    gpu_only(cfg, "tensorrt")
}

pub fn fastllm(cfg: &BaselineConfig) -> StepTrace {
    gpu_only(cfg, "fastllm")
}

/// vLLM-like paged KV + host swap (§2.2 and the paper's §6.2-6.3
/// observations). Per step: the GPU processes the resident group at the
/// paged-kernel rate; when resident capacity shrinks below the live
/// batch, groups rotate through host memory, paying KV transfer over
/// PCIe every rotation — rare but very slow steps (the P99 spikes of
/// Fig 10).
pub fn vllm(cfg: &BaselineConfig) -> StepTrace {
    let gpu = tuned_gpu(cfg.device, "vllm");
    let layers = cfg.spec.n_layers as f64;
    let kv_per_tok = cfg.spec.kv_bytes_per_token(Precision::F16);
    let mut trace = StepTrace::default();
    // progress per sequence group; all must reach seq_len
    let b_total = cfg.batch;
    let mut done_tokens = vec![0usize; b_total.max(1)];
    let mut step = 0usize;
    loop {
        // unfinished sequences, least-advanced first (vLLM-style FCFS
        // over preempted sequences)
        let mut order: Vec<usize> = (0..b_total)
            .filter(|&i| done_tokens[i] < cfg.seq_len)
            .collect();
        if order.is_empty() {
            break;
        }
        order.sort_by_key(|&i| done_tokens[i]);
        // context of the laggiest live sequence defines the resident cap
        let ctx = done_tokens[order[0]] + 1;
        let cap = cfg.gpu_batch_cap(ctx).min(order.len());
        let group = &order[..cap];
        let s = layers * gpu.s_part_latency(&cfg.spec, cap);
        let r = layers * gpu.r_part_latency(&cfg.spec, cap, ctx);
        // swap cost: when not everything is resident, the resident group
        // rotates every `residency` steps, re-staging its KV over PCIe —
        // rare but very slow steps (the Fig 10 P99 spikes).
        let mut swap = 0.0;
        if cap < order.len() {
            let residency = 64; // steps a group stays resident
            if step % residency == 0 {
                let group_kv = cap * kv_per_tok * ctx;
                swap = cfg.pcie.transfer_time(2 * group_kv); // out + in
            }
        }
        for &i in group {
            done_tokens[i] += 1;
        }
        trace.push(StepRecord {
            step,
            latency_s: s + r + swap,
            s_time: s,
            r_time: r,
            comm_time: swap,
            tokens: cap,
            total_ctx: cap * ctx,
            ..Default::default()
        });
        step += 1;
        if step > 4 * cfg.seq_len * b_total {
            break; // safety rail
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LLAMA_13B, LLAMA_7B};

    #[test]
    fn weight_bytes_7b_about_13gb() {
        let cfg = BaselineConfig::a10(LLAMA_7B, 16, 1024);
        let gb = cfg.total_weight_bytes() as f64 / (1u64 << 30) as f64;
        assert!((11.0..=14.5).contains(&gb), "{gb} GB");
    }

    /// §6.2: GPU-only systems "barely more than 16" sequences at S=1024.
    #[test]
    fn gpu_batch_cap_matches_paper() {
        let cfg = BaselineConfig::a10(LLAMA_7B, 1024, 1024);
        let cap = cfg.gpu_batch_cap(1024);
        assert!((8..=32).contains(&cap), "cap {cap}");
        // 13b doesn't fit an A10 at full weights: it gets the reduced-
        // layer floor (40 % of 24 GB), and its fatter KV rows still give
        // a smaller cap than the 7b model
        let cfg13 = BaselineConfig::a10(LLAMA_13B, 1024, 1024);
        let cap13 = cfg13.gpu_batch_cap(1024);
        assert!(cap13 < cap, "cap13 {cap13} !< cap7 {cap}");
    }

    /// Fig 9/10 ordering: TRT beats fastllm beats vanilla on latency.
    #[test]
    fn kernel_tier_ordering() {
        let cfg = BaselineConfig::a10(LLAMA_7B, 16, 256);
        let lat = |t: &StepTrace| t.steady_latency(8);
        let v = lat(&vanilla(&cfg));
        let f = lat(&fastllm(&cfg));
        let t = lat(&tensorrt(&cfg));
        assert!(t < f && f < v, "trt {t} fastllm {f} vanilla {v}");
    }

    /// vLLM starts with a big batch (short KV), degrades as KV grows
    /// (the paper's §6.2 observation).
    #[test]
    fn vllm_batch_decays() {
        let cfg = BaselineConfig::a10(LLAMA_7B, 1024, 512);
        let trace = vllm(&cfg);
        let early = trace.records[2].tokens;
        let late = trace.records[trace.len() - 1].tokens;
        assert!(early > 4 * late, "early {early} late {late}");
        // everyone finished
        let total: usize = trace.records.iter().map(|r| r.tokens).sum();
        assert_eq!(total, 1024 * 512);
    }

    /// vLLM's swap steps create a long tail: max ≫ median latency.
    #[test]
    fn vllm_has_swap_spikes() {
        let cfg = BaselineConfig::a10(LLAMA_7B, 256, 512);
        let trace = vllm(&cfg);
        let mut lats: Vec<f64> =
            trace.records.iter().map(|r| r.latency_s).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = lats[lats.len() / 2];
        let max = lats[lats.len() - 1];
        assert!(max > 3.0 * p50, "max {max} p50 {p50}");
    }

    /// vLLM still beats the static GPU-only systems on throughput
    /// (it IS the strongest baseline in Fig 9).
    #[test]
    fn vllm_beats_static_baselines() {
        let cfg = BaselineConfig::a10(LLAMA_7B, 1024, 512);
        let tp_vllm = vllm(&cfg).throughput();
        let tp_trt = tensorrt(&cfg).throughput();
        assert!(tp_vllm > tp_trt, "vllm {tp_vllm} trt {tp_trt}");
    }
}
