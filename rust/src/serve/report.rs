//! Per-request serving metrics: TTFT, inter-token latency, end-to-end
//! latency (each a log-bucketed [`Histogram`] with p50/p95/p99), plus
//! throughput and goodput counters — the numbers an open-loop
//! rate-vs-latency sweep plots.

use crate::metrics::Histogram;
use crate::obs::NetStats;
use crate::util::json::Json;

/// One finished request, with its generated tokens and latencies.
#[derive(Clone, Debug)]
pub struct Completion {
    pub request_id: u64,
    /// Generated tokens (exactly `target_len` of them; the prompt is
    /// not echoed).
    pub tokens: Vec<i32>,
    pub arrive_step: usize,
    pub admit_step: usize,
    pub finish_step: usize,
    /// Wall time from arrival (queue included) to the first generated
    /// token, seconds.
    pub ttft_s: f64,
    /// Wall time from arrival to the last generated token, seconds.
    pub e2e_s: f64,
}

impl Completion {
    /// Steps spent in the admission queue.
    pub fn wait_steps(&self) -> usize {
        self.admit_step - self.arrive_step
    }
}

/// Summary of one serving run.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Requests offered by the trace.
    pub requests: usize,
    /// Requests served to completion.
    pub completed: usize,
    /// Generated tokens across all requests.
    pub tokens: u64,
    /// Wall time of the whole run, seconds.
    pub elapsed_s: f64,
    /// Engine steps driven (including idle steps waiting on arrivals).
    pub steps: usize,
    /// Mean steps spent waiting in the admission queue.
    pub mean_wait_steps: f64,
    /// Time to first token, per request.
    pub ttft: Histogram,
    /// Gap between consecutive generated tokens, per token.
    pub itl: Histogram,
    /// End-to-end latency, per request.
    pub e2e: Histogram,
    /// Admissions that COW-forked a resident prompt prefix instead of
    /// recomputing it.
    pub prefix_forks: u64,
    /// Prompt tokens admitted via fork (KV neither recomputed nor
    /// stored twice).
    pub shared_prefix_tokens: u64,
    /// Most requests simultaneously holding slots at any step.
    pub peak_active: usize,
    /// Peak bytes of KV block storage held (physical, shared blocks
    /// counted once).
    pub kv_allocated_bytes: usize,
    /// Peak bytes the logical KV would occupy stored contiguously and
    /// unshared.
    pub kv_logical_bytes: usize,
    /// Per-node wire accounting and measured performance profiles
    /// (EWMA throughput, service-time percentiles, queue depth) at the
    /// end of the run. Empty for in-process backends (no wire).
    pub node_stats: Vec<NetStats>,
}

impl ServeReport {
    /// Generated tokens per second of wall time.
    pub fn throughput(&self) -> f64 {
        if self.elapsed_s == 0.0 {
            0.0
        } else {
            self.tokens as f64 / self.elapsed_s
        }
    }

    /// Completed requests per second of wall time.
    pub fn goodput(&self) -> f64 {
        if self.elapsed_s == 0.0 {
            0.0
        } else {
            self.completed as f64 / self.elapsed_s
        }
    }

    /// Peak logical/allocated KV ratio: below 1.0 the gap is block
    /// padding, above 1.0 prefix sharing stored less than the sequences
    /// logically hold. 0 when no KV was ever held.
    pub fn kv_utilization(&self) -> f64 {
        if self.kv_allocated_bytes == 0 {
            0.0
        } else {
            self.kv_logical_bytes as f64 / self.kv_allocated_bytes as f64
        }
    }

    /// Machine-readable summary — the `serve` section of
    /// `BENCH_serve_openloop.json` (see `bench::snapshot` for the full
    /// schema).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("requests", self.requests)
            .set("completed", self.completed)
            .set("tokens", self.tokens)
            .set("elapsed_s", self.elapsed_s)
            .set("steps", self.steps)
            .set("mean_wait_steps", self.mean_wait_steps)
            .set("throughput_tok_s", self.throughput())
            .set("goodput_req_s", self.goodput())
            .set("prefix_forks", self.prefix_forks)
            .set("shared_prefix_tokens", self.shared_prefix_tokens)
            .set("peak_active", self.peak_active)
            .set("kv_allocated_bytes", self.kv_allocated_bytes)
            .set("kv_logical_bytes", self.kv_logical_bytes)
            .set("kv_utilization", self.kv_utilization())
            .set("ttft", self.ttft.to_json_ms())
            .set("itl", self.itl.to_json_ms())
            .set("e2e", self.e2e.to_json_ms())
            .set(
                "nodes",
                Json::Arr(
                    self.node_stats.iter().map(NetStats::to_json).collect(),
                ),
            )
    }

    /// Multi-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "requests {}/{} · {} tokens in {:.2} s \
             ({:.1} tok/s, {:.2} req/s)\n\
             wait     : {:.1} steps mean\n\
             ttft     : {}\n\
             itl      : {}\n\
             e2e      : {}",
            self.completed,
            self.requests,
            self.tokens,
            self.elapsed_s,
            self.throughput(),
            self.goodput(),
            self.mean_wait_steps,
            self.ttft.summary_ms(),
            self.itl.summary_ms(),
            self.e2e.summary_ms(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_handle_zero_elapsed() {
        let r = ServeReport::default();
        assert_eq!(r.throughput(), 0.0);
        assert_eq!(r.goodput(), 0.0);
    }

    #[test]
    fn summary_carries_counts() {
        let mut r = ServeReport {
            requests: 4,
            completed: 4,
            tokens: 32,
            elapsed_s: 2.0,
            steps: 10,
            mean_wait_steps: 1.5,
            ..Default::default()
        };
        r.ttft.record_secs(0.01);
        let s = r.summary();
        assert!(s.contains("4/4"));
        assert!(s.contains("16.0 tok/s"));
        assert!((r.goodput() - 2.0).abs() < 1e-12);
        // the JSON view parses and carries the same counters
        let j = Json::parse(&r.to_json().render()).unwrap();
        assert_eq!(j.get("completed").and_then(Json::as_f64), Some(4.0));
        assert_eq!(
            j.get("throughput_tok_s").and_then(Json::as_f64),
            Some(16.0)
        );
        let ttft = j.get("ttft").expect("ttft block");
        assert_eq!(ttft.get("count").and_then(Json::as_f64), Some(1.0));
    }

    #[test]
    fn kv_utilization_reflects_sharing() {
        let mut r = ServeReport::default();
        assert_eq!(r.kv_utilization(), 0.0); // no KV held, no NaN
        r.kv_allocated_bytes = 1024;
        r.kv_logical_bytes = 1536; // prefix sharing: logical > physical
        assert!((r.kv_utilization() - 1.5).abs() < 1e-12);
        let j = Json::parse(&r.to_json().render()).unwrap();
        assert_eq!(
            j.get("kv_utilization").and_then(Json::as_f64),
            Some(1.5)
        );
        assert_eq!(
            j.get("prefix_forks").and_then(Json::as_f64),
            Some(0.0)
        );
    }

    #[test]
    fn completion_wait_steps() {
        let c = Completion {
            request_id: 0,
            tokens: vec![1],
            arrive_step: 3,
            admit_step: 8,
            finish_step: 9,
            ttft_s: 0.1,
            e2e_s: 0.2,
        };
        assert_eq!(c.wait_steps(), 5);
    }
}
