//! Slot-based sequence manager: the engine's fixed batch of B rows
//! becomes B independent decode slots.
//!
//! A slot holds one in-flight request from admission to retirement.
//! Sequences finish independently (per-request `target_len`), free
//! their slot, and the freed slot is backfilled from the admission
//! queue on the next step WITHOUT disturbing in-flight neighbors —
//! continuous batching at request granularity, in contrast to the
//! wave-at-a-time `server::AdmissionQueue` front-end.
//!
//! Slot misuse (placing into an occupied slot, taking from an empty
//! one) is an engine-logic bug, but it surfaces as a routed `Err`
//! rather than a panic: a serving engine mid-run holds live KV on
//! every node, and the routed-error discipline says the caller decides
//! how to unwind, not a poisoned thread.

use anyhow::{bail, Result};

/// One admitted, in-flight request occupying a slot.
#[derive(Clone, Debug)]
pub struct ActiveRequest {
    pub request_id: u64,
    /// Engine sequence id (KV-cache key across the socket pool).
    pub seq_id: u64,
    pub prompt: Vec<i32>,
    /// Tokens to generate (the request retires after producing exactly
    /// this many).
    pub target_len: usize,
    /// Prompt tokens already fed to the engine. `== prompt.len()` once
    /// the request is decoding; smaller only mid-prefill in
    /// token-at-a-time mode (batched prefill feeds the whole prompt in
    /// the admission step).
    pub fed: usize,
    /// Generated tokens so far (the first is produced by the row that
    /// consumes the prompt's last token).
    pub produced: Vec<i32>,
    /// Input token of the next decode row (the last produced token).
    pub next_token: i32,
    pub arrive_step: usize,
    pub admit_step: usize,
    /// Wall-clock offsets from the serve run's start, seconds.
    pub wall_arrive_s: f64,
    pub wall_last_token_s: f64,
    /// Time to first token, recorded when `produced` gains its first
    /// entry; 0 until then.
    pub ttft_s: f64,
}

impl ActiveRequest {
    /// Prefill is done; every pass row for this request is now a decode
    /// row.
    pub fn decoding(&self) -> bool {
        self.fed == self.prompt.len()
    }

    /// The request has produced its full target and can retire.
    pub fn done(&self) -> bool {
        self.produced.len() >= self.target_len
    }
}

/// Fixed set of B slots with first-free backfill.
pub struct SlotManager {
    slots: Vec<Option<ActiveRequest>>,
}

impl SlotManager {
    pub fn new(slots: usize) -> SlotManager {
        assert!(slots > 0, "need at least one slot");
        SlotManager {
            slots: (0..slots).map(|_| None).collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn active_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn free_count(&self) -> usize {
        self.capacity() - self.active_count()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
    }

    /// Lowest-index free slot, if any.
    pub fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Place a request into an empty slot; a routed error if the slot
    /// is occupied (the request is handed back inside the error path by
    /// NOT being consumed — the caller still owns the queue it came
    /// from).
    pub fn place(&mut self, slot: usize, req: ActiveRequest) -> Result<()> {
        if let Some(occupant) = &self.slots[slot] {
            bail!(
                "slot {slot} already occupied by request {} (placing \
                 request {})",
                occupant.request_id,
                req.request_id
            );
        }
        self.slots[slot] = Some(req);
        Ok(())
    }

    pub fn get_mut(&mut self, slot: usize) -> Option<&mut ActiveRequest> {
        self.slots[slot].as_mut()
    }

    /// Retire the request in `slot`, freeing it for backfill; a routed
    /// error if the slot is already empty.
    pub fn take(&mut self, slot: usize) -> Result<ActiveRequest> {
        match self.slots[slot].take() {
            Some(req) => Ok(req),
            None => bail!("taking an empty slot {slot}"),
        }
    }

    /// Occupied slots in slot order (stable row order across steps for
    /// sequences that stay put).
    pub fn iter_active(&self) -> impl Iterator<Item = (usize, &ActiveRequest)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|r| (i, r)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> ActiveRequest {
        ActiveRequest {
            request_id: id,
            seq_id: 100 + id,
            prompt: vec![1, 2, 3],
            target_len: 4,
            fed: 0,
            produced: Vec::new(),
            next_token: 0,
            arrive_step: 0,
            admit_step: 0,
            wall_arrive_s: 0.0,
            wall_last_token_s: 0.0,
            ttft_s: 0.0,
        }
    }

    #[test]
    fn backfill_reuses_freed_slot_without_disturbing_neighbors() {
        let mut sm = SlotManager::new(3);
        for id in 0..3 {
            let s = sm.free_slot().unwrap();
            sm.place(s, req(id)).unwrap();
        }
        assert_eq!(sm.free_count(), 0);
        assert_eq!(sm.free_slot(), None);
        // request 1 (slot 1) finishes; neighbors keep their slots
        let finished = sm.take(1).unwrap();
        assert_eq!(finished.request_id, 1);
        assert_eq!(sm.free_slot(), Some(1));
        sm.place(1, req(9)).unwrap();
        let ids: Vec<u64> =
            sm.iter_active().map(|(_, r)| r.request_id).collect();
        assert_eq!(ids, vec![0, 9, 2]); // slot order, neighbors untouched
    }

    #[test]
    fn lifecycle_predicates() {
        let mut r = req(0);
        assert!(!r.decoding() && !r.done());
        r.fed = 3;
        assert!(r.decoding());
        r.produced = vec![5, 6, 7, 8];
        assert!(r.done());
    }

    /// Slot misuse is a routed error, not a panic: double placement
    /// leaves the occupant untouched; taking an empty slot names it.
    #[test]
    fn slot_misuse_is_a_routed_error() {
        let mut sm = SlotManager::new(1);
        sm.place(0, req(0)).unwrap();
        let err = sm.place(0, req(1)).unwrap_err();
        assert!(format!("{err:#}").contains("already occupied"), "{err:#}");
        assert_eq!(sm.take(0).unwrap().request_id, 0, "occupant displaced");
        let err = sm.take(0).unwrap_err();
        assert!(format!("{err:#}").contains("empty slot"), "{err:#}");
    }
}
