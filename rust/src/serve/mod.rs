//! Request-level continuous-batching serving over the live engine —
//! the subsystem that turns the fixed-batch decoder into a server.
//!
//! # Request lifecycle
//!
//! ```text
//! arrival ──▶ admission queue ──▶ prefill ──▶ decode slot ──▶ retire
//!  (trace)    (AdmissionPolicy     (one multi-  (one row per    (KV drop +
//!             under W_lim via      row causal    step until      slot
//!             Algorithm 1)         pass)         target_len)     backfill)
//! ```
//!
//! * **Arrival** — an open-loop trace ([`crate::workload::generate_trace`])
//!   replayed on a virtual step clock; requests become visible at
//!   ⌊arrival_s · steps_per_sec⌋ and queue until admitted.
//! * **Admission** — a pluggable [`AdmissionPolicy`] ([`Fifo`],
//!   [`ShortestJobFirst`], [`SlsEarliestStart`]) picks which waiting
//!   request starts each step, constrained by Algorithm 1's load
//!   controller so the aggregate KV load never exceeds W_lim; the
//!   batched prefill's bulk append is modeled as an `init` offset
//!   ([`crate::sched::LoadControl::add_init`]). W_lim bounds the
//!   PHYSICAL KV token count: the paged cache stores fixed-size
//!   refcounted blocks, and a block shared by a copy-on-write prefix
//!   fork is charged once however many sequences reference it — so
//!   under a shared-prefix workload the same budget admits more
//!   concurrent sequences than a contiguous (per-sequence) cache
//!   would. The per-step trace's `total_ctx` records this measured
//!   physical load; `ServeReport::kv_logical_bytes` vs
//!   `kv_allocated_bytes` quantifies the gap.
//! * **Prefix sharing** — with `ServeConfig::share_prefixes` on
//!   (default), a prompt whose prefix is already resident in an active
//!   sequence is admitted by COW-forking those blocks
//!   ([`crate::coordinator::real::FastDecode::fork_seq`]) instead of
//!   recomputing them: the child starts with `fed = upto` and prefills
//!   only its divergent tail. Forks are semantically invisible —
//!   generated tokens are bit-identical with sharing on or off.
//! * **Prefill** — the whole prompt crosses the S↔R pipeline as one
//!   multi-row causal pass ([`PrefillMode::Batched`]); the row that
//!   consumes the prompt's last token produces the first generated
//!   token (TTFT). `ServeConfig::max_prefill_rows` chunks a long
//!   prompt across several passes (bounding the rows any one step
//!   carries) without changing any generated token. Token-at-a-time
//!   prefill survives as a comparison baseline.
//! * **Decode slots** — the engine's batch is B independent slots
//!   ([`SlotManager`]); sequences of different lengths finish
//!   independently, and prefill and decode rows share one ragged pass
//!   per step (continuous batching).
//! * **Retire** — a finished sequence frees its KV across the socket
//!   pool and its slot is backfilled next step without disturbing
//!   in-flight neighbors.
//!
//! Per-request TTFT, inter-token latency and end-to-end latency land in
//! a [`ServeReport`] (p50/p95/p99 + throughput/goodput); the per-step
//! engine trace carries the measured aggregate KV load. The open-loop
//! sweep lives in `benches/serve_openloop.rs`, the end-to-end example
//! in `examples/serve_e2e.rs`, and the acceptance suite in
//! `tests/serve_continuous.rs`.

mod engine;
mod policy;
mod report;
mod slots;

pub use engine::{PrefillMode, ServeConfig, ServeEngine, ServeOutcome};
pub(crate) use policy::admit_one;
pub use policy::{
    AdmissionPolicy, Fifo, QueuedJob, ShortestJobFirst, SlsEarliestStart,
};
pub use report::{Completion, ServeReport};
pub use slots::{ActiveRequest, SlotManager};
