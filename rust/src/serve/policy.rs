//! Pluggable admission policies over Algorithm 1's load controller.
//!
//! Every policy answers one question each step: *which waiting job, if
//! any, may start NOW?* The serving engine (`serve::engine`) and the
//! live coordinator's SLS mode (`FastDecode::drive_arrivals_with`) call
//! [`AdmissionPolicy::select`] in a loop until it returns `None` (or
//! slots run out), so a policy expresses ordering only — the W_lim
//! safety invariant is enforced by [`LoadControl`] regardless of the
//! policy, and the callers re-verify the contract before committing.

use anyhow::{bail, Result};

use crate::sched::LoadControl;

/// One admission-queue entry, reduced to what a policy may legitimately
/// look at: size, KV growth profile, and arrival order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueuedJob {
    /// Request (or arrival) id — informational, for error messages.
    pub id: u64,
    /// Sequences admitted together (1 for a single request).
    pub m: usize,
    /// KV tokens per sequence already present when the job's first step
    /// runs (a batched prefill appends the whole prompt at once); 0 for
    /// plain decode arrivals and token-at-a-time prefill.
    pub init_len: usize,
    /// Steps the job stays live, growing by `m` KV tokens per step.
    pub grow_len: usize,
    /// Step at which the job joined the queue.
    pub arrive_step: usize,
}

impl QueuedJob {
    /// Aggregate KV tokens at the job's final step — what W_lim must
    /// absorb.
    pub fn peak_tokens(&self) -> usize {
        self.m * (self.init_len + self.grow_len)
    }

    /// Total per-sequence tokens processed over the job's lifetime —
    /// the "job size" shortest-job-first orders by.
    pub fn total_work(&self) -> usize {
        self.init_len + self.grow_len
    }
}

/// An admission ordering over the waiting queue.
///
/// Contract: `select` may only return the index of a job whose
/// [`LoadControl::earliest_start_init`] at `now` is exactly `now` — a
/// job that can start this step without pushing any live batch's peak
/// past `w_lim`. Returning `None` defers admission to a later step.
pub trait AdmissionPolicy: Send {
    /// Short name for reports and error messages.
    fn name(&self) -> &'static str;

    /// Index into `waiting` of the job to admit at step `now`, or
    /// `None` to admit nothing this step.
    fn select(
        &self,
        now: usize,
        waiting: &[QueuedJob],
        lc: &LoadControl,
        w_lim: usize,
    ) -> Option<usize>;
}

/// One admission round, shared by the serving engine and the live SLS
/// mode so the policy contract is enforced in exactly one place: ask
/// the policy for a startable job, bounds-check the returned index,
/// re-verify the startable-now contract, and commit the job to the
/// load controller. `Ok(Some(idx))` means `waiting[idx]` was admitted
/// and charged — the caller removes it from its queue; `Ok(None)`
/// means nothing can start this step.
pub(crate) fn admit_one(
    policy: &dyn AdmissionPolicy,
    now: usize,
    waiting: &[QueuedJob],
    lc: &mut LoadControl,
    w_lim: usize,
) -> Result<Option<usize>> {
    let Some(idx) = policy.select(now, waiting, lc, w_lim) else {
        return Ok(None);
    };
    let Some(job) = waiting.get(idx) else {
        bail!(
            "admission policy {} returned index {idx} for a queue of {}",
            policy.name(),
            waiting.len()
        );
    };
    if lc.earliest_start_init(now, job.m, job.init_len, job.grow_len, w_lim)
        != Some(now)
    {
        bail!(
            "admission policy {} selected job {} which cannot start at \
             step {now}",
            policy.name(),
            job.id
        );
    }
    lc.add_init(now, job.m, job.init_len, job.grow_len);
    Ok(Some(idx))
}

/// Can `job` start at exactly `now` under `w_lim`?
fn startable_now(
    now: usize,
    job: &QueuedJob,
    lc: &LoadControl,
    w_lim: usize,
) -> bool {
    lc.earliest_start_init(now, job.m, job.init_len, job.grow_len, w_lim)
        == Some(now)
}

/// Strict arrival order with head-of-line blocking: the head of the
/// queue is admitted as soon as it can start, and NO later job may
/// overtake a deferred head (the semantics the live SLS mode shipped
/// with before policies were pluggable).
#[derive(Clone, Copy, Debug, Default)]
pub struct Fifo;

impl AdmissionPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn select(
        &self,
        now: usize,
        waiting: &[QueuedJob],
        lc: &LoadControl,
        w_lim: usize,
    ) -> Option<usize> {
        let head = waiting.first()?;
        startable_now(now, head, lc, w_lim).then_some(0)
    }
}

/// Shortest job first: among the jobs that can start now, the one with
/// the least total work (ties broken by arrival order). Minimizes mean
/// wait under bursty arrivals at the cost of possible long-job
/// starvation — the classic trade-off, observable in the open-loop
/// bench.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShortestJobFirst;

impl AdmissionPolicy for ShortestJobFirst {
    fn name(&self) -> &'static str {
        "sjf"
    }

    fn select(
        &self,
        now: usize,
        waiting: &[QueuedJob],
        lc: &LoadControl,
        w_lim: usize,
    ) -> Option<usize> {
        waiting
            .iter()
            .enumerate()
            .filter(|(_, j)| startable_now(now, j, lc, w_lim))
            .min_by_key(|(i, j)| (j.total_work(), *i))
            .map(|(i, _)| i)
    }
}

/// SLS-aware earliest start: the job whose feasible start step under
/// W_lim is soonest goes first (ties broken by arrival order), and it
/// is admitted once that start arrives. Unlike FIFO this lets a small
/// job slip past a deferred large head, keeping the engine busy — at
/// the cost that each admission re-tightens the head's own earliest
/// start, so a large job can be delayed repeatedly under sustained
/// small-job pressure (the same starvation trade-off as SJF, bounded
/// here by W_lim draining between admissions).
#[derive(Clone, Copy, Debug, Default)]
pub struct SlsEarliestStart;

impl AdmissionPolicy for SlsEarliestStart {
    fn name(&self) -> &'static str {
        "sls-earliest-start"
    }

    fn select(
        &self,
        now: usize,
        waiting: &[QueuedJob],
        lc: &LoadControl,
        w_lim: usize,
    ) -> Option<usize> {
        let (start, idx) = waiting
            .iter()
            .enumerate()
            .filter_map(|(i, j)| {
                lc.earliest_start_init(now, j.m, j.init_len, j.grow_len, w_lim)
                    .map(|s| (s, i))
            })
            .min()?;
        (start == now).then_some(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, init: usize, grow: usize) -> QueuedJob {
        QueuedJob {
            id,
            m: 1,
            init_len: init,
            grow_len: grow,
            arrive_step: 0,
        }
    }

    #[test]
    fn fifo_blocks_behind_deferred_head() {
        let mut lc = LoadControl::new();
        lc.add(0, 1, 10); // peak 10 at step 9
        // the head's prefill bulk (init 8) exceeds the headroom left at
        // the elder's peak under w_lim 16, so it must wait for step 10
        // — and FIFO then admits NOTHING this step, even though the
        // tiny second job would fit now
        let waiting = [job(0, 8, 8), job(1, 0, 2)];
        let fifo = Fifo;
        assert_eq!(fifo.select(0, &waiting, &lc, 16), None);
        let sjf = ShortestJobFirst;
        assert_eq!(sjf.select(0, &waiting, &lc, 16), Some(1));
    }

    #[test]
    fn sjf_prefers_least_work_breaking_ties_by_arrival() {
        let lc = LoadControl::new();
        let waiting = [job(0, 0, 8), job(1, 2, 2), job(2, 0, 4), job(3, 0, 4)];
        let sjf = ShortestJobFirst;
        assert_eq!(sjf.select(0, &waiting, &lc, 100), Some(1)); // work 4
        let tie = [job(0, 0, 4), job(1, 0, 4)];
        assert_eq!(sjf.select(0, &tie, &lc, 100), Some(0));
    }

    #[test]
    fn sls_admits_soonest_feasible_start() {
        let mut lc = LoadControl::new();
        lc.add(0, 2, 10); // peak 20 at step 9
        // job 0 can only start after the elder ends; job 1 fits now
        let waiting = [job(0, 10, 10), job(1, 0, 5)];
        let sls = SlsEarliestStart;
        assert_eq!(sls.select(0, &waiting, &lc, 25), Some(1));
        // once nothing can start now, nothing is admitted
        let deferred = [job(0, 10, 10)];
        assert_eq!(sls.select(0, &deferred, &lc, 25), None);
    }

    #[test]
    fn infeasible_jobs_are_never_selected() {
        let lc = LoadControl::new();
        let waiting = [job(0, 50, 60)]; // peak 110 > any tested limit
        assert_eq!(Fifo.select(0, &waiting, &lc, 100), None);
        assert_eq!(ShortestJobFirst.select(0, &waiting, &lc, 100), None);
        assert_eq!(SlsEarliestStart.select(0, &waiting, &lc, 100), None);
    }

    #[test]
    fn empty_queue_selects_nothing() {
        let lc = LoadControl::new();
        assert_eq!(Fifo.select(3, &[], &lc, 10), None);
        assert_eq!(ShortestJobFirst.select(3, &[], &lc, 10), None);
        assert_eq!(SlsEarliestStart.select(3, &[], &lc, 10), None);
    }

    #[test]
    fn admit_one_commits_selected_job() {
        let mut lc = LoadControl::new();
        let waiting = [job(0, 2, 4)];
        let idx = admit_one(&Fifo, 0, &waiting, &mut lc, 100).unwrap();
        assert_eq!(idx, Some(0));
        assert_eq!(lc.load_at(0), 3, "job not charged to the controller");
        // infeasible job: nothing admitted, nothing charged
        let deferred = [job(1, 0, 200)];
        assert_eq!(admit_one(&Fifo, 0, &deferred, &mut lc, 100).unwrap(), None);
    }

    /// A policy violating the index or startable-now contract is an
    /// error, never a panic or a silent W_lim breach.
    #[test]
    fn admit_one_rejects_contract_violations() {
        struct Bad(usize);
        impl AdmissionPolicy for Bad {
            fn name(&self) -> &'static str {
                "bad"
            }
            fn select(
                &self,
                _: usize,
                _: &[QueuedJob],
                _: &LoadControl,
                _: usize,
            ) -> Option<usize> {
                Some(self.0)
            }
        }
        let mut lc = LoadControl::new();
        let waiting = [job(0, 0, 4)];
        // out-of-range index
        assert!(admit_one(&Bad(7), 0, &waiting, &mut lc, 100).is_err());
        // in-range but not startable now: job 0 can only start later
        lc.add(0, 1, 10); // peak 10 at step 9
        let blocked = [job(0, 8, 8)]; // init 8 exceeds headroom 6
        assert!(admit_one(&Bad(0), 0, &blocked, &mut lc, 16).is_err());
    }
}
