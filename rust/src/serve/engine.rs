//! The continuous-batching serving engine: drives the live
//! [`FastDecode`] coordinator from an open-loop request trace.
//!
//! Per step: (1) trace requests whose arrival step has come join the
//! waiting queue; (2) the [`AdmissionPolicy`] admits startable requests
//! into free slots under the aggregate-KV limit W_lim (Algorithm 1 via
//! [`LoadControl`], with the batched prefill's bulk append modeled as
//! an `init` offset) — when `share_prefixes` is on, a prompt whose
//! prefix is already resident in an active sequence COW-forks those KV
//! blocks instead of recomputing them, and only its divergent tail is
//! charged; (3) every occupied slot contributes rows to ONE ragged
//! forward pass — freshly admitted requests their (multi-row, possibly
//! `max_prefill_rows`-chunked) prefill, decoding requests one row each;
//! (4) finished requests drop their KV ([`FastDecode::retire_seqs`])
//! and free their slot for backfill, without disturbing in-flight
//! neighbors.
//!
//! All latencies are real wall-clock seconds measured from the run's
//! start; the step clock is virtual (`steps_per_sec` maps the trace's
//! arrival times onto it), so a faster engine drains the same trace in
//! less wall time at identical step-level admission decisions.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::real::FastDecode;
use crate::metrics::{Histogram, StepRecord, StepTrace};
use crate::obs::Metrics;
use crate::sched::LoadControl;
use crate::workload::Request;

use super::policy::{admit_one, AdmissionPolicy, QueuedJob};
use super::report::{Completion, ServeReport};
use super::slots::{ActiveRequest, SlotManager};

/// How a newly admitted request's prompt enters the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefillMode {
    /// The whole prompt crosses the pipeline as one multi-row causal
    /// pass in the admission step (one round trip per layer) — the
    /// production mode.
    Batched,
    /// One prompt token per step through the decode path (the repo's
    /// historical prefill; kept as the TTFT comparison baseline).
    TokenAtATime,
}

#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Aggregate KV-token limit enforced by admission (Algorithm 1's
    /// W_lim). Under paging this bounds PHYSICAL per-layer tokens:
    /// blocks shared by a COW fork are charged once, so a shared-prefix
    /// workload fits more concurrent sequences into the same budget.
    pub w_lim: usize,
    /// Virtual step rate mapping `Request::arrival_s` onto the step
    /// clock: a request arrives at step ⌊arrival_s · steps_per_sec⌋.
    pub steps_per_sec: f64,
    pub prefill: PrefillMode,
    /// Hard cap on driven steps — exceeded means the configuration
    /// cannot drain the trace (an error, never an infinite loop).
    pub max_steps: usize,
    /// Chunked prefill: at most this many prompt rows per request per
    /// pass (0 = the whole remaining prompt in one pass). Caps the
    /// prefill burst a long prompt injects into a step without changing
    /// any generated token — per-row append/attend order is identical.
    /// [`PrefillMode::Batched`] only; token-at-a-time already feeds one
    /// row per step.
    pub max_prefill_rows: usize,
    /// COW-fork the KV blocks of a prompt prefix already resident in an
    /// active sequence instead of recomputing them. Semantically
    /// invisible (generated tokens are bit-identical either way); only
    /// the divergent tail is charged against W_lim.
    /// [`PrefillMode::Batched`] only.
    pub share_prefixes: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            w_lim: 4096,
            steps_per_sec: 100.0,
            prefill: PrefillMode::Batched,
            max_steps: 100_000,
            max_prefill_rows: 0,
            share_prefixes: true,
        }
    }
}

/// Everything a serving run produced.
pub struct ServeOutcome {
    pub report: ServeReport,
    /// Finished requests, sorted by request id.
    pub completions: Vec<Completion>,
    /// Per-step engine trace (measured stage times, tokens per pass,
    /// and the MEASURED aggregate KV load in `total_ctx`).
    pub trace: StepTrace,
    /// Name of the admission policy that ran.
    pub policy: &'static str,
}

/// A request waiting for admission.
struct WaitingReq {
    /// Index into the trace slice.
    idx: usize,
    arrive_step: usize,
    wall_arrive_s: f64,
}

/// Shortest prefix worth forking: below this the block-table plumbing
/// outweighs the savings, and degenerate one-token "prefixes" would
/// fork on almost every admission.
const MIN_FORK_LEN: usize = 2;

/// Longest usable shared prompt prefix between `prompt` and any active
/// request: the parent must have fed the prefix already (`fed`), the
/// child must keep at least one prompt row of its own (the row that
/// produces its first token), and prefixes shorter than
/// [`MIN_FORK_LEN`] are ignored. Returns the parent's seq id and the
/// fork length.
fn fork_candidate(slots: &SlotManager, prompt: &[i32]) -> Option<(u64, usize)> {
    let mut best: Option<(u64, usize)> = None;
    for (_, req) in slots.iter_active() {
        let common = req
            .prompt
            .iter()
            .zip(prompt)
            .take_while(|&(a, b)| a == b)
            .count();
        let upto = common.min(prompt.len() - 1).min(req.fed);
        if upto >= MIN_FORK_LEN && upto > best.map_or(0, |(_, u)| u) {
            best = Some((req.seq_id, upto));
        }
    }
    best
}

/// Continuous-batching serving engine over the live coordinator.
pub struct ServeEngine {
    fd: FastDecode,
    cfg: ServeConfig,
    policy: Box<dyn AdmissionPolicy>,
}

impl ServeEngine {
    pub fn new(
        fd: FastDecode,
        cfg: ServeConfig,
        policy: Box<dyn AdmissionPolicy>,
    ) -> Result<ServeEngine> {
        if cfg.w_lim == 0 {
            bail!("W_lim must be ≥ 1");
        }
        if !cfg.steps_per_sec.is_finite() || cfg.steps_per_sec <= 0.0 {
            bail!("steps_per_sec must be positive and finite");
        }
        if cfg.max_steps == 0 {
            bail!("max_steps must be ≥ 1");
        }
        Ok(ServeEngine { fd, cfg, policy })
    }

    /// Decode slots (the engine's configured batch width).
    pub fn slots(&self) -> usize {
        self.fd.cfg.batch
    }

    /// Hand the coordinator back (e.g. to re-prime it for a fixed-batch
    /// run).
    pub fn into_engine(self) -> FastDecode {
        self.fd
    }

    /// The admission queue's KV growth model for one request: batched
    /// prefill bulk-appends `plen` tokens in the admission step (the
    /// same step also produces the first token, so `init = plen − 1`
    /// and the job lives `target_len` steps); token-at-a-time grows by
    /// one token for `plen + target_len − 1` steps.
    fn job_for(&self, r: &Request, arrive_step: usize) -> QueuedJob {
        match self.cfg.prefill {
            PrefillMode::Batched => QueuedJob {
                id: r.id,
                m: 1,
                init_len: r.prompt.len() - 1,
                grow_len: r.target_len,
                arrive_step,
            },
            PrefillMode::TokenAtATime => QueuedJob {
                id: r.id,
                m: 1,
                init_len: 0,
                grow_len: r.prompt.len() + r.target_len - 1,
                arrive_step,
            },
        }
    }

    /// Serve every request of `trace` to completion (open loop: the
    /// engine never waits for a client). Returns the per-request
    /// completions, the latency report, and the per-step trace.
    pub fn run(&mut self, trace: &[Request]) -> Result<ServeOutcome> {
        let cap = self.fd.cfg.capacity_per_seq;
        for r in trace {
            if r.prompt.is_empty() {
                bail!("request {}: empty prompt", r.id);
            }
            if r.target_len == 0 {
                bail!("request {}: target_len must be ≥ 1", r.id);
            }
            let peak = r.prompt.len() + r.target_len - 1;
            if peak > cap {
                bail!(
                    "request {}: prompt + target ({peak} KV tokens) exceeds \
                     per-sequence capacity {cap}",
                    r.id
                );
            }
            if peak > self.cfg.w_lim {
                bail!(
                    "request {}: peak KV footprint {peak} alone exceeds \
                     W_lim {} — it could never be admitted",
                    r.id,
                    self.cfg.w_lim
                );
            }
            for &t in &r.prompt {
                if t < 0 || t as usize >= self.fd.spec.vocab {
                    bail!(
                        "request {}: prompt token {t} outside vocab {}",
                        r.id,
                        self.fd.spec.vocab
                    );
                }
            }
        }
        // take manual control of the sequence lifecycle
        self.fd.reset();
        // serving-level events (admissions, passes) get their own track
        // beside the pipeline's coordinator/sworker/socket tracks
        let track = self.fd.tracer().track("serve");

        // arrivals in time order (stable on the trace's own order for
        // simultaneous arrivals)
        let mut order: Vec<usize> = (0..trace.len()).collect();
        order.sort_by(|&a, &b| trace[a].arrival_s.total_cmp(&trace[b].arrival_s));
        let arrival_step = |r: &Request| -> usize {
            // clamp so a pathological arrival time cannot overflow the
            // step clock; max_steps then reports the real problem
            (r.arrival_s * self.cfg.steps_per_sec)
                .floor()
                .min(self.cfg.max_steps as f64) as usize
        };

        let mut next_arrival = 0usize;
        // one queue: a job's KV profile travels WITH its trace index
        // and arrival times, so they can never be paired up wrongly
        let mut waiting: Vec<(QueuedJob, WaitingReq)> = Vec::new();
        let mut lc = LoadControl::new();
        let mut slots = SlotManager::new(self.slots());
        let mut completions: Vec<Completion> = Vec::new();
        let mut steps = StepTrace::default();
        let mut ttft_h = Histogram::new();
        let mut itl_h = Histogram::new();
        let mut e2e_h = Histogram::new();
        let mut total_wait_steps = 0usize;
        let mut total_tokens = 0u64;
        let mut prefix_forks = 0u64;
        let mut shared_prefix_tokens = 0u64;
        let mut peak_active = 0usize;
        let mut peak_kv_allocated = 0usize;
        let mut peak_kv_logical = 0usize;
        let share = self.cfg.share_prefixes
            && self.cfg.prefill == PrefillMode::Batched;
        // live-metrics handle resolved once per run; every call below
        // is a single branch when FASTDECODE_METRICS is off
        let metrics = Metrics::global();
        let t0 = Instant::now();
        let mut t = 0usize;

        while completions.len() < trace.len() {
            if t >= self.cfg.max_steps {
                bail!(
                    "serve exceeded max_steps = {} with {} of {} requests \
                     completed (policy {})",
                    self.cfg.max_steps,
                    completions.len(),
                    trace.len(),
                    self.policy.name()
                );
            }
            // 1. arrivals visible at step t join the queue
            while next_arrival < trace.len() {
                let r = &trace[order[next_arrival]];
                let astep = arrival_step(r);
                if astep > t {
                    break;
                }
                waiting.push((
                    self.job_for(r, astep),
                    WaitingReq {
                        idx: order[next_arrival],
                        arrive_step: astep,
                        wall_arrive_s: t0.elapsed().as_secs_f64(),
                    },
                ));
                next_arrival += 1;
            }
            // 2. admission into free slots under W_lim (`admit_one`
            // enforces the policy contract and charges the controller)
            lc.retire_before(t);
            while slots.free_count() > 0 && !waiting.is_empty() {
                // fork candidates are re-scanned every round: an
                // admission can itself become the parent of the next
                let forks: Vec<Option<(u64, usize)>> = waiting
                    .iter()
                    .map(|(_, meta)| {
                        if share {
                            fork_candidate(&slots, &trace[meta.idx].prompt)
                        } else {
                            None
                        }
                    })
                    .collect();
                let jobs: Vec<QueuedJob> = waiting
                    .iter()
                    .zip(&forks)
                    .map(|((j, _), f)| match f {
                        // the shared prefix is already resident as COW
                        // blocks — only the divergent tail is new
                        // physical KV, so only it is charged
                        Some((_, upto)) => QueuedJob {
                            init_len: j.init_len - upto,
                            ..*j
                        },
                        None => *j,
                    })
                    .collect();
                let Some(sel) = admit_one(
                    self.policy.as_ref(),
                    t,
                    &jobs,
                    &mut lc,
                    self.cfg.w_lim,
                )?
                else {
                    break;
                };
                let fork = forks[sel];
                let (_, meta) = waiting.remove(sel);
                let r = &trace[meta.idx];
                track.instant(
                    "admit",
                    &[
                        ("request", r.id as f64),
                        ("step", t as f64),
                        ("prompt", r.prompt.len() as f64),
                        ("target", r.target_len as f64),
                        ("waited_steps", (t - meta.arrive_step) as f64),
                        ("shared_prefix", fork.map_or(0.0, |(_, u)| u as f64)),
                    ],
                );
                let seq_id = self.fd.alloc_seq_ids(1)[0];
                let fed = match fork {
                    Some((parent, upto)) => {
                        self.fd.fork_seq(parent, seq_id, upto)?;
                        prefix_forks += 1;
                        shared_prefix_tokens += upto as u64;
                        upto
                    }
                    None => {
                        self.fd.register_seqs(&[seq_id])?;
                        0
                    }
                };
                let Some(slot) = slots.free_slot() else {
                    // the loop condition guarantees a free slot; if the
                    // invariant ever breaks, route it — the engine holds
                    // live KV a panic would strand
                    bail!(
                        "admission selected request {} with no free slot",
                        r.id
                    );
                };
                total_wait_steps += t - meta.arrive_step;
                slots.place(
                    slot,
                    ActiveRequest {
                        request_id: r.id,
                        seq_id,
                        prompt: r.prompt.clone(),
                        target_len: r.target_len,
                        fed,
                        produced: Vec::new(),
                        next_token: 0,
                        arrive_step: meta.arrive_step,
                        admit_step: t,
                        wall_arrive_s: meta.wall_arrive_s,
                        wall_last_token_s: 0.0,
                        ttft_s: 0.0,
                    },
                )?;
                metrics.inc("serve_admissions", &[], 1);
            }
            peak_active = peak_active.max(slots.active_count());
            metrics.set_gauge(
                "serve_active_slots",
                &[],
                slots.active_count() as f64,
            );
            metrics.set_gauge("serve_queue_depth", &[], waiting.len() as f64);
            // 3. assemble one ragged pass over every occupied slot
            struct PassSeg {
                slot: usize,
                rows: usize,
                prefill: bool,
            }
            let mut tokens: Vec<i32> = Vec::new();
            let mut row_seqs: Vec<u64> = Vec::new();
            let mut segs: Vec<PassSeg> = Vec::new();
            for (slot, req) in slots.iter_active() {
                if req.decoding() {
                    tokens.push(req.next_token);
                    row_seqs.push(req.seq_id);
                    segs.push(PassSeg {
                        slot,
                        rows: 1,
                        prefill: false,
                    });
                } else {
                    let rows = match self.cfg.prefill {
                        PrefillMode::Batched => {
                            let left = req.prompt.len() - req.fed;
                            match self.cfg.max_prefill_rows {
                                0 => left,
                                cap => left.min(cap),
                            }
                        }
                        PrefillMode::TokenAtATime => 1,
                    };
                    for &tok in &req.prompt[req.fed..req.fed + rows] {
                        tokens.push(tok);
                        row_seqs.push(req.seq_id);
                    }
                    segs.push(PassSeg {
                        slot,
                        rows,
                        prefill: true,
                    });
                }
            }
            if tokens.is_empty() {
                // idle step: nothing active yet (arrivals still ahead on
                // the step clock, or the policy deferred everything) —
                // spin the virtual clock
                steps.push(StepRecord {
                    step: t,
                    ..Default::default()
                });
                t += 1;
                continue;
            }
            // 4. one pipeline pass; then per-request bookkeeping
            let prefill_rows: usize =
                segs.iter().filter(|s| s.prefill).map(|s| s.rows).sum();
            let decode_rows = tokens.len() - prefill_rows;
            let t_pass = Instant::now();
            let (next, timing) = self.fd.forward_rows(&tokens, &row_seqs)?;
            track.record(
                "pass",
                t_pass,
                Instant::now(),
                &[
                    ("step", t as f64),
                    ("prefill_rows", prefill_rows as f64),
                    ("decode_rows", decode_rows as f64),
                ],
            );
            let now_s = t0.elapsed().as_secs_f64();
            // measure the aggregate KV load this pass actually held,
            // BEFORE finished sequences release their caches — this is
            // what W_lim must bound. One stats round trip yields both
            // the physical per-layer load and the byte-level peaks.
            let cs = self.fd.cache_stats()?;
            let kv_load = cs.physical_tokens / self.fd.layers();
            peak_kv_allocated = peak_kv_allocated.max(cs.allocated_bytes);
            peak_kv_logical = peak_kv_logical.max(cs.logical_bytes);
            let mut finished_seqs: Vec<u64> = Vec::new();
            let mut row = 0usize;
            for seg in &segs {
                let last = next[row + seg.rows - 1];
                row += seg.rows;
                let done = {
                    let Some(req) = slots.get_mut(seg.slot) else {
                        bail!(
                            "pass segment references empty slot {} at step \
                             {t}",
                            seg.slot
                        );
                    };
                    if seg.prefill {
                        req.fed += seg.rows;
                        if req.decoding() {
                            // the row that consumed the prompt's last
                            // token produced the first generated token
                            req.ttft_s = now_s - req.wall_arrive_s;
                            ttft_h.record_secs(req.ttft_s);
                            metrics.observe_secs(
                                "serve_ttft",
                                &[],
                                req.ttft_s,
                            );
                            req.produced.push(last);
                            req.next_token = last;
                            req.wall_last_token_s = now_s;
                            total_tokens += 1;
                        }
                        // earlier prefill rows' samples are discarded
                    } else {
                        itl_h.record_secs(now_s - req.wall_last_token_s);
                        metrics.observe_secs(
                            "serve_itl",
                            &[],
                            now_s - req.wall_last_token_s,
                        );
                        req.produced.push(last);
                        req.next_token = last;
                        req.wall_last_token_s = now_s;
                        total_tokens += 1;
                    }
                    req.done()
                };
                if done {
                    let req = slots.take(seg.slot)?;
                    finished_seqs.push(req.seq_id);
                    let e2e_s = now_s - req.wall_arrive_s;
                    e2e_h.record_secs(e2e_s);
                    completions.push(Completion {
                        request_id: req.request_id,
                        tokens: req.produced,
                        arrive_step: req.arrive_step,
                        admit_step: req.admit_step,
                        finish_step: t,
                        ttft_s: req.ttft_s,
                        e2e_s,
                    });
                }
            }
            if !finished_seqs.is_empty() {
                self.fd.retire_seqs(&finished_seqs)?;
            }
            metrics.inc(
                "serve_completions",
                &[],
                finished_seqs.len() as u64,
            );
            if metrics.is_enabled() {
                let wall_s = t0.elapsed().as_secs_f64();
                let goodput = if wall_s > 0.0 {
                    total_tokens as f64 / wall_s
                } else {
                    0.0
                };
                metrics.set_gauge("serve_goodput_tok_per_s", &[], goodput);
                metrics.set_gauge(
                    "serve_kv_physical_tokens",
                    &[],
                    cs.physical_tokens as f64,
                );
                metrics.sample("serve_goodput_tok_per_s", &[], goodput);
                metrics.sample(
                    "serve_active_slots",
                    &[],
                    slots.active_count() as f64,
                );
            }
            steps.push(StepRecord {
                step: t,
                latency_s: timing.latency_s,
                s_time: timing.s_time,
                r_time: timing.r_time,
                comm_time: timing.comm_time,
                queue_wait_s: timing.queue_wait_s,
                gather_wait_s: timing.gather_wait_s,
                dispatch_s: timing.dispatch_s,
                skew_s: timing.skew_s,
                socket_busy: timing.socket_busy,
                tokens: tokens.len(),
                total_ctx: kv_load,
            });
            t += 1;
        }

        completions.sort_by_key(|c| c.request_id);
        let elapsed_s = t0.elapsed().as_secs_f64();
        let report = ServeReport {
            requests: trace.len(),
            completed: completions.len(),
            tokens: total_tokens,
            elapsed_s,
            steps: t,
            mean_wait_steps: if completions.is_empty() {
                0.0
            } else {
                total_wait_steps as f64 / completions.len() as f64
            },
            ttft: ttft_h,
            itl: itl_h,
            e2e: e2e_h,
            prefix_forks,
            shared_prefix_tokens,
            peak_active,
            kv_allocated_bytes: peak_kv_allocated,
            kv_logical_bytes: peak_kv_logical,
            // per-node wire totals and measured profiles at end of run
            // (empty for in-process backends): the serving layer's view
            // of node heterogeneity
            node_stats: self.fd.net_stats(),
        };
        Ok(ServeOutcome {
            report,
            completions,
            trace: steps,
            policy: self.policy.name(),
        })
    }
}
