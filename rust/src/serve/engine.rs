//! The continuous-batching serving engine: drives the live
//! [`FastDecode`] coordinator from an open-loop request trace.
//!
//! Per step: (1) trace requests whose arrival step has come join the
//! waiting queue; (2) the [`AdmissionPolicy`] admits startable requests
//! into free slots under the aggregate-KV limit W_lim (Algorithm 1 via
//! [`LoadControl`], with the batched prefill's bulk append modeled as
//! an `init` offset); (3) every occupied slot contributes rows to ONE
//! ragged forward pass — freshly admitted requests their (multi-row)
//! prefill, decoding requests one row each; (4) finished requests drop
//! their KV ([`FastDecode::retire_seqs`]) and free their slot for
//! backfill, without disturbing in-flight neighbors.
//!
//! All latencies are real wall-clock seconds measured from the run's
//! start; the step clock is virtual (`steps_per_sec` maps the trace's
//! arrival times onto it), so a faster engine drains the same trace in
//! less wall time at identical step-level admission decisions.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::coordinator::real::FastDecode;
use crate::metrics::{Histogram, StepRecord, StepTrace};
use crate::sched::LoadControl;
use crate::workload::Request;

use super::policy::{admit_one, AdmissionPolicy, QueuedJob};
use super::report::{Completion, ServeReport};
use super::slots::{ActiveRequest, SlotManager};

/// How a newly admitted request's prompt enters the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefillMode {
    /// The whole prompt crosses the pipeline as one multi-row causal
    /// pass in the admission step (one round trip per layer) — the
    /// production mode.
    Batched,
    /// One prompt token per step through the decode path (the repo's
    /// historical prefill; kept as the TTFT comparison baseline).
    TokenAtATime,
}

#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Aggregate KV-token limit enforced by admission (Algorithm 1's
    /// W_lim).
    pub w_lim: usize,
    /// Virtual step rate mapping `Request::arrival_s` onto the step
    /// clock: a request arrives at step ⌊arrival_s · steps_per_sec⌋.
    pub steps_per_sec: f64,
    pub prefill: PrefillMode,
    /// Hard cap on driven steps — exceeded means the configuration
    /// cannot drain the trace (an error, never an infinite loop).
    pub max_steps: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            w_lim: 4096,
            steps_per_sec: 100.0,
            prefill: PrefillMode::Batched,
            max_steps: 100_000,
        }
    }
}

/// Everything a serving run produced.
pub struct ServeOutcome {
    pub report: ServeReport,
    /// Finished requests, sorted by request id.
    pub completions: Vec<Completion>,
    /// Per-step engine trace (measured stage times, tokens per pass,
    /// and the MEASURED aggregate KV load in `total_ctx`).
    pub trace: StepTrace,
    /// Name of the admission policy that ran.
    pub policy: &'static str,
}

/// A request waiting for admission.
struct WaitingReq {
    /// Index into the trace slice.
    idx: usize,
    arrive_step: usize,
    wall_arrive_s: f64,
}

/// Continuous-batching serving engine over the live coordinator.
pub struct ServeEngine {
    fd: FastDecode,
    cfg: ServeConfig,
    policy: Box<dyn AdmissionPolicy>,
}

impl ServeEngine {
    pub fn new(
        fd: FastDecode,
        cfg: ServeConfig,
        policy: Box<dyn AdmissionPolicy>,
    ) -> Result<ServeEngine> {
        if cfg.w_lim == 0 {
            bail!("W_lim must be ≥ 1");
        }
        if !cfg.steps_per_sec.is_finite() || cfg.steps_per_sec <= 0.0 {
            bail!("steps_per_sec must be positive and finite");
        }
        if cfg.max_steps == 0 {
            bail!("max_steps must be ≥ 1");
        }
        Ok(ServeEngine { fd, cfg, policy })
    }

    /// Decode slots (the engine's configured batch width).
    pub fn slots(&self) -> usize {
        self.fd.cfg.batch
    }

    /// Hand the coordinator back (e.g. to re-prime it for a fixed-batch
    /// run).
    pub fn into_engine(self) -> FastDecode {
        self.fd
    }

    /// The admission queue's KV growth model for one request: batched
    /// prefill bulk-appends `plen` tokens in the admission step (the
    /// same step also produces the first token, so `init = plen − 1`
    /// and the job lives `target_len` steps); token-at-a-time grows by
    /// one token for `plen + target_len − 1` steps.
    fn job_for(&self, r: &Request, arrive_step: usize) -> QueuedJob {
        match self.cfg.prefill {
            PrefillMode::Batched => QueuedJob {
                id: r.id,
                m: 1,
                init_len: r.prompt.len() - 1,
                grow_len: r.target_len,
                arrive_step,
            },
            PrefillMode::TokenAtATime => QueuedJob {
                id: r.id,
                m: 1,
                init_len: 0,
                grow_len: r.prompt.len() + r.target_len - 1,
                arrive_step,
            },
        }
    }

    /// Serve every request of `trace` to completion (open loop: the
    /// engine never waits for a client). Returns the per-request
    /// completions, the latency report, and the per-step trace.
    pub fn run(&mut self, trace: &[Request]) -> Result<ServeOutcome> {
        let cap = self.fd.cfg.capacity_per_seq;
        for r in trace {
            if r.prompt.is_empty() {
                bail!("request {}: empty prompt", r.id);
            }
            if r.target_len == 0 {
                bail!("request {}: target_len must be ≥ 1", r.id);
            }
            let peak = r.prompt.len() + r.target_len - 1;
            if peak > cap {
                bail!(
                    "request {}: prompt + target ({peak} KV tokens) exceeds \
                     per-sequence capacity {cap}",
                    r.id
                );
            }
            if peak > self.cfg.w_lim {
                bail!(
                    "request {}: peak KV footprint {peak} alone exceeds \
                     W_lim {} — it could never be admitted",
                    r.id,
                    self.cfg.w_lim
                );
            }
            for &t in &r.prompt {
                if t < 0 || t as usize >= self.fd.spec.vocab {
                    bail!(
                        "request {}: prompt token {t} outside vocab {}",
                        r.id,
                        self.fd.spec.vocab
                    );
                }
            }
        }
        // take manual control of the sequence lifecycle
        self.fd.reset();
        // serving-level events (admissions, passes) get their own track
        // beside the pipeline's coordinator/sworker/socket tracks
        let track = self.fd.tracer().track("serve");

        // arrivals in time order (stable on the trace's own order for
        // simultaneous arrivals)
        let mut order: Vec<usize> = (0..trace.len()).collect();
        order.sort_by(|&a, &b| trace[a].arrival_s.total_cmp(&trace[b].arrival_s));
        let arrival_step = |r: &Request| -> usize {
            // clamp so a pathological arrival time cannot overflow the
            // step clock; max_steps then reports the real problem
            (r.arrival_s * self.cfg.steps_per_sec)
                .floor()
                .min(self.cfg.max_steps as f64) as usize
        };

        let mut next_arrival = 0usize;
        // one queue: a job's KV profile travels WITH its trace index
        // and arrival times, so they can never be paired up wrongly
        let mut waiting: Vec<(QueuedJob, WaitingReq)> = Vec::new();
        let mut lc = LoadControl::new();
        let mut slots = SlotManager::new(self.slots());
        let mut completions: Vec<Completion> = Vec::new();
        let mut steps = StepTrace::default();
        let mut ttft_h = Histogram::new();
        let mut itl_h = Histogram::new();
        let mut e2e_h = Histogram::new();
        let mut total_wait_steps = 0usize;
        let mut total_tokens = 0u64;
        let t0 = Instant::now();
        let mut t = 0usize;

        while completions.len() < trace.len() {
            if t >= self.cfg.max_steps {
                bail!(
                    "serve exceeded max_steps = {} with {} of {} requests \
                     completed (policy {})",
                    self.cfg.max_steps,
                    completions.len(),
                    trace.len(),
                    self.policy.name()
                );
            }
            // 1. arrivals visible at step t join the queue
            while next_arrival < trace.len() {
                let r = &trace[order[next_arrival]];
                let astep = arrival_step(r);
                if astep > t {
                    break;
                }
                waiting.push((
                    self.job_for(r, astep),
                    WaitingReq {
                        idx: order[next_arrival],
                        arrive_step: astep,
                        wall_arrive_s: t0.elapsed().as_secs_f64(),
                    },
                ));
                next_arrival += 1;
            }
            // 2. admission into free slots under W_lim (`admit_one`
            // enforces the policy contract and charges the controller)
            lc.retire_before(t);
            while slots.free_count() > 0 && !waiting.is_empty() {
                let jobs: Vec<QueuedJob> =
                    waiting.iter().map(|&(j, _)| j).collect();
                let Some(sel) = admit_one(
                    self.policy.as_ref(),
                    t,
                    &jobs,
                    &mut lc,
                    self.cfg.w_lim,
                )?
                else {
                    break;
                };
                let (_, meta) = waiting.remove(sel);
                let r = &trace[meta.idx];
                track.instant(
                    "admit",
                    &[
                        ("request", r.id as f64),
                        ("step", t as f64),
                        ("prompt", r.prompt.len() as f64),
                        ("target", r.target_len as f64),
                        ("waited_steps", (t - meta.arrive_step) as f64),
                    ],
                );
                let seq_id = self.fd.alloc_seq_ids(1)[0];
                self.fd.register_seqs(&[seq_id])?;
                let slot = slots.free_slot().expect("free slot checked");
                total_wait_steps += t - meta.arrive_step;
                slots.place(
                    slot,
                    ActiveRequest {
                        request_id: r.id,
                        seq_id,
                        prompt: r.prompt.clone(),
                        target_len: r.target_len,
                        fed: 0,
                        produced: Vec::new(),
                        next_token: 0,
                        arrive_step: meta.arrive_step,
                        admit_step: t,
                        wall_arrive_s: meta.wall_arrive_s,
                        wall_last_token_s: 0.0,
                        ttft_s: 0.0,
                    },
                );
            }
            // 3. assemble one ragged pass over every occupied slot
            struct PassSeg {
                slot: usize,
                rows: usize,
                prefill: bool,
            }
            let mut tokens: Vec<i32> = Vec::new();
            let mut row_seqs: Vec<u64> = Vec::new();
            let mut segs: Vec<PassSeg> = Vec::new();
            for (slot, req) in slots.iter_active() {
                if req.decoding() {
                    tokens.push(req.next_token);
                    row_seqs.push(req.seq_id);
                    segs.push(PassSeg {
                        slot,
                        rows: 1,
                        prefill: false,
                    });
                } else {
                    let rows = match self.cfg.prefill {
                        PrefillMode::Batched => req.prompt.len() - req.fed,
                        PrefillMode::TokenAtATime => 1,
                    };
                    for &tok in &req.prompt[req.fed..req.fed + rows] {
                        tokens.push(tok);
                        row_seqs.push(req.seq_id);
                    }
                    segs.push(PassSeg {
                        slot,
                        rows,
                        prefill: true,
                    });
                }
            }
            if tokens.is_empty() {
                // idle step: nothing active yet (arrivals still ahead on
                // the step clock, or the policy deferred everything) —
                // spin the virtual clock
                steps.push(StepRecord {
                    step: t,
                    ..Default::default()
                });
                t += 1;
                continue;
            }
            // 4. one pipeline pass; then per-request bookkeeping
            let prefill_rows: usize =
                segs.iter().filter(|s| s.prefill).map(|s| s.rows).sum();
            let decode_rows = tokens.len() - prefill_rows;
            let t_pass = Instant::now();
            let (next, timing) = self.fd.forward_rows(&tokens, &row_seqs)?;
            track.record(
                "pass",
                t_pass,
                Instant::now(),
                &[
                    ("step", t as f64),
                    ("prefill_rows", prefill_rows as f64),
                    ("decode_rows", decode_rows as f64),
                ],
            );
            let now_s = t0.elapsed().as_secs_f64();
            // measure the aggregate KV load this pass actually held,
            // BEFORE finished sequences release their caches — this is
            // what W_lim must bound
            let kv_load = self.fd.measured_kv_load()?;
            let mut finished_seqs: Vec<u64> = Vec::new();
            let mut row = 0usize;
            for seg in &segs {
                let last = next[row + seg.rows - 1];
                row += seg.rows;
                let done = {
                    let req = slots.get_mut(seg.slot).expect("active slot");
                    if seg.prefill {
                        req.fed += seg.rows;
                        if req.decoding() {
                            // the row that consumed the prompt's last
                            // token produced the first generated token
                            req.ttft_s = now_s - req.wall_arrive_s;
                            ttft_h.record_secs(req.ttft_s);
                            req.produced.push(last);
                            req.next_token = last;
                            req.wall_last_token_s = now_s;
                            total_tokens += 1;
                        }
                        // earlier prefill rows' samples are discarded
                    } else {
                        itl_h.record_secs(now_s - req.wall_last_token_s);
                        req.produced.push(last);
                        req.next_token = last;
                        req.wall_last_token_s = now_s;
                        total_tokens += 1;
                    }
                    req.done()
                };
                if done {
                    let req = slots.take(seg.slot);
                    finished_seqs.push(req.seq_id);
                    let e2e_s = now_s - req.wall_arrive_s;
                    e2e_h.record_secs(e2e_s);
                    completions.push(Completion {
                        request_id: req.request_id,
                        tokens: req.produced,
                        arrive_step: req.arrive_step,
                        admit_step: req.admit_step,
                        finish_step: t,
                        ttft_s: req.ttft_s,
                        e2e_s,
                    });
                }
            }
            if !finished_seqs.is_empty() {
                self.fd.retire_seqs(&finished_seqs)?;
            }
            steps.push(StepRecord {
                step: t,
                latency_s: timing.latency_s,
                s_time: timing.s_time,
                r_time: timing.r_time,
                comm_time: timing.comm_time,
                queue_wait_s: timing.queue_wait_s,
                gather_wait_s: timing.gather_wait_s,
                dispatch_s: timing.dispatch_s,
                skew_s: timing.skew_s,
                socket_busy: timing.socket_busy,
                tokens: tokens.len(),
                total_ctx: kv_load,
            });
            t += 1;
        }

        completions.sort_by_key(|c| c.request_id);
        let elapsed_s = t0.elapsed().as_secs_f64();
        let report = ServeReport {
            requests: trace.len(),
            completed: completions.len(),
            tokens: total_tokens,
            elapsed_s,
            steps: t,
            mean_wait_steps: if completions.is_empty() {
                0.0
            } else {
                total_wait_steps as f64 / completions.len() as f64
            },
            ttft: ttft_h,
            itl: itl_h,
            e2e: e2e_h,
        };
        Ok(ServeOutcome {
            report,
            completions,
            trace: steps,
            policy: self.policy.name(),
        })
    }
}
