//! In-tree micro-benchmark harness (offline stand-in for criterion).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! `Bench::measure` and print paper-style tables via `Table`. Results
//! are also appended as JSON lines to `target/bench_results.jsonl` so
//! EXPERIMENTS.md numbers are reproducible.

pub mod compare;
pub mod snapshot;

use std::io::Write as _;
use std::time::Instant;

use crate::util::json::Json;

/// Timing statistics of one measured closure.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Stats {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let idx = |q: f64| ((q * (n - 1) as f64).round() as usize).min(n - 1);
        Stats {
            iters: n,
            mean_s: samples.iter().sum::<f64>() / n as f64,
            min_s: samples[0],
            max_s: samples[n - 1],
            p50_s: samples[idx(0.5)],
            p99_s: samples[idx(0.99)],
        }
    }
}

/// Benchmark runner with warmup and a time budget.
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop sampling after this much measuring time.
    pub budget_s: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            budget_s: 2.0,
        }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 20,
            budget_s: 0.5,
        }
    }

    /// Measure `f`, returning stats over its per-call wall time.
    pub fn measure<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters
                && start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        Stats::from_samples(samples)
    }
}

/// Fixed-width table printer for paper-style rows.
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = format!("\n## {}\n\n", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Append a JSON record to target/bench_results.jsonl (best effort).
pub fn record_result(bench: &str, payload: Json) {
    let j = Json::obj().set("bench", bench).set("data", payload);
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target");
    let _ = std::fs::create_dir_all(&dir);
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(dir.join("bench_results.jsonl"))
    {
        let _ = writeln!(f, "{}", j.render());
    }
}

/// `--real` on a figure bench's command line swaps the virtual-clock
/// simulator for the live threaded engine (at reduced scale) behind the
/// same `Box<dyn Coordinator>`.
pub fn real_flag() -> bool {
    std::env::args().any(|a| a == "--real")
}

/// Live-engine coordinator at reduced scale for the figure benches: the
/// real pipeline cannot hold LLAMA-7B-class weights on a bench box, so
/// `--real` reruns a figure's sweep *shape* on this machine with the
/// tiny model instead (2 layers, fp16 KV). The engine is primed and
/// ready for `run_steps` up to `steps` (KV capacity is sized to it).
pub fn real_mini(
    batch: usize,
    sockets: usize,
    depth: usize,
    steps: usize,
) -> Box<dyn crate::coordinator::Coordinator> {
    use crate::coordinator::real::{FastDecode, FastDecodeConfig};
    let mut fd = FastDecode::new(
        crate::model::TINY,
        FastDecodeConfig {
            batch,
            sockets,
            capacity_per_seq: steps + 2,
            layers: 2,
            depth,
            ..Default::default()
        },
    )
    .expect("mini live engine");
    let prompts = crate::workload::fixed_batch(batch, 2, crate::model::TINY.vocab, 11);
    fd.prime(&prompts, 1).expect("prime mini live engine");
    Box::new(fd)
}

/// Run the virtual-clock simulator behind `Box<dyn Coordinator>` for
/// `cfg.steps` steps — the figure benches' standard "ours" invocation.
pub fn sim_trace(
    cfg: &crate::coordinator::SimConfig,
) -> crate::metrics::StepTrace {
    use crate::coordinator::{Coordinator, SimCoordinator};
    let mut c: Box<dyn Coordinator> = Box::new(SimCoordinator::new(*cfg));
    c.run_steps(cfg.steps).expect("sim never fails")
}

/// Virtual-clock coordinator matched to [`real_mini`]'s scale, for
/// side-by-side backend tables.
pub fn sim_mini(
    batch: usize,
    sockets: usize,
    seq: usize,
) -> Box<dyn crate::coordinator::Coordinator> {
    use crate::coordinator::{SimConfig, SimCoordinator};
    use crate::perfmodel::{CpuModel, GpuModel, A10, EPYC_7452};
    let cfg = SimConfig::new(
        crate::model::TINY,
        GpuModel::new(A10),
        CpuModel::from_device(EPYC_7452),
        sockets,
        batch,
        seq,
    );
    Box::new(SimCoordinator::new(cfg))
}

/// Human format for seconds.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_samples() {
        let s = Stats::from_samples(vec![0.1, 0.2, 0.3, 0.4, 0.5]);
        assert_eq!(s.iters, 5);
        assert!((s.mean_s - 0.3).abs() < 1e-12);
        assert_eq!(s.min_s, 0.1);
        assert_eq!(s.max_s, 0.5);
        assert_eq!(s.p50_s, 0.3);
    }

    #[test]
    fn measure_runs_at_least_min_iters() {
        let mut calls = 0usize;
        let b = Bench {
            warmup_iters: 1,
            min_iters: 4,
            max_iters: 8,
            budget_s: 0.0,
        };
        let s = b.measure(|| calls += 1);
        assert!(calls >= 5); // warmup + min_iters
        assert_eq!(s.iters, 4);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["col", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.50 ms".into()]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("| long-name | 2.50 ms |"));
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.0), "2.00 s");
        assert_eq!(fmt_time(0.0025), "2.50 ms");
        assert_eq!(fmt_time(2.5e-6), "2.5 µs");
    }
}
