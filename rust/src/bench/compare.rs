//! Perf-regression gate: compare fresh `BENCH_*.json` snapshots
//! against the checked-in `rust/bench.baseline.json`.
//!
//! CI-scale benches are tiny and run on shared noisy runners, so the
//! gate is deliberately NOISE-AWARE: it fails only on ratio changes
//! far outside run-to-run variance (defaults: throughput below 50% of
//! baseline, or p99 step latency above 1.75× baseline), and the
//! baseline file can widen them further per repository. The gate's job
//! is to catch a real regression — an accidental O(n²), a lost
//! overlap, a serialization on the hot path — not 10% jitter.
//!
//! A snapshot with no baseline entry is a WARNING, not a failure: new
//! benches land before their baseline does, and the baseline is then
//! refreshed deliberately (a human re-runs the bench and commits the
//! new numbers with the change that moved them).
//!
//! # Baseline schema (version 1)
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "note": "provenance of the numbers",
//!   "thresholds": { "min_tok_ratio": 0.2, "max_p99_ratio": 5.0 },
//!   "benches": {
//!     "fig9":  { "tok_per_s": 1500.0, "p99_ms": 30.0 },
//!     "fig13_tcp": { "tok_per_s": 400.0, "p99_ms": 80.0 }
//!   }
//! }
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Bump when the baseline layout changes incompatibly.
pub const BASELINE_SCHEMA_VERSION: u64 = 1;

/// Ratio gates applied to `current / baseline`.
#[derive(Clone, Copy, Debug)]
pub struct CompareThresholds {
    /// Fail when `tok_per_s(current) / tok_per_s(baseline)` drops
    /// below this.
    pub min_tok_ratio: f64,
    /// Fail when `p99_ms(current) / p99_ms(baseline)` rises above
    /// this.
    pub max_p99_ratio: f64,
}

impl Default for CompareThresholds {
    fn default() -> Self {
        CompareThresholds {
            min_tok_ratio: 0.5,
            max_p99_ratio: 1.75,
        }
    }
}

/// One bench's pinned numbers.
#[derive(Clone, Copy, Debug)]
pub struct BaselinePoint {
    pub tok_per_s: f64,
    pub p99_ms: f64,
}

/// A parsed baseline file: thresholds plus per-bench points.
#[derive(Clone, Debug)]
pub struct Baseline {
    pub thresholds: CompareThresholds,
    /// (bench name, pinned numbers), in file order.
    pub entries: Vec<(String, BaselinePoint)>,
}

impl Baseline {
    pub fn point(&self, name: &str) -> Option<BaselinePoint> {
        self.entries
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| *p)
    }
}

fn req_pos(j: &Json, ctx: &str, key: &str) -> Result<f64> {
    let v = j
        .get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("{ctx}: missing numeric field '{key}'"))?;
    if !v.is_finite() || v <= 0.0 {
        bail!("{ctx}: field '{key}' is {v}, want finite and > 0");
    }
    Ok(v)
}

/// Parse a baseline document (schema above).
pub fn parse_baseline(doc: &Json) -> Result<Baseline> {
    let version = doc
        .get("schema_version")
        .and_then(Json::as_f64)
        .context("baseline: missing numeric 'schema_version'")?;
    if version != BASELINE_SCHEMA_VERSION as f64 {
        bail!(
            "unsupported baseline schema_version {version} (want \
             {BASELINE_SCHEMA_VERSION})"
        );
    }
    let thresholds = match doc.get("thresholds") {
        Some(t) => CompareThresholds {
            min_tok_ratio: req_pos(t, "thresholds", "min_tok_ratio")?,
            max_p99_ratio: req_pos(t, "thresholds", "max_p99_ratio")?,
        },
        None => CompareThresholds::default(),
    };
    if thresholds.min_tok_ratio >= 1.0 {
        bail!(
            "baseline: min_tok_ratio {} would fail an UNCHANGED bench \
             (want < 1)",
            thresholds.min_tok_ratio
        );
    }
    if thresholds.max_p99_ratio <= 1.0 {
        bail!(
            "baseline: max_p99_ratio {} would fail an UNCHANGED bench \
             (want > 1)",
            thresholds.max_p99_ratio
        );
    }
    let benches = match doc.get("benches") {
        Some(Json::Obj(fields)) => fields,
        _ => bail!("baseline: missing object field 'benches'"),
    };
    if benches.is_empty() {
        bail!("baseline: empty 'benches' — nothing to gate");
    }
    let mut entries = Vec::with_capacity(benches.len());
    for (name, point) in benches {
        entries.push((
            name.clone(),
            BaselinePoint {
                tok_per_s: req_pos(
                    point,
                    &format!("benches.{name}"),
                    "tok_per_s",
                )?,
                p99_ms: req_pos(point, &format!("benches.{name}"), "p99_ms")?,
            },
        ));
    }
    Ok(Baseline {
        thresholds,
        entries,
    })
}

/// Read and [`parse_baseline`] a baseline file.
pub fn load_baseline(path: &Path) -> Result<Baseline> {
    let body = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let doc = Json::parse(&body)
        .with_context(|| format!("parsing {}", path.display()))?;
    parse_baseline(&doc)
        .with_context(|| format!("loading baseline {}", path.display()))
}

/// Verdict of one snapshot-vs-baseline comparison.
#[derive(Clone, Debug, PartialEq)]
pub enum CompareOutcome {
    /// No baseline entry for this bench — report, don't fail.
    NoBaseline { name: String },
    /// Within thresholds. Ratios are current/baseline.
    Pass {
        name: String,
        tok_ratio: f64,
        p99_ratio: f64,
    },
    /// Outside thresholds; one human-readable reason per breach.
    Fail {
        name: String,
        reasons: Vec<String>,
    },
}

impl CompareOutcome {
    pub fn is_fail(&self) -> bool {
        matches!(self, CompareOutcome::Fail { .. })
    }
}

/// Compare one parsed `BENCH_*.json` snapshot against the baseline.
/// The snapshot must already be schema-valid (`snapshot::validate`).
pub fn compare_snapshot(
    doc: &Json,
    baseline: &Baseline,
) -> Result<CompareOutcome> {
    let name = doc
        .get("name")
        .and_then(Json::as_str)
        .context("snapshot: missing string field 'name'")?
        .to_string();
    let tok_per_s = req_pos(doc, "snapshot", "tok_per_s")?;
    let steps = doc.get("steps").context("snapshot: missing 'steps'")?;
    let p99_ms = req_pos(steps, "steps", "p99_ms")?;
    let Some(base) = baseline.point(&name) else {
        return Ok(CompareOutcome::NoBaseline { name });
    };
    let tok_ratio = tok_per_s / base.tok_per_s;
    let p99_ratio = p99_ms / base.p99_ms;
    let mut reasons = Vec::new();
    if tok_ratio < baseline.thresholds.min_tok_ratio {
        reasons.push(format!(
            "throughput regressed: {tok_per_s:.1} tok/s is {:.0}% of the \
             {:.1} tok/s baseline (floor {:.0}%)",
            tok_ratio * 100.0,
            base.tok_per_s,
            baseline.thresholds.min_tok_ratio * 100.0
        ));
    }
    if p99_ratio > baseline.thresholds.max_p99_ratio {
        reasons.push(format!(
            "p99 step latency regressed: {p99_ms:.2} ms is {p99_ratio:.2}x \
             the {:.2} ms baseline (ceiling {:.2}x)",
            base.p99_ms, baseline.thresholds.max_p99_ratio
        ));
    }
    if reasons.is_empty() {
        Ok(CompareOutcome::Pass {
            name,
            tok_ratio,
            p99_ratio,
        })
    } else {
        Ok(CompareOutcome::Fail { name, reasons })
    }
}

/// Read, parse and [`compare_snapshot`] a `BENCH_*.json` file.
pub fn compare_file(
    path: &Path,
    baseline: &Baseline,
) -> Result<CompareOutcome> {
    let body = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let doc = Json::parse(&body)
        .with_context(|| format!("parsing {}", path.display()))?;
    compare_snapshot(&doc, baseline)
        .with_context(|| format!("comparing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn baseline_doc() -> Json {
        Json::obj()
            .set("schema_version", BASELINE_SCHEMA_VERSION)
            .set("note", "unit test")
            .set(
                "thresholds",
                Json::obj()
                    .set("min_tok_ratio", 0.5)
                    .set("max_p99_ratio", 1.75),
            )
            .set(
                "benches",
                Json::obj().set(
                    "fig9",
                    Json::obj()
                        .set("tok_per_s", 1000.0)
                        .set("p99_ms", 10.0),
                ),
            )
    }

    fn snapshot_doc(name: &str, tok_per_s: f64, p99_ms: f64) -> Json {
        Json::obj()
            .set("schema_version", 1u64)
            .set("name", name)
            .set("tok_per_s", tok_per_s)
            .set("steps", Json::obj().set("p99_ms", p99_ms))
    }

    #[test]
    fn unchanged_numbers_pass() {
        let base = parse_baseline(&baseline_doc()).unwrap();
        let out =
            compare_snapshot(&snapshot_doc("fig9", 1000.0, 10.0), &base)
                .unwrap();
        match out {
            CompareOutcome::Pass {
                tok_ratio,
                p99_ratio,
                ..
            } => {
                assert!((tok_ratio - 1.0).abs() < 1e-12);
                assert!((p99_ratio - 1.0).abs() < 1e-12);
            }
            other => panic!("expected Pass, got {other:?}"),
        }
        // noise inside the band passes too
        assert!(!compare_snapshot(
            &snapshot_doc("fig9", 800.0, 14.0),
            &base
        )
        .unwrap()
        .is_fail());
    }

    #[test]
    fn synthetic_2x_p99_regression_fails() {
        let base = parse_baseline(&baseline_doc()).unwrap();
        let out =
            compare_snapshot(&snapshot_doc("fig9", 1000.0, 20.0), &base)
                .unwrap();
        match out {
            CompareOutcome::Fail { reasons, .. } => {
                assert_eq!(reasons.len(), 1, "{reasons:?}");
                assert!(
                    reasons[0].contains("p99"),
                    "reason names p99: {reasons:?}"
                );
            }
            other => panic!("2x p99 must fail, got {other:?}"),
        }
    }

    #[test]
    fn throughput_collapse_fails() {
        let base = parse_baseline(&baseline_doc()).unwrap();
        let out =
            compare_snapshot(&snapshot_doc("fig9", 300.0, 10.0), &base)
                .unwrap();
        assert!(out.is_fail(), "{out:?}");
    }

    #[test]
    fn missing_baseline_entry_warns_not_fails() {
        let base = parse_baseline(&baseline_doc()).unwrap();
        let out =
            compare_snapshot(&snapshot_doc("brand_new", 1.0, 1.0), &base)
                .unwrap();
        assert_eq!(
            out,
            CompareOutcome::NoBaseline {
                name: "brand_new".to_string()
            }
        );
    }

    #[test]
    fn malformed_baselines_are_rejected() {
        // wrong version
        let mut doc = baseline_doc();
        if let Json::Obj(fields) = &mut doc {
            fields[0].1 = Json::Num(99.0);
        }
        assert!(parse_baseline(&doc).is_err());
        // thresholds that would fail an unchanged bench
        let tight = Json::obj()
            .set("schema_version", BASELINE_SCHEMA_VERSION)
            .set(
                "thresholds",
                Json::obj()
                    .set("min_tok_ratio", 1.5)
                    .set("max_p99_ratio", 1.75),
            )
            .set(
                "benches",
                Json::obj().set(
                    "x",
                    Json::obj().set("tok_per_s", 1.0).set("p99_ms", 1.0),
                ),
            );
        assert!(parse_baseline(&tight).is_err());
        // no benches
        let empty = Json::obj()
            .set("schema_version", BASELINE_SCHEMA_VERSION)
            .set("benches", Json::obj());
        assert!(parse_baseline(&empty).is_err());
        // non-positive pinned numbers
        let zero = Json::obj()
            .set("schema_version", BASELINE_SCHEMA_VERSION)
            .set(
                "benches",
                Json::obj().set(
                    "x",
                    Json::obj().set("tok_per_s", 0.0).set("p99_ms", 1.0),
                ),
            );
        assert!(parse_baseline(&zero).is_err());
    }

    #[test]
    fn checked_in_baseline_parses() {
        // the repo's own baseline must stay loadable — CI depends on it
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("bench.baseline.json");
        let base = load_baseline(&path).unwrap();
        assert!(!base.entries.is_empty());
        for (name, p) in &base.entries {
            assert!(p.tok_per_s > 0.0, "{name}: tok_per_s");
            assert!(p.p99_ms > 0.0, "{name}: p99_ms");
        }
    }
}
