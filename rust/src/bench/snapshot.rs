//! Machine-readable benchmark snapshots — `BENCH_<name>.json`.
//!
//! A [`Snapshot`] condenses one benchmark run (a [`StepTrace`] plus the
//! run's configuration) into a single JSON document written to
//! [`crate::artifacts_dir`]`()/BENCH_<name>.json`, so CI and
//! EXPERIMENTS.md can diff numbers across commits without scraping
//! stdout tables.
//!
//! # Schema (version 1)
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "name": "fig9",                  // snapshot name (file is BENCH_<name>.json)
//!   "commit": "ac1bb66",             // git rev-parse --short HEAD, or "unknown"
//!   "config": { ... },               // free-form run configuration
//!   "tok_per_s": 1234.5,             // generated tokens / wall second
//!   "steps": {                       // per-step latency percentiles
//!     "count": 128, "mean_ms": ..., "p50_ms": ..., "p95_ms": ...,
//!     "p99_ms": ..., "max_ms": ...
//!   },
//!   "breakdown": {                   // mean per-step stage times, ms
//!     "s_ms": ..., "r_ms": ..., "comm_ms": ..., "queue_wait_ms": ...,
//!     "gather_wait_ms": ..., "dispatch_ms": ..., "skew_ms": ...
//!   },
//!   "extra": { ... }                 // bench-specific payload (optional)
//! }
//! ```
//!
//! [`validate`] is the CI gate: it rejects documents that are missing
//! fields, carry the wrong schema version, or describe an empty run
//! (zero steps / zero throughput) — a bench that silently produced
//! nothing must fail the pipeline, not archive an empty file.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::metrics::{Histogram, StepTrace};
use crate::util::json::Json;

/// Bump when the JSON layout changes incompatibly.
pub const SCHEMA_VERSION: u64 = 1;

/// Mean per-step stage times in milliseconds (the breakdown block).
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    pub s_ms: f64,
    pub r_ms: f64,
    pub comm_ms: f64,
    pub queue_wait_ms: f64,
    pub gather_wait_ms: f64,
    pub dispatch_ms: f64,
    pub skew_ms: f64,
}

/// One benchmark run, ready to serialize.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub name: String,
    pub config: Json,
    pub tok_per_s: f64,
    /// Per-step latency distribution over productive (token-carrying)
    /// steps.
    pub steps: Histogram,
    pub breakdown: Breakdown,
    /// Bench-specific payload (e.g. the serve report); `Json::Null`
    /// when absent.
    pub extra: Json,
}

impl Snapshot {
    /// Build a snapshot from a finished run's step trace. Throughput
    /// uses the whole trace; percentiles and breakdown means use only
    /// productive steps (tokens > 0) so idle polling steps don't skew
    /// the latency picture.
    pub fn from_trace(name: &str, config: Json, trace: &StepTrace) -> Snapshot {
        let mut steps = Histogram::new();
        let mut sums = [0.0f64; 7];
        let mut n = 0usize;
        for rec in trace.records.iter().filter(|r| r.tokens > 0) {
            steps.record_secs(rec.latency_s);
            sums[0] += rec.s_time;
            sums[1] += rec.r_time;
            sums[2] += rec.comm_time;
            sums[3] += rec.queue_wait_s;
            sums[4] += rec.gather_wait_s;
            sums[5] += rec.dispatch_s;
            sums[6] += rec.skew_s;
            n += 1;
        }
        let mean_ms = |sum: f64| {
            if n == 0 {
                0.0
            } else {
                sum / n as f64 * 1e3
            }
        };
        Snapshot {
            name: name.to_string(),
            config,
            tok_per_s: trace.throughput(),
            steps,
            breakdown: Breakdown {
                s_ms: mean_ms(sums[0]),
                r_ms: mean_ms(sums[1]),
                comm_ms: mean_ms(sums[2]),
                queue_wait_ms: mean_ms(sums[3]),
                gather_wait_ms: mean_ms(sums[4]),
                dispatch_ms: mean_ms(sums[5]),
                skew_ms: mean_ms(sums[6]),
            },
            extra: Json::Null,
        }
    }

    /// Attach a bench-specific payload (builder style).
    pub fn with_extra(mut self, extra: Json) -> Snapshot {
        self.extra = extra;
        self
    }

    pub fn to_json(&self) -> Json {
        let b = &self.breakdown;
        Json::obj()
            .set("schema_version", SCHEMA_VERSION)
            .set("name", self.name.as_str())
            .set("commit", git_commit())
            .set("config", self.config.clone())
            .set("tok_per_s", self.tok_per_s)
            .set("steps", self.steps.to_json_ms())
            .set(
                "breakdown",
                Json::obj()
                    .set("s_ms", b.s_ms)
                    .set("r_ms", b.r_ms)
                    .set("comm_ms", b.comm_ms)
                    .set("queue_wait_ms", b.queue_wait_ms)
                    .set("gather_wait_ms", b.gather_wait_ms)
                    .set("dispatch_ms", b.dispatch_ms)
                    .set("skew_ms", b.skew_ms),
            )
            .set("extra", self.extra.clone())
    }

    /// Write `BENCH_<name>.json` under [`crate::artifacts_dir`],
    /// returning the path.
    pub fn write(&self) -> Result<PathBuf> {
        let dir = crate::artifacts_dir();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        let mut body = self.to_json().render();
        body.push('\n');
        std::fs::write(&path, body)
            .with_context(|| format!("writing {}", path.display()))?;
        Ok(path)
    }
}

/// Short commit hash of HEAD, best-effort ("unknown" outside git).
fn git_commit() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn req_num(j: &Json, ctx: &str, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("{ctx}: missing numeric field '{key}'"))
}

/// Validate a parsed snapshot document against schema version 1.
/// Rejects wrong versions, missing/mistyped fields, and empty runs.
pub fn validate(doc: &Json) -> Result<()> {
    let version = req_num(doc, "snapshot", "schema_version")?;
    if version != SCHEMA_VERSION as f64 {
        bail!("unsupported schema_version {version} (want {SCHEMA_VERSION})");
    }
    let name = doc
        .get("name")
        .and_then(Json::as_str)
        .context("snapshot: missing string field 'name'")?;
    if name.is_empty() {
        bail!("snapshot: empty name");
    }
    doc.get("commit")
        .and_then(Json::as_str)
        .context("snapshot: missing string field 'commit'")?;
    if !matches!(doc.get("config"), Some(Json::Obj(_))) {
        bail!("snapshot: 'config' must be an object");
    }
    let tok_per_s = req_num(doc, "snapshot", "tok_per_s")?;
    if tok_per_s <= 0.0 {
        bail!("snapshot: tok_per_s {tok_per_s} is not positive — empty run?");
    }
    let steps = doc.get("steps").context("snapshot: missing 'steps'")?;
    let count = req_num(steps, "steps", "count")?;
    if count < 1.0 {
        bail!("snapshot: steps.count {count} — empty run");
    }
    let p50 = req_num(steps, "steps", "p50_ms")?;
    let p95 = req_num(steps, "steps", "p95_ms")?;
    let p99 = req_num(steps, "steps", "p99_ms")?;
    req_num(steps, "steps", "mean_ms")?;
    req_num(steps, "steps", "max_ms")?;
    if !(p50 <= p95 && p95 <= p99) {
        bail!("snapshot: percentiles not monotone: p50 {p50} p95 {p95} p99 {p99}");
    }
    let breakdown = doc
        .get("breakdown")
        .context("snapshot: missing 'breakdown'")?;
    for key in [
        "s_ms",
        "r_ms",
        "comm_ms",
        "queue_wait_ms",
        "gather_wait_ms",
        "dispatch_ms",
        "skew_ms",
    ] {
        let v = req_num(breakdown, "breakdown", key)?;
        if v < 0.0 {
            bail!("breakdown: {key} is negative ({v})");
        }
    }
    Ok(())
}

/// Read, parse and [`validate`] a `BENCH_*.json` file.
pub fn validate_file(path: &Path) -> Result<()> {
    let body = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let doc = Json::parse(&body)
        .with_context(|| format!("parsing {}", path.display()))?;
    validate(&doc).with_context(|| format!("validating {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::StepRecord;

    fn synthetic_trace() -> StepTrace {
        let mut trace = StepTrace::default();
        for step in 0..32 {
            trace.push(StepRecord {
                step,
                latency_s: 2e-3 + step as f64 * 1e-5,
                s_time: 1e-3,
                r_time: 8e-4,
                comm_time: 1e-4,
                queue_wait_s: 5e-5,
                gather_wait_s: 4e-4,
                dispatch_s: 2e-5,
                skew_s: 1e-4,
                socket_busy: vec![7e-4, 8e-4],
                tokens: 16,
                total_ctx: 16 * (step + 1),
            });
        }
        // an idle step must not pollute the latency percentiles
        trace.push(StepRecord {
            step: 32,
            latency_s: 5.0,
            ..Default::default()
        });
        trace
    }

    #[test]
    fn snapshot_roundtrips_and_validates() {
        let trace = synthetic_trace();
        let cfg = Json::obj().set("batch", 16usize).set("sockets", 2usize);
        let snap = Snapshot::from_trace("unit", cfg, &trace)
            .with_extra(Json::obj().set("note", "test"));
        let doc = Json::parse(&snap.to_json().render()).unwrap();
        validate(&doc).unwrap();
        assert_eq!(doc.get("name").and_then(Json::as_str), Some("unit"));
        assert_eq!(
            doc.get("steps").and_then(|s| s.get("count")).and_then(Json::as_f64),
            Some(32.0) // the idle step is excluded
        );
        let tok = doc.get("tok_per_s").and_then(Json::as_f64).unwrap();
        assert!((tok - trace.throughput()).abs() / trace.throughput() < 1e-9);
        let b = doc.get("breakdown").unwrap();
        let s_ms = b.get("s_ms").and_then(Json::as_f64).unwrap();
        assert!((s_ms - 1.0).abs() < 1e-9, "s_ms {s_ms}");
    }

    #[test]
    fn validate_rejects_malformed() {
        let trace = synthetic_trace();
        let good = Snapshot::from_trace("unit", Json::obj(), &trace).to_json();
        validate(&good).unwrap();

        // wrong schema version
        let bad = good.clone();
        let mut fields = match bad {
            Json::Obj(f) => f,
            _ => unreachable!(),
        };
        fields[0].1 = Json::Num(99.0);
        assert!(validate(&Json::Obj(fields)).is_err());

        // empty run: no productive steps → count 0, tok_per_s 0
        let empty = Snapshot::from_trace("unit", Json::obj(), &StepTrace::default());
        assert!(validate(&empty.to_json()).is_err());

        // missing field
        let partial = Json::obj().set("schema_version", SCHEMA_VERSION);
        assert!(validate(&partial).is_err());

        // not even an object
        assert!(validate(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn validate_file_reports_unreadable_and_garbage() {
        let dir = std::env::temp_dir().join("fastdecode_snapshot_test");
        std::fs::create_dir_all(&dir).unwrap();
        let missing = dir.join("nope.json");
        assert!(validate_file(&missing).is_err());
        let garbage = dir.join("garbage.json");
        std::fs::write(&garbage, "not json").unwrap();
        assert!(validate_file(&garbage).is_err());
    }
}
