//! Software IEEE-754 binary16 ("fp16") — the paper's §5.1 substrate.
//!
//! The R-worker stores KV-cache in fp16 and computes in fp32
//! ("mixed-precision CPU attention"). The paper uses AVX2
//! `vcvtph2ps` intrinsics; portable Rust gets the same effect with a
//! 65536-entry decode LUT (256 KiB, resident in L2 during the hot loop)
//! plus a branchy round-to-nearest-even encoder used only on the store
//! path (appending one token's K/V), which is off the per-step critical
//! path.

use std::sync::OnceLock;

/// A 16-bit IEEE binary16 value stored as raw bits.
#[derive(Clone, Copy, PartialEq, Eq, Default, Debug)]
#[repr(transparent)]
pub struct F16(pub u16);

/// Decode LUT: all 65536 bit patterns → f32. Built once, 256 KiB.
static F16_TO_F32_LUT: OnceLock<Vec<f32>> = OnceLock::new();

#[inline]
fn decode_lut() -> &'static [f32] {
    F16_TO_F32_LUT
        .get_or_init(|| (0..=u16::MAX).map(f16_bits_to_f32_slow).collect())
}

/// Bit-exact fp16 → fp32 (reference path, no LUT).
pub fn f16_bits_to_f32_slow(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = match exp {
        0 => {
            if mant == 0 {
                sign // ±0
            } else {
                // subnormal: mant * 2^-24
                let v = (mant as f32) * f32::from_bits(0x3380_0000); // 2^-24
                return if sign != 0 { -v } else { v };
            }
        }
        31 => sign | 0x7f80_0000 | (mant << 13), // inf / nan
        _ => sign | ((exp + 112) << 23) | (mant << 13),
    };
    f32::from_bits(bits)
}

/// fp32 → fp16 with round-to-nearest-even (reference-quality encoder).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x7f_ffff;

    if exp == 255 {
        // inf / nan (preserve a nan payload bit)
        return sign | 0x7c00 | if mant != 0 { 0x200 } else { 0 };
    }
    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7c00; // overflow → inf
    }
    if e >= -14 {
        // normal range
        let mut m = mant >> 13;
        let rest = mant & 0x1fff;
        // round to nearest even
        if rest > 0x1000 || (rest == 0x1000 && (m & 1) == 1) {
            m += 1;
        }
        let mut he = (e + 15) as u32;
        if m == 0x400 {
            m = 0;
            he += 1;
            if he >= 31 {
                return sign | 0x7c00;
            }
        }
        return sign | ((he as u16) << 10) | (m as u16);
    }
    if e >= -25 {
        // subnormal
        let full = mant | 0x80_0000; // implicit bit
        let shift = (-14 - e) as u32 + 13;
        let m = full >> shift;
        let rest = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut m = m;
        if rest > half || (rest == half && (m & 1) == 1) {
            m += 1;
        }
        return sign | (m as u16); // may carry into exponent — that's correct
    }
    sign // underflow → ±0
}

impl F16 {
    pub const ZERO: F16 = F16(0);

    #[inline(always)]
    pub fn to_f32(self) -> f32 {
        // LUT path: one L2-resident load. Exact for every bit pattern
        // (incl. inf/nan); used off the vectorized hot loop.
        let lut = decode_lut();
        debug_assert!((self.0 as usize) < lut.len());
        // SAFETY: the LUT spans every u16 bit pattern (0..=u16::MAX,
        // 65536 entries), so indexing with any u16 is in bounds.
        unsafe { *lut.get_unchecked(self.0 as usize) }
    }

    /// Branchless decode for FINITE values — shift the exponent+mantissa
    /// into an f32 whose value is 2⁻¹¹² × |x|, rescale, re-apply the
    /// sign. Exact for normals AND subnormals (the scaled f32 is always
    /// normal); only inf/nan decode differently, and the KV-cache never
    /// stores those. Pure integer/FP ops with no table or branch, so
    /// LLVM auto-vectorizes the attention dot/axpy loops (§Perf log in
    /// EXPERIMENTS.md: ~3.9× on this host vs the LUT).
    #[inline(always)]
    pub fn to_f32_finite(self) -> f32 {
        const SCALE: f32 = 5.192296858534828e33; // 2^112
        let h = self.0 as u32;
        let magnitude = f32::from_bits((h & 0x7fff) << 13) * SCALE;
        f32::from_bits(magnitude.to_bits() | ((h & 0x8000) << 16))
    }

    #[inline]
    pub fn from_f32(x: f32) -> F16 {
        F16(f32_to_f16_bits(x))
    }
}

impl From<f32> for F16 {
    fn from(x: f32) -> F16 {
        F16::from_f32(x)
    }
}
impl From<F16> for f32 {
    fn from(h: F16) -> f32 {
        h.to_f32()
    }
}

/// Decode a slice of fp16 into an fp32 buffer (lengths must match).
pub fn decode_slice(src: &[F16], dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d = s.to_f32();
    }
}

/// Encode a slice of fp32 into an fp16 buffer (lengths must match).
pub fn encode_slice(src: &[f32], dst: &mut [F16]) {
    assert_eq!(src.len(), dst.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d = F16::from_f32(*s);
    }
}

/// Encode an fp32 vec into a fresh fp16 vec.
pub fn encode_vec(src: &[f32]) -> Vec<F16> {
    src.iter().map(|&x| F16::from_f32(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        for &(f, h) in &[
            (0.0f32, 0x0000u16),
            (1.0, 0x3c00),
            (-1.0, 0xbc00),
            (2.0, 0x4000),
            (0.5, 0x3800),
            (65504.0, 0x7bff), // f16::MAX
            (6.103515625e-5, 0x0400), // smallest normal
            (5.960464477539063e-8, 0x0001), // smallest subnormal
        ] {
            assert_eq!(f32_to_f16_bits(f), h, "encode {f}");
            assert_eq!(f16_bits_to_f32_slow(h), f, "decode {h:#x}");
            assert_eq!(F16(h).to_f32(), f, "LUT decode {h:#x}");
        }
    }

    #[test]
    fn overflow_and_specials() {
        assert_eq!(f32_to_f16_bits(1e9), 0x7c00); // +inf
        assert_eq!(f32_to_f16_bits(-1e9), 0xfc00);
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert!(f16_bits_to_f32_slow(0x7e00).is_nan());
        assert!(F16::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(f32_to_f16_bits(1e-10), 0x0000); // underflow → 0
        assert_eq!(f32_to_f16_bits(-1e-10), 0x8000);
    }

    #[test]
    fn round_to_nearest_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 and 1.0+2^-10:
        // must round to even mantissa (1.0).
        let halfway = 1.0 + 2f32.powi(-11);
        assert_eq!(f32_to_f16_bits(halfway), 0x3c00);
        // just above halfway rounds up
        let above = 1.0 + 2f32.powi(-11) + 2f32.powi(-20);
        assert_eq!(f32_to_f16_bits(above), 0x3c01);
    }

    #[test]
    fn lut_matches_slow_path_everywhere() {
        for h in 0..=u16::MAX {
            let slow = f16_bits_to_f32_slow(h);
            let fast = F16(h).to_f32();
            assert!(
                slow == fast || (slow.is_nan() && fast.is_nan()),
                "mismatch at {h:#x}: {slow} vs {fast}"
            );
        }
    }

    #[test]
    fn finite_decode_matches_slow_path_on_finites() {
        for h in 0..=u16::MAX {
            let exp = (h >> 10) & 0x1f;
            if exp == 31 {
                continue; // inf/nan excluded by contract
            }
            let slow = f16_bits_to_f32_slow(h);
            let fast = F16(h).to_f32_finite();
            assert!(
                slow == fast || (slow == 0.0 && fast == 0.0),
                "mismatch at {h:#x}: {slow} vs {fast}"
            );
        }
    }

    #[test]
    fn roundtrip_is_exact_for_representable() {
        // every finite f16 value survives f16→f32→f16 bit-exactly
        for h in 0..=u16::MAX {
            let f = f16_bits_to_f32_slow(h);
            if f.is_nan() {
                continue;
            }
            assert_eq!(f32_to_f16_bits(f), h, "roundtrip {h:#x}");
        }
    }

    #[test]
    fn encode_error_within_half_ulp() {
        // property: |decode(encode(x)) - x| <= 2^-11 * |x| for normal range
        let mut state = 0x1234_5678u64;
        for _ in 0..10_000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let x = ((state >> 40) as f32 / (1u32 << 24) as f32 - 0.5) * 100.0;
            let y = F16::from_f32(x).to_f32();
            assert!(
                (y - x).abs() <= x.abs() * 4.9e-4 + 6e-8,
                "x={x} y={y}"
            );
        }
    }
}
