//! Minimal JSON reader/writer (offline stand-in for serde_json).
//!
//! Only what the metrics dumps and bench reports need: objects, arrays,
//! strings, numbers, bools. The writer produces the `BENCH_*.json`
//! snapshots and Chrome traces; the parser ([`Json::parse`]) is what
//! the snapshot schema validator and the trace tests read them back
//! with.

use std::fmt::Write as _;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a field (builder style).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), value.into()));
            self
        } else {
            panic!("set() on non-object Json");
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(input: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // integers render without a trailing .0
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{}", n);
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Recursion guard: deeper documents than any we emit, shallower than
/// the stack.
const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("document nested deeper than {MAX_DEPTH}");
        }
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            other => bail!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let value = self.value(depth + 1)?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let Some(b) = self.peek() else {
                bail!("unterminated string");
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(esc) = self.peek() else {
                        bail!("unterminated escape");
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let c = if (0xd800..0xdc00).contains(&hi) {
                                // surrogate pair
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xdc00..0xe000).contains(&lo) {
                                    bail!("bad low surrogate");
                                }
                                let cp = 0x10000
                                    + ((hi - 0xd800) << 10)
                                    + (lo - 0xdc00);
                                char::from_u32(cp)
                            } else {
                                char::from_u32(hi)
                            };
                            match c {
                                Some(c) => s.push(c),
                                None => bail!("invalid \\u escape"),
                            }
                        }
                        other => {
                            bail!("bad escape '\\{}'", other as char)
                        }
                    }
                }
                b if b < 0x20 => bail!("raw control byte in string"),
                b if b < 0x80 => s.push(b as char),
                b => {
                    // multi-byte UTF-8: the input came from a &str so
                    // the sequence is valid — decode it from the source
                    let start = self.pos - 1;
                    let len = match b {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let Some(slice) = self.bytes.get(start..start + len)
                    else {
                        bail!("truncated UTF-8 sequence");
                    };
                    let text = std::str::from_utf8(slice)
                        .map_err(|_| anyhow::anyhow!("invalid UTF-8"))?;
                    s.push_str(text);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("truncated \\u escape");
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| anyhow::anyhow!("non-ASCII \\u escape"))?;
        let v = u32::from_str_radix(hex, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape '{hex}'"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit()
                || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .expect("ASCII number bytes");
        match text.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(Json::Num(n)),
            _ => bail!("bad number '{text}' at byte {start}"),
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig9")
            .set("tok_per_s", 2048.5)
            .set("batch", 1024usize)
            .set("series", vec![1.0f64, 2.0, 3.5])
            .set("ok", true);
        assert_eq!(
            j.render(),
            r#"{"name":"fig9","tok_per_s":2048.5,"batch":1024,"series":[1,2,3.5],"ok":true}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }

    #[test]
    fn parse_roundtrips_nested() {
        let j = Json::obj()
            .set("name", "fig9")
            .set("tok_per_s", 2048.5)
            .set("batch", 1024usize)
            .set("series", vec![1.0f64, 2.0, 3.5])
            .set("ok", true)
            .set("none", Json::Null);
        assert_eq!(Json::parse(&j.render()).unwrap(), j);
    }

    #[test]
    fn parse_handles_whitespace_and_escapes() {
        let j = Json::parse(
            " { \"a\\n\\\"b\" : [ 1 , -2.5e3 , \"\\u00e9\\ud83d\\ude00\" ] } ",
        )
        .unwrap();
        let arr = j.get("a\n\"b").and_then(Json::as_arr).unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_f64(), Some(-2500.0));
        assert_eq!(arr[2].as_str(), Some("é😀"));
        // raw multi-byte UTF-8 survives too
        assert_eq!(
            Json::parse("\"héllo\"").unwrap().as_str(),
            Some("héllo")
        );
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"unterminated",
            "[1] junk", "{\"a\" 1}", "\"\\q\"", "nan",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn prop_render_parse_roundtrip() {
        use crate::util::prop;
        fn tree(g: &mut prop::Gen, depth: usize) -> Json {
            let kind = if depth >= 3 {
                g.usize_in(0, 3)
            } else {
                g.usize_in(0, 5)
            };
            match kind {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => {
                    if g.bool() {
                        Json::Num(g.u64_in(0, 1 << 40) as f64)
                    } else {
                        Json::Num(g.f32_in(-1e6, 1e6) as f64)
                    }
                }
                3 => {
                    let n = g.usize_in(0, 8);
                    Json::Str(
                        (0..n)
                            .map(|_| {
                                *g.pick(&[
                                    'a', 'Z', '"', '\\', '\n', '\t', 'é',
                                    '😀', '\u{1}',
                                ])
                            })
                            .collect(),
                    )
                }
                4 => Json::Arr(
                    (0..g.usize_in(0, 4))
                        .map(|_| tree(g, depth + 1))
                        .collect(),
                ),
                _ => Json::Obj(
                    (0..g.usize_in(0, 4))
                        .map(|i| (format!("k{i}"), tree(g, depth + 1)))
                        .collect(),
                ),
            }
        }
        prop::check("json-roundtrip", 200, |g| {
            let j = tree(g, 0);
            let back = Json::parse(&j.render()).expect("parses own render");
            assert_eq!(back, j, "render: {}", j.render());
        });
    }
}
