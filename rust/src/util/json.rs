//! Minimal JSON *writer* (offline stand-in for serde_json).
//!
//! Only what the metrics dumps and bench reports need: objects, arrays,
//! strings, numbers, bools. No parsing — machine-readable inputs use the
//! line-based `artifacts/manifest.txt` format instead.

use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a field (builder style).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), value.into()));
            self
        } else {
            panic!("set() on non-object Json");
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    // integers render without a trailing .0
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{}", n);
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj()
            .set("name", "fig9")
            .set("tok_per_s", 2048.5)
            .set("batch", 1024usize)
            .set("series", vec![1.0f64, 2.0, 3.5])
            .set("ok", true);
        assert_eq!(
            j.render(),
            r#"{"name":"fig9","tok_per_s":2048.5,"batch":1024,"series":[1,2,3.5],"ok":true}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_is_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
    }
}
