//! Mini property-testing harness (offline stand-in for proptest).
//!
//! `check(name, cases, |g| ...)` runs a closure over `cases` seeded
//! generators; on failure it panics with the failing seed so the case
//! can be replayed deterministically with `replay(seed, ...)`.

use super::rng::Rng;

/// A per-case generator handle wrapping the seeded RNG.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.range_usize(0, xs.len())]
    }
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }
    pub fn vec_normal(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        self.rng.normal_vec(n, sigma)
    }
}

/// Run `f` over `cases` deterministic random cases. Panics with the
/// failing seed on the first assertion failure inside `f`.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut f: F) {
    // Base seed can be pinned via env for replay of a whole suite.
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xfa57_dec0u64);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9e37_79b9));
        let mut g = Gen {
            rng: Rng::new(seed),
            seed,
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut g),
        ));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {i} (seed={seed:#x}): {msg}\n\
                 replay: PROP_SEED={base} (case {i})"
            );
        }
    }
}

/// Replay one case with an explicit seed.
pub fn replay<F: FnOnce(&mut Gen)>(seed: u64, f: F) {
    let mut g = Gen {
        rng: Rng::new(seed),
        seed,
    };
    f(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("add-commutes", 50, |g| {
            let a = g.u64_in(0, 1000);
            let b = g.u64_in(0, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn reports_failing_seed() {
        check("always-fails", 5, |g| {
            let x = g.usize_in(0, 10);
            assert!(x > 100, "x was {x}");
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        check("record", 5, |g| first.push(g.u64_in(0, 1_000_000)));
        let mut second = Vec::new();
        check("record", 5, |g| second.push(g.u64_in(0, 1_000_000)));
        assert_eq!(first, second);
    }
}
