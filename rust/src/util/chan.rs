//! Bounded MPMC channel on Mutex + Condvar (offline stand-in for
//! crossbeam-channel). Used for S-worker ↔ R-worker message passing and
//! the request queue. Bounded capacity gives natural backpressure.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Inner<T> {
    queue: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct State<T> {
    buf: VecDeque<T>,
    senders: usize,
    receivers: usize,
}

pub struct Sender<T>(Arc<Inner<T>>);
pub struct Receiver<T>(Arc<Inner<T>>);

/// Error returned when all receivers are gone.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Error returned when the channel is empty and all senders are gone.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity > 0, "capacity must be positive");
    let inner = Arc::new(Inner {
        queue: Mutex::new(State {
            buf: VecDeque::with_capacity(capacity),
            senders: 1,
            receivers: 1,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        capacity,
    });
    (Sender(inner.clone()), Receiver(inner))
}

impl<T> Sender<T> {
    /// Blocking send; fails only if every receiver has been dropped.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut st = self.0.queue.lock().unwrap();
        loop {
            if st.receivers == 0 {
                return Err(SendError(value));
            }
            if st.buf.len() < self.0.capacity {
                st.buf.push_back(value);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            st = self.0.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking send; Err(value) if full or disconnected.
    pub fn try_send(&self, value: T) -> Result<(), T> {
        let mut st = self.0.queue.lock().unwrap();
        if st.receivers == 0 || st.buf.len() >= self.0.capacity {
            return Err(value);
        }
        st.buf.push_back(value);
        self.0.not_empty.notify_one();
        Ok(())
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; fails when empty and all senders dropped.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.0.queue.lock().unwrap();
        loop {
            if let Some(v) = st.buf.pop_front() {
                self.0.not_full.notify_one();
                return Ok(v);
            }
            if st.senders == 0 {
                return Err(RecvError);
            }
            st = self.0.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.0.queue.lock().unwrap();
        let v = st.buf.pop_front();
        if v.is_some() {
            self.0.not_full.notify_one();
        }
        v
    }

    /// Drain everything currently queued.
    pub fn drain(&self) -> Vec<T> {
        let mut st = self.0.queue.lock().unwrap();
        let out: Vec<T> = st.buf.drain(..).collect();
        if !out.is_empty() {
            self.0.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.0.queue.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.queue.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}
impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.0.queue.lock().unwrap().receivers += 1;
        Receiver(self.0.clone())
    }
}
impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.0.queue.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            self.0.not_empty.notify_all();
        }
    }
}
impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut st = self.0.queue.lock().unwrap();
        st.receivers -= 1;
        if st.receivers == 0 {
            self.0.not_full.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(8);
        for i in 0..8 {
            tx.send(i).unwrap();
        }
        for i in 0..8 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn blocks_when_full_then_progresses() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert!(tx.try_send(3).is_err());
        let h = thread::spawn(move || tx.send(3).unwrap());
        assert_eq!(rx.recv().unwrap(), 1);
        h.join().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap(), 3);
    }

    #[test]
    fn recv_fails_after_all_senders_drop() {
        let (tx, rx) = bounded::<i32>(2);
        tx.send(5).unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), 5);
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn send_fails_after_all_receivers_drop() {
        let (tx, rx) = bounded::<i32>(2);
        drop(rx);
        assert_eq!(tx.send(1), Err(SendError(1)));
    }

    #[test]
    fn mpmc_sums_match() {
        let (tx, rx) = bounded::<u64>(4);
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                thread::spawn(move || {
                    for i in 0..100u64 {
                        tx.send(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                thread::spawn(move || {
                    let mut sum = 0u64;
                    while let Ok(v) = rx.recv() {
                        sum += v;
                    }
                    sum
                })
            })
            .collect();
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let total: u64 = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        let expect: u64 = (0..4u64)
            .map(|p| (0..100u64).map(|i| p * 1000 + i).sum::<u64>())
            .sum();
        assert_eq!(total, expect);
    }
}
