//! Small deterministic PRNG (xoshiro256**) — no external crates offline.
//!
//! Used by the workload generator, synthetic weights, and the in-tree
//! property-testing harness. Not cryptographic; determinism across runs
//! (and platforms) is the point.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9e3779b97f4a7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u32 << 24) as f32
    }

    /// Uniform integer in [lo, hi) (hi > lo).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.next_f64()).max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos())
            as f32
    }

    /// Exponential with rate `lambda` (inter-arrival sampling).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Fill with N(0, sigma) values.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * sigma;
        }
    }

    pub fn normal_vec(&mut self, n: usize, sigma: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v, sigma);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.range_usize(5, 17);
            assert!((5..17).contains(&x));
        }
    }
}
