//! In-tree substrates for an offline build (DESIGN.md §1): software fp16,
//! channels, RNG, property testing, JSON writing.

pub mod chan;
pub mod f16;
pub mod json;
pub mod prop;
pub mod rng;

pub use f16::F16;
pub use rng::Rng;
