//! The mixed-precision attention hot loop (paper §5.1).
//!
//! fp16 (or int8/int4) K/V are decoded to fp32 in registers and folded
//! into an online softmax — a single pass over the cache, no `[S]` score
//! buffer, no allocation. The paper uses AVX2 `vcvtph2ps`; here the fp16
//! decode is a 256 KiB LUT (util::f16) and the dot/axpy loops are written
//! so LLVM auto-vectorizes them (fixed-stride, no bounds checks in the
//! inner loop via chunks_exact).
//!
//! Two entry points share the same per-token kernels: [`attend_one`]
//! scans a contiguous [`SeqKv`], [`attend_paged`] walks a [`PagedKv`]
//! block table. The online-softmax state `(m, l, acc)` threads across
//! block boundaries, so the paged scan performs the IDENTICAL sequence
//! of floating-point operations as the contiguous one — bit-identical
//! outputs, pinned by tests below.

use crate::kvcache::{PagedKv, SeqKv, SocketCache};
use crate::model::Precision;
use crate::util::f16::F16;

/// Reusable per-thread scratch so the hot loop never allocates.
pub struct AttnScratch {
    /// fp32 staging for one decoded K/V row.
    pub row: Vec<f32>,
    /// fp32 output accumulator, one head at a time.
    pub acc: Vec<f32>,
}

impl AttnScratch {
    pub fn new(head_dim: usize) -> AttnScratch {
        AttnScratch {
            row: vec![0.0; head_dim],
            acc: vec![0.0; head_dim],
        }
    }
}

#[inline(always)]
fn dot_f16(a: &[f32], b: &[F16]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4 independent accumulators break the FP-add dependency chain so
    // the loop vectorizes AND pipelines (§Perf: +3.9× over the LUT
    // decode on this host). to_f32_finite is branchless integer math.
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for j in 0..8 {
            acc[j] += xa[j] * xb[j].to_f32_finite();
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y.to_f32_finite();
    }
    acc.iter().sum::<f32>() + tail
}

#[inline(always)]
fn axpy_f16(alpha: f32, x: &[F16], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // 8-wide blocks with chunks_exact: bound-check-free and wide enough
    // for one AVX2 lane per block (indexed 4-unrolling measured SLOWER —
    // see EXPERIMENTS.md §Perf).
    let mut cx = x.chunks_exact(8);
    let mut cy = y.chunks_exact_mut(8);
    for (xc, yc) in (&mut cx).zip(&mut cy) {
        for j in 0..8 {
            yc[j] += alpha * xc[j].to_f32_finite();
        }
    }
    for (xi, yi) in cx.remainder().iter().zip(cy.into_remainder()) {
        *yi += alpha * xi.to_f32_finite();
    }
}

#[inline(always)]
fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let n4 = a.len() / 4 * 4;
    for i in (0..n4).step_by(4) {
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut tail = 0.0f32;
    for i in n4..a.len() {
        tail += a[i] * b[i];
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

#[inline(always)]
fn axpy_f32(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (xi, yi) in x.iter().zip(y.iter_mut()) {
        *yi += alpha * xi;
    }
}

#[inline(always)]
fn dot_i8(a: &[f32], b: &[i8]) -> f32 {
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for j in 0..8 {
            acc[j] += xa[j] * xb[j] as f32;
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * *y as f32;
    }
    acc.iter().sum::<f32>() + tail
}

#[inline(always)]
fn axpy_i8(alpha: f32, x: &[i8], y: &mut [f32]) {
    for (xi, yi) in x.iter().zip(y.iter_mut()) {
        *yi += alpha * *xi as f32;
    }
}

/// Running online-softmax state for one head, threaded across chunks so
/// a blockwise scan is bit-identical to a contiguous one.
struct OnlineState {
    m: f32,
    l: f32,
}

impl OnlineState {
    #[inline(always)]
    fn new() -> OnlineState {
        OnlineState {
            m: f32::NEG_INFINITY,
            l: 0.0,
        }
    }

    #[inline(always)]
    fn finish(&self, o: &mut [f32], acc: &[f32]) {
        let inv = 1.0 / self.l;
        for (oi, a) in o.iter_mut().zip(acc.iter()) {
            *oi = a * inv;
        }
    }
}

/// Decode attention for ONE sequence on one layer: q `[H*D]` against the
/// sequence's cache (its `len` tokens), output into `o` `[H*D]`.
/// Dispatches on the cache's storage precision. Zero allocations.
pub fn attend_one(kv: &SeqKv, q: &[f32], o: &mut [f32], scratch: &mut AttnScratch) {
    let (h, d) = (kv.n_heads, kv.head_dim);
    assert_eq!(q.len(), h * d);
    assert_eq!(o.len(), h * d);
    assert!(kv.len > 0, "attention over an empty cache");
    let scale = 1.0 / (d as f32).sqrt();

    for head in 0..h {
        let qh = &q[head * d..(head + 1) * d];
        let acc = &mut scratch.acc[..d];
        acc.fill(0.0);
        let mut st = OnlineState::new();
        match kv.precision() {
            Precision::F16 => chunk_f16(
                qh,
                kv.k16_head(head),
                kv.v16_head(head),
                kv.len,
                d,
                scale,
                &mut st,
                acc,
            ),
            Precision::F32 => chunk_f32(
                qh,
                kv.k32_head(head),
                kv.v32_head(head),
                kv.len,
                d,
                scale,
                &mut st,
                acc,
            ),
            Precision::Int8 => {
                let (krow, kscale) = kv.k8_head(head);
                let (vrow, vscale) = kv.v8_head(head);
                chunk_i8(
                    qh, krow, kscale, vrow, vscale, kv.len, d, scale,
                    &mut st, acc,
                );
            }
            Precision::Int4 => {
                let (krow, kscale) = kv.k4_head(head);
                let (vrow, vscale) = kv.v4_head(head);
                chunk_i4(
                    qh, krow, kscale, vrow, vscale, kv.len, d, scale,
                    &mut st, acc,
                );
            }
        }
        st.finish(&mut o[head * d..(head + 1) * d], acc);
    }
}

/// Decode attention over a PAGED view: walk the sequence's block table,
/// feeding each block's contiguous per-head rows through the same chunk
/// kernels as [`attend_one`] with the online-softmax state carried
/// across block boundaries. Identical FP operation sequence — outputs
/// are bit-identical to the contiguous scan for every precision.
pub fn attend_paged(
    kv: &PagedKv<'_>,
    q: &[f32],
    o: &mut [f32],
    scratch: &mut AttnScratch,
) {
    let (h, d) = (kv.n_heads, kv.head_dim);
    assert_eq!(q.len(), h * d);
    assert_eq!(o.len(), h * d);
    assert!(kv.len > 0, "attention over an empty cache");
    let scale = 1.0 / (d as f32).sqrt();
    let nb = kv.n_blocks();
    let prec = kv.precision();

    for head in 0..h {
        let qh = &q[head * d..(head + 1) * d];
        let acc = &mut scratch.acc[..d];
        acc.fill(0.0);
        let mut st = OnlineState::new();
        for b in 0..nb {
            let blk = kv.block(b);
            // a shared tail block may hold more tokens than this
            // sequence references — scan only our own
            let n = kv.block_tokens(b);
            match prec {
                Precision::F16 => chunk_f16(
                    qh,
                    blk.k16_head(head),
                    blk.v16_head(head),
                    n,
                    d,
                    scale,
                    &mut st,
                    acc,
                ),
                Precision::F32 => chunk_f32(
                    qh,
                    blk.k32_head(head),
                    blk.v32_head(head),
                    n,
                    d,
                    scale,
                    &mut st,
                    acc,
                ),
                Precision::Int8 => {
                    let (krow, kscale) = blk.k8_head(head);
                    let (vrow, vscale) = blk.v8_head(head);
                    chunk_i8(
                        qh, krow, kscale, vrow, vscale, n, d, scale,
                        &mut st, acc,
                    );
                }
                Precision::Int4 => {
                    let (krow, kscale) = blk.k4_head(head);
                    let (vrow, vscale) = blk.v4_head(head);
                    chunk_i4(
                        qh, krow, kscale, vrow, vscale, n, d, scale,
                        &mut st, acc,
                    );
                }
            }
        }
        st.finish(&mut o[head * d..(head + 1) * d], acc);
    }
}

/// f32-cache variant used for exact cross-checks against the HLO oracle.
pub fn attend_one_f32(kv: &SeqKv, q: &[f32], o: &mut [f32], scratch: &mut AttnScratch) {
    assert_eq!(kv.precision(), Precision::F32);
    attend_one(kv, q, o, scratch);
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn chunk_f16(
    q: &[f32],
    k: &[F16],
    v: &[F16],
    len: usize,
    d: usize,
    scale: f32,
    st: &mut OnlineState,
    acc: &mut [f32],
) {
    for t in 0..len {
        let krow = &k[t * d..(t + 1) * d];
        let s = dot_f16(q, krow) * scale;
        let (p, corr) = online_step(&mut st.m, s);
        if corr != 1.0 {
            for a in acc.iter_mut() {
                *a *= corr;
            }
            st.l *= corr;
        }
        st.l += p;
        axpy_f16(p, &v[t * d..(t + 1) * d], acc);
    }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn chunk_f32(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    len: usize,
    d: usize,
    scale: f32,
    st: &mut OnlineState,
    acc: &mut [f32],
) {
    for t in 0..len {
        let s = dot_f32(q, &k[t * d..(t + 1) * d]) * scale;
        let (p, corr) = online_step(&mut st.m, s);
        if corr != 1.0 {
            for a in acc.iter_mut() {
                *a *= corr;
            }
            st.l *= corr;
        }
        st.l += p;
        axpy_f32(p, &v[t * d..(t + 1) * d], acc);
    }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn chunk_i8(
    q: &[f32],
    k: &[i8],
    k_scale: &[f32],
    v: &[i8],
    v_scale: &[f32],
    len: usize,
    d: usize,
    scale: f32,
    st: &mut OnlineState,
    acc: &mut [f32],
) {
    for t in 0..len {
        let s = dot_i8(q, &k[t * d..(t + 1) * d]) * k_scale[t] * scale;
        let (p, corr) = online_step(&mut st.m, s);
        if corr != 1.0 {
            for a in acc.iter_mut() {
                *a *= corr;
            }
            st.l *= corr;
        }
        st.l += p;
        axpy_i8(p * v_scale[t], &v[t * d..(t + 1) * d], acc);
    }
}

#[allow(clippy::too_many_arguments)]
#[inline]
fn chunk_i4(
    q: &[f32],
    k: &[u8],
    k_scale: &[f32],
    v: &[u8],
    v_scale: &[f32],
    len: usize,
    d: usize,
    scale: f32,
    st: &mut OnlineState,
    acc: &mut [f32],
) {
    let pd = d / 2;
    let lut = crate::kvcache::nibble_pair_lut();
    for t in 0..len {
        // fused nibble decode + dot: one byte yields two fused
        // multiply-adds, no staging buffer (§Perf: ~8× over the
        // dequant-then-dot version)
        let krow = &k[t * pd..(t + 1) * pd];
        let mut s0 = 0.0f32;
        let mut s1 = 0.0f32;
        for (j, &byte) in krow.iter().enumerate() {
            let pair = lut[byte as usize];
            s0 += q[2 * j] * pair[0];
            s1 += q[2 * j + 1] * pair[1];
        }
        let s = (s0 + s1) * k_scale[t] * scale;
        let (p, corr) = online_step(&mut st.m, s);
        if corr != 1.0 {
            for a in acc.iter_mut() {
                *a *= corr;
            }
            st.l *= corr;
        }
        st.l += p;
        let vrow = &v[t * pd..(t + 1) * pd];
        let pv = p * v_scale[t];
        for (j, &byte) in vrow.iter().enumerate() {
            let pair = lut[byte as usize];
            acc[2 * j] += pv * pair[0];
            acc[2 * j + 1] += pv * pair[1];
        }
    }
}

/// One online-softmax update: given the running max `m` and a new score
/// `s`, returns (p = e^{s-m'}, correction = e^{m-m'}) and updates `m`.
#[inline(always)]
fn online_step(m: &mut f32, s: f32) -> (f32, f32) {
    if s <= *m {
        ((s - *m).exp(), 1.0)
    } else {
        let corr = (*m - s).exp();
        *m = s;
        (1.0, corr)
    }
}

/// Measure this machine's effective per-thread KV streaming bandwidth
/// (bytes/s) with a realistic attention scan — over the PAGED store,
/// the shape the serving hot loop actually runs. Calibrates the R-Part
/// cost model (perfmodel) so virtual-clock figures use *measured* CPU
/// numbers.
pub fn stream_bandwidth_probe(mb: usize) -> f64 {
    let d = 128;
    let tokens = mb * 1024 * 1024 / (2 * d * 2); // K+V fp16 rows
    let mut cache = SocketCache::new(1, d, 1, tokens, 64, Precision::F16);
    cache.add_seq(0);
    let mut val = vec![0.01f32; d];
    for _ in 0..tokens {
        // fdlint: allow(no-unwrap-in-routed): offline calibration probe over a fresh cache, not a serving path
        cache.append(0, 0, &val, &val).expect("probe append");
    }
    let q = vec![0.5f32; d];
    let mut o = vec![0.0f32; d];
    let mut scratch = AttnScratch::new(d);
    // warm
    // fdlint: allow(no-unwrap-in-routed): offline calibration probe, sequence 0 was just appended
    let kv = cache.get(0, 0).expect("probe view");
    attend_paged(&kv, &q, &mut o, &mut scratch);
    let start = std::time::Instant::now();
    let reps = 3;
    for _ in 0..reps {
        attend_paged(&kv, &q, &mut o, &mut scratch);
        val[0] = o[0]; // keep the result alive
    }
    let dt = start.elapsed().as_secs_f64() / reps as f64;
    let bytes = tokens * 2 * d * 2;
    bytes as f64 / dt
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Two-pass reference softmax-attention in f64 for one head.
    fn ref_head(q: &[f32], ks: &[Vec<f32>], vs: &[Vec<f32>]) -> Vec<f32> {
        let d = q.len();
        let scale = 1.0 / (d as f64).sqrt();
        let scores: Vec<f64> = ks
            .iter()
            .map(|k| {
                q.iter()
                    .zip(k)
                    .map(|(a, b)| *a as f64 * *b as f64)
                    .sum::<f64>()
                    * scale
            })
            .collect();
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = scores.iter().map(|s| (s - m).exp()).collect();
        let l: f64 = exps.iter().sum();
        let mut out = vec![0.0f32; d];
        for (e, v) in exps.iter().zip(vs) {
            for (o, x) in out.iter_mut().zip(v) {
                *o += (*e / l) as f32 * *x;
            }
        }
        out
    }

    fn case(prec: Precision, tol: f32) {
        let (h, d, len) = (3, 16, 33);
        let mut rng = Rng::new(11);
        let mut kv = SeqKv::new(h, d, 64, prec);
        let mut ks: Vec<Vec<f32>> = Vec::new();
        let mut vs: Vec<Vec<f32>> = Vec::new();
        for _ in 0..len {
            let k = rng.normal_vec(h * d, 0.7);
            let v = rng.normal_vec(h * d, 0.7);
            kv.append(&k, &v);
            ks.push(k);
            vs.push(v);
        }
        let q = rng.normal_vec(h * d, 0.7);
        let mut o = vec![0.0; h * d];
        let mut scratch = AttnScratch::new(d);
        attend_one(&kv, &q, &mut o, &mut scratch);

        for head in 0..h {
            let sel = |rows: &[Vec<f32>]| -> (Vec<Vec<f32>>, ()) {
                (
                    rows.iter()
                        .map(|r| r[head * d..(head + 1) * d].to_vec())
                        .collect(),
                    (),
                )
            };
            let (kh, _) = sel(&ks);
            let (vh, _) = sel(&vs);
            let want = ref_head(&q[head * d..(head + 1) * d], &kh, &vh);
            for (a, b) in o[head * d..(head + 1) * d].iter().zip(&want) {
                assert!(
                    (a - b).abs() <= tol,
                    "{prec:?} head {head}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn f32_matches_reference() {
        case(Precision::F32, 2e-5);
    }

    #[test]
    fn f16_matches_reference() {
        // fp16 storage error propagates through exp(); generous bound.
        case(Precision::F16, 6e-3);
    }

    #[test]
    fn int8_close_to_reference() {
        case(Precision::Int8, 6e-2);
    }

    #[test]
    fn int4_coarse_but_sane() {
        case(Precision::Int4, 0.6);
    }

    /// THE refactor pin: the paged scan is BIT-IDENTICAL to the
    /// contiguous scan for every precision and for block sizes that
    /// split the sequence raggedly (including block_size 1 and a block
    /// larger than the whole sequence).
    #[test]
    fn paged_attend_bit_identical_to_contiguous() {
        for prec in [
            Precision::F32,
            Precision::F16,
            Precision::Int8,
            Precision::Int4,
        ] {
            let (h, d, len) = (3, 16, 33);
            let mut rng = Rng::new(77);
            let mut kv = SeqKv::new(h, d, 64, prec);
            let rows: Vec<(Vec<f32>, Vec<f32>)> = (0..len)
                .map(|_| {
                    (rng.normal_vec(h * d, 0.7), rng.normal_vec(h * d, 0.7))
                })
                .collect();
            for (k, v) in &rows {
                kv.append(k, v);
            }
            let q = rng.normal_vec(h * d, 0.7);
            let mut want = vec![0.0; h * d];
            let mut scratch = AttnScratch::new(d);
            attend_one(&kv, &q, &mut want, &mut scratch);

            for bs in [1usize, 3, 8, 64] {
                let mut sc = SocketCache::new(h, d, 1, 64, bs, prec);
                sc.add_seq(0);
                for (k, v) in &rows {
                    sc.append(0, 0, k, v).unwrap();
                }
                let view = sc.get(0, 0).unwrap();
                let mut got = vec![0.0; h * d];
                attend_paged(&view, &q, &mut got, &mut scratch);
                assert!(
                    want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{prec:?} bs={bs}: paged attend diverged from contiguous"
                );
            }
        }
    }

    /// A forked child attends through SHARED blocks bit-identically to
    /// a sequence that appended the same tokens itself — prefix sharing
    /// changes where bytes live, never what attention computes.
    #[test]
    fn forked_view_attends_bit_identical() {
        let (h, d, bs) = (2, 8, 3);
        let mut rng = Rng::new(31);
        let rows: Vec<(Vec<f32>, Vec<f32>)> = (0..10)
            .map(|_| (rng.normal_vec(h * d, 0.7), rng.normal_vec(h * d, 0.7)))
            .collect();
        let divergent: Vec<(Vec<f32>, Vec<f32>)> = (0..3)
            .map(|_| (rng.normal_vec(h * d, 0.7), rng.normal_vec(h * d, 0.7)))
            .collect();
        let q = rng.normal_vec(h * d, 0.7);
        let mut scratch = AttnScratch::new(d);

        // baseline: one sequence appends prefix + divergent tail itself
        let mut sc = SocketCache::new(h, d, 1, 32, bs, Precision::F32);
        sc.add_seq(0);
        for (k, v) in rows.iter().take(7).chain(&divergent) {
            sc.append(0, 0, k, v).unwrap();
        }
        let mut want = vec![0.0; h * d];
        attend_paged(&sc.get(0, 0).unwrap(), &q, &mut want, &mut scratch);

        // forked: parent appends all 10, child forks at 7 and diverges
        let mut sc2 = SocketCache::new(h, d, 1, 32, bs, Precision::F32);
        sc2.add_seq(1);
        for (k, v) in &rows {
            sc2.append(1, 0, k, v).unwrap();
        }
        sc2.fork_seq(1, 2, 7).unwrap();
        for (k, v) in &divergent {
            sc2.append(2, 0, k, v).unwrap();
        }
        let mut got = vec![0.0; h * d];
        attend_paged(&sc2.get(2, 0).unwrap(), &q, &mut got, &mut scratch);
        assert!(
            want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
            "forked attend diverged from self-appended"
        );
    }

    #[test]
    fn single_token_returns_v() {
        let d = 8;
        let mut kv = SeqKv::new(1, d, 4, Precision::F32);
        let k: Vec<f32> = (0..d).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..d).map(|i| 10.0 + i as f32).collect();
        kv.append(&k, &v);
        let q = vec![1.0; d];
        let mut o = vec![0.0; d];
        attend_one(&kv, &q, &mut o, &mut AttnScratch::new(d));
        for (a, b) in o.iter().zip(&v) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn online_softmax_handles_huge_scores() {
        // No overflow even when scores span a huge range.
        let d = 4;
        let mut kv = SeqKv::new(1, d, 4, Precision::F32);
        kv.append(&vec![100.0; d], &vec![1.0; d]);
        kv.append(&vec![-100.0; d], &vec![2.0; d]);
        kv.append(&vec![200.0; d], &vec![3.0; d]);
        let q = vec![5.0; d];
        let mut o = vec![0.0; d];
        attend_one(&kv, &q, &mut o, &mut AttnScratch::new(d));
        // dominated by the largest-score token (k=200 → v=3)
        assert!(o.iter().all(|x| (x - 3.0).abs() < 1e-3), "{o:?}");
    }

    #[test]
    fn probe_returns_positive_bandwidth() {
        // debug builds are ~30× slower than --release; only sanity-check
        let bw = stream_bandwidth_probe(2);
        assert!(bw > 1e7, "absurdly low bandwidth {bw}"); // >10 MB/s
    }
}
