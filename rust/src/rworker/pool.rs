//! The R-worker pool: 𝒫 sockets plus sequence→socket placement
//! (paper §4.1 "different parts of them related to different sequences
//! are sent to the R-workers").
//!
//! Placement is round-robin at sequence granularity — R-Part has no
//! cross-sequence interaction, so any balanced assignment is work-
//! preserving; round-robin keeps per-socket total sequence length
//! balanced when combined with the SLS schedule (sequences of mixed ages
//! land on every socket).

use std::collections::HashMap;
use std::time::Duration;

use crate::model::{ModelSpec, Precision};

use super::worker::{RRequest, RResponse, RWorker, SeqTask};

#[derive(Clone, Copy, Debug)]
pub struct RPoolConfig {
    pub sockets: usize,
    pub capacity_per_seq: usize,
    pub precision: Precision,
    /// Artificial dilation per appended token row of every attend (a
    /// decode task is one row, a prefill task is T rows), applied
    /// inside every socket and counted in its busy time. Zero in
    /// production; pipeline smoke/depth tests use it to pin the R-stage
    /// latency (see `RWorker::spawn`).
    pub attend_pad: Duration,
}

impl Default for RPoolConfig {
    fn default() -> Self {
        RPoolConfig {
            sockets: 2,
            capacity_per_seq: 2048,
            precision: Precision::F16,
            attend_pad: Duration::ZERO,
        }
    }
}

/// Handle to an attend that has been scattered to the sockets but not
/// yet gathered (returned by [`RPool::submit_attend`]).
pub struct PendingAttend {
    active: Vec<usize>,
    layer: usize,
    n: usize,
}

/// Outputs of one pooled attend call.
pub struct PoolStep {
    /// seq_id → attention output `[H*D]`.
    pub outputs: HashMap<u64, Vec<f32>>,
    /// Max busy time across sockets (the pipeline-visible R latency).
    pub max_busy: Duration,
    /// Sum of busy times (for utilization accounting).
    pub total_busy: Duration,
}

pub struct RPool {
    workers: Vec<RWorker>,
    placement: HashMap<u64, usize>,
    next_socket: usize,
}

impl RPool {
    pub fn spawn(spec: &ModelSpec, cfg: RPoolConfig) -> RPool {
        assert!(cfg.sockets > 0);
        let workers = (0..cfg.sockets)
            .map(|i| {
                RWorker::spawn(
                    i,
                    spec.n_heads,
                    spec.head_dim(),
                    spec.n_layers,
                    cfg.capacity_per_seq,
                    cfg.precision,
                    cfg.attend_pad,
                )
            })
            .collect();
        RPool {
            workers,
            placement: HashMap::new(),
            next_socket: 0,
        }
    }

    pub fn sockets(&self) -> usize {
        self.workers.len()
    }

    pub fn socket_of(&self, seq_id: u64) -> Option<usize> {
        self.placement.get(&seq_id).copied()
    }

    /// Place and register new sequences (round-robin).
    pub fn add_seqs(&mut self, seq_ids: &[u64]) {
        let mut per_socket: Vec<Vec<u64>> = vec![vec![]; self.workers.len()];
        for &id in seq_ids {
            assert!(
                !self.placement.contains_key(&id),
                "sequence {id} already placed"
            );
            let s = self.next_socket;
            self.next_socket = (self.next_socket + 1) % self.workers.len();
            self.placement.insert(id, s);
            per_socket[s].push(id);
        }
        for (s, ids) in per_socket.into_iter().enumerate() {
            if !ids.is_empty() {
                self.workers[s].submit(RRequest::AddSeqs(ids));
            } else {
                continue;
            }
            match self.workers[s].recv() {
                RResponse::Ack => {}
                _ => panic!("expected ack from socket {s}"),
            }
        }
    }

    /// Drop finished sequences and free their cache.
    pub fn drop_seqs(&mut self, seq_ids: &[u64]) {
        let mut per_socket: Vec<Vec<u64>> = vec![vec![]; self.workers.len()];
        for &id in seq_ids {
            if let Some(s) = self.placement.remove(&id) {
                per_socket[s].push(id);
            }
        }
        for (s, ids) in per_socket.into_iter().enumerate() {
            if ids.is_empty() {
                continue;
            }
            self.workers[s].submit(RRequest::DropSeqs(ids));
            match self.workers[s].recv() {
                RResponse::Ack => {}
                _ => panic!("expected ack from socket {s}"),
            }
        }
    }

    /// Scatter one layer's tasks to their sockets WITHOUT waiting for
    /// the results — the sockets start computing immediately, and the
    /// caller is free to do S-Part work for the other mini-batch before
    /// calling [`RPool::wait_attend`]. This split is what the threaded
    /// token-level pipeline (Fig 5b) is built on.
    ///
    /// At most one task per sequence per call: outputs are keyed by
    /// `seq_id`, so a duplicate would silently collapse — `wait_attend`
    /// counts outputs against tasks and panics if that happens. Multi-
    /// token work for one sequence travels as ONE multi-row task (see
    /// [`SeqTask`]).
    pub fn submit_attend(
        &mut self,
        layer: usize,
        tasks: Vec<SeqTask>,
    ) -> PendingAttend {
        let n = tasks.len();
        let mut per_socket: Vec<Vec<SeqTask>> =
            (0..self.workers.len()).map(|_| Vec::new()).collect();
        for task in tasks {
            let s = *self
                .placement
                .get(&task.seq_id)
                .unwrap_or_else(|| panic!("sequence {} not placed", task.seq_id));
            per_socket[s].push(task);
        }
        let mut active = Vec::new();
        for (s, tasks) in per_socket.into_iter().enumerate() {
            if !tasks.is_empty() {
                self.workers[s].submit(RRequest::Attend { layer, tasks });
                active.push(s);
            }
        }
        PendingAttend { active, layer, n }
    }

    /// Gather one in-flight attend. Replies are FIFO per socket, so
    /// pending handles must be waited in submission order; the echoed
    /// layer tag and output count turn an out-of-order wait into a
    /// panic instead of silently crossed activations.
    pub fn wait_attend(&mut self, pending: PendingAttend) -> PoolStep {
        let mut outputs = HashMap::with_capacity(pending.n);
        let mut max_busy = Duration::ZERO;
        let mut total_busy = Duration::ZERO;
        for s in pending.active {
            match self.workers[s].recv() {
                RResponse::Outputs { layer, outs, busy } => {
                    assert_eq!(
                        layer, pending.layer,
                        "socket {s} replied for layer {layer}, \
                         handle is for layer {}: attends gathered out \
                         of submission order",
                        pending.layer
                    );
                    max_busy = max_busy.max(busy);
                    total_busy += busy;
                    for (id, o) in outs {
                        outputs.insert(id, o);
                    }
                }
                _ => panic!("expected outputs from socket {s}"),
            }
        }
        assert_eq!(
            outputs.len(),
            pending.n,
            "attend returned {} outputs for {} tasks",
            outputs.len(),
            pending.n
        );
        PoolStep {
            outputs,
            max_busy,
            total_busy,
        }
    }

    /// Scatter one layer's tasks to sockets, attend in parallel, gather.
    ///
    /// All sockets compute concurrently; the returned `max_busy` is what
    /// the token-level pipeline sees as R-Part latency (Fig 15's
    /// "performance variance across nodes makes some workers wait").
    pub fn attend(&mut self, layer: usize, tasks: Vec<SeqTask>) -> PoolStep {
        let pending = self.submit_attend(layer, tasks);
        self.wait_attend(pending)
    }

    /// Aggregate cache statistics across sockets.
    pub fn stats(&self) -> Vec<crate::kvcache::CacheStats> {
        let mut all = Vec::new();
        for w in &self.workers {
            w.submit(RRequest::Stats);
            match w.recv() {
                RResponse::Stats(st) => all.push(st),
                _ => panic!("expected stats"),
            }
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TINY;
    use crate::util::Rng;

    fn mk_task(rng: &mut Rng, id: u64, n: usize) -> SeqTask {
        SeqTask {
            seq_id: id,
            q: rng.normal_vec(n, 1.0),
            k_new: rng.normal_vec(n, 1.0),
            v_new: rng.normal_vec(n, 1.0),
        }
    }

    #[test]
    fn round_robin_placement_balances() {
        let mut pool = RPool::spawn(
            &TINY,
            RPoolConfig {
                sockets: 3,
                capacity_per_seq: 8,
                precision: Precision::F32,
                ..Default::default()
            },
        );
        pool.add_seqs(&[0, 1, 2, 3, 4, 5]);
        let mut counts = [0usize; 3];
        for id in 0..6u64 {
            counts[pool.socket_of(id).unwrap()] += 1;
        }
        assert_eq!(counts, [2, 2, 2]);
    }

    #[test]
    fn scatter_gather_matches_single_socket() {
        // Same tasks through 1 socket and 3 sockets must agree exactly.
        let n = TINY.hidden;
        let run = |sockets: usize| {
            let mut pool = RPool::spawn(
                &TINY,
                RPoolConfig {
                    sockets,
                    capacity_per_seq: 8,
                    precision: Precision::F32,
                    ..Default::default()
                },
            );
            let ids: Vec<u64> = (0..5).collect();
            pool.add_seqs(&ids);
            let mut rng = Rng::new(42);
            let mut last = HashMap::new();
            for _ in 0..3 {
                let tasks: Vec<SeqTask> =
                    ids.iter().map(|&i| mk_task(&mut rng, i, n)).collect();
                last = pool.attend(0, tasks).outputs;
            }
            last
        };
        let one = run(1);
        let three = run(3);
        assert_eq!(one.len(), three.len());
        for (id, o1) in &one {
            let o3 = &three[id];
            for (a, b) in o1.iter().zip(o3) {
                assert_eq!(a, b, "seq {id} diverged across pool sizes");
            }
        }
    }

    #[test]
    fn drop_frees_cache() {
        let mut pool = RPool::spawn(
            &TINY,
            RPoolConfig {
                sockets: 2,
                capacity_per_seq: 8,
                precision: Precision::F16,
                ..Default::default()
            },
        );
        pool.add_seqs(&[1, 2, 3, 4]);
        let before: usize = pool.stats().iter().map(|s| s.sequences).sum();
        assert_eq!(before, 4);
        pool.drop_seqs(&[2, 3]);
        let after: usize = pool.stats().iter().map(|s| s.sequences).sum();
        assert_eq!(after, 2);
        assert_eq!(pool.socket_of(2), None);
    }

    #[test]
    #[should_panic(expected = "not placed")]
    fn attend_unplaced_panics() {
        let mut pool = RPool::spawn(&TINY, RPoolConfig::default());
        let mut rng = Rng::new(1);
        pool.attend(0, vec![mk_task(&mut rng, 99, TINY.hidden)]);
    }
}
